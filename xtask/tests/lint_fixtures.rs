//! Negative-fixture tests: spawn the real `xtask` binary over tiny source
//! trees under `tests/fixtures/` and assert each lint fires (non-zero
//! exit, named diagnostic) and that clean/allowlisted trees pass. The
//! fixture `.rs` files are test data — cargo never compiles them.

use std::process::Command;

struct Outcome {
    ok: bool,
    code: Option<i32>,
    stdout: String,
    stderr: String,
}

fn run_xtask(args: &[&str]) -> Outcome {
    let out = Command::new(env!("CARGO_BIN_EXE_xtask"))
        .args(args)
        .output()
        .expect("spawn the xtask binary");
    Outcome {
        ok: out.status.success(),
        code: out.status.code(),
        stdout: String::from_utf8_lossy(&out.stdout).into_owned(),
        stderr: String::from_utf8_lossy(&out.stderr).into_owned(),
    }
}

fn fixture(case: &str) -> String {
    format!("{}/tests/fixtures/{case}", env!("CARGO_MANIFEST_DIR"))
}

fn lint_fixture(case: &str) -> Outcome {
    run_xtask(&["lint", "--root", &fixture(case)])
}

#[test]
fn clean_tree_passes() {
    let out = lint_fixture("clean");
    assert!(out.ok, "clean fixture must pass:\n{}{}", out.stdout, out.stderr);
    assert!(out.stdout.contains("0 violation(s)"), "{}", out.stdout);
}

#[test]
fn hashmap_in_det_module_fails() {
    let out = lint_fixture("nondet");
    assert!(!out.ok);
    assert_eq!(out.code, Some(1));
    assert!(out.stdout.contains("[nondeterministic-order]"), "{}", out.stdout);
    assert!(out.stdout.contains("rust/src/engine/bad.rs"), "{}", out.stdout);
    // `kernels` is determinism-critical too (lane composition feeds bits)
    assert!(out.stdout.contains("rust/src/kernels/bad.rs"), "{}", out.stdout);
}

#[test]
fn net_module_is_determinism_fenced() {
    // the distributed transport joins DET_MODULES and the raw-entropy
    // fence: randomized containers and ambient clocks on decision paths
    // must both fire there
    let out = lint_fixture("netdet");
    assert!(!out.ok);
    assert!(out.stdout.contains("[nondeterministic-order]"), "{}", out.stdout);
    assert!(out.stdout.contains("rust/src/net/bad.rs"), "{}", out.stdout);
    assert!(out.stdout.contains("[raw-entropy]"), "{}", out.stdout);
    assert!(out.stdout.contains("rust/src/net/clock.rs"), "{}", out.stdout);
}

#[test]
fn alloc_in_marked_fn_fails() {
    let out = lint_fixture("hotalloc");
    assert!(!out.ok);
    assert!(out.stdout.contains("[hot-path-alloc]"), "{}", out.stdout);
    // both the Vec::new and the .collect() must be reported
    assert!(out.stdout.contains("Vec::new"), "{}", out.stdout);
    assert!(out.stdout.contains(".collect()"), "{}", out.stdout);
}

#[test]
fn wall_clock_outside_timer_fails() {
    let out = lint_fixture("entropy");
    assert!(!out.ok);
    assert!(out.stdout.contains("[raw-entropy]"), "{}", out.stdout);
    assert!(out.stdout.contains("Instant::now"), "{}", out.stdout);
}

#[test]
fn unsafe_without_safety_comment_fails() {
    let out = lint_fixture("unsafe_nocomment");
    assert!(!out.ok);
    assert!(out.stdout.contains("[unsafe-safety-comment]"), "{}", out.stdout);
}

#[test]
fn codec_field_order_drift_fails() {
    let out = lint_fixture("codec_drift");
    assert!(!out.ok);
    assert!(out.stdout.contains("[codec-symmetry]"), "{}", out.stdout);
    assert!(out.stdout.contains("u64, f64_slice"), "{}", out.stdout);
    assert!(out.stdout.contains("f64_slice, u64"), "{}", out.stdout);
}

#[test]
fn divergent_match_arms_fail() {
    let out = lint_fixture("codec_match_divergent");
    assert!(!out.ok);
    assert!(out.stdout.contains("[codec-symmetry]"), "{}", out.stdout);
    assert!(out.stdout.contains("divergent"), "{}", out.stdout);
}

#[test]
fn parallel_unordered_reduction_fails() {
    let out = lint_fixture("parreduce");
    assert!(!out.ok);
    assert!(out.stdout.contains("[float-reduce-order]"), "{}", out.stdout);
    // exactly one violation: the serial .sum() inside the sharded
    // for_each closure must NOT be flagged
    assert!(out.stdout.contains("1 violation(s)"), "{}", out.stdout);
}

#[test]
fn canonical_tree_reduce_passes() {
    // a parallel .reduce whose combine routes through tree8 has a pinned
    // association — the float-reduce-order lint must treat it as ordered
    let out = lint_fixture("canonreduce");
    assert!(out.ok, "canonical-reducer fixture must pass:\n{}{}", out.stdout, out.stderr);
    assert!(out.stdout.contains("0 violation(s)"), "{}", out.stdout);
}

#[test]
fn allowlist_suppresses_with_reason() {
    let out = lint_fixture("allowed");
    assert!(out.ok, "allowlisted fixture must pass:\n{}{}", out.stdout, out.stderr);
    assert!(out.stdout.contains("suppressed by lint.toml"), "{}", out.stdout);
    // the entry is used, so no unused-entry warning
    assert!(!out.stderr.contains("unused lint.toml entry"), "{}", out.stderr);
}

#[test]
fn json_format_is_machine_readable() {
    let out = run_xtask(&["lint", "--root", &fixture("nondet"), "--format", "json"]);
    assert!(!out.ok);
    assert!(out.stdout.contains("\"violations\""), "{}", out.stdout);
    assert!(out.stdout.contains("\"lint\": \"nondeterministic-order\""), "{}", out.stdout);
    assert!(out.stdout.contains("\"line\": "), "{}", out.stdout);
}

#[test]
fn bad_root_and_bad_flags_exit_2() {
    let out = run_xtask(&["lint", "--root", "/nonexistent-firefly-root"]);
    assert_eq!(out.code, Some(2), "{}", out.stderr);
    let out = run_xtask(&["lint", "--format", "yaml"]);
    assert_eq!(out.code, Some(2), "{}", out.stderr);
    let out = run_xtask(&["frobnicate"]);
    assert_eq!(out.code, Some(2), "{}", out.stderr);
}

#[test]
fn bench_gate_rejects_allocating_flymc() {
    let dir = fixture("benchfail");
    let out = run_xtask(&["bench-gate", "--measured", &dir, "--baseline", &dir]);
    assert!(!out.ok);
    assert!(out.stderr.contains("allocs_per_iter"), "{}", out.stderr);
    assert!(out.stderr.contains("MAP-tuned FlyMC"), "{}", out.stderr);
    // the fixture predates the kernel layer: its missing kernel_identity
    // field must itself be a violation (the bench can't stop checking)
    assert!(out.stderr.contains("kernel_identity"), "{}", out.stderr);
}

#[test]
fn bench_gate_rejects_kernel_path_divergence() {
    // allocs are clean here; the only violation is kernel_identity: false
    let dir = fixture("benchkern");
    let out = run_xtask(&["bench-gate", "--measured", &dir, "--baseline", &dir]);
    assert!(!out.ok);
    assert!(out.stderr.contains("kernel_identity"), "{}", out.stderr);
    assert!(out.stderr.contains("1 bench-gate violation"), "{}", out.stderr);
}

#[test]
fn bench_gate_rejects_null_head2head_bias() {
    // hotpath is clean here; the only violation is the head2head schema —
    // a null bias field must fail, not read as "no bias detected"
    let dir = fixture("benchh2h");
    let out = run_xtask(&["bench-gate", "--measured", &dir, "--baseline", &dir]);
    assert!(!out.ok);
    assert!(out.stderr.contains("bias_max_abs_z missing or non-numeric"), "{}", out.stderr);
    assert!(out.stderr.contains("1 bench-gate violation"), "{}", out.stderr);
}

#[test]
fn bench_gate_rejects_dist_identity_divergence() {
    // hotpath and head2head are clean here; the only violation is
    // dist_identity: false — a distributed chain that diverged from the
    // serial cpu trace must never pass the gate
    let dir = fixture("benchdist");
    let out = run_xtask(&["bench-gate", "--measured", &dir, "--baseline", &dir]);
    assert!(!out.ok);
    assert!(out.stderr.contains("dist_identity = Some(false)"), "{}", out.stderr);
    assert!(out.stderr.contains("1 bench-gate violation"), "{}", out.stderr);
}

#[test]
fn lint_runs_clean_on_this_repository() {
    // the real acceptance criterion: the tree this crate ships in passes
    // its own lint pass with the committed lint.toml
    let repo_root = format!("{}/..", env!("CARGO_MANIFEST_DIR"));
    let out = run_xtask(&["lint", "--root", &repo_root]);
    assert!(
        out.ok,
        "firefly-lint must run clean on the repo:\n{}{}",
        out.stdout, out.stderr
    );
    assert!(!out.stderr.contains("unused lint.toml entry"), "{}", out.stderr);
}
