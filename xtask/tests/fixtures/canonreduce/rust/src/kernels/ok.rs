use rayon::prelude::*;

/// Literal 8-leaf reduction tree — combine order pinned by construction.
fn tree8(p: [f64; 8]) -> f64 {
    ((p[0] + p[1]) + (p[2] + p[3])) + ((p[4] + p[5]) + (p[6] + p[7]))
}

/// Parallel reduce whose combine step routes through the canonical tree:
/// the float association is fixed no matter how rayon schedules the
/// splits, so `float-reduce-order` must not fire here.
pub fn lane_total(tiles: &[[f64; 8]]) -> f64 {
    tiles
        .par_iter()
        .map(|t| tree8(*t))
        .reduce(|| 0.0, |a, b| tree8([a, b, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]))
}
