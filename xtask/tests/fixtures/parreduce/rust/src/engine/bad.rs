use rayon::prelude::*;

pub fn log_lik(lls: &[f64]) -> f64 {
    lls.par_iter().map(|x| x.ln()).sum()
}

pub fn safe_sharded(lls: &[f64], out: &mut [f64]) {
    lls.par_chunks(64).zip(out.par_chunks_mut(64)).for_each(|(xs, os)| {
        let s: f64 = xs.iter().sum();
        os[0] = s;
    });
}
