pub struct Drift {
    a: u64,
    xs: Vec<f64>,
}

impl Drift {
    pub fn save_state(&self, w: &mut ByteWriter) {
        w.u64(self.a);
        w.f64_slice(&self.xs);
    }

    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        // field order swapped relative to save_state: layout drift
        r.f64_slice_into(&mut self.xs)?;
        self.a = r.u64()?;
        Ok(())
    }
}
