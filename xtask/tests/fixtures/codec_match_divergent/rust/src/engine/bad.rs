pub enum Kind {
    Small(u32),
    Big(Vec<f64>),
}

impl Kind {
    pub fn save_state(&self, w: &mut ByteWriter) {
        match self {
            Kind::Small(v) => {
                w.u8(0);
                w.u32(*v);
            }
            Kind::Big(xs) => {
                w.u8(1);
                w.f64_slice(xs);
            }
        }
    }

    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let _tag = r.u8()?;
        let _v = r.u32()?;
        Ok(())
    }
}
