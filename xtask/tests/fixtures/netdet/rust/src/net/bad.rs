use std::collections::HashMap;

// Shard ownership keyed by a HashMap: iteration order would randomize the
// reduction order across processes — exactly what the net module must
// never do.
pub fn owners(ranges: &[(usize, usize)]) -> HashMap<usize, usize> {
    let mut m = HashMap::new();
    for (i, &(start, _)) in ranges.iter().enumerate() {
        m.insert(start, i);
    }
    m
}
