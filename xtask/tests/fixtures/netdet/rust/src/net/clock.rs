use std::time::Instant;

// An ambient clock on the retry decision path: whether to retry must come
// from config (timeout_ms / retries), never from wall-clock sampling —
// the raw-entropy lint fences `net` like every other deterministic module.
pub fn should_retry(started: Instant, budget_ms: u64) -> bool {
    Instant::now().duration_since(started).as_millis() < budget_ms as u128
}
