use std::collections::HashSet;

/// `kernels` is a determinism-critical module: lane composition must not
/// depend on randomized iteration order.
pub fn lane_set(idx: &[u32]) -> usize {
    let s: HashSet<u32> = idx.iter().copied().collect();
    s.len()
}
