pub struct Eval;

impl Eval {
    // lint: zero-alloc
    pub fn eval(&self, theta: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        let doubled: Vec<f64> = theta.iter().map(|t| t * 2.0).collect();
        out.extend(doubled);
        out
    }
}
