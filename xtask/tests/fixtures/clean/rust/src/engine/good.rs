//! Fixture: a file every lint passes. Mentions of HashMap or Instant::now
//! in comments or "string HashMap literals" must not trip anything.

use std::collections::BTreeMap;

pub struct State {
    order: BTreeMap<u32, f64>,
    buf: Vec<f64>,
}

impl State {
    /// Setup-time construction may allocate freely.
    pub fn new(n: usize) -> State {
        State { order: BTreeMap::new(), buf: vec![0.0; n] }
    }

    // lint: zero-alloc
    pub fn accumulate(&mut self, xs: &[f64]) -> f64 {
        let mut total = 0.0;
        for (i, &x) in xs.iter().enumerate() {
            self.buf[i % self.buf.len()] += x;
            total += x;
        }
        total
    }

    pub fn save_state(&self, w: &mut ByteWriter) {
        w.usize(self.buf.len());
        w.f64_slice(&self.buf);
        w.bool(self.order.is_empty());
    }

    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let _n = r.usize()?;
        r.f64_slice_into(&mut self.buf)?;
        let _empty = r.bool()?;
        Ok(())
    }

    pub fn serial_reduce(&self) -> f64 {
        self.buf.iter().sum()
    }
}

// SAFETY: the pointer is derived from a live slice and never outlives it.
pub unsafe fn first_elem(p: *const f64) -> f64 {
    *p
}
