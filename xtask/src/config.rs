//! `lint.toml` allowlist parsing — a deliberate TOML subset.
//!
//! The allowlist is a flat sequence of `[[allow]]` tables with three string
//! keys (`lint`, `path`, `reason`), which is all the expressiveness the lint
//! pass wants: every suppression names exactly one lint at exactly one file,
//! with a written justification. Anything outside that subset is a hard
//! parse error, so the file cannot quietly grow structure the tool ignores.

/// One `[[allow]]` entry: suppress `lint` diagnostics in `path`.
pub struct Allow {
    pub lint: String,
    pub path: String,
    pub reason: String,
}

/// Parse the `lint.toml` subset. Returns entries in file order.
pub fn parse(text: &str) -> Result<Vec<Allow>, String> {
    let mut out: Vec<Allow> = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        let lineno = idx + 1;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line == "[[allow]]" {
            out.push(Allow { lint: String::new(), path: String::new(), reason: String::new() });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(format!("lint.toml:{lineno}: expected `[[allow]]` or `key = \"value\"`"));
        };
        let key = key.trim();
        let value = value.trim();
        let Some(value) = value.strip_prefix('"').and_then(|v| v.strip_suffix('"')) else {
            return Err(format!("lint.toml:{lineno}: value for `{key}` must be a quoted string"));
        };
        let Some(entry) = out.last_mut() else {
            return Err(format!("lint.toml:{lineno}: `{key}` appears before any [[allow]] table"));
        };
        match key {
            "lint" => entry.lint = value.to_string(),
            "path" => entry.path = value.to_string(),
            "reason" => entry.reason = value.to_string(),
            other => {
                return Err(format!(
                    "lint.toml:{lineno}: unknown key `{other}` (expected lint/path/reason)"
                ));
            }
        }
    }
    for (i, e) in out.iter().enumerate() {
        if e.lint.is_empty() || e.path.is_empty() || e.reason.is_empty() {
            return Err(format!(
                "lint.toml: [[allow]] entry {} must set lint, path, and reason",
                i + 1
            ));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_shape() {
        let text = "# comment\n[[allow]]\nlint = \"nondeterministic-order\"\n\
                    path = \"rust/src/runtime/xla_backend.rs\"\nreason = \"cache\"\n";
        let allows = parse(text).unwrap();
        assert_eq!(allows.len(), 1);
        assert_eq!(allows[0].lint, "nondeterministic-order");
        assert_eq!(allows[0].path, "rust/src/runtime/xla_backend.rs");
    }

    #[test]
    fn rejects_unknown_keys_and_incomplete_entries() {
        assert!(parse("[[allow]]\nwat = \"x\"\n").is_err());
        assert!(parse("[[allow]]\nlint = \"raw-entropy\"\n").is_err());
        assert!(parse("lint = \"orphan\"\n").is_err());
        assert!(parse("[[allow]]\nlint = unquoted\n").is_err());
    }

    #[test]
    fn empty_and_comment_only_files_parse() {
        assert!(parse("").unwrap().is_empty());
        assert!(parse("# nothing here\n\n").unwrap().is_empty());
    }
}
