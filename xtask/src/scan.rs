//! Source scanning: directory walking and the comment/string-stripped
//! "code view" every lint runs over.
//!
//! The stripper is a small character-level state machine, not a parser: it
//! tracks line comments, nested block comments, string / raw-string / char
//! literals (distinguishing char literals from lifetimes by lookahead), and
//! produces two line-aligned views of each file — `code` (literals and
//! comments blanked out) and `comments` (only comment text kept). Every
//! lint then works on plain substring/word searches over the right view,
//! which is exactly the level of rigor the repo's invariants need and keeps
//! the whole tool dependency-free.

use std::fs;
use std::path::{Path, PathBuf};

/// One scanned source file with its original, code-only, and comment-only
/// line-aligned views.
pub struct FileView {
    /// repo-relative path with forward slashes (stable diagnostics on CI)
    pub path: String,
    /// code view: comments and literal contents replaced by spaces
    pub code: Vec<String>,
    /// comment view: everything except comment text replaced by spaces
    pub comments: Vec<String>,
}

impl FileView {
    /// Build the views from raw source text.
    pub fn parse(path: String, text: &str) -> FileView {
        let (code, comments) = strip(text);
        FileView {
            path,
            code: code.split('\n').map(str::to_string).collect(),
            comments: comments.split('\n').map(str::to_string).collect(),
        }
    }

    /// The code view flattened to one string (newline-joined), plus the
    /// byte offset of each line start — lints that need cross-line
    /// structure (brace matching, call sequences) work on this.
    pub fn flat_code(&self) -> (String, Vec<usize>) {
        let mut flat = String::new();
        let mut starts = Vec::with_capacity(self.code.len());
        for line in &self.code {
            starts.push(flat.len());
            flat.push_str(line);
            flat.push('\n');
        }
        (flat, starts)
    }
}

/// Map a byte offset in the flat code view back to a 1-based line number.
pub fn line_of(starts: &[usize], offset: usize) -> usize {
    match starts.binary_search(&offset) {
        Ok(i) => i + 1,
        Err(i) => i, // first start greater than offset -> previous line
    }
}

#[derive(PartialEq)]
enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    Char,
}

/// Split source text into a code view and a comment view (same length,
/// newlines preserved so both stay line-aligned with the original).
fn strip(text: &str) -> (String, String) {
    let b = text.as_bytes();
    let mut code = String::with_capacity(text.len());
    let mut comments = String::with_capacity(text.len());
    let mut state = State::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            code.push('\n');
            comments.push('\n');
            if state == State::LineComment {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                    state = State::LineComment;
                    code.push_str("  ");
                    comments.push_str("//");
                    i += 2;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    state = State::BlockComment(1);
                    code.push_str("  ");
                    comments.push_str("/*");
                    i += 2;
                } else if c == b'"' {
                    state = State::Str;
                    code.push(' ');
                    comments.push(' ');
                    i += 1;
                } else if (c == b'r' || c == b'b') && !prev_is_ident(b, i) {
                    // raw string r"...", r#"..."#, br"..." — scan r/b prefix,
                    // optional hashes, then a quote
                    let mut j = i;
                    if c == b'b' && j + 1 < b.len() && b[j + 1] == b'r' {
                        j += 1;
                    }
                    if b[j] == b'r' {
                        let mut k = j + 1;
                        let mut hashes = 0u32;
                        while k < b.len() && b[k] == b'#' {
                            hashes += 1;
                            k += 1;
                        }
                        if k < b.len() && b[k] == b'"' {
                            for _ in i..=k {
                                code.push(' ');
                                comments.push(' ');
                            }
                            state = State::RawStr(hashes);
                            i = k + 1;
                            continue;
                        }
                    }
                    code.push(char::from(c));
                    comments.push(' ');
                    i += 1;
                } else if c == b'\'' && is_char_literal(b, i) {
                    state = State::Char;
                    code.push(' ');
                    comments.push(' ');
                    i += 1;
                } else {
                    code.push(char::from(c));
                    comments.push(' ');
                    i += 1;
                }
            }
            State::LineComment => {
                code.push(' ');
                comments.push(char::from(c));
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    code.push_str("  ");
                    comments.push_str("*/");
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    code.push_str("  ");
                    comments.push_str("/*");
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    code.push(' ');
                    comments.push(char::from(c));
                    i += 1;
                }
            }
            State::Str => {
                if c == b'\\' && i + 1 < b.len() {
                    // keep line alignment across escaped-newline continuations
                    let esc = if b[i + 1] == b'\n' { " \n" } else { "  " };
                    code.push_str(esc);
                    comments.push_str(esc);
                    i += 2;
                } else {
                    if c == b'"' {
                        state = State::Code;
                    }
                    code.push(' ');
                    comments.push(' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == b'"' && closes_raw(b, i, hashes) {
                    for _ in 0..=hashes {
                        code.push(' ');
                        comments.push(' ');
                    }
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    code.push(' ');
                    comments.push(' ');
                    i += 1;
                }
            }
            State::Char => {
                if c == b'\\' && i + 1 < b.len() {
                    code.push_str("  ");
                    comments.push_str("  ");
                    i += 2;
                } else {
                    if c == b'\'' {
                        state = State::Code;
                    }
                    code.push(' ');
                    comments.push(' ');
                    i += 1;
                }
            }
        }
    }
    (code, comments)
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

/// `'` starts a char literal (not a lifetime) when it is `'\...` or a
/// single character followed by a closing `'`.
fn is_char_literal(b: &[u8], i: usize) -> bool {
    if i + 1 >= b.len() {
        return false;
    }
    if b[i + 1] == b'\\' {
        return true;
    }
    i + 2 < b.len() && b[i + 1] != b'\'' && b[i + 2] == b'\''
}

fn closes_raw(b: &[u8], i: usize, hashes: u32) -> bool {
    let mut k = i + 1;
    for _ in 0..hashes {
        if k >= b.len() || b[k] != b'#' {
            return false;
        }
        k += 1;
    }
    true
}

/// Recursively collect every `.rs` file under `dir`, sorted by path so
/// diagnostics and JSON output are deterministic.
pub fn rust_files(root: &Path, dir: &str) -> Vec<PathBuf> {
    let mut out = Vec::new();
    collect(&root.join(dir), &mut out);
    out.sort();
    out
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let src = "let x = \"Hash//Map\"; // HashMap here\nlet y = 'a';\n";
        let v = FileView::parse("t.rs".into(), src);
        assert!(!v.code[0].contains("HashMap"));
        assert!(v.comments[0].contains("HashMap here"));
        assert!(v.code[0].contains("let x ="));
        assert!(!v.code[1].contains('a'));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn row<'a>(&'a self) -> &'a [f64] { &self.x }\n";
        let v = FileView::parse("t.rs".into(), src);
        assert!(v.code[0].contains("fn row<'a>"));
        assert!(v.code[0].contains("&self.x"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* a /* b */ HashSet */ let s = r#\"Instant::now\"#;\n";
        let v = FileView::parse("t.rs".into(), src);
        assert!(!v.code[0].contains("HashSet"));
        assert!(!v.code[0].contains("Instant"));
        assert!(v.code[0].contains("let s ="));
    }

    #[test]
    fn line_mapping_round_trips() {
        let v = FileView::parse("t.rs".into(), "a\nbb\nccc\n");
        let (flat, starts) = v.flat_code();
        assert_eq!(line_of(&starts, flat.find("bb").unwrap()), 2);
        assert_eq!(line_of(&starts, flat.find("ccc").unwrap()), 3);
    }
}
