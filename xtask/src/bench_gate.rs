//! `cargo xtask bench-gate` — perf-invariant gate over the bench JSON.
//!
//! Reads the `BENCH_*.json` files the smoke benches emit and enforces:
//!
//! 1. **allocs/iter == 0** for every FlyMC algorithm in `BENCH_hotpath.json`
//!    — live immediately, no baseline needed (the steady state of the
//!    sampler must never touch the allocator).
//! 2. **kernel identity** — `BENCH_hotpath.json` must report
//!    `kernel_identity: true`: the bench re-runs a short chain on the
//!    scalar and the autovectorized kernel paths and compares the traces
//!    byte-for-byte (DESIGN.md §Kernels). Live immediately; a missing
//!    field fails too, so the bench can never silently stop checking.
//! 3. **queries/iter drift** — once `BENCH_baseline/BENCH_hotpath.json` is
//!    committed without its `"pending"` flag, measured queries/iter must
//!    match the baseline to 1e-6 relative (query counts are deterministic
//!    given the seeds, so any drift is a behavior change, not noise). A
//!    baseline carrying `"provenance": "analytic"` was derived by hand
//!    rather than measured: it arms the drift comparison in warn-only mode
//!    (mismatches print as notes) until a measured run replaces it.
//! 3b. **re-anchor coverage** — `BENCH_hotpath.json` must report a finite
//!    `bright_fraction_post_reanchor` (the mean bright fraction over the
//!    re-anchored FlyMC rows): a missing or non-finite field means the
//!    re-anchor section silently stopped running. The re-anchored rows are
//!    also held to the zero-alloc and drift gates above.
//! 4. **trace identity** — `BENCH_dataio.json` must report
//!    `trace_identity_dense_vs_block: true`.
//! 5. **checkpoint size drift** — with a non-pending checkpoint baseline,
//!    `ckpt_bytes` must match exactly per scenario (the format is
//!    deterministic; wall-clock fields are never gated).
//! 6. **dist identity + coverage** — `BENCH_dist.json` must report
//!    `dist_identity: true` (the bench runs the same chain on the serial
//!    cpu backend and the distributed backend and compares θ-traces,
//!    acceptances, z-flips, and query counters byte-for-byte; DESIGN.md
//!    §Distribution) plus, for each worker count in {1, 2, 4}, finite
//!    `secs_per_iter`, `queries_per_iter`, and `wire_bytes_per_iter`.
//!    `queries_per_iter` must also be bitwise equal across worker counts:
//!    query metering is part of the determinism contract, so any variation
//!    with the shard layout is a behavior change. Live immediately; a
//!    missing file or field fails too.
//!
//! Baselines live in `BENCH_baseline/` (NOT the repo root, where the
//! benches write their fresh measurements). A baseline with
//! `"pending": true` is a bootstrap placeholder: the gate records what it
//! would have compared and succeeds, and CI uploads the measured JSON as
//! the proposed baseline to commit.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;
use std::str::FromStr;

/// Minimal JSON value — everything the bench files use.
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn str_val(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn arr(&self) -> &[Json] {
        match self {
            Json::Arr(items) => items,
            _ => &[],
        }
    }

    pub fn bool_val(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse a JSON document (objects, arrays, strings, numbers, bools, null).
pub fn parse(text: &str) -> Result<Json, String> {
    let b = text.as_bytes();
    let mut pos = 0;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing content at byte {pos}"));
    }
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && b[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err("unexpected end of input".to_string());
    };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_str(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {pos}", pos = *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    f64::from_str(s).map(Json::Num).map_err(|e| format!("bad number `{s}`: {e}"))
}

fn parse_str(b: &[u8], pos: &mut usize) -> Result<String, String> {
    *pos += 1; // opening quote
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                let esc = *b.get(*pos).ok_or("unterminated escape")?;
                match esc {
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'u' => {
                        // \uXXXX — the bench files never emit these, but
                        // decode the BMP case rather than corrupting
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .and_then(|h| std::str::from_utf8(h).ok())
                            .ok_or("bad \\u escape")?;
                        let code = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    other => out.push(char::from(other)),
                }
                *pos += 1;
            }
            other => {
                out.push(char::from(other));
                *pos += 1;
            }
        }
    }
    Err("unterminated string".to_string())
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // {
    let mut pairs = Vec::new();
    loop {
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            Some(b'"') => {
                let key = parse_str(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected `:` after key `{key}`"));
                }
                *pos += 1;
                let value = parse_value(b, pos)?;
                pairs.push((key, value));
                skip_ws(b, pos);
                if b.get(*pos) == Some(&b',') {
                    *pos += 1;
                }
            }
            _ => return Err("expected `\"` or `}` in object".to_string()),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // [
    let mut items = Vec::new();
    loop {
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b']') {
            *pos += 1;
            return Ok(Json::Arr(items));
        }
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b',') {
            *pos += 1;
        }
    }
}

// ------------------------------------------------------------ the gates --

fn load(dir: &Path, name: &str) -> Result<Option<Json>, String> {
    let p = dir.join(name);
    if !p.exists() {
        return Ok(None);
    }
    let text = fs::read_to_string(&p).map_err(|e| format!("{}: {e}", p.display()))?;
    parse(&text).map(Some).map_err(|e| format!("{}: {e}", p.display()))
}

fn is_pending(j: &Json) -> bool {
    j.get("pending").and_then(Json::bool_val).unwrap_or(false)
}

/// A baseline whose numbers were derived by hand rather than measured
/// (`"provenance": "analytic"`). Such a baseline arms the drift gates in
/// warn-only mode until a measured run replaces it.
fn is_analytic(j: &Json) -> bool {
    j.get("provenance").and_then(Json::str_val) == Some("analytic")
}

/// scenario+algorithm key -> queries_per_iter, for the hotpath schema.
/// Covers both the one-shot `scenarios` rows and the `reanchor` rows (the
/// algorithm labels are disjoint, so the keys never collide).
fn hotpath_queries(j: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for section in ["scenarios", "reanchor"] {
        for s in j.get(section).map(Json::arr).unwrap_or(&[]) {
            let task = s.get("task").and_then(Json::str_val).unwrap_or("?");
            let sampler = s.get("sampler").and_then(Json::str_val).unwrap_or("?");
            let n = s.get("n").and_then(Json::num).unwrap_or(0.0);
            for a in s.get("algorithms").map(Json::arr).unwrap_or(&[]) {
                let alg = a.get("algorithm").and_then(Json::str_val).unwrap_or("?");
                if let Some(q) = a.get("queries_per_iter").and_then(Json::num) {
                    out.push((format!("{task}/{sampler}/n={n}/{alg}"), q));
                }
            }
        }
    }
    out
}

/// Baseline-free hotpath invariants: zero-alloc FlyMC rows (one-shot and
/// re-anchored), kernel identity, and a finite re-anchor bright fraction.
fn hotpath_live_failures(j: &Json) -> Vec<String> {
    let mut failures = Vec::new();
    for s in j.get("scenarios").map(Json::arr).unwrap_or(&[]) {
        let task = s.get("task").and_then(Json::str_val).unwrap_or("?");
        for a in s.get("algorithms").map(Json::arr).unwrap_or(&[]) {
            let alg = a.get("algorithm").and_then(Json::str_val).unwrap_or("?");
            let allocs = a.get("allocs_per_iter").and_then(Json::num).unwrap_or(0.0);
            if alg.contains("FlyMC") && allocs != 0.0 {
                failures.push(format!(
                    "hotpath {task}/{alg}: allocs_per_iter = {allocs} (must be 0 — the \
                     FlyMC steady state is allocation-free)"
                ));
            }
        }
    }
    // every re-anchor row is FlyMC, and the post-re-anchor steady state is
    // held to the same zero-alloc invariant as the one-shot rows
    for s in j.get("reanchor").map(Json::arr).unwrap_or(&[]) {
        let task = s.get("task").and_then(Json::str_val).unwrap_or("?");
        for a in s.get("algorithms").map(Json::arr).unwrap_or(&[]) {
            let alg = a.get("algorithm").and_then(Json::str_val).unwrap_or("?");
            let allocs = a.get("allocs_per_iter").and_then(Json::num).unwrap_or(0.0);
            if allocs != 0.0 {
                failures.push(format!(
                    "hotpath reanchor {task}/{alg}: allocs_per_iter = {allocs} (must be \
                     0 — the post-re-anchor steady state is allocation-free)"
                ));
            }
        }
    }
    match j.get("kernel_identity").and_then(Json::bool_val) {
        Some(true) => {}
        other => failures.push(format!(
            "hotpath: kernel_identity = {other:?} (must be true — the scalar and \
             autovectorized SoA kernel paths must produce byte-identical traces; \
             a missing field means the bench stopped checking)"
        )),
    }
    match j.get("bright_fraction_post_reanchor").and_then(Json::num) {
        Some(v) if v.is_finite() => {}
        Some(v) => failures.push(format!(
            "hotpath: bright_fraction_post_reanchor = {v} (must be a finite number — \
             the re-anchored chains produced no usable bright statistics)"
        )),
        None => failures.push(
            "hotpath: bright_fraction_post_reanchor missing or non-numeric (the \
             re-anchor bench section silently stopped running)"
                .to_string(),
        ),
    }
    failures
}

/// Required per-algorithm metric fields in the head2head schema. Every
/// value must be a finite JSON number: `null`, a missing key, or a
/// non-numeric value all fail the gate (a bias column that silently went
/// NaN would otherwise read as "no bias detected").
const HEAD2HEAD_FIELDS: [&str; 3] = ["ess_per_sec", "queries_per_iter", "bias_max_abs_z"];

/// The algorithm keys every head2head workload must report.
const HEAD2HEAD_ALGOS: [&str; 4] = ["full", "flymc", "sgld", "austerity"];

/// The workloads the head2head bench must cover (the three paper tasks).
const HEAD2HEAD_TASKS: [&str; 3] = ["logistic", "softmax", "robust"];

/// Schema validation for `BENCH_head2head.json`: all three paper workloads,
/// all four algorithms each, every metric field present and finite.
fn head2head_failures(j: &Json) -> Vec<String> {
    let mut failures = Vec::new();
    let workloads = j.get("workloads").map(Json::arr).unwrap_or(&[]);
    for want in HEAD2HEAD_TASKS {
        if !workloads.iter().any(|w| w.get("task").and_then(Json::str_val) == Some(want)) {
            failures.push(format!("head2head: workload `{want}` missing"));
        }
    }
    for w in workloads {
        let task = w.get("task").and_then(Json::str_val).unwrap_or("?");
        let algos = w.get("algorithms").map(Json::arr).unwrap_or(&[]);
        for want in HEAD2HEAD_ALGOS {
            let Some(a) =
                algos.iter().find(|a| a.get("algorithm").and_then(Json::str_val) == Some(want))
            else {
                failures.push(format!("head2head {task}: algorithm `{want}` missing"));
                continue;
            };
            for field in HEAD2HEAD_FIELDS {
                match a.get(field).and_then(Json::num) {
                    Some(v) if v.is_finite() => {}
                    Some(v) => failures.push(format!(
                        "head2head {task}/{want}: {field} = {v} (must be finite)"
                    )),
                    None => failures.push(format!(
                        "head2head {task}/{want}: {field} missing or non-numeric"
                    )),
                }
            }
        }
    }
    failures
}

/// The worker counts the dist bench must cover (serial-equivalent, even
/// split, uneven split — enough to exercise every shard-boundary case).
const DIST_WORKER_COUNTS: [f64; 3] = [1.0, 2.0, 4.0];

/// Required per-worker-count metric fields in the dist schema. Finite-only,
/// like the head2head fields: `null`/missing/non-numeric all fail.
const DIST_ROW_FIELDS: [&str; 3] = ["secs_per_iter", "queries_per_iter", "wire_bytes_per_iter"];

/// Schema + invariant validation for `BENCH_dist.json`: the cpu-vs-dist
/// trace probe must hold, every worker count must be covered with finite
/// metrics, and queries/iter may not vary with the worker count.
fn dist_failures(j: &Json) -> Vec<String> {
    let mut failures = Vec::new();
    match j.get("dist_identity").and_then(Json::bool_val) {
        Some(true) => {}
        other => failures.push(format!(
            "dist: dist_identity = {other:?} (must be true — the distributed \
             backend's θ-trace, acceptances, z-flips, and query counters must be \
             byte-identical to the serial cpu backend at every worker count; a \
             missing field means the bench stopped probing)"
        )),
    }
    let rows = j.get("worker_counts").map(Json::arr).unwrap_or(&[]);
    let mut queries_seen: Vec<(f64, f64)> = Vec::new();
    for want in DIST_WORKER_COUNTS {
        let Some(row) =
            rows.iter().find(|r| r.get("workers").and_then(Json::num) == Some(want))
        else {
            failures.push(format!(
                "dist: no entry for workers = {want} (the bench must cover 1, 2, and 4)"
            ));
            continue;
        };
        for field in DIST_ROW_FIELDS {
            match row.get(field).and_then(Json::num) {
                Some(v) if v.is_finite() => {
                    if field == "queries_per_iter" {
                        queries_seen.push((want, v));
                    }
                }
                Some(v) => failures
                    .push(format!("dist workers={want}: {field} = {v} (must be finite)")),
                None => failures
                    .push(format!("dist workers={want}: {field} missing or non-numeric")),
            }
        }
    }
    // query metering is deterministic and shard-layout-independent, so the
    // per-iter count must be bitwise equal at every worker count
    if let Some(&(w0, q0)) = queries_seen.first() {
        for &(w, q) in &queries_seen[1..] {
            if q != q0 {
                failures.push(format!(
                    "dist: queries_per_iter varies with worker count ({q0} at workers={w0} \
                     vs {q} at workers={w}) — metering must not depend on the shard layout"
                ));
            }
        }
    }
    failures
}

/// Run the gate. `args`: `--baseline DIR` (default BENCH_baseline),
/// `--measured DIR` (default `.` — where the benches write).
pub fn run(args: &[String]) -> Result<(), String> {
    let mut baseline_dir = "BENCH_baseline".to_string();
    let mut measured_dir = ".".to_string();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--baseline" => {
                baseline_dir = it.next().ok_or("--baseline needs a value")?.clone();
            }
            "--measured" => {
                measured_dir = it.next().ok_or("--measured needs a value")?.clone();
            }
            other => return Err(format!("unknown bench-gate flag `{other}`")),
        }
    }
    let bdir = Path::new(&baseline_dir);
    let mdir = Path::new(&measured_dir);
    let mut failures: Vec<String> = Vec::new();
    let mut notes = String::new();

    // -- hotpath: live invariants (zero-alloc, kernel identity, re-anchor
    //    coverage) + queries drift (baseline-armed) ------------------------
    let measured_hot = load(mdir, "BENCH_hotpath.json")?
        .ok_or("BENCH_hotpath.json not found — run the hotpath bench first")?;
    failures.extend(hotpath_live_failures(&measured_hot));
    match load(bdir, "BENCH_hotpath.json")? {
        Some(base) if !is_pending(&base) => {
            let analytic = is_analytic(&base);
            let same_mode = measured_hot.get("smoke").and_then(Json::bool_val)
                == base.get("smoke").and_then(Json::bool_val);
            if same_mode {
                let baseline = hotpath_queries(&base);
                for (key, q) in hotpath_queries(&measured_hot) {
                    match baseline.iter().find(|(k, _)| *k == key) {
                        Some((_, qb)) => {
                            let tol = 1e-6 * qb.abs().max(1.0);
                            if (q - qb).abs() > tol {
                                if analytic {
                                    let _ = writeln!(
                                        notes,
                                        "note: {key}: queries_per_iter {q} differs from \
                                         the analytic baseline {qb} — warn-only until a \
                                         measured baseline replaces it"
                                    );
                                } else {
                                    failures.push(format!(
                                        "hotpath {key}: queries_per_iter {q} drifted from \
                                         committed baseline {qb} (tolerance {tol:.1e})"
                                    ));
                                }
                            }
                        }
                        None => {
                            let _ = writeln!(notes, "note: {key} has no baseline entry");
                        }
                    }
                }
            } else {
                let _ = writeln!(
                    notes,
                    "note: smoke flag differs between measurement and baseline — \
                     queries drift not compared"
                );
            }
        }
        Some(_) => {
            let _ = writeln!(
                notes,
                "note: hotpath baseline is pending — commit the measured \
                 BENCH_hotpath.json into BENCH_baseline/ to arm the drift gate"
            );
        }
        None => {
            let _ = writeln!(notes, "note: no hotpath baseline committed");
        }
    }

    // -- dataio: the dense-vs-block trace identity must hold --------------
    if let Some(m) = load(mdir, "BENCH_dataio.json")? {
        match m.get("trace_identity_dense_vs_block").and_then(Json::bool_val) {
            Some(true) => {}
            other => failures.push(format!(
                "dataio: trace_identity_dense_vs_block = {other:?} (must be true — \
                 block-cached reads may never change a chain)"
            )),
        }
    }

    // -- checkpoint: deterministic byte-size drift ------------------------
    if let (Some(m), Some(base)) =
        (load(mdir, "BENCH_checkpoint.json")?, load(bdir, "BENCH_checkpoint.json")?)
    {
        if is_pending(&base) {
            let _ = writeln!(notes, "note: checkpoint baseline is pending");
        } else {
            let analytic = is_analytic(&base);
            for s in m.get("scenarios").map(Json::arr).unwrap_or(&[]) {
                let task = s.get("task").and_then(Json::str_val).unwrap_or("?");
                let bytes = s.get("ckpt_bytes").and_then(Json::num);
                let base_bytes = base
                    .get("scenarios")
                    .map(Json::arr)
                    .unwrap_or(&[])
                    .iter()
                    .find(|bs| bs.get("task").and_then(Json::str_val) == Some(task))
                    .and_then(|bs| bs.get("ckpt_bytes").and_then(Json::num));
                if let (Some(got), Some(want)) = (bytes, base_bytes) {
                    if got != want {
                        if analytic {
                            let _ = writeln!(
                                notes,
                                "note: checkpoint {task}: ckpt_bytes {got} differs from \
                                 the analytic baseline {want} — warn-only until a \
                                 measured baseline replaces it"
                            );
                        } else {
                            failures.push(format!(
                                "checkpoint {task}: ckpt_bytes {got} != committed {want} — \
                                 the .fckpt layout changed; re-baseline deliberately"
                            ));
                        }
                    }
                }
            }
        }
    }

    // -- head2head: competitor-baseline schema must stay complete ---------
    let measured_h2h = load(mdir, "BENCH_head2head.json")?
        .ok_or("BENCH_head2head.json not found — run the head2head bench first")?;
    failures.extend(head2head_failures(&measured_h2h));

    // -- dist: cpu-identity probe + per-worker-count coverage -------------
    let measured_dist = load(mdir, "BENCH_dist.json")?
        .ok_or("BENCH_dist.json not found — run the dist bench first")?;
    failures.extend(dist_failures(&measured_dist));

    print!("{notes}");
    if failures.is_empty() {
        println!("bench-gate: all perf invariants hold");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("bench-gate violation: {f}");
        }
        Err(format!("{} bench-gate violation(s)", failures.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_hotpath_shape() {
        let text = r#"{
  "bench": "hotpath", "smoke": true,
  "scenarios": [
    {"task": "logistic", "sampler": "rwmh", "n": 4000,
     "algorithms": [
      {"algorithm": "MAP-tuned FlyMC", "wallclock_per_iter_secs": 5.1e-5,
       "queries_per_iter": 812.250, "allocs_per_iter": 0.000, "avg_bright": 401.20},
      {"algorithm": "Regular MCMC", "wallclock_per_iter_secs": 1.0e-4,
       "queries_per_iter": 4000.0, "allocs_per_iter": 0.000, "avg_bright": null}
     ]}
  ]
}"#;
        let j = parse(text).unwrap();
        let q = hotpath_queries(&j);
        assert_eq!(q.len(), 2);
        assert!(q[0].0.contains("MAP-tuned FlyMC"));
        assert!((q[0].1 - 812.25).abs() < 1e-9);
        assert!(!is_pending(&j));
        assert!(is_pending(&parse(r#"{"pending": true}"#).unwrap()));
    }

    /// A complete, valid head2head document (template for the fixtures).
    fn h2h_fixture() -> String {
        let mut s = String::from("{\"bench\": \"head2head\", \"workloads\": [\n");
        for (i, task) in HEAD2HEAD_TASKS.iter().enumerate() {
            s.push_str(&format!("{{\"task\": \"{task}\", \"algorithms\": [\n"));
            for (k, alg) in HEAD2HEAD_ALGOS.iter().enumerate() {
                s.push_str(&format!(
                    "{{\"algorithm\": \"{alg}\", \"ess_per_sec\": 12.5, \
                     \"queries_per_iter\": 300.0, \"bias_max_abs_z\": 1.07}}{}",
                    if k + 1 < HEAD2HEAD_ALGOS.len() { ",\n" } else { "" }
                ));
            }
            s.push_str(&format!(
                "]}}{}",
                if i + 1 < HEAD2HEAD_TASKS.len() { ",\n" } else { "" }
            ));
        }
        s.push_str("]}");
        s
    }

    #[test]
    fn head2head_complete_document_passes() {
        let j = parse(&h2h_fixture()).unwrap();
        assert!(head2head_failures(&j).is_empty());
    }

    #[test]
    fn head2head_missing_bias_field_fails() {
        let text = h2h_fixture().replacen("\"bias_max_abs_z\": 1.07", "\"note\": \"gone\"", 1);
        let fails = head2head_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("bias_max_abs_z missing"), "{fails:?}");
    }

    #[test]
    fn head2head_null_metric_fails() {
        let text = h2h_fixture().replacen("\"ess_per_sec\": 12.5", "\"ess_per_sec\": null", 1);
        let fails = head2head_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("ess_per_sec missing or non-numeric"), "{fails:?}");
    }

    #[test]
    fn head2head_non_finite_metric_fails() {
        // 1e999 parses as f64::INFINITY — finite-only is the contract
        let text =
            h2h_fixture().replacen("\"bias_max_abs_z\": 1.07", "\"bias_max_abs_z\": 1e999", 1);
        let fails = head2head_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("must be finite"), "{fails:?}");
    }

    #[test]
    fn head2head_missing_algorithm_and_workload_fail() {
        let text = h2h_fixture().replacen("\"algorithm\": \"sgld\"", "\"algorithm\": \"sgd\"", 1);
        let fails = head2head_failures(&parse(&text).unwrap());
        assert!(fails.iter().any(|f| f.contains("algorithm `sgld` missing")), "{fails:?}");

        let text = h2h_fixture().replacen("\"task\": \"robust\"", "\"task\": \"opv\"", 1);
        let fails = head2head_failures(&parse(&text).unwrap());
        assert!(fails.iter().any(|f| f.contains("workload `robust` missing")), "{fails:?}");
    }

    /// A minimal hotpath document that satisfies every live invariant.
    fn hotpath_fixture() -> String {
        r#"{
  "bench": "hotpath", "smoke": true,
  "scenarios": [
    {"task": "logistic", "sampler": "rwmh", "n": 400,
     "algorithms": [
      {"algorithm": "MAP-tuned FlyMC", "wallclock_per_iter_secs": 5.1e-5,
       "queries_per_iter": 120.0, "allocs_per_iter": 0.000, "avg_bright": 80.0}
     ]}
  ],
  "reanchor": [
    {"task": "logistic", "sampler": "rwmh", "n": 400,
     "algorithms": [
      {"algorithm": "untuned+reanchor", "wallclock_per_iter_secs": 4.0e-5,
       "queries_per_iter": 110.0, "allocs_per_iter": 0.000, "avg_bright": 70.0}
     ]}
  ],
  "bright_fraction_post_reanchor": 0.175,
  "kernel_identity": true
}"#
        .to_string()
    }

    #[test]
    fn hotpath_live_invariants_pass_on_a_complete_document() {
        let j = parse(&hotpath_fixture()).unwrap();
        assert!(hotpath_live_failures(&j).is_empty(), "{:?}", hotpath_live_failures(&j));
        // reanchor rows contribute drift keys alongside the one-shot rows
        let keys: Vec<String> = hotpath_queries(&j).into_iter().map(|(k, _)| k).collect();
        assert!(keys.iter().any(|k| k.ends_with("/untuned+reanchor")), "{keys:?}");
        assert!(keys.iter().any(|k| k.ends_with("/MAP-tuned FlyMC")), "{keys:?}");
    }

    #[test]
    fn missing_bright_fraction_post_reanchor_fails() {
        let text = hotpath_fixture()
            .replacen("\"bright_fraction_post_reanchor\": 0.175,", "", 1);
        let fails = hotpath_live_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("bright_fraction_post_reanchor missing"), "{fails:?}");
    }

    #[test]
    fn non_finite_bright_fraction_post_reanchor_fails() {
        // 1e999 parses as infinity — the field must be finite
        let text = hotpath_fixture().replacen(
            "\"bright_fraction_post_reanchor\": 0.175",
            "\"bright_fraction_post_reanchor\": 1e999",
            1,
        );
        let fails = hotpath_live_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("must be a finite number"), "{fails:?}");
    }

    #[test]
    fn allocating_reanchor_row_fails_the_zero_alloc_gate() {
        let text = hotpath_fixture().replacen(
            "\"queries_per_iter\": 110.0, \"allocs_per_iter\": 0.000",
            "\"queries_per_iter\": 110.0, \"allocs_per_iter\": 2.500",
            1,
        );
        let fails = hotpath_live_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("reanchor logistic/untuned+reanchor"), "{fails:?}");
        assert!(fails[0].contains("allocation-free"), "{fails:?}");
    }

    /// A complete, valid dist document (template for the fixtures).
    fn dist_fixture() -> String {
        r#"{
  "bench": "dist", "smoke": true,
  "dist_identity": true,
  "worker_counts": [
    {"workers": 1, "secs_per_iter": 6.2e-5, "queries_per_iter": 812.250, "wire_bytes_per_iter": 21480.0},
    {"workers": 2, "secs_per_iter": 4.8e-5, "queries_per_iter": 812.250, "wire_bytes_per_iter": 22132.0},
    {"workers": 4, "secs_per_iter": 4.1e-5, "queries_per_iter": 812.250, "wire_bytes_per_iter": 23410.0}
  ]
}"#
        .to_string()
    }

    #[test]
    fn dist_complete_document_passes() {
        let j = parse(&dist_fixture()).unwrap();
        assert!(dist_failures(&j).is_empty(), "{:?}", dist_failures(&j));
    }

    #[test]
    fn dist_identity_false_or_missing_fails() {
        let text = dist_fixture().replacen("\"dist_identity\": true", "\"dist_identity\": false", 1);
        let fails = dist_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("dist_identity = Some(false)"), "{fails:?}");
        assert!(fails[0].contains("byte-identical"), "{fails:?}");

        let text = dist_fixture().replacen("\"dist_identity\": true,", "", 1);
        let fails = dist_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("stopped probing"), "{fails:?}");
    }

    #[test]
    fn dist_missing_worker_count_fails() {
        let text = dist_fixture().replacen("\"workers\": 4", "\"workers\": 8", 1);
        let fails = dist_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("no entry for workers = 4"), "{fails:?}");
    }

    #[test]
    fn dist_null_and_non_finite_metrics_fail() {
        let text =
            dist_fixture().replacen("\"wire_bytes_per_iter\": 22132.0", "\"wire_bytes_per_iter\": null", 1);
        let fails = dist_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("workers=2: wire_bytes_per_iter missing"), "{fails:?}");

        // 1e999 parses as infinity — finite-only is the contract
        let text = dist_fixture().replacen("\"secs_per_iter\": 6.2e-5", "\"secs_per_iter\": 1e999", 1);
        let fails = dist_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("workers=1: secs_per_iter"), "{fails:?}");
        assert!(fails[0].contains("must be finite"), "{fails:?}");
    }

    #[test]
    fn dist_query_count_varying_with_workers_fails() {
        let text = dist_fixture().replacen(
            "\"workers\": 4, \"secs_per_iter\": 4.1e-5, \"queries_per_iter\": 812.250",
            "\"workers\": 4, \"secs_per_iter\": 4.1e-5, \"queries_per_iter\": 812.375",
            1,
        );
        let fails = dist_failures(&parse(&text).unwrap());
        assert_eq!(fails.len(), 1, "{fails:?}");
        assert!(fails[0].contains("varies with worker count"), "{fails:?}");
        assert!(fails[0].contains("workers=4"), "{fails:?}");
    }

    #[test]
    fn analytic_provenance_is_detected() {
        assert!(is_analytic(&parse(r#"{"provenance": "analytic"}"#).unwrap()));
        assert!(!is_analytic(&parse(r#"{"provenance": "measured"}"#).unwrap()));
        assert!(!is_analytic(&parse(r#"{"pending": true}"#).unwrap()));
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(parse("{").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("[1, 2] trailing").is_err());
        assert!(parse("{\"n\": 1e}").is_err());
    }
}
