//! The six invariant lints (see DESIGN.md §Static-analysis).
//!
//! Each lint guards an invariant the runtime tests already encode, at the
//! source level, so a regression is caught with a file:line pointer before
//! anything is compiled or run:
//!
//! * `nondeterministic-order` — iteration-order-dependent containers in
//!   determinism-critical modules.
//! * `hot-path-alloc` — allocating idioms inside `// lint: zero-alloc` fns.
//! * `raw-entropy` — wall clocks / ambient randomness outside `util::Rng`.
//! * `unsafe-safety-comment` — every `unsafe` carries a `// SAFETY:` note.
//! * `codec-symmetry` — `save_state`/`load_state` pairs write and read the
//!   same field sequence.
//! * `float-reduce-order` — unordered parallel float reductions
//!   (`.fold`/`.reduce` combining through the canonical kernel trees
//!   `tree8`/`dot_lanes` are order-pinned and exempt).

use crate::scan::{line_of, FileView};

/// One lint violation at a source line.
pub struct Diag {
    pub lint: &'static str,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

/// Run every lint over one file view.
pub fn run_all(view: &FileView) -> Vec<Diag> {
    let mut diags = Vec::new();
    nondeterministic_order(view, &mut diags);
    hot_path_alloc(view, &mut diags);
    raw_entropy(view, &mut diags);
    unsafe_safety_comment(view, &mut diags);
    codec_symmetry(view, &mut diags);
    float_reduce_order(view, &mut diags);
    diags.sort_by(|a, b| (a.line, a.lint).cmp(&(b.line, b.lint)));
    diags
}

fn is_ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of word-bounded occurrences of `word` in `hay`.
fn find_word(hay: &str, word: &str) -> Vec<usize> {
    let h = hay.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = hay[from..].find(word) {
        let at = from + rel;
        let before_ok = at == 0 || !is_ident_byte(h[at - 1]);
        let end = at + word.len();
        let after_ok = end >= h.len() || !is_ident_byte(h[end]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

// ---------------------------------------------------------------- lint 1 --

/// Modules where iteration order feeds observable output, so HashMap /
/// HashSet (randomized iteration since they hash-seed per process) are
/// banned in favor of BTreeMap / sorted vectors.
const DET_MODULES: &[&str] = &[
    "flymc",
    "engine",
    "samplers",
    "diagnostics",
    "data",
    "linalg",
    "runtime",
    "kernels",
    // the distributed transport: request partitioning and response
    // reduction order feed the bit-identity contract (DESIGN.md
    // §Distribution), so no randomized iteration there either
    "net",
];

fn nondeterministic_order(view: &FileView, diags: &mut Vec<Diag>) {
    let in_det_module = DET_MODULES.iter().any(|m| {
        view.path.starts_with(&format!("rust/src/{m}/"))
            || view.path == format!("rust/src/{m}.rs")
    });
    if !in_det_module {
        return;
    }
    for (i, line) in view.code.iter().enumerate() {
        for container in ["HashMap", "HashSet"] {
            if !find_word(line, container).is_empty() {
                diags.push(Diag {
                    lint: "nondeterministic-order",
                    path: view.path.clone(),
                    line: i + 1,
                    msg: format!(
                        "{container} in determinism-critical module — iteration order is \
                         per-process-random; use BTreeMap/BTreeSet or a sorted Vec \
                         (allowlist in lint.toml if order provably never escapes)"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------- lint 2 --

/// Allocating idioms forbidden inside `// lint: zero-alloc` functions.
/// (`.push`/`.extend`/`.resize` into pre-reserved buffers stay legal — the
/// counting-allocator tests police actual allocator traffic; this lint
/// catches the idioms that always allocate.)
const ALLOC_IDIOMS: &[&str] = &[
    "vec!",
    "Vec::new",
    "Vec::with_capacity",
    ".to_vec()",
    ".clone()",
    ".collect()",
    ".collect::<",
    "format!",
    "Box::new",
    "String::",
    ".to_owned()",
    ".to_string()",
];

fn hot_path_alloc(view: &FileView, diags: &mut Vec<Diag>) {
    let (flat, starts) = view.flat_code();
    for (i, comment) in view.comments.iter().enumerate() {
        if !comment.contains("lint: zero-alloc") {
            continue;
        }
        let marker_line = i + 1;
        let search_from = starts[i];
        let Some(body) = next_fn_body(&flat, search_from) else {
            diags.push(Diag {
                lint: "hot-path-alloc",
                path: view.path.clone(),
                line: marker_line,
                msg: "dangling `// lint: zero-alloc` marker: no fn with a body follows"
                    .to_string(),
            });
            continue;
        };
        let text = &flat[body.0..body.1];
        for idiom in ALLOC_IDIOMS {
            let mut from = 0;
            while let Some(rel) = text[from..].find(idiom) {
                let at = body.0 + from + rel;
                // word-bound the leading edge of identifier-like idioms
                let lead = text.as_bytes()[from + rel];
                let bounded = !is_ident_byte(lead)
                    || at == 0
                    || !is_ident_byte(flat.as_bytes()[at - 1]);
                if bounded {
                    diags.push(Diag {
                        lint: "hot-path-alloc",
                        path: view.path.clone(),
                        line: line_of(&starts, at),
                        msg: format!(
                            "`{idiom}` inside a `// lint: zero-alloc` function (marker at \
                             line {marker_line}) — hoist the allocation to setup/scratch"
                        ),
                    });
                }
                from += rel + idiom.len();
            }
        }
    }
}

/// From `from`, find the next `fn` keyword and return the byte range of its
/// brace-delimited body (open brace .. close brace inclusive).
fn next_fn_body(flat: &str, from: usize) -> Option<(usize, usize)> {
    let fn_at = find_word(&flat[from..], "fn").first().map(|r| from + r)?;
    let open = from_offset(flat, fn_at, b'{')?;
    let close = matching_brace(flat, open)?;
    Some((open, close + 1))
}

fn from_offset(flat: &str, from: usize, target: u8) -> Option<usize> {
    flat.as_bytes()[from..].iter().position(|&c| c == target).map(|r| from + r)
}

/// Offset of the `}` matching the `{` at `open`.
fn matching_brace(flat: &str, open: usize) -> Option<usize> {
    let b = flat.as_bytes();
    let mut depth = 0usize;
    for (i, &c) in b.iter().enumerate().skip(open) {
        if c == b'{' {
            depth += 1;
        } else if c == b'}' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// Offset of the `)` matching the `(` at `open`.
fn matching_paren(flat: &str, open: usize) -> Option<usize> {
    let b = flat.as_bytes();
    let mut depth = 0usize;
    for (i, &c) in b.iter().enumerate().skip(open) {
        if c == b'(' {
            depth += 1;
        } else if c == b')' {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

// ---------------------------------------------------------------- lint 3 --

/// Ambient-entropy / wall-clock constructs that break seeded
/// reproducibility when they feed anything a chain observes.
const ENTROPY_PATTERNS: &[&str] = &[
    "Instant::now",
    "SystemTime",
    "thread_rng",
    "rand::",
    "getrandom",
    "RandomState",
    "from_entropy",
];

/// The only places wall-clock time is legitimate: the Timer abstraction
/// itself and the measurement layers that consume it.
const ENTROPY_ALLOWED: &[&str] =
    &["rust/src/util/mod.rs", "rust/src/metrics/", "rust/src/bench_harness/"];

fn raw_entropy(view: &FileView, diags: &mut Vec<Diag>) {
    if ENTROPY_ALLOWED.iter().any(|p| view.path.starts_with(p)) {
        return;
    }
    for (i, line) in view.code.iter().enumerate() {
        for pat in ENTROPY_PATTERNS {
            if line.contains(pat) {
                diags.push(Diag {
                    lint: "raw-entropy",
                    path: view.path.clone(),
                    line: i + 1,
                    msg: format!(
                        "`{pat}` outside the timing/metrics layers — all randomness must \
                         flow through the seeded util::Rng, all timing through util::Timer"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------- lint 4 --

fn unsafe_safety_comment(view: &FileView, diags: &mut Vec<Diag>) {
    for (i, line) in view.code.iter().enumerate() {
        if find_word(line, "unsafe").is_empty() {
            continue;
        }
        if has_safety_comment(view, i) {
            continue;
        }
        diags.push(Diag {
            lint: "unsafe-safety-comment",
            path: view.path.clone(),
            line: i + 1,
            msg: "`unsafe` without a `// SAFETY:` comment on it or the contiguous \
                  comment block above"
                .to_string(),
        });
    }
}

fn has_safety_comment(view: &FileView, line_idx: usize) -> bool {
    if view.comments[line_idx].contains("SAFETY:") {
        return true;
    }
    // walk the contiguous comment/attribute block directly above
    let mut i = line_idx;
    while i > 0 {
        i -= 1;
        let code = view.code[i].trim();
        let comment = view.comments[i].trim();
        let is_attr = code.starts_with("#[") || code.starts_with("#![");
        let is_comment_only = code.is_empty() && !comment.is_empty();
        if !is_attr && !is_comment_only {
            return false;
        }
        if comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------- lint 5 --

const WRITER_NAMES: &[&str] = &["save_state", "snapshot"];
const READER_NAMES: &[&str] = &["load_state", "restore"];
const NEST_NAMES: &[&str] = &["save_state", "snapshot", "load_state", "restore"];

/// Writer-side codec methods, i.e. the canonical sequence vocabulary.
const WRITER_METHODS: &[&str] =
    &["u8", "bool", "u32", "u64", "usize", "f64", "f64_slice", "u32_slice", "u64_slice", "bytes"];

/// Reader method -> canonical writer-side kind.
fn normalize_read(method: &str) -> Option<&'static str> {
    match method {
        "u8" => Some("u8"),
        "bool" => Some("bool"),
        "u32" => Some("u32"),
        "u64" => Some("u64"),
        "usize" => Some("usize"),
        "f64" => Some("f64"),
        "f64_slice_into" | "f64_vec" => Some("f64_slice"),
        "u32_slice_into" | "u32_vec" => Some("u32_slice"),
        "u64_slice_into" | "u64_vec" => Some("u64_slice"),
        "bytes" => Some("bytes"),
        _ => None,
    }
}

struct CodecFn {
    writer: bool,
    name: String,
    line: usize,
    seq: Result<Vec<String>, (usize, String)>,
}

fn codec_symmetry(view: &FileView, diags: &mut Vec<Diag>) {
    let (flat, starts) = view.flat_code();
    let mut fns: Vec<CodecFn> = Vec::new();
    for fn_at in find_word(&flat, "fn") {
        let Some(f) = parse_codec_fn(&flat, &starts, fn_at) else {
            continue;
        };
        fns.push(f);
    }
    // pair each writer with the next reader that follows it
    let mut pending: Option<CodecFn> = None;
    for f in fns {
        // a sequence-extraction failure is itself a violation
        if let Err((at, msg)) = &f.seq {
            diags.push(Diag {
                lint: "codec-symmetry",
                path: view.path.clone(),
                line: line_of(&starts, *at),
                msg: format!("in `{}`: {msg}", f.name),
            });
            continue;
        }
        if f.writer {
            pending = Some(f);
        } else if let Some(w) = pending.take() {
            let wseq = w.seq.as_ref().unwrap();
            let rseq = f.seq.as_ref().unwrap();
            if wseq != rseq {
                diags.push(Diag {
                    lint: "codec-symmetry",
                    path: view.path.clone(),
                    line: f.line,
                    msg: format!(
                        "`{}` (line {}) writes [{}] but `{}` reads [{}] — the checkpoint \
                         byte layout has drifted",
                        w.name,
                        w.line,
                        wseq.join(", "),
                        f.name,
                        rseq.join(", "),
                    ),
                });
            }
        }
    }
}

/// Parse the fn whose `fn` keyword starts at `fn_at`; return a CodecFn if
/// it is a named save/load (or snapshot/restore) taking a ByteWriter /
/// ByteReader and having a body.
fn parse_codec_fn(flat: &str, starts: &[usize], fn_at: usize) -> Option<CodecFn> {
    let b = flat.as_bytes();
    let mut i = fn_at + 2;
    while i < b.len() && b[i].is_ascii_whitespace() {
        i += 1;
    }
    let name_start = i;
    while i < b.len() && is_ident_byte(b[i]) {
        i += 1;
    }
    let name = &flat[name_start..i];
    let writer = WRITER_NAMES.contains(&name);
    let reader = READER_NAMES.contains(&name);
    if !writer && !reader {
        return None;
    }
    let open_paren = from_offset(flat, i, b'(')?;
    let close_paren = matching_paren(flat, open_paren)?;
    let params = &flat[open_paren + 1..close_paren];
    let marker = if writer { "ByteWriter" } else { "ByteReader" };
    if !params.contains(marker) {
        return None;
    }
    let param = param_name(params, marker)?;
    // body: first `{` or `;` at paren depth 0 after the params
    let mut j = close_paren + 1;
    let mut depth = 0usize;
    let open_brace = loop {
        if j >= b.len() {
            return None;
        }
        match b[j] {
            b'(' => depth += 1,
            b')' => depth = depth.saturating_sub(1),
            b'{' if depth == 0 => break j,
            b';' if depth == 0 => return None, // trait declaration, no body
            _ => {}
        }
        j += 1;
    };
    let close_brace = matching_brace(flat, open_brace)?;
    let mut seq = Vec::new();
    let seq = match extract_seq(flat, open_brace + 1, close_brace, &param, writer, &mut seq) {
        Ok(()) => Ok(seq),
        Err(e) => Err(e),
    };
    Some(CodecFn { writer, name: name.to_string(), line: line_of(starts, fn_at), seq })
}

/// The identifier of the parameter whose type mentions `marker`.
fn param_name(params: &str, marker: &str) -> Option<String> {
    let mut depth = 0usize;
    let mut start = 0;
    let mut pieces = Vec::new();
    let b = params.as_bytes();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'(' | b'[' | b'<' => depth += 1,
            b')' | b']' | b'>' => depth = depth.saturating_sub(1),
            b',' if depth == 0 => {
                pieces.push(&params[start..i]);
                start = i + 1;
            }
            _ => {}
        }
        i += 1;
    }
    pieces.push(&params[start..]);
    let piece = pieces.into_iter().find(|p| p.contains(marker))?;
    let name = piece.split(':').next()?.trim();
    let name = name.strip_prefix("mut ").unwrap_or(name).trim();
    Some(name.to_string())
}

/// Append the codec-call kind sequence of `flat[from..to]` to `out`.
///
/// `match` blocks are handled structurally: each arm is extracted
/// separately, empty arms are ignored, and all non-empty arms must agree
/// (their common sequence is appended once) — branch-divergent arms are a
/// violation in their own right. `if`/`else` is treated linearly, which is
/// exactly right for the presence-flag idiom (`w.bool(flag); if flag {
/// w.f64(x) }`).
fn extract_seq(
    flat: &str,
    from: usize,
    to: usize,
    param: &str,
    writer: bool,
    out: &mut Vec<String>,
) -> Result<(), (usize, String)> {
    let b = flat.as_bytes();
    let mut i = from;
    while i < to {
        let c = b[i];
        if !is_ident_byte(c) {
            i += 1;
            continue;
        }
        if i > 0 && is_ident_byte(b[i - 1]) {
            i += 1;
            continue;
        }
        let word_start = i;
        while i < to && is_ident_byte(b[i]) {
            i += 1;
        }
        let word = &flat[word_start..i];
        if word == "match" {
            i = extract_match(flat, i, to, param, writer, out)?;
        } else if word == param {
            // param.method( ... )
            let mut j = i;
            while j < to && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if j < to && b[j] == b'.' {
                let m_start = j + 1;
                let mut m = m_start;
                while m < to && is_ident_byte(b[m]) {
                    m += 1;
                }
                let method = &flat[m_start..m];
                let mut k = m;
                while k < to && b[k].is_ascii_whitespace() {
                    k += 1;
                }
                if k < to && b[k] == b'(' {
                    let known = if writer {
                        WRITER_METHODS.contains(&method).then(|| method.to_string())
                    } else {
                        normalize_read(method).map(str::to_string)
                    };
                    if let Some(kind) = known {
                        out.push(kind);
                    }
                    i = k + 1;
                }
            }
        } else if NEST_NAMES.contains(&word) {
            // some_field.save_state(w) / load_state(r)? -> opaque NEST
            let mut j = i;
            while j < to && b[j].is_ascii_whitespace() {
                j += 1;
            }
            if j < to && b[j] == b'(' {
                if let Some(close) = matching_paren(flat, j) {
                    if close <= to && !find_word(&flat[j + 1..close], param).is_empty() {
                        out.push("NEST".to_string());
                    }
                }
                i = j + 1;
            }
        }
    }
    Ok(())
}

/// Handle a `match` construct whose keyword just ended at `after_kw`;
/// returns the offset just past the match block.
fn extract_match(
    flat: &str,
    after_kw: usize,
    to: usize,
    param: &str,
    writer: bool,
    out: &mut Vec<String>,
) -> Result<usize, (usize, String)> {
    let b = flat.as_bytes();
    // scrutinee: up to the `{` at paren depth 0
    let mut i = after_kw;
    let mut depth = 0usize;
    let open = loop {
        if i >= to {
            // malformed; treat the rest linearly
            extract_seq(flat, after_kw, to, param, writer, out)?;
            return Ok(to);
        }
        match b[i] {
            b'(' | b'[' => depth += 1,
            b')' | b']' => depth = depth.saturating_sub(1),
            b'{' if depth == 0 => break i,
            _ => {}
        }
        i += 1;
    };
    extract_seq(flat, after_kw, open, param, writer, out)?;
    let close = match matching_brace(flat, open) {
        Some(c) if c <= to => c,
        _ => return Err((after_kw, "unbalanced match block".to_string())),
    };

    // split arms at `=>` boundaries at depth 0 inside the block
    let mut arm_seqs: Vec<Vec<String>> = Vec::new();
    let mut i = open + 1;
    let mut depth = 0usize;
    while i < close {
        match b[i] {
            b'(' | b'[' | b'{' => depth += 1,
            b')' | b']' | b'}' => depth = depth.saturating_sub(1),
            b'=' if depth == 0 && i + 1 < close && b[i + 1] == b'>' => {
                // arm body: braced block, or expression up to `,` at depth 0
                let mut j = i + 2;
                while j < close && b[j].is_ascii_whitespace() {
                    j += 1;
                }
                let (body_from, body_to, resume) = if j < close && b[j] == b'{' {
                    let bc = match matching_brace(flat, j) {
                        Some(c) if c <= close => c,
                        _ => return Err((j, "unbalanced match arm".to_string())),
                    };
                    (j + 1, bc, bc + 1)
                } else {
                    let mut k = j;
                    let mut d = 0usize;
                    while k < close {
                        match b[k] {
                            b'(' | b'[' | b'{' => d += 1,
                            b')' | b']' | b'}' => d = d.saturating_sub(1),
                            b',' if d == 0 => break,
                            _ => {}
                        }
                        k += 1;
                    }
                    (j, k, k)
                };
                let mut arm = Vec::new();
                extract_seq(flat, body_from, body_to, param, writer, &mut arm)?;
                arm_seqs.push(arm);
                i = resume;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    let nonempty: Vec<&Vec<String>> = arm_seqs.iter().filter(|a| !a.is_empty()).collect();
    if let Some(first) = nonempty.first() {
        if nonempty.iter().any(|a| a != first) {
            return Err((
                open,
                "match arms produce divergent codec sequences — every data-carrying arm \
                 must write/read the same field layout"
                    .to_string(),
            ));
        }
        out.extend(first.iter().cloned());
    }
    Ok(close + 1)
}

// ---------------------------------------------------------------- lint 6 --

const PAR_ADAPTERS: &[&str] = &[
    "par_iter",
    "par_iter_mut",
    "into_par_iter",
    "par_chunks",
    "par_chunks_mut",
    "par_chunks_exact",
    "par_bridge",
    "par_windows",
];

const UNORDERED_REDUCERS: &[&str] = &["sum", "product", "reduce", "fold"];

/// Fixed-shape reduction trees from `crate::kernels` whose combine order is
/// deterministic by construction (`tree8` is a literal 8-leaf tree,
/// `dot_lanes` the canonical 4-accumulator dot association). A parallel
/// `.reduce`/`.fold` whose combine step routes through one of these is
/// order-pinned regardless of work stealing, so it is not a violation.
const CANONICAL_REDUCERS: &[&str] = &["tree8", "dot_lanes"];

/// Does the argument list of the reducer call starting at the `(` at
/// `open` mention a canonical kernel reducer?
fn reducer_args_canonical(flat: &str, open: usize, limit: usize) -> bool {
    let Some(close) = matching_paren(flat, open) else {
        return false;
    };
    if close > limit {
        return false;
    }
    let args = &flat[open + 1..close];
    CANONICAL_REDUCERS.iter().any(|c| !find_word(args, c).is_empty())
}

fn float_reduce_order(view: &FileView, diags: &mut Vec<Diag>) {
    let (flat, starts) = view.flat_code();
    let b = flat.as_bytes();
    let mut depth = 0usize;
    let mut armed: Option<usize> = None; // brace depth where a par adapter appeared
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'{' => depth += 1,
            b'}' => {
                depth = depth.saturating_sub(1);
                if armed.is_some_and(|d| depth < d) {
                    armed = None;
                }
            }
            b';' => {
                if armed.is_some_and(|d| depth <= d) {
                    armed = None;
                }
            }
            c if is_ident_byte(c) && (i == 0 || !is_ident_byte(b[i - 1])) => {
                let start = i;
                while i < b.len() && is_ident_byte(b[i]) {
                    i += 1;
                }
                let word = &flat[start..i];
                if PAR_ADAPTERS.contains(&word) {
                    armed = Some(depth);
                } else if UNORDERED_REDUCERS.contains(&word)
                    && start > 0
                    && b[start - 1] == b'.'
                    && armed == Some(depth)
                {
                    // `.fold`/`.reduce` combining through a canonical kernel
                    // tree (tree8 / dot_lanes) has a pinned association —
                    // skip it. Find the call's `(` past whitespace/turbofish.
                    let mut k = i;
                    while k < b.len() && b[k] != b'(' && b[k] != b';' && b[k] != b'{' {
                        k += 1;
                    }
                    if k < b.len()
                        && b[k] == b'('
                        && matches!(word, "reduce" | "fold")
                        && reducer_args_canonical(&flat, k, b.len())
                    {
                        continue;
                    }
                    diags.push(Diag {
                        lint: "float-reduce-order",
                        path: view.path.clone(),
                        line: line_of(&starts, start),
                        msg: format!(
                            "`.{word}()` on a parallel iterator — float reduction order is \
                             nondeterministic under work stealing; reduce per shard and \
                             combine in shard order (see ParBackend)"
                        ),
                    });
                }
                continue;
            }
            _ => {}
        }
        i += 1;
    }
}
