//! firefly-lint — the repo's invariant-enforcing static-analysis pass,
//! plus the bench-JSON perf gate. Pure `std`, zero dependencies.
//!
//! Run through cargo (`cargo xtask lint`, `cargo xtask bench-gate`) or
//! build it with nothing but rustc when no cargo exists at all:
//!
//! ```text
//! rustc --edition 2021 -O xtask/src/main.rs -o firefly-lint
//! ./firefly-lint lint --root /path/to/repo
//! ```
//!
//! `lint` scans `rust/src`, `rust/tests`, and `benches/` and enforces the
//! six lints in [`lints`] (documented in DESIGN.md §Static-analysis), with
//! per-line diagnostics, `--format json` output, a `lint.toml` allowlist,
//! and a non-zero exit on any violation. `bench-gate` checks the emitted
//! `BENCH_*.json` against the committed baselines in `BENCH_baseline/`.

mod bench_gate;
mod config;
mod lints;
mod scan;

use std::path::Path;
use std::process::ExitCode;

const SCAN_DIRS: &[&str] = &["rust/src", "rust/tests", "benches"];

const USAGE: &str = "usage:
  xtask lint [--root DIR] [--format human|json]
  xtask bench-gate [--baseline DIR] [--measured DIR]";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("lint") => lint_cmd(&args[1..]),
        Some("bench-gate") => bench_gate::run(&args[1..]).map(|()| true),
        _ => {
            eprintln!("{USAGE}");
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("xtask: {e}");
            ExitCode::from(2)
        }
    }
}

/// Run the lint pass. Ok(true) = clean, Ok(false) = violations found.
fn lint_cmd(args: &[String]) -> Result<bool, String> {
    let mut root = ".".to_string();
    let mut json = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => root = it.next().ok_or("--root needs a value")?.clone(),
            "--format" => match it.next().map(String::as_str) {
                Some("human") => json = false,
                Some("json") => json = true,
                _ => return Err("--format must be `human` or `json`".to_string()),
            },
            other => return Err(format!("unknown lint flag `{other}`")),
        }
    }
    let root = Path::new(&root);
    if !root.join("rust/src").is_dir() {
        return Err(format!(
            "`{}` does not look like the repo root (no rust/src) — pass --root",
            root.display()
        ));
    }

    let allows = match std::fs::read_to_string(root.join("lint.toml")) {
        Ok(text) => config::parse(&text)?,
        Err(_) => Vec::new(), // no allowlist file: empty allowlist
    };

    let mut diags = Vec::new();
    for dir in SCAN_DIRS {
        for file in scan::rust_files(root, dir) {
            let text = std::fs::read_to_string(&file)
                .map_err(|e| format!("{}: {e}", file.display()))?;
            let rel = file
                .strip_prefix(root)
                .unwrap_or(&file)
                .to_string_lossy()
                .replace('\\', "/");
            diags.extend(lints::run_all(&scan::FileView::parse(rel, &text)));
        }
    }
    diags.sort_by(|a, b| (&a.path, a.line, a.lint).cmp(&(&b.path, b.line, b.lint)));

    let mut used = vec![false; allows.len()];
    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for d in diags {
        let hit = allows
            .iter()
            .position(|al| al.lint == d.lint && al.path == d.path);
        if let Some(i) = hit {
            used[i] = true;
            suppressed += 1;
        } else {
            kept.push(d);
        }
    }
    for (al, was_used) in allows.iter().zip(&used) {
        if !was_used {
            eprintln!(
                "warning: unused lint.toml entry: {} at {} ({})",
                al.lint, al.path, al.reason
            );
        }
    }

    if json {
        print_json(&kept, suppressed);
    } else {
        for d in &kept {
            println!("{}:{}: [{}] {}", d.path, d.line, d.lint, d.msg);
        }
        println!(
            "firefly-lint: {} violation(s), {} suppressed by lint.toml",
            kept.len(),
            suppressed
        );
    }
    Ok(kept.is_empty())
}

fn print_json(diags: &[lints::Diag], suppressed: usize) {
    let mut out = String::from("{\n  \"violations\": [\n");
    for (i, d) in diags.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"lint\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}{}\n",
            d.lint,
            json_escape(&d.path),
            d.line,
            json_escape(&d.msg),
            if i + 1 < diags.len() { "," } else { "" }
        ));
    }
    out.push_str(&format!("  ],\n  \"suppressed\": {suppressed}\n}}\n"));
    print!("{out}");
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            other => out.push(other),
        }
    }
    out
}
