//! Regenerates Table 1, rows 7–9 (OPV / robust regression / slice sampling).
//!
//!     cargo bench --bench table1_robust [-- --n 200000 --iters 400]
//!
//! The paper uses N = 1.8M molecules; the default here simulates at 200k
//! (the N/M speedup ratio is scale-free — pass --n 1800000 for full scale).
//! Paper reference (shape: regular ≈ 10 N queries/iter because slice
//! sampling evaluates several times per update; untuned ≈ 1.5 N, ~5.7x;
//! MAP-tuned ≈ 0.3 N, ~29x):
//!   Regular MCMC    18,182,764 q/iter   1.3 ESS/1k   (1)
//!   Untuned FlyMC    2,753,428 q/iter   1.1 ESS/1k   5.7
//!   MAP-tuned FlyMC    575,528 q/iter   1.2 ESS/1k   29

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 100_000);
    let base = ExperimentConfig {
        task: Task::RobustOpv,
        n_data: Some(n),
        iters: args.get_usize("iters", 2000),
        burnin: args.get_usize("burnin", 1000),
        chains: args.get_usize("chains", 1),
        seed: args.get_u64("seed", 0),
        record_every: 0,
        map_steps: args.get_usize("map-steps", 800),
        prior_scale: Some(0.5),
        ..Default::default()
    };
    let mut report = Report::new(
        &format!("Table 1 rows 7-9: OPV / robust regression / slice sampling (N={n})"),
        &["Algorithm", "Avg lik queries/iter", "q/iter / N", "ESS/1000 iters", "Speedup", "paper q/N", "paper speedup"],
    );
    // paper ratios: 18.18M/1.8M = 10.1, 2.75M/1.8M = 1.53, 0.576M/1.8M = 0.32
    let paper = [("10.1", "(1)"), ("1.53", "5.7"), ("0.32", "29")];
    let mut regular: Option<TableRow> = None;
    for (i, alg) in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc]
        .into_iter()
        .enumerate()
    {
        let mut cfg = base.clone();
        cfg.algorithm = alg;
        if alg == Algorithm::RegularMcmc {
            cfg.iters = cfg.iters.min(args.get_usize("regular-iters", 300));
            cfg.burnin = cfg.iters / 3;
        }
        let res = run_experiment(&cfg).expect("run");
        let row = res.table_row();
        let speedup = match &regular {
            None => {
                regular = Some(row.clone());
                "(1)".into()
            }
            Some(r) => format!("{:.1}", row.speedup_vs(r)),
        };
        report.row(&[
            row.algorithm.clone(),
            format!("{:.0}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.avg_lik_queries_per_iter / n as f64),
            format!("{:.2}", row.ess_per_1000),
            speedup,
            paper[i].0.into(),
            paper[i].1.into(),
        ]);
    }
    report.print();
    report.write_csv("target/bench_table1_robust.csv").unwrap();
    println!("wrote target/bench_table1_robust.csv");
}
