//! Hot-path microbenchmarks (§Perf instrumentation): per-datum CPU
//! evaluation, collapsed bound product, BrightSet ops, the implicit
//! z-resampling sweep, and XLA execution per bucket. These are the numbers
//! the DESIGN.md §Perf before/after table tracks.
//!
//!     cargo bench --bench microbench

use std::sync::Arc;

use firefly::bench_harness::Bench;
use firefly::data::synth;
use firefly::flymc::{BrightSet, PseudoPosterior};
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior, RobustT, SoftmaxBohning};
use firefly::prelude::*;
use firefly::runtime::{BatchEval, CpuBackend};

fn main() {
    let mut rng = Rng::new(1);

    // --- per-datum fused eval (logistic d=51), batch of 256 ------------------
    let data = Arc::new(synth::synth_mnist(20_000, 50, 1));
    let logi: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
    let mut cpu = CpuBackend::new(logi.clone(), Counters::new());
    let theta: Vec<f64> = (0..logi.dim()).map(|_| rng.normal() * 0.3).collect();
    let idx: Vec<u32> = (0..256).collect();
    let (mut ll, mut lb) = (Vec::new(), Vec::new());
    Bench::new("cpu eval 256x logistic d51 (ll+lb)")
        .samples(30)
        .iters_per_sample(50)
        .run(|| {
            cpu.eval(&theta, &idx, &mut ll, &mut lb);
            std::hint::black_box(&ll);
        });
    let mut grad = vec![0.0; logi.dim()];
    Bench::new("cpu eval 256x logistic d51 (+pseudo grad)")
        .samples(30)
        .iters_per_sample(50)
        .run(|| {
            grad.fill(0.0);
            cpu.eval_pseudo_grad(&theta, &idx, &mut ll, &mut lb, &mut grad);
            std::hint::black_box(&grad);
        });

    // --- softmax + robust per-datum eval -------------------------------------
    let sdata = Arc::new(synth::synth_cifar3(5000, 256, 2));
    let soft: Arc<dyn ModelBound> = Arc::new(SoftmaxBohning::new(sdata));
    let mut scpu = CpuBackend::new(soft.clone(), Counters::new());
    let stheta: Vec<f64> = (0..soft.dim()).map(|_| rng.normal() * 0.1).collect();
    Bench::new("cpu eval 256x softmax k3 d256 (ll+lb)")
        .samples(20)
        .iters_per_sample(20)
        .run(|| {
            scpu.eval(&stheta, &idx, &mut ll, &mut lb);
            std::hint::black_box(&ll);
        });

    let rdata = Arc::new(synth::synth_opv(20_000, 57, 3));
    let rob: Arc<dyn ModelBound> = Arc::new(RobustT::new(rdata, 4.0, 0.5));
    let mut rcpu = CpuBackend::new(rob.clone(), Counters::new());
    let rtheta: Vec<f64> = (0..rob.dim()).map(|_| rng.normal() * 0.3).collect();
    Bench::new("cpu eval 256x robust d57 (ll+lb)")
        .samples(30)
        .iters_per_sample(50)
        .run(|| {
            rcpu.eval(&rtheta, &idx, &mut ll, &mut lb);
            std::hint::black_box(&ll);
        });

    // --- collapsed bound product (the O(D^2) pseudo-prior step) --------------
    let mut lsc = logi.new_scratch();
    Bench::new("collapsed bound product logistic d51")
        .samples(30)
        .iters_per_sample(2000)
        .run(|| {
            std::hint::black_box(logi.log_bound_product(&theta, &mut lsc));
        });
    let mut ssc = soft.new_scratch();
    Bench::new("collapsed bound product softmax k3 d256")
        .samples(20)
        .iters_per_sample(200)
        .run(|| {
            std::hint::black_box(soft.log_bound_product(&stheta, &mut ssc));
        });
    let mut sgrad = vec![0.0; soft.dim()];
    Bench::new("collapsed bound grad softmax k3 d256")
        .samples(20)
        .iters_per_sample(200)
        .run(|| {
            sgrad.fill(0.0);
            soft.grad_log_bound_product_acc(&stheta, &mut sgrad, &mut ssc);
            std::hint::black_box(&sgrad);
        });

    // --- BrightSet ops --------------------------------------------------------
    let mut bs = BrightSet::new(1_000_000);
    let ops: Vec<usize> = (0..10_000).map(|_| rng.below(1_000_000)).collect();
    Bench::new("BrightSet 10k brighten/darken pairs (N=1M)")
        .samples(20)
        .iters_per_sample(10)
        .run(|| {
            for &n in &ops {
                bs.brighten(n);
            }
            for &n in &ops {
                bs.darken(n);
            }
        });

    // --- implicit resampling sweep -------------------------------------------
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
    let eval = Box::new(CpuBackend::new(logi.clone(), Counters::new()));
    let mut pp = PseudoPosterior::new(logi.clone(), prior, eval, theta.clone());
    let mut zrng = Rng::new(9);
    pp.init_z(&mut zrng);
    Bench::new("implicit z-resample sweep (N=20k, q=0.01)")
        .samples(20)
        .iters_per_sample(20)
        .run(|| {
            std::hint::black_box(pp.implicit_resample(0.01, &mut zrng));
        });

    // --- XLA execution per bucket ---------------------------------------------
    if cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.txt").exists() {
        use firefly::runtime::XlaBackend;
        let data = Arc::new(synth::synth_mnist(20_000, 50, 1));
        let model = Arc::new(LogisticJJ::new(data, 1.5));
        let mut xla = XlaBackend::new(model.clone(), Counters::new(), "artifacts").unwrap();
        for bs in [256usize, 2048] {
            let idx: Vec<u32> = (0..bs as u32).collect();
            let name = format!("xla exec logistic d51 bucket {bs}");
            let (mut ll2, mut lb2) = (Vec::new(), Vec::new());
            Bench::new(&name).samples(20).iters_per_sample(10).run(|| {
                xla.eval(&theta, &idx, &mut ll2, &mut lb2);
                std::hint::black_box(&ll2);
            });
        }
    }
}
