//! Regenerates Table 1, rows 1–3 (MNIST / logistic regression / MH).
//!
//!     cargo bench --bench table1_logistic [-- --iters 2000 --chains 3]
//!
//! Paper reference (absolute numbers are testbed-specific; the SHAPE to
//! reproduce is: untuned ≈ N/2 queries and ~0.7x speedup; MAP-tuned ≈ 1-2%
//! of N queries and >~20x speedup):
//!   Regular MCMC    12,214 q/iter   3.7 ESS/1k   (1)
//!   Untuned FlyMC    6,252 q/iter   1.3 ESS/1k   0.7
//!   MAP-tuned FlyMC    207 q/iter   1.4 ESS/1k   22

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let base = ExperimentConfig {
        task: Task::LogisticMnist,
        n_data: Some(args.get_usize("n", 12_214)),
        iters: args.get_usize("iters", 1500),
        burnin: args.get_usize("burnin", 400),
        chains: args.get_usize("chains", 1),
        seed: args.get_u64("seed", 0),
        record_every: 0,
        ..Default::default()
    };
    let mut report = Report::new(
        "Table 1 rows 1-3: MNIST / logistic regression / Metropolis-Hastings",
        &["Algorithm", "Avg lik queries/iter", "ESS/1000 iters", "Speedup", "paper q/iter", "paper speedup"],
    );
    let paper = [("12214", "(1)"), ("6252", "0.7"), ("207", "22")];
    let mut regular: Option<TableRow> = None;
    for (i, alg) in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc]
        .into_iter()
        .enumerate()
    {
        let mut cfg = base.clone();
        cfg.algorithm = alg;
        let res = run_experiment(&cfg).expect("run");
        let row = res.table_row();
        let speedup = match &regular {
            None => {
                regular = Some(row.clone());
                "(1)".into()
            }
            Some(r) => format!("{:.1}", row.speedup_vs(r)),
        };
        report.row(&[
            row.algorithm.clone(),
            format!("{:.0}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.ess_per_1000),
            speedup,
            paper[i].0.into(),
            paper[i].1.into(),
        ]);
    }
    report.print();
    report.write_csv("target/bench_table1_logistic.csv").unwrap();
    println!("wrote target/bench_table1_logistic.csv");
}
