//! Regenerates Table 1, rows 4–6 (3-class CIFAR-10 / softmax / Langevin).
//!
//!     cargo bench --bench table1_softmax [-- --iters 800]
//!
//! Paper reference (shape: untuned ≈ 0.45 N queries, ~1.2x; MAP-tuned ≈ 3-4%
//! of N, ~11x):
//!   Regular MCMC    18,000 q/iter   8.0 ESS/1k   (1)
//!   Untuned FlyMC    8,058 q/iter   4.2 ESS/1k   1.2
//!   MAP-tuned FlyMC    654 q/iter   3.3 ESS/1k   11

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let base = ExperimentConfig {
        task: Task::SoftmaxCifar,
        n_data: Some(args.get_usize("n", 18_000)),
        iters: args.get_usize("iters", 1500),
        burnin: args.get_usize("burnin", 600),
        chains: args.get_usize("chains", 1),
        seed: args.get_u64("seed", 0),
        record_every: 0,
        map_steps: args.get_usize("map-steps", 600),
        ..Default::default()
    };
    let mut report = Report::new(
        "Table 1 rows 4-6: 3-Class CIFAR-10 / softmax / Langevin (MALA)",
        &["Algorithm", "Avg lik queries/iter", "ESS/1000 iters", "Speedup", "paper q/iter", "paper speedup"],
    );
    let paper = [("18000", "(1)"), ("8058", "1.2"), ("654", "11")];
    let mut regular: Option<TableRow> = None;
    for (i, alg) in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc]
        .into_iter()
        .enumerate()
    {
        let mut cfg = base.clone();
        cfg.algorithm = alg;
        let res = run_experiment(&cfg).expect("run");
        let row = res.table_row();
        let speedup = match &regular {
            None => {
                regular = Some(row.clone());
                "(1)".into()
            }
            Some(r) => format!("{:.1}", row.speedup_vs(r)),
        };
        report.row(&[
            row.algorithm.clone(),
            format!("{:.0}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.ess_per_1000),
            speedup,
            paper[i].0.into(),
            paper[i].1.into(),
        ]);
    }
    report.print();
    report.write_csv("target/bench_table1_softmax.csv").unwrap();
    println!("wrote target/bench_table1_softmax.csv");
}
