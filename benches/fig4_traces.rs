//! Regenerates Fig 4 (a, b, c): for each experiment, 5 replica runs of the
//! three algorithms; emits the mean ± 1σ series of (top) the full-data log
//! posterior and (bottom) likelihood queries per iteration.
//!
//!     cargo bench --bench fig4_traces [-- --runs 5 --iters 600 --panel a|b|c|all]
//!
//! CSV columns: iter, then per algorithm mean and std of both series.
//! The paper's qualitative shape to look for: MAP-tuned FlyMC converges
//! SLOWER during burn-in (bounds loose far from the mode) but runs at a tiny
//! query budget after; untuned FlyMC is the reverse.

use firefly::bench_harness::{ascii_plot, Report};
use firefly::cli::Args;
use firefly::prelude::*;
use firefly::util::math;

fn panel(task: Task, label: &str, n: usize, iters: usize, runs: usize, map_steps: usize) {
    let algorithms =
        [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc];
    // series[alg][run] = (logpost at recorded iters, queries per iter)
    let mut logpost: Vec<Vec<Vec<f64>>> = vec![Vec::new(); 3];
    let mut queries: Vec<Vec<Vec<f64>>> = vec![Vec::new(); 3];
    let record_every = 5usize;

    for run in 0..runs {
        for (ai, alg) in algorithms.into_iter().enumerate() {
            let cfg = ExperimentConfig {
                task,
                algorithm: alg,
                n_data: Some(n),
                iters,
                burnin: iters / 4,
                seed: 1000 + run as u64,
                record_every,
                map_steps,
                prior_scale: None,
                ..Default::default()
            };
            let res = run_experiment(&cfg).expect("run");
            logpost[ai].push(res.chains[0].full_logpost.iter().map(|&(_, l)| l).collect());
            queries[ai]
                .push(res.chains[0].queries_per_iter.iter().map(|&q| q as f64).collect());
        }
    }

    // aggregate mean/std over runs
    let agg = |runs_data: &Vec<Vec<f64>>| -> (Vec<f64>, Vec<f64>) {
        let len = runs_data.iter().map(|r| r.len()).min().unwrap_or(0);
        let mut mean = vec![0.0; len];
        let mut std = vec![0.0; len];
        for i in 0..len {
            let vals: Vec<f64> = runs_data.iter().map(|r| r[i]).collect();
            mean[i] = math::mean(&vals);
            std[i] = if vals.len() > 1 { math::variance(&vals).sqrt() } else { 0.0 };
        }
        (mean, std)
    };

    let names = ["regular", "untuned", "maptuned"];
    let mut rep = Report::new(
        &format!("Fig 4{label} series"),
        &[
            "iter",
            "regular_logpost_mean", "regular_logpost_std",
            "untuned_logpost_mean", "untuned_logpost_std",
            "maptuned_logpost_mean", "maptuned_logpost_std",
            "regular_q_mean", "untuned_q_mean", "maptuned_q_mean",
        ],
    );
    let lp: Vec<(Vec<f64>, Vec<f64>)> = logpost.iter().map(agg).collect();
    let qq: Vec<(Vec<f64>, Vec<f64>)> = queries.iter().map(agg).collect();
    let npoints = lp.iter().map(|(m, _)| m.len()).min().unwrap();
    for i in 0..npoints {
        let qi = (i * record_every).min(qq[0].0.len().saturating_sub(1));
        rep.row(&[
            (i * record_every).to_string(),
            format!("{:.3}", lp[0].0[i]), format!("{:.3}", lp[0].1[i]),
            format!("{:.3}", lp[1].0[i]), format!("{:.3}", lp[1].1[i]),
            format!("{:.3}", lp[2].0[i]), format!("{:.3}", lp[2].1[i]),
            format!("{:.1}", qq[0].0[qi]), format!("{:.1}", qq[1].0[qi]), format!("{:.1}", qq[2].0[qi]),
        ]);
    }
    let path = format!("target/bench_fig4{label}.csv");
    rep.write_csv(&path).unwrap();
    println!("wrote {path}");

    let series: Vec<(&str, &[f64])> = names
        .iter()
        .zip(&lp)
        .map(|(n, (m, _))| (*n, m.as_slice()))
        .collect();
    ascii_plot(
        &format!("Fig 4{label} top: full-data log posterior (mean of {runs} runs)"),
        &series,
        72,
        12,
    );
    let qseries: Vec<(&str, &[f64])> = names
        .iter()
        .zip(&qq)
        .map(|(n, (m, _))| (*n, m.as_slice()))
        .collect();
    ascii_plot(
        &format!("Fig 4{label} bottom: likelihood queries per iteration"),
        &qseries,
        72,
        12,
    );
}

fn main() {
    let args = Args::from_env();
    let runs = args.get_usize("runs", 3);
    let iters = args.get_usize("iters", 600);
    let which = args.get_str("panel", "all");

    if which == "a" || which == "all" {
        panel(Task::LogisticMnist, "a", args.get_usize("n", 12_214), iters, runs, 400);
    }
    if which == "b" || which == "all" {
        panel(Task::SoftmaxCifar, "b", args.get_usize("n-cifar", 9_000), iters.min(300), runs, 400);
    }
    if which == "c" || which == "all" {
        panel(Task::RobustOpv, "c", args.get_usize("n-opv", 30_000), iters.min(250), runs, 500);
    }
}
