//! Multi-chain engine bench: R replica chains of each algorithm on the
//! serial (`cpu`) and sharded (`parcpu`) backends — split-R̂ (worst θ
//! component and joint log-density), pooled ESS, queries/iter, and
//! wallclock, so backend sharding and chain-level threading can be compared
//! at identical statistical output (the chains are bit-identical across
//! backends and thread caps by construction).
//!
//!     cargo bench --bench multichain [-- --n 4000 --iters 400 --chains 4 --threads 0]

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::engine::multi_chain;
use firefly::prelude::*;
use firefly::util::Timer;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 4000);
    let chains = args.get_usize("chains", 4);
    let mut report = Report::new(
        &format!("Multi-chain engine (N={n}, R={chains} replicas)"),
        &[
            "backend",
            "algorithm",
            "queries/iter",
            "split-Rhat (worst dim)",
            "split-Rhat (logpost)",
            "pooled ESS",
            "total lik queries",
            "wallclock (s)",
        ],
    );
    for backend in [Backend::Cpu, Backend::ParCpu] {
        for algorithm in [Algorithm::RegularMcmc, Algorithm::MapTunedFlyMc] {
            let cfg = ExperimentConfig {
                task: Task::LogisticMnist,
                algorithm,
                backend,
                n_data: Some(n),
                iters: args.get_usize("iters", 400),
                burnin: args.get_usize("burnin", 100),
                chains,
                threads: args.get_usize("threads", 0),
                map_steps: args.get_usize("map-steps", 200),
                seed: args.get_u64("seed", 0),
                record_every: 0,
                ..Default::default()
            };
            let timer = Timer::start();
            let (_result, summary) = multi_chain::run_multi_chain(&cfg).expect("run");
            let secs = timer.elapsed_secs();
            report.row(&[
                format!("{backend:?}"),
                algorithm.label().to_string(),
                format!("{:.1}", summary.avg_queries_per_iter),
                format!("{:.3}", summary.split_rhat_max),
                format!("{:.3}", summary.split_rhat_logpost),
                format!("{:.1}", summary.pooled_ess),
                summary.total_lik_queries.to_string(),
                format!("{secs:.2}"),
            ]);
        }
    }
    report.print();
    report.write_csv("target/bench_multichain.csv").unwrap();
    println!("wrote target/bench_multichain.csv");
    println!(
        "(identical seeds give bit-identical chains on cpu and parcpu; \
         the wallclock column is the only one allowed to differ)"
    );
}
