//! Head-to-head competitor bench: FlyMC vs full-data MH vs the approximate
//! baselines (SGLD, austerity MH) on all three paper workloads.
//!
//! For every workload × algorithm the bench reports
//!
//! * **ESS/sec** — minimum-component effective sample size of the recorded
//!   θ-trace (projected onto the leading 3 components, same projection for
//!   every algorithm) divided by sampling wall-clock,
//! * **queries/iter** — mean post-burnin likelihood queries per iteration,
//!   the paper's cost unit, metered identically for exact and approximate
//!   samplers through the shared `BatchEval` path,
//! * **bias** — the worst |z| from `testing::posterior_check`'s two-sample
//!   moment/quantile battery against a long full-data reference chain run
//!   at the same seed (so both chains share θ0). For the exact samplers
//!   this is calibrated noise (|z| below the Bonferroni threshold); for the
//!   approximate samplers it measures the subsampling bias the paper's
//!   exactness claim is about,
//!
//! and emits `BENCH_head2head.json`, validated by `cargo xtask bench-gate`
//! (every workload × algorithm entry must carry finite `ess_per_sec`,
//! `queries_per_iter`, and `bias_max_abs_z` fields).
//!
//!     cargo bench --bench head2head                # full per-task sizes
//!     cargo bench --bench head2head -- --smoke     # CI smoke mode
//!
//! `--seed` is the only other knob; sizes are fixed per task so trajectory
//! points stay comparable across PRs. The bias column is never NaN: a
//! degenerate report (NaN z-score) is clamped to the finite sentinel 1e9,
//! which no calibrated chain can reach.

use firefly::bench_harness::{fmt_time, Report};
use firefly::cli::Args;
use firefly::configx::{Algorithm, ExperimentConfig, Task};
use firefly::diagnostics::{ess_min_components, TraceMatrix};
use firefly::engine::run_experiment;
use firefly::testing::posterior_check::check_against_reference;

/// Two-sample battery size (see `posterior_check`): alpha for the
/// Bonferroni-corrected threshold reported next to each bias value.
const ALPHA: f64 = 1e-3;

/// Components kept for the ESS and bias statistics — a fixed, small
/// projection keeps the Bonferroni battery identical across workloads
/// whose full dimensions differ by two orders of magnitude.
const PROJ: usize = 3;

/// Finite sentinel for a degenerate (NaN/∞) bias statistic.
const BIAS_SENTINEL: f64 = 1e9;

struct Workload {
    task: Task,
    label: &'static str,
    sampler: &'static str,
    n: usize,
    iters: usize,
    burnin: usize,
    ref_iters: usize,
}

struct Row {
    algo_key: &'static str,
    algo_label: &'static str,
    ess_per_sec: f64,
    queries_per_iter: f64,
    bias: f64,
    threshold: f64,
    passed: bool,
    wallclock: f64,
}

/// Keep the first `k` components of a recorded trace.
fn project(trace: &TraceMatrix, k: usize) -> TraceMatrix {
    let k = k.min(trace.dim());
    let mut out = TraceMatrix::with_capacity(k, trace.n_rows());
    for row in trace.rows() {
        out.push_row(&row[..k]);
    }
    out
}

fn base_cfg(w: &Workload, algorithm: Algorithm, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        task: w.task,
        algorithm,
        n_data: Some(w.n),
        iters: w.iters,
        burnin: w.burnin,
        map_steps: 60,
        chains: 1,
        record_every: 0,
        seed,
        ..Default::default()
    };
    match algorithm {
        Algorithm::Sgld => {
            cfg.minibatch = (w.n / 10).clamp(10, 100);
            // moderate near-constant step: small enough to track the
            // posterior, large enough to move in bench-scale chains
            cfg.sgld_step_a = match w.task {
                Task::SoftmaxCifar => 1e-5,
                _ => 1e-4,
            };
            cfg.sgld_step_b = 1.0;
            cfg.sgld_step_gamma = 0.33;
        }
        Algorithm::Austerity => {
            cfg.minibatch = (w.n / 10).clamp(10, 100);
            cfg.austerity_eps = 0.05;
        }
        _ => {}
    }
    cfg
}

fn run_algo(w: &Workload, algorithm: Algorithm, seed: u64, reference: &TraceMatrix) -> Row {
    let cfg = base_cfg(w, algorithm, seed);
    let res = run_experiment(&cfg).expect("run experiment");
    let chain = &res.chains[0];
    let trace = project(&chain.theta_trace, PROJ);
    let report = check_against_reference(&trace, reference, ALPHA);
    let raw_bias = report.max_abs_z();
    let bias = if raw_bias.is_finite() { raw_bias } else { BIAS_SENTINEL };
    let ess = ess_min_components(&trace);
    let secs = chain.wallclock_secs.max(1e-9);
    let ess_per_sec = ess / secs;
    Row {
        algo_key: match algorithm {
            Algorithm::RegularMcmc => "full",
            Algorithm::MapTunedFlyMc => "flymc",
            Algorithm::Sgld => "sgld",
            Algorithm::Austerity => "austerity",
            Algorithm::UntunedFlyMc => "flymc_untuned",
        },
        algo_label: algorithm.label(),
        ess_per_sec: if ess_per_sec.is_finite() { ess_per_sec } else { 0.0 },
        queries_per_iter: res.table_row().avg_lik_queries_per_iter,
        bias,
        threshold: report.threshold,
        passed: report.passed(),
        wallclock: chain.wallclock_secs,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 11);

    // Per-task sizes. The full-data MH reference bounds the runtime (N
    // likelihood queries per iteration; slice on robust: ~10·N), so the
    // softmax/robust workloads run smaller N. Fixed per mode — trajectory
    // points stay comparable across PRs.
    let workloads = [
        Workload {
            task: Task::LogisticMnist,
            label: "logistic",
            sampler: "rwmh",
            n: if smoke { 300 } else { 2000 },
            iters: if smoke { 600 } else { 6000 },
            burnin: if smoke { 200 } else { 2000 },
            ref_iters: if smoke { 1500 } else { 15000 },
        },
        Workload {
            task: Task::SoftmaxCifar,
            label: "softmax",
            sampler: "mala",
            n: if smoke { 60 } else { 400 },
            iters: if smoke { 240 } else { 1500 },
            burnin: if smoke { 80 } else { 500 },
            ref_iters: if smoke { 600 } else { 3600 },
        },
        Workload {
            task: Task::RobustOpv,
            label: "robust",
            sampler: "slice",
            n: if smoke { 200 } else { 800 },
            iters: if smoke { 300 } else { 2000 },
            burnin: if smoke { 100 } else { 600 },
            ref_iters: if smoke { 800 } else { 5000 },
        },
    ];

    const ALGOS: [Algorithm; 4] = [
        Algorithm::RegularMcmc,
        Algorithm::MapTunedFlyMc,
        Algorithm::Sgld,
        Algorithm::Austerity,
    ];

    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"head2head\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str(&format!("  \"alpha\": {ALPHA:e},\n"));
    json.push_str(&format!("  \"projection_components\": {PROJ},\n"));
    json.push_str("  \"workloads\": [\n");

    for (wi, w) in workloads.iter().enumerate() {
        println!(
            "head2head: {} + {} N={}, {} iters ({} burnin), reference {} iters{}",
            w.label,
            w.sampler,
            w.n,
            w.iters,
            w.burnin,
            w.ref_iters,
            if smoke { " (smoke)" } else { "" }
        );
        // Long full-data reference chain at the same seed: θ0 matches every
        // chain under test, so initialization transients largely cancel in
        // the two-sample bias statistics.
        let mut ref_cfg = base_cfg(w, Algorithm::RegularMcmc, seed);
        ref_cfg.iters = w.ref_iters;
        let reference = run_experiment(&ref_cfg).expect("run reference");
        let ref_trace = project(&reference.chains[0].theta_trace, PROJ);

        let mut report = Report::new(
            &format!("head-to-head ({} + {}, N={})", w.label, w.sampler, w.n),
            &["algorithm", "ESS/sec", "queries/iter", "bias max|z|", "biased?", "wallclock"],
        );
        let mut rows = Vec::new();
        for algorithm in ALGOS {
            let r = run_algo(w, algorithm, seed, &ref_trace);
            report.row(&[
                r.algo_label.to_string(),
                format!("{:.1}", r.ess_per_sec),
                format!("{:.1}", r.queries_per_iter),
                format!("{:.2} (thr {:.2})", r.bias, r.threshold),
                if r.passed { "no".into() } else { "YES".into() },
                fmt_time(r.wallclock),
            ]);
            rows.push(r);
        }
        report.print();

        json.push_str(&format!(
            "    {{\"task\": \"{}\", \"sampler\": \"{}\", \"n\": {}, \"iters\": {}, \
             \"burnin\": {}, \"reference_iters\": {},\n     \"algorithms\": [\n",
            w.label, w.sampler, w.n, w.iters, w.burnin, w.ref_iters,
        ));
        for (i, r) in rows.iter().enumerate() {
            json.push_str(&format!(
                "      {{\"algorithm\": \"{}\", \"ess_per_sec\": {:.4}, \
                 \"queries_per_iter\": {:.3}, \"bias_max_abs_z\": {:.4}, \
                 \"bias_threshold\": {:.4}, \"bias_detected\": {}, \
                 \"wallclock_secs\": {:e}}}{}\n",
                r.algo_key,
                r.ess_per_sec,
                r.queries_per_iter,
                r.bias,
                r.threshold,
                !r.passed,
                r.wallclock,
                if i + 1 < rows.len() { "," } else { "" },
            ));
        }
        json.push_str(&format!(
            "     ]}}{}\n",
            if wi + 1 < workloads.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_head2head.json", &json).expect("write BENCH_head2head.json");
    println!("wrote BENCH_head2head.json");
}
