//! Checkpoint-layer macro-benchmark: what durability costs.
//!
//! For each paper task, runs the same FlyMC chain twice — without
//! checkpointing and with a periodic `.fckpt` writer — and reports:
//!
//! * wallclock per iteration for both runs and the implied **write
//!   overhead per iteration** (the amortized cost of durability),
//! * seconds per checkpoint write and the serialized checkpoint size,
//! * **resume latency**: the time to read + validate + restore the final
//!   checkpoint into a freshly built chain (model/backend construction is
//!   excluded — a resuming process pays that to start sampling at all).
//!
//! Emits `BENCH_checkpoint.json` so future PRs have a trajectory to beat.
//!
//!     cargo bench --bench checkpoint             # full sizes
//!     cargo bench --bench checkpoint -- --smoke  # CI smoke mode
//!
//! The two runs are also byte-compared (traces, counters): a checkpoint
//! writer that perturbs the chain would invalidate every number here.

use firefly::bench_harness::{fmt_time, Report};
use firefly::cli::Args;
use firefly::engine::experiment::{build_chain, build_model, build_sampler, chain_config};
use firefly::engine::observer::ChainObserver;
use firefly::engine::{
    read_checkpoint, replica_checkpoint_path, run_chain_segments, ChainCheckpointSpec,
    ChainResult, ChainState, CheckpointObserver, RecordingObserver, StreamingObserver,
};
use firefly::prelude::*;
use firefly::util::Timer;

struct Scenario {
    task: Task,
    label: &'static str,
    n: usize,
    iters: usize,
    every: usize,
}

struct Numbers {
    base_per_iter: f64,
    ckpt_per_iter: f64,
    writes: u64,
    ckpt_bytes: u64,
    resume_restore_secs: f64,
}

fn build(cfg: &ExperimentConfig) -> (firefly::engine::ChainTarget, Box<dyn Sampler>, Vec<f64>) {
    let (model, prior, _, _) = build_model(cfg).expect("build model");
    let (target, theta0) = build_chain(cfg, model, prior, cfg.seed).expect("build chain");
    (target, build_sampler(cfg.task), theta0)
}

fn run(cfg: &ExperimentConfig, spec: Option<&ChainCheckpointSpec>) -> (f64, ChainResult) {
    let (target, sampler, theta0) = build(cfg);
    let ccfg = chain_config(cfg, cfg.seed);
    let timer = Timer::start();
    let res = run_chain_segments(target, sampler, theta0, &ccfg, spec).expect("chain run");
    (timer.elapsed_secs(), res)
}

fn assert_identical(a: &ChainResult, b: &ChainResult, label: &str) {
    assert_eq!(a.logpost_joint, b.logpost_joint, "{label}: checkpointing perturbed the chain");
    assert_eq!(a.queries_per_iter, b.queries_per_iter, "{label}: query accounting drifted");
    assert_eq!(a.theta_trace, b.theta_trace, "{label}: θ trace drifted");
}

fn measure(scenario: &Scenario, dir: &str, seed: u64) -> Numbers {
    let cfg = ExperimentConfig {
        task: scenario.task,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(scenario.n),
        iters: scenario.iters,
        burnin: scenario.iters / 4,
        record_every: 0,
        seed,
        ..Default::default()
    };
    let fingerprint = cfg.fingerprint();
    let path = replica_checkpoint_path(dir, 0);

    let (base_secs, base_res) = run(&cfg, None);
    let spec = ChainCheckpointSpec {
        path: path.clone(),
        every: scenario.every,
        fingerprint,
        resume: false,
        stop_after: None,
    };
    let (ckpt_secs, ckpt_res) = run(&cfg, Some(&spec));
    assert_identical(&base_res, &ckpt_res, scenario.label);

    let writes = (scenario.iters / scenario.every) as u64
        + u64::from(scenario.iters % scenario.every != 0);
    let ckpt_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);

    // resume latency: read + validate + restore into a freshly built chain
    let (target, sampler, theta0) = build(&cfg);
    let ccfg = chain_config(&cfg, cfg.seed);
    let dim = theta0.len();
    let mut state = ChainState::new(target, sampler, theta0, &ccfg);
    let mut rec = RecordingObserver::new(&ccfg, dim);
    let mut stats = StreamingObserver::new(&ccfg, dim);
    let mut writer = CheckpointObserver::new(&path, scenario.every, fingerprint);
    let mut observers: [&mut dyn ChainObserver; 3] = [&mut rec, &mut stats, &mut writer];
    let timer = Timer::start();
    let image = read_checkpoint(&path).expect("read checkpoint");
    assert_eq!(image.fingerprint, fingerprint);
    state.restore(&image, &mut observers).expect("restore");
    let resume_restore_secs = timer.elapsed_secs();
    assert_eq!(state.completed(), scenario.iters, "final checkpoint sits at completion");

    Numbers {
        base_per_iter: base_secs / scenario.iters as f64,
        ckpt_per_iter: ckpt_secs / scenario.iters as f64,
        writes,
        ckpt_bytes,
        resume_restore_secs,
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 0);
    let dir = std::env::temp_dir()
        .join(format!("firefly_bench_ckpt_{}", std::process::id()))
        .to_string_lossy()
        .into_owned();
    std::fs::create_dir_all(&dir).expect("bench checkpoint dir");

    let scenarios = [
        Scenario {
            task: Task::LogisticMnist,
            label: "logistic",
            n: if smoke { 400 } else { 5000 },
            iters: if smoke { 200 } else { 2000 },
            every: if smoke { 50 } else { 200 },
        },
        Scenario {
            task: Task::SoftmaxCifar,
            label: "softmax",
            n: if smoke { 240 } else { 1500 },
            iters: if smoke { 80 } else { 500 },
            every: if smoke { 20 } else { 100 },
        },
        Scenario {
            task: Task::RobustOpv,
            label: "robust",
            n: if smoke { 400 } else { 2000 },
            iters: if smoke { 80 } else { 500 },
            every: if smoke { 20 } else { 100 },
        },
    ];

    let mut report = Report::new(
        "Checkpoint overhead (untuned FlyMC)",
        &[
            "task",
            "base/iter",
            "ckpt/iter",
            "overhead/iter",
            "per write",
            "ckpt size",
            "restore",
        ],
    );
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"checkpoint\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n  \"scenarios\": [\n"));

    for (si, s) in scenarios.iter().enumerate() {
        println!(
            "checkpoint bench: {} N={} iters={} every={}{}",
            s.label,
            s.n,
            s.iters,
            s.every,
            if smoke { " (smoke)" } else { "" }
        );
        let n = measure(s, &dir, seed);
        let overhead = (n.ckpt_per_iter - n.base_per_iter).max(0.0);
        let per_write = overhead * s.iters as f64 / n.writes as f64;
        report.row(&[
            s.label.to_string(),
            fmt_time(n.base_per_iter),
            fmt_time(n.ckpt_per_iter),
            fmt_time(overhead),
            fmt_time(per_write),
            format!("{} B", n.ckpt_bytes),
            fmt_time(n.resume_restore_secs),
        ]);
        json.push_str(&format!(
            "    {{\"task\": \"{}\", \"n\": {}, \"iters\": {}, \"checkpoint_every\": {}, \
             \"baseline_secs_per_iter\": {:e}, \"checkpointed_secs_per_iter\": {:e}, \
             \"write_overhead_secs_per_iter\": {:e}, \"writes\": {}, \
             \"secs_per_write\": {:e}, \"ckpt_bytes\": {}, \
             \"resume_restore_secs\": {:e}}}{}\n",
            s.label,
            s.n,
            s.iters,
            s.every,
            n.base_per_iter,
            n.ckpt_per_iter,
            overhead,
            n.writes,
            per_write,
            n.ckpt_bytes,
            n.resume_restore_secs,
            if si + 1 < scenarios.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    report.print();
    std::fs::write("BENCH_checkpoint.json", &json).expect("write BENCH_checkpoint.json");
    println!("wrote BENCH_checkpoint.json");
    let _ = std::fs::remove_dir_all(dir);
}
