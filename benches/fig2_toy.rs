//! Regenerates Fig 2 (headless form of examples/toy_trajectory.rs): FlyMC on
//! the toy 2-d logistic problem, emitting the θ/z trajectories as CSV plus a
//! one-iteration before/after snapshot of the z flips.
//!
//!     cargo bench --bench fig2_toy [-- --iters 80]

use std::sync::Arc;

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::data::synth;
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
use firefly::prelude::*;
use firefly::runtime::CpuBackend;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 30);
    let iters = args.get_usize("iters", 80);

    let data = Arc::new(synth::synth_toy2d(n, 3));
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data.clone(), 1.5));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 2.0 });
    let eval = Box::new(CpuBackend::new(model.clone(), Counters::new()));
    let mut rng = Rng::new(7);
    let theta0 = prior.sample(3, &mut rng);
    let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
    pp.init_z(&mut rng);
    let mut mh = RandomWalkMh::adaptive(0.3);
    let mut theta = theta0;

    // per-datum z trace CSV (the paper's bottom-right panel shows all z_n)
    let mut headers: Vec<String> = vec!["iter".into(), "theta0".into(), "theta1".into(), "bias".into()];
    headers.extend((0..n).map(|i| format!("z{i}")));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut rep = Report::new("Fig 2 trajectories", &hrefs);

    let mut flips_at_snapshot = (0usize, 0usize);
    for it in 0..iters {
        mh.step(&mut pp, &mut theta, &mut rng);
        let z = pp.implicit_resample(0.2, &mut rng);
        if it == 3 {
            // the paper's top panel: the t=3 -> t=4 transition
            flips_at_snapshot = (z.brightened, z.darkened);
        }
        let mut row = vec![
            it.to_string(),
            format!("{:.5}", theta[0]),
            format!("{:.5}", theta[1]),
            format!("{:.5}", theta[2]),
        ];
        row.extend((0..n).map(|i| if pp.bright.is_bright(i) { "1".to_string() } else { "0".to_string() }));
        rep.row(&row);
    }
    rep.write_csv("target/bench_fig2_toy.csv").unwrap();
    println!("wrote target/bench_fig2_toy.csv ({iters} iterations, {n} data points)");
    println!(
        "t=3 -> t=4 transition: {} dark->bright, {} bright->dark (paper shows one bright point going dark)",
        flips_at_snapshot.0, flips_at_snapshot.1
    );
    println!("final bright count: {} of {n}", pp.n_bright());
}
