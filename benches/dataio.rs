//! Data-plane macro-benchmark: dense in-RAM vs `.fbin` block-cached reads.
//!
//! Measures what the `DataStore` layer costs (and saves) per likelihood
//! evaluation on the logistic task:
//!
//! * batched random-subset `BatchEval::eval` (the FlyMC bright-set access
//!   pattern) through the serial CPU backend, dense vs block-cached at two
//!   cache budgets — reporting ns/row and the measured cache hit rate from
//!   the new `metrics` counters;
//! * a sequential full pass (the `init_z` / `rebuild_stats` pattern);
//! * a short FlyMC chain dense vs block with a deliberately tiny cache,
//!   **asserting byte-identity** of the θ/logpost traces (the out-of-core
//!   smoke gate CI runs via `--smoke`).
//!
//! Emits `BENCH_dataio.json` so the data-plane trajectory is tracked across
//! PRs next to `BENCH_hotpath.json`.
//!
//!     cargo bench --bench dataio             # full sizes
//!     cargo bench --bench dataio -- --smoke  # CI smoke mode

use std::sync::Arc;

use firefly::bench_harness::{fmt_time, Report};
use firefly::cli::Args;
use firefly::configx::{Algorithm, ExperimentConfig, Task};
use firefly::data::fbin::{open_fbin, write_fbin};
use firefly::data::store::BlockCacheConfig;
use firefly::data::{AnyData, LogisticData};
use firefly::engine::{run_experiment, synth_dataset};
use firefly::metrics::Counters;
use firefly::models::{LogisticJJ, ModelBound};
use firefly::runtime::{BatchEval, CpuBackend};
use firefly::util::{Rng, Timer};

struct IoStats {
    label: String,
    ns_per_row_random: f64,
    ns_per_row_sequential: f64,
    hit_rate: f64,
}

fn bench_store(label: &str, data: Arc<LogisticData>, n: usize, reps: usize) -> IoStats {
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
    let counters = Counters::new();
    let mut cpu = CpuBackend::new(model.clone(), counters.clone());
    let theta = vec![0.1; model.dim()];
    let mut rng = Rng::new(17);
    // FlyMC-shaped access: a "bright set" of 500 scattered rows, re-drawn
    // occasionally (brightness churn), evaluated repeatedly
    let mut idx: Vec<u32> = (0..500).map(|_| rng.below(n) as u32).collect();
    let (mut ll, mut lb) = (Vec::new(), Vec::new());
    cpu.eval(&theta, &idx, &mut ll, &mut lb); // warm
    counters.reset();
    let timer = Timer::start();
    for rep in 0..reps {
        if rep % 10 == 9 {
            for v in idx.iter_mut().step_by(20) {
                *v = rng.below(n) as u32;
            }
        }
        cpu.eval(&theta, &idx, &mut ll, &mut lb);
        std::hint::black_box(&ll);
    }
    let random_secs = timer.elapsed_secs();
    let rows_touched = (reps * idx.len()) as f64;
    let (hits, misses) = (counters.data_cache_hits(), counters.data_cache_misses());
    let hit_rate = if hits + misses == 0 {
        1.0 // dense: every read is a direct borrow
    } else {
        hits as f64 / (hits + misses) as f64
    };

    // sequential full pass (init_z shape)
    let all: Vec<u32> = (0..n as u32).collect();
    cpu.eval(&theta, &all, &mut ll, &mut lb); // warm
    let seq_reps = (reps / 10).max(1);
    let timer = Timer::start();
    for _ in 0..seq_reps {
        cpu.eval(&theta, &all, &mut ll, &mut lb);
        std::hint::black_box(&ll);
    }
    let seq_secs = timer.elapsed_secs();

    IoStats {
        label: label.to_string(),
        ns_per_row_random: random_secs / rows_touched * 1e9,
        ns_per_row_sequential: seq_secs / (seq_reps * n) as f64 * 1e9,
        hit_rate,
    }
}

/// Short dense-vs-block chains through the real engine; panics unless the
/// traces are byte-identical (the acceptance criterion CI smoke enforces).
/// Writes its own `.fbin` from the exact dataset the dense run synthesizes
/// (same task/n/seed), as `integration_store.rs` does.
fn verify_trace_identity(n: usize, iters: usize, cache_rows: usize) {
    let mut cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(n),
        iters,
        burnin: iters / 4,
        record_every: 0,
        seed: 3,
        ..Default::default()
    };
    let path = std::env::temp_dir()
        .join(format!("firefly_dataio_verify_{}.fbin", std::process::id()))
        .to_string_lossy()
        .into_owned();
    write_fbin(&path, &synth_dataset(cfg.task, n, cfg.seed)).expect("write verify fbin");
    let dense = run_experiment(&cfg).expect("dense run");
    cfg.data_path = Some(path.clone());
    cfg.cache_rows = cache_rows;
    let block = run_experiment(&cfg).expect("block run");
    let (d, b) = (&dense.chains[0], &block.chains[0]);
    assert_eq!(d.queries_per_iter, b.queries_per_iter, "query accounting drifted");
    for (i, (x, y)) in d.logpost_joint.iter().zip(&b.logpost_joint).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "logpost differs at iter {i}");
    }
    for i in 0..d.theta_trace.n_rows() {
        for (x, y) in d.theta_trace.row(i).iter().zip(b.theta_trace.row(i)) {
            assert_eq!(x.to_bits(), y.to_bits(), "theta differs at row {i}");
        }
    }
    println!(
        "trace identity: dense vs block (cache {cache_rows} rows < N={n}) byte-identical \
         over {iters} iterations"
    );
    let _ = std::fs::remove_file(path);
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 0);
    let n = args.get_usize("n", if smoke { 4_000 } else { 50_000 });
    let reps = if smoke { 40 } else { 400 };

    let data = match synth_dataset(Task::LogisticMnist, n, seed) {
        AnyData::Logistic(dd) => dd,
        _ => unreachable!(),
    };
    let d = data.d();
    println!("dataio bench: logistic N={n} D={d}{}", if smoke { " (smoke)" } else { "" });
    let path = std::env::temp_dir()
        .join(format!("firefly_dataio_{}.fbin", std::process::id()))
        .to_string_lossy()
        .into_owned();
    write_fbin(&path, &AnyData::Logistic(data.clone())).expect("write fbin");

    let open_block = |budget: usize| -> Arc<LogisticData> {
        match open_fbin(&path, BlockCacheConfig::with_budget(budget)).expect("open fbin") {
            AnyData::Logistic(dd) => Arc::new(dd),
            _ => unreachable!(),
        }
    };

    let configs: Vec<(String, Arc<LogisticData>)> = vec![
        ("dense".to_string(), Arc::new(data)),
        (format!("block cache {} rows (25% of N)", n / 4), open_block(n / 4)),
        (format!("block cache {} rows (5% of N)", n / 20), open_block(n / 20)),
    ];

    let mut report = Report::new(
        "DataStore read cost (logistic, CPU backend)",
        &["store", "random eval ns/row", "sequential ns/row", "cache hit rate"],
    );
    let mut rows = Vec::new();
    for (label, dd) in configs {
        let s = bench_store(&label, dd, n, reps);
        report.row(&[
            s.label.clone(),
            fmt_time(s.ns_per_row_random * 1e-9),
            fmt_time(s.ns_per_row_sequential * 1e-9),
            format!("{:.3}", s.hit_rate),
        ]);
        rows.push(s);
    }
    report.print();

    // correctness gate: tiny cache, real chain, byte-identical traces
    verify_trace_identity(
        if smoke { 1_000 } else { 4_000 },
        if smoke { 120 } else { 400 },
        if smoke { 64 } else { 256 },
    );

    // JSON trajectory point (no serde in the offline build).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"dataio\",\n");
    json.push_str(&format!(
        "  \"smoke\": {smoke},\n  \"n\": {n}, \"d\": {d}, \"reps\": {reps},\n"
    ));
    json.push_str("  \"trace_identity_dense_vs_block\": true,\n  \"stores\": [\n");
    for (i, s) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"store\": \"{}\", \"random_ns_per_row\": {:.2}, \
             \"sequential_ns_per_row\": {:.2}, \"cache_hit_rate\": {:.4}}}{}\n",
            s.label,
            s.ns_per_row_random,
            s.ns_per_row_sequential,
            s.hit_rate,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_dataio.json", &json).expect("write BENCH_dataio.json");
    println!("wrote BENCH_dataio.json");
    let _ = std::fs::remove_file(path);
}
