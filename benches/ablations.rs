//! Ablations over the design choices DESIGN.md calls out (§3.2 of the paper):
//!   1. q_{d→b} sweep — mixing vs likelihood queries trade-off
//!   2. untuned ξ sweep — bound tightness vs bright fraction
//!   3. explicit (Alg 1) vs implicit (Alg 2) z-resampling at equal query cost
//!   4. XLA bucket padding overhead vs bright-set size
//!
//!     cargo bench --bench ablations [-- --n 4000 --iters 500]

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 4000);
    let iters = args.get_usize("iters", 500);
    let burnin = iters / 4;

    // --- 1. q_{d->b} sweep (MAP-tuned FlyMC) --------------------------------
    let mut rep = Report::new(
        "Ablation: q_dark_to_bright sweep (MAP-tuned, MNIST-like)",
        &["q_db", "queries/iter", "avg bright M", "ESS/1000", "ESS per 1k queries"],
    );
    for q in [0.001, 0.005, 0.01, 0.05, 0.1, 0.5] {
        let cfg = ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm: Algorithm::MapTunedFlyMc,
            n_data: Some(n),
            iters,
            burnin,
            q_dark_to_bright: Some(q),
            record_every: 0,
            map_steps: 200,
            ..Default::default()
        };
        let row = run_experiment(&cfg).expect("run").table_row();
        rep.row(&[
            format!("{q}"),
            format!("{:.1}", row.avg_lik_queries_per_iter),
            format!("{:.1}", row.avg_bright),
            format!("{:.2}", row.ess_per_1000),
            format!("{:.3}", 1000.0 * row.efficiency()),
        ]);
    }
    rep.print();
    rep.write_csv("target/bench_ablation_qdb.csv").unwrap();

    // --- 2. untuned xi sweep ------------------------------------------------
    let mut rep = Report::new(
        "Ablation: untuned JJ xi sweep (bound tightness vs bright fraction)",
        &["xi", "queries/iter", "avg bright M", "M / N"],
    );
    for xi in [0.5, 1.0, 1.5, 2.5, 4.0] {
        let cfg = ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm: Algorithm::UntunedFlyMc,
            n_data: Some(n),
            iters,
            burnin,
            untuned_xi: xi,
            record_every: 0,
            ..Default::default()
        };
        let row = run_experiment(&cfg).expect("run").table_row();
        rep.row(&[
            format!("{xi}"),
            format!("{:.1}", row.avg_lik_queries_per_iter),
            format!("{:.1}", row.avg_bright),
            format!("{:.3}", row.avg_bright / n as f64),
        ]);
    }
    rep.print();
    rep.write_csv("target/bench_ablation_xi.csv").unwrap();

    // --- 3. explicit vs implicit z-resampling -------------------------------
    let mut rep = Report::new(
        "Ablation: explicit (Alg 1) vs implicit (Alg 2) z-resampling",
        &["scheme", "param", "queries/iter", "ESS/1000", "ESS per 1k queries"],
    );
    for (explicit, param) in [
        (false, 0.01),
        (false, 0.1),
        (true, 0.05),
        (true, 0.1),
        (true, 0.3),
    ] {
        let cfg = ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm: Algorithm::MapTunedFlyMc,
            n_data: Some(n),
            iters,
            burnin,
            explicit_resample: explicit,
            resample_fraction: param,
            q_dark_to_bright: Some(param),
            record_every: 0,
            map_steps: 200,
            ..Default::default()
        };
        let row = run_experiment(&cfg).expect("run").table_row();
        rep.row(&[
            (if explicit { "explicit" } else { "implicit" }).into(),
            format!("{param}"),
            format!("{:.1}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.ess_per_1000),
            format!("{:.3}", 1000.0 * row.efficiency()),
        ]);
    }
    rep.print();
    rep.write_csv("target/bench_ablation_resampling.csv").unwrap();

    // --- 4. XLA bucket padding overhead -------------------------------------
    if cfg!(feature = "xla") && std::path::Path::new("artifacts/manifest.txt").exists() {
        use firefly::data::synth;
        use firefly::metrics::Counters;
        use firefly::models::LogisticJJ;
        use firefly::runtime::{BatchEval, XlaBackend};
        use std::sync::Arc;

        let data = Arc::new(synth::synth_mnist(20_000, 50, 1));
        let model = Arc::new(LogisticJJ::new(data, 1.5));
        let counters = Counters::new();
        let mut xla = XlaBackend::new(model.clone(), counters.clone(), "artifacts").unwrap();
        let theta = vec![0.05; model.dim()];
        let mut rep = Report::new(
            "Ablation: XLA bucketed execution (padding + chunking overhead)",
            &["batch", "bucket used", "padded lanes", "execs", "time/call (us)"],
        );
        for &bs in &[10usize, 200, 256, 1000, 2048, 5000, 20000] {
            let idx: Vec<u32> = (0..bs as u32).collect();
            let (mut ll, mut lb) = (Vec::new(), Vec::new());
            counters.reset();
            let reps = 20;
            let t = firefly::util::Timer::start();
            for _ in 0..reps {
                xla.eval(&theta, &idx, &mut ll, &mut lb);
            }
            let us = t.elapsed_secs() * 1e6 / reps as f64;
            let padded = counters.padded_lanes() / reps;
            let execs = counters.xla_executions() / reps;
            let bucket = if bs <= 256 { 256 } else if bs <= 2048 { 2048 } else { 16384 };
            rep.row(&[
                bs.to_string(),
                bucket.to_string(),
                padded.to_string(),
                execs.to_string(),
                format!("{us:.1}"),
            ]);
        }
        rep.print();
        rep.write_csv("target/bench_ablation_buckets.csv").unwrap();
    } else {
        println!("(skipping XLA bucket ablation: run `make artifacts`)");
    }
}
