//! Distributed-backend macro-benchmark + serial-identity probe.
//!
//! Two jobs, mirroring the contract in DESIGN.md §Distribution:
//!
//! * **identity probe** — runs the same FlyMC chain through the serial CPU
//!   backend and the distributed backend at 1, 2, and 4 in-process workers
//!   and compares θ-traces, joint log-posteriors, acceptances, z-flips,
//!   and per-iteration query counts byte-for-byte. The result lands in
//!   `BENCH_dist.json` as `dist_identity` and the bench-gate fails on
//!   anything but `true`.
//! * **scaling point** — times the bright-set eval pattern through
//!   `DistBackend` at each worker count, reporting secs/iter, queries/iter
//!   (which must not vary with the worker count — the gate checks), and
//!   wire bytes/iter from the transport's own `WireStats`.
//!
//!     cargo bench --bench dist             # full sizes
//!     cargo bench --bench dist -- --smoke  # CI smoke mode
//!
//! The workers here are spawned in-process threads on loopback sockets —
//! same wire protocol and reduction path as the multi-process deployment,
//! so the identity probe covers the real coordinator code.

use std::sync::Arc;

use firefly::bench_harness::{fmt_time, Report};
use firefly::cli::Args;
use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::data::AnyData;
use firefly::engine::{run_experiment, synth_dataset};
use firefly::metrics::Counters;
use firefly::models::{LogisticJJ, ModelBound};
use firefly::runtime::{BatchEval, DistBackend, DistOptions};
use firefly::util::{Rng, Timer};

struct DistPoint {
    workers: usize,
    secs_per_iter: f64,
    queries_per_iter: f64,
    wire_bytes_per_iter: f64,
}

/// Bright-set-shaped eval loop through `DistBackend`. The batch sequence
/// is seeded identically for every worker count, so queries/iter must come
/// out bitwise equal — the gate holds us to that.
fn bench_workers(workers: usize, n: usize, seed: u64, reps: usize) -> DistPoint {
    let data = synth_dataset(Task::LogisticMnist, n, seed);
    let model: Arc<dyn ModelBound> = match data {
        AnyData::Logistic(dd) => Arc::new(LogisticJJ::new(Arc::new(dd), 1.5)),
        _ => unreachable!(),
    };
    let counters = Counters::new();
    let opts = DistOptions { workers, ..DistOptions::default() };
    let mut dist = DistBackend::new(model.clone(), counters.clone(), &opts).expect("dist backend");
    let theta = vec![0.1; model.dim()];
    let mut rng = Rng::new(17);
    let mut idx: Vec<u32> = (0..(n / 8).max(16)).map(|_| rng.below(n) as u32).collect();
    let (mut ll, mut lb) = (Vec::new(), Vec::new());
    dist.eval(&theta, &idx, &mut ll, &mut lb); // warm: connections + caches
    counters.reset();
    let base_sent = opts.wire.bytes_sent();
    let base_recv = opts.wire.bytes_received();
    let timer = Timer::start();
    for rep in 0..reps {
        if rep % 10 == 9 {
            // brightness churn: re-draw a twentieth of the bright set
            for v in idx.iter_mut().step_by(20) {
                *v = rng.below(n) as u32;
            }
        }
        dist.eval(&theta, &idx, &mut ll, &mut lb);
        std::hint::black_box(&ll);
    }
    let secs = timer.elapsed_secs();
    let wire_bytes =
        (opts.wire.bytes_sent() - base_sent) + (opts.wire.bytes_received() - base_recv);
    DistPoint {
        workers,
        secs_per_iter: secs / reps as f64,
        queries_per_iter: counters.lik_queries() as f64 / reps as f64,
        wire_bytes_per_iter: wire_bytes as f64 / reps as f64,
    }
}

/// Full-engine probe: the distributed chain must be byte-identical to the
/// serial CPU chain — θ-trace, logposts, acceptances, z-flips, queries.
fn chain_identity(workers: usize, n: usize, iters: usize) -> bool {
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm: Algorithm::MapTunedFlyMc,
        n_data: Some(n),
        iters,
        burnin: iters / 4,
        record_every: 0,
        seed: 5,
        ..Default::default()
    };
    let serial = run_experiment(&cfg).expect("serial run");
    let dist_cfg =
        ExperimentConfig { backend: Backend::Dist, dist_workers: workers, ..cfg.clone() };
    let dist = run_experiment(&dist_cfg).expect("dist run");
    let (s, d) = (&serial.chains[0], &dist.chains[0]);
    let mut ok = true;
    if s.queries_per_iter != d.queries_per_iter {
        eprintln!("dist workers={workers}: queries_per_iter series diverged");
        ok = false;
    }
    if (s.accepted, s.z_brightened, s.z_darkened) != (d.accepted, d.z_brightened, d.z_darkened)
    {
        eprintln!("dist workers={workers}: acceptance / z-flip totals diverged");
        ok = false;
    }
    for (i, (x, y)) in s.logpost_joint.iter().zip(&d.logpost_joint).enumerate() {
        if x.to_bits() != y.to_bits() {
            eprintln!("dist workers={workers}: logpost differs at iter {i}");
            ok = false;
            break;
        }
    }
    for i in 0..s.theta_trace.n_rows() {
        if s.theta_trace
            .row(i)
            .iter()
            .zip(d.theta_trace.row(i))
            .any(|(x, y)| x.to_bits() != y.to_bits())
        {
            eprintln!("dist workers={workers}: theta differs at trace row {i}");
            ok = false;
            break;
        }
    }
    if ok {
        println!(
            "identity: serial vs {workers}-worker dist byte-identical over {iters} iterations"
        );
    }
    ok
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 0);
    let n = args.get_usize("n", if smoke { 2_000 } else { 20_000 });
    let reps = if smoke { 60 } else { 400 };
    let iters = if smoke { 120 } else { 400 };
    println!("dist bench: logistic N={n}{}", if smoke { " (smoke)" } else { "" });

    let worker_counts = [1usize, 2, 4];
    let mut identity = true;
    for &w in &worker_counts {
        identity &= chain_identity(w, if smoke { 800 } else { 4_000 }, iters);
    }

    let mut report = Report::new(
        "DistBackend eval cost (logistic, loopback workers)",
        &["workers", "secs/iter", "queries/iter", "wire KiB/iter"],
    );
    let mut points = Vec::new();
    for &w in &worker_counts {
        let p = bench_workers(w, n, seed, reps);
        report.row(&[
            p.workers.to_string(),
            fmt_time(p.secs_per_iter),
            format!("{:.3}", p.queries_per_iter),
            format!("{:.1}", p.wire_bytes_per_iter / 1024.0),
        ]);
        points.push(p);
    }
    report.print();

    // queries/iter must be layout-independent; fail fast here too so the
    // bench never writes a JSON the gate would have to catch
    for p in &points[1..] {
        assert_eq!(
            p.queries_per_iter.to_bits(),
            points[0].queries_per_iter.to_bits(),
            "queries/iter varied with worker count"
        );
    }

    // JSON trajectory point (no serde in the offline build).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"dist\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n  \"n\": {n}, \"reps\": {reps},\n"));
    json.push_str(&format!("  \"dist_identity\": {identity},\n  \"worker_counts\": [\n"));
    for (i, p) in points.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"workers\": {}, \"secs_per_iter\": {:.6e}, \"queries_per_iter\": {:.3}, \
             \"wire_bytes_per_iter\": {:.1}}}{}\n",
            p.workers,
            p.secs_per_iter,
            p.queries_per_iter,
            p.wire_bytes_per_iter,
            if i + 1 < points.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_dist.json", &json).expect("write BENCH_dist.json");
    println!("wrote BENCH_dist.json");
    assert!(identity, "distributed chains diverged from the serial cpu backend");
}
