//! FlyMC hot-path macro-benchmark: the per-PR perf trajectory.
//!
//! Runs regular MCMC, untuned FlyMC, and MAP-tuned FlyMC for **every paper
//! task** — logistic + random-walk MH, softmax + MALA (the gradient path),
//! robust + slice — on the serial CPU backend with a hand-rolled chain
//! loop, and reports, per steady-state iteration (measured *after*
//! warm-up):
//!
//! * wallclock seconds,
//! * likelihood queries (the paper's cost unit),
//! * heap allocations (via a counting global allocator; every FlyMC row
//!   must report 0 — the invariant the `integration_hotpath*` test
//!   binaries enforce, now including the gradient path),
//!
//! plus a re-anchor section — both FlyMC algorithms re-run with an online
//! bound re-anchor at the warm-up boundary (DESIGN.md §Bound-management),
//! reporting the post-re-anchor steady state and the summary field
//! `bright_fraction_post_reanchor` the bench gate requires —
//!
//! plus two kernel-layer sections (DESIGN.md §Kernels):
//!
//! * per-kernel ns/datum for every SoA batch kernel on both lane paths
//!   (scalar reference vs autovectorized fast path), and
//! * `kernel_identity` — short probe chains for all three tasks re-run on
//!   both paths with the θ-traces compared bit-for-bit; `cargo xtask
//!   bench-gate` fails if the field is missing or false,
//!
//! and emits `BENCH_hotpath.json` so future PRs have a trajectory to beat.
//!
//!     cargo bench --bench hotpath                # full per-task sizes
//!     cargo bench --bench hotpath -- --smoke     # CI smoke mode
//!
//! Sizes are fixed per task (the regular-MCMC baselines bound the runtime:
//! slice costs ~10·N likelihood queries per iteration), so trajectory
//! points stay comparable across PRs; `--seed`/`--map-steps` are the only
//! knobs besides `--smoke`.
//!
//! Record before/after numbers in DESIGN.md §Perf when touching the hot path.

use std::sync::Arc;

use firefly::bench_harness::{fmt_time, Report};
use firefly::cli::Args;
use firefly::engine::experiment::{build_model, build_sampler};
use firefly::flymc::{FullPosterior, PseudoPosterior};
use firefly::kernels::{set_kernel_path, KernelPath};
use firefly::metrics::Counters;
use firefly::models::ModelBound;
use firefly::prelude::*;
use firefly::runtime::{CpuBackend, XlaSource};
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::Timer;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

struct Scenario {
    task: Task,
    task_label: &'static str,
    sampler_label: &'static str,
    n: usize,
    iters: usize,
    warmup: usize,
}

struct AlgoStats {
    label: &'static str,
    wallclock_per_iter: f64,
    queries_per_iter: f64,
    allocs_per_iter: f64,
    avg_bright: f64,
}

/// Advance the chain `k` iterations: θ-step, then (FlyMC only) a z-sweep.
/// Hand-rolled rather than `run_chain` so the measured window contains
/// exactly the sampling transitions, with no trace recording.
#[allow(clippy::too_many_arguments)]
fn run_iters(
    k: usize,
    q_db: f64,
    sampler: &mut dyn Sampler,
    pseudo: &mut Option<PseudoPosterior>,
    full: &mut Option<FullPosterior>,
    theta: &mut Vec<f64>,
    rng: &mut Rng,
    bright_sum: &mut usize,
) {
    for _ in 0..k {
        if let Some(pp) = pseudo.as_mut() {
            sampler.step(pp, theta, rng);
            pp.implicit_resample(q_db, rng);
            *bright_sum += pp.n_bright();
        } else if let Some(fp) = full.as_mut() {
            sampler.step(fp, theta, rng);
        }
    }
}

fn run_algo(scenario: &Scenario, algorithm: Algorithm, seed: u64, map_steps: usize) -> AlgoStats {
    let cfg = ExperimentConfig {
        task: scenario.task,
        algorithm,
        n_data: Some(scenario.n),
        record_every: 0,
        map_steps,
        seed,
        ..Default::default()
    };
    let (source, prior, _map, _tuning_queries) = build_model(&cfg).expect("build model");
    let model: Arc<dyn ModelBound> = source.as_model_bound();
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(seed ^ 0x1217);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let q_db = cfg.effective_q_db();
    let flymc = algorithm != Algorithm::RegularMcmc;

    let mut theta = theta0.clone();
    // the paper's sampler for the task, from the same builder the engine
    // uses — one source of truth for sampler choice and tuning
    let mut sampler = build_sampler(scenario.task);
    let mut pseudo: Option<PseudoPosterior> = None;
    let mut full: Option<FullPosterior> = None;
    if flymc {
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
        pp.init_z(&mut rng);
        pseudo = Some(pp);
    } else {
        full = Some(FullPosterior::new(model, prior, eval, theta0));
    }

    let (iters, warmup) = (scenario.iters, scenario.warmup);
    let mut bright_sum: usize = 0;
    run_iters(
        warmup,
        q_db,
        &mut *sampler,
        &mut pseudo,
        &mut full,
        &mut theta,
        &mut rng,
        &mut bright_sum,
    );
    sampler.freeze_adaptation();
    bright_sum = 0;

    let allocs_before = ALLOC.allocations();
    let queries_before = counters.lik_queries();
    let timer = Timer::start();
    run_iters(
        iters,
        q_db,
        &mut *sampler,
        &mut pseudo,
        &mut full,
        &mut theta,
        &mut rng,
        &mut bright_sum,
    );
    let secs = timer.elapsed_secs();
    let queries = counters.lik_queries() - queries_before;
    let allocs = ALLOC.allocations() - allocs_before;

    AlgoStats {
        label: algorithm.label(),
        wallclock_per_iter: secs / iters as f64,
        queries_per_iter: queries as f64 / iters as f64,
        allocs_per_iter: allocs as f64 / iters as f64,
        avg_bright: if flymc { bright_sum as f64 / iters as f64 } else { f64::NAN },
    }
}

/// FlyMC chain with an online bound re-anchor at the end of warm-up: the
/// anchor is the running posterior mean of the warm-up trajectory (the same
/// statistic `ChainState` feeds `PseudoPosterior::reanchor`). The measured
/// window is the post-re-anchor steady state, so `queries/iter` is directly
/// comparable with the one-shot rows above (same sizes, same seed) — and
/// must stay zero-alloc like every other FlyMC row.
fn run_reanchored(
    scenario: &Scenario,
    algorithm: Algorithm,
    seed: u64,
    map_steps: usize,
) -> AlgoStats {
    let cfg = ExperimentConfig {
        task: scenario.task,
        algorithm,
        n_data: Some(scenario.n),
        record_every: 0,
        map_steps,
        seed,
        ..Default::default()
    };
    let (source, prior, _map, _tuning_queries) = build_model(&cfg).expect("build model");
    let model: Arc<dyn ModelBound> = source.as_model_bound();
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(seed ^ 0x1217);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let q_db = cfg.effective_q_db();
    let mut sampler = build_sampler(scenario.task);
    let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
    pp.init_z(&mut rng);
    let mut theta = theta0;

    let mut mean = vec![0.0f64; theta.len()];
    for it in 0..scenario.warmup {
        sampler.step(&mut pp, &mut theta, &mut rng);
        pp.implicit_resample(q_db, &mut rng);
        let k = (it + 1) as f64;
        for (m, t) in mean.iter_mut().zip(&theta) {
            *m += (t - *m) / k;
        }
    }
    pp.reanchor(&mean, &mut rng);
    sampler.freeze_adaptation();

    let mut bright_sum: usize = 0;
    let allocs_before = ALLOC.allocations();
    let queries_before = counters.lik_queries();
    let timer = Timer::start();
    for _ in 0..scenario.iters {
        sampler.step(&mut pp, &mut theta, &mut rng);
        pp.implicit_resample(q_db, &mut rng);
        bright_sum += pp.n_bright();
    }
    let secs = timer.elapsed_secs();
    let queries = counters.lik_queries() - queries_before;
    let allocs = ALLOC.allocations() - allocs_before;

    AlgoStats {
        label: match algorithm {
            Algorithm::UntunedFlyMc => "untuned+reanchor",
            Algorithm::MapTunedFlyMc => "maptuned+reanchor",
            _ => unreachable!("re-anchoring is FlyMC-only"),
        },
        wallclock_per_iter: secs / scenario.iters as f64,
        queries_per_iter: queries as f64 / scenario.iters as f64,
        allocs_per_iter: allocs as f64 / scenario.iters as f64,
        avg_bright: bright_sum as f64 / scenario.iters as f64,
    }
}

const KERNEL_NAMES: [&str; 5] = [
    "log_lik_batch",
    "log_both_batch",
    "pseudo_grad_batch",
    "log_lik_grad_batch",
    "log_bound_product_batch",
];

struct KernelRow {
    model: &'static str,
    kernel: &'static str,
    scalar_ns: f64,
    fast_ns: f64,
}

/// ns/datum for `reps` repetitions of an `n_items`-point batch.
fn ns_per_datum<F: FnMut()>(reps: usize, n_items: usize, mut f: F) -> f64 {
    let timer = Timer::start();
    for _ in 0..reps {
        f();
    }
    timer.elapsed_secs() * 1e9 / (reps as f64 * n_items as f64)
}

/// Time the five batch kernels for one model on both lane paths.
fn time_batch_kernels(
    task: Task,
    model_label: &'static str,
    n: usize,
    seed: u64,
    reps: usize,
    rows: &mut Vec<KernelRow>,
) {
    let cfg = ExperimentConfig {
        task,
        algorithm: Algorithm::UntunedFlyMc,
        n_data: Some(n),
        record_every: 0,
        map_steps: 0,
        seed,
        ..Default::default()
    };
    let (source, prior, _map, _tuning_queries) = build_model(&cfg).expect("build model");
    let model: Arc<dyn ModelBound> = source.as_model_bound();
    let mut scratch = model.new_scratch();
    let mut rng = Rng::new(seed ^ 0x5eed);
    let theta = prior.sample(model.dim(), &mut rng);
    let idx: Vec<u32> = (0..n as u32).collect();
    let (mut ll, mut lb) = (vec![0.0; n], vec![0.0; n]);
    let mut grad = vec![0.0; model.dim()];
    let start = rows.len();
    for path in [KernelPath::Scalar, KernelPath::Fast] {
        set_kernel_path(path);
        let mut ns = [0.0f64; 5];
        ns[0] = ns_per_datum(reps, n, || {
            model.log_lik_batch(&theta, &idx, &mut ll, &mut scratch);
        });
        ns[1] = ns_per_datum(reps, n, || {
            model.log_both_batch(&theta, &idx, &mut ll, &mut lb, &mut scratch);
        });
        ns[2] = ns_per_datum(reps, n, || {
            grad.iter_mut().for_each(|g| *g = 0.0);
            model.pseudo_grad_batch(&theta, &idx, &mut ll, &mut lb, &mut grad, &mut scratch);
        });
        ns[3] = ns_per_datum(reps, n, || {
            grad.iter_mut().for_each(|g| *g = 0.0);
            model.log_lik_grad_batch(&theta, &idx, &mut ll, &mut grad, &mut scratch);
        });
        ns[4] = ns_per_datum(reps, n, || {
            std::hint::black_box(model.log_bound_product_batch(&theta, &idx, &mut scratch));
        });
        for (k, kernel) in KERNEL_NAMES.iter().enumerate() {
            if path == KernelPath::Scalar {
                rows.push(KernelRow {
                    model: model_label,
                    kernel,
                    scalar_ns: ns[k],
                    fast_ns: 0.0,
                });
            } else {
                rows[start + k].fast_ns = ns[k];
            }
        }
    }
    set_kernel_path(KernelPath::Fast);
}

/// One short MAP-tuned FlyMC chain; returns the θ-trace as raw f64 bits
/// (run under whatever kernel path is currently active).
fn probe_trace(task: Task, n: usize, iters: usize, seed: u64) -> Vec<u64> {
    let cfg = ExperimentConfig {
        task,
        algorithm: Algorithm::MapTunedFlyMc,
        n_data: Some(n),
        record_every: 0,
        map_steps: 30,
        seed,
        ..Default::default()
    };
    let (source, prior, _map, _tuning_queries) = build_model(&cfg).expect("build model");
    let model: Arc<dyn ModelBound> = source.as_model_bound();
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters));
    let mut rng = Rng::new(seed ^ 0x1217);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let q_db = cfg.effective_q_db();
    let mut sampler = build_sampler(task);
    let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
    pp.init_z(&mut rng);
    let mut theta = theta0;
    let mut bits = Vec::with_capacity(iters * theta.len());
    for _ in 0..iters {
        sampler.step(&mut pp, &mut theta, &mut rng);
        pp.implicit_resample(q_db, &mut rng);
        bits.extend(theta.iter().map(|v| v.to_bits()));
    }
    bits
}

/// Re-run a short probe chain for each task on the scalar and the fast
/// kernel path and compare the θ-traces bit-for-bit. This is the field
/// `cargo xtask bench-gate` refuses to pass without.
fn kernel_identity_probe(seed: u64) -> bool {
    let mut ok = true;
    for (task, label) in [
        (Task::LogisticMnist, "logistic"),
        (Task::SoftmaxCifar, "softmax"),
        (Task::RobustOpv, "robust"),
    ] {
        set_kernel_path(KernelPath::Scalar);
        let scalar = probe_trace(task, 200, 40, seed);
        set_kernel_path(KernelPath::Fast);
        let fast = probe_trace(task, 200, 40, seed);
        if scalar != fast {
            ok = false;
            println!("kernel identity FAILED: {label} scalar vs fast θ-traces diverge");
        }
    }
    set_kernel_path(KernelPath::Fast);
    ok
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let seed = args.get_u64("seed", 0);
    let map_steps = args.get_usize("map-steps", if smoke { 60 } else { 400 });

    // Per-task sizes: regular MCMC pays N (slice: ~10·N) likelihood queries
    // per iteration, so the softmax/robust baselines bound the runtime.
    // Deliberately NOT overridable per run — fixed sizes keep the JSON
    // trajectory comparable across PRs.
    let scenarios = [
        Scenario {
            task: Task::LogisticMnist,
            task_label: "logistic",
            sampler_label: "rwmh",
            n: if smoke { 400 } else { 5000 },
            iters: if smoke { 150 } else { 2000 },
            warmup: if smoke { 50 } else { 500 },
        },
        Scenario {
            task: Task::SoftmaxCifar,
            task_label: "softmax",
            sampler_label: "mala",
            n: if smoke { 240 } else { 1500 },
            iters: if smoke { 60 } else { 500 },
            warmup: if smoke { 20 } else { 150 },
        },
        Scenario {
            task: Task::RobustOpv,
            task_label: "robust",
            sampler_label: "slice",
            n: if smoke { 400 } else { 2000 },
            iters: if smoke { 60 } else { 500 },
            warmup: if smoke { 20 } else { 150 },
        },
    ];

    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"hotpath\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str("  \"scenarios\": [\n");
    let mut fly_allocs = 0.0f64;

    for (si, scenario) in scenarios.iter().enumerate() {
        println!(
            "hotpath bench: {} + {} N={}, {} warmup + {} measured iterations{}",
            scenario.task_label,
            scenario.sampler_label,
            scenario.n,
            scenario.warmup,
            scenario.iters,
            if smoke { " (smoke)" } else { "" }
        );
        let mut report = Report::new(
            &format!(
                "FlyMC hot path ({} + {}, N={})",
                scenario.task_label, scenario.sampler_label, scenario.n
            ),
            &["algorithm", "wallclock/iter", "queries/iter", "allocs/iter", "avg bright"],
        );
        let mut results = Vec::new();
        for algorithm in [
            Algorithm::RegularMcmc,
            Algorithm::UntunedFlyMc,
            Algorithm::MapTunedFlyMc,
        ] {
            let r = run_algo(scenario, algorithm, seed, map_steps);
            report.row(&[
                r.label.to_string(),
                fmt_time(r.wallclock_per_iter),
                format!("{:.1}", r.queries_per_iter),
                format!("{:.2}", r.allocs_per_iter),
                if r.avg_bright.is_nan() { "-".into() } else { format!("{:.1}", r.avg_bright) },
            ]);
            if algorithm != Algorithm::RegularMcmc {
                fly_allocs += r.allocs_per_iter;
            }
            results.push(r);
        }
        report.print();

        // JSON trajectory point (no serde in the offline build).
        json.push_str(&format!(
            "    {{\"task\": \"{}\", \"sampler\": \"{}\", \"n\": {}, \
             \"warmup_iters\": {}, \"measured_iters\": {},\n     \"algorithms\": [\n",
            scenario.task_label, scenario.sampler_label, scenario.n, scenario.warmup,
            scenario.iters,
        ));
        for (i, r) in results.iter().enumerate() {
            json.push_str(&format!(
                "      {{\"algorithm\": \"{}\", \"wallclock_per_iter_secs\": {:e}, \
                 \"queries_per_iter\": {:.3}, \"allocs_per_iter\": {:.3}, \"avg_bright\": {}}}{}\n",
                r.label,
                r.wallclock_per_iter,
                r.queries_per_iter,
                r.allocs_per_iter,
                if r.avg_bright.is_nan() {
                    "null".to_string()
                } else {
                    format!("{:.2}", r.avg_bright)
                },
                if i + 1 < results.len() { "," } else { "" },
            ));
        }
        json.push_str(&format!(
            "     ]}}{}\n",
            if si + 1 < scenarios.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");

    // -- online bound re-anchoring ----------------------------------------
    // The two FlyMC algorithms again, now with a re-anchor at the running
    // posterior mean at the warm-up boundary. `queries/iter` is the
    // post-re-anchor steady state: for the untuned (mis-anchored) chain it
    // must drop strictly below the one-shot untuned row above, and for the
    // MAP-tuned chain it must not exceed the one-shot MAP-tuned row.
    // `cargo xtask bench-gate` refuses a BENCH_hotpath.json without the
    // summary field `bright_fraction_post_reanchor`.
    json.push_str("  \"reanchor\": [\n");
    let mut bright_fracs: Vec<f64> = Vec::new();
    for (si, scenario) in scenarios.iter().enumerate() {
        let mut report = Report::new(
            &format!(
                "FlyMC + re-anchor ({} + {}, N={})",
                scenario.task_label, scenario.sampler_label, scenario.n
            ),
            &["algorithm", "wallclock/iter", "queries/iter", "allocs/iter", "avg bright"],
        );
        let mut results = Vec::new();
        for algorithm in [Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc] {
            let r = run_reanchored(scenario, algorithm, seed, map_steps);
            report.row(&[
                r.label.to_string(),
                fmt_time(r.wallclock_per_iter),
                format!("{:.1}", r.queries_per_iter),
                format!("{:.2}", r.allocs_per_iter),
                format!("{:.1}", r.avg_bright),
            ]);
            fly_allocs += r.allocs_per_iter;
            bright_fracs.push(r.avg_bright / scenario.n as f64);
            results.push(r);
        }
        report.print();
        json.push_str(&format!(
            "    {{\"task\": \"{}\", \"sampler\": \"{}\", \"n\": {}, \
             \"warmup_iters\": {}, \"measured_iters\": {},\n     \"algorithms\": [\n",
            scenario.task_label, scenario.sampler_label, scenario.n, scenario.warmup,
            scenario.iters,
        ));
        for (i, r) in results.iter().enumerate() {
            json.push_str(&format!(
                "      {{\"algorithm\": \"{}\", \"wallclock_per_iter_secs\": {:e}, \
                 \"queries_per_iter\": {:.3}, \"allocs_per_iter\": {:.3}, \
                 \"avg_bright\": {:.2}}}{}\n",
                r.label,
                r.wallclock_per_iter,
                r.queries_per_iter,
                r.allocs_per_iter,
                r.avg_bright,
                if i + 1 < results.len() { "," } else { "" },
            ));
        }
        json.push_str(&format!(
            "     ]}}{}\n",
            if si + 1 < scenarios.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    let bright_fraction_post_reanchor =
        bright_fracs.iter().sum::<f64>() / bright_fracs.len() as f64;
    json.push_str(&format!(
        "  \"bright_fraction_post_reanchor\": {bright_fraction_post_reanchor:.4},\n"
    ));
    println!(
        "bright fraction post-re-anchor (mean over FlyMC rows): {:.4}",
        bright_fraction_post_reanchor
    );

    // -- per-kernel ns/datum on both lane paths ---------------------------
    let reps = if smoke { 5 } else { 50 };
    let kernel_n = if smoke { 400 } else { 4000 };
    let mut rows = Vec::new();
    for (task, label) in [
        (Task::LogisticMnist, "logistic"),
        (Task::SoftmaxCifar, "softmax"),
        (Task::RobustOpv, "robust"),
    ] {
        time_batch_kernels(task, label, kernel_n, seed, reps, &mut rows);
    }
    let mut kreport = Report::new(
        &format!("SoA batch kernels, ns/datum (N={kernel_n}, {reps} reps)"),
        &["model/kernel", "scalar", "fast", "fast/scalar"],
    );
    json.push_str("  \"kernels\": [\n");
    for (i, r) in rows.iter().enumerate() {
        kreport.row(&[
            format!("{}/{}", r.model, r.kernel),
            format!("{:.1}", r.scalar_ns),
            format!("{:.1}", r.fast_ns),
            format!("{:.2}", r.fast_ns / r.scalar_ns),
        ]);
        json.push_str(&format!(
            "    {{\"model\": \"{}\", \"kernel\": \"{}\", \"scalar_ns_per_datum\": {:.2}, \
             \"fast_ns_per_datum\": {:.2}}}{}\n",
            r.model,
            r.kernel,
            r.scalar_ns,
            r.fast_ns,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ],\n");
    kreport.print();

    // -- scalar vs fast full-trace identity (bench-gate enforced) ---------
    let identity = kernel_identity_probe(seed);
    println!(
        "kernel identity (scalar vs fast θ-traces, 3 tasks): {}",
        if identity { "OK" } else { "FAILED" }
    );
    json.push_str(&format!("  \"kernel_identity\": {identity}\n"));
    json.push_str("}\n");
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");

    if fly_allocs > 0.0 {
        println!(
            "WARNING: a FlyMC hot path allocated ({fly_allocs:.2} allocs/iter summed over \
             scenarios) — the zero-alloc invariant regressed (see DESIGN.md §Perf)"
        );
    }
}
