//! FlyMC hot-path macro-benchmark: the first perf trajectory point.
//!
//! Runs regular MCMC, untuned FlyMC, and MAP-tuned FlyMC on the logistic
//! task over the serial CPU backend with a hand-rolled chain loop, and
//! reports — per steady-state iteration, measured *after* warm-up —
//!
//! * wallclock seconds,
//! * likelihood queries (the paper's cost unit),
//! * heap allocations (via a counting global allocator; the FlyMC hot path
//!   must report 0 — the invariant `rust/tests/integration_hotpath.rs`
//!   enforces),
//!
//! and emits `BENCH_hotpath.json` so future PRs have a trajectory to beat.
//!
//!     cargo bench --bench hotpath [-- --n 5000 --iters 2000 --warmup 500]
//!     cargo bench --bench hotpath -- --smoke     # CI smoke mode
//!
//! Record before/after numbers in DESIGN.md §Perf when touching the hot path.

use std::sync::Arc;

use firefly::bench_harness::{fmt_time, Report};
use firefly::cli::Args;
use firefly::engine::experiment::build_model;
use firefly::flymc::{FullPosterior, PseudoPosterior};
use firefly::metrics::Counters;
use firefly::models::ModelBound;
use firefly::prelude::*;
use firefly::runtime::{CpuBackend, XlaSource};
use firefly::util::alloc_count::CountingAlloc;
use firefly::util::Timer;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

struct AlgoStats {
    label: &'static str,
    wallclock_per_iter: f64,
    queries_per_iter: f64,
    allocs_per_iter: f64,
    avg_bright: f64,
}

/// Advance the chain `k` iterations: θ-step, then (FlyMC only) a z-sweep.
/// Hand-rolled rather than `run_chain` so the measured window contains
/// exactly the sampling transitions, with no trace recording.
#[allow(clippy::too_many_arguments)]
fn run_iters(
    k: usize,
    q_db: f64,
    mh: &mut RandomWalkMh,
    pseudo: &mut Option<PseudoPosterior>,
    full: &mut Option<FullPosterior>,
    theta: &mut Vec<f64>,
    rng: &mut Rng,
    bright_sum: &mut usize,
) {
    for _ in 0..k {
        if let Some(pp) = pseudo.as_mut() {
            mh.step(pp, theta, rng);
            pp.implicit_resample(q_db, rng);
            *bright_sum += pp.n_bright();
        } else if let Some(fp) = full.as_mut() {
            mh.step(fp, theta, rng);
        }
    }
}

fn run_algo(
    algorithm: Algorithm,
    n: usize,
    warmup: usize,
    iters: usize,
    seed: u64,
) -> AlgoStats {
    let cfg = ExperimentConfig {
        task: Task::LogisticMnist,
        algorithm,
        n_data: Some(n),
        record_every: 0,
        seed,
        ..Default::default()
    };
    let (source, prior, _map, _tuning_queries) = build_model(&cfg);
    let model: Arc<dyn ModelBound> = source.as_model_bound();
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(seed ^ 0x1217);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let q_db = cfg.effective_q_db();
    let flymc = algorithm != Algorithm::RegularMcmc;

    let mut theta = theta0.clone();
    let mut mh = RandomWalkMh::adaptive(0.05);
    let mut pseudo: Option<PseudoPosterior> = None;
    let mut full: Option<FullPosterior> = None;
    if flymc {
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
        pp.init_z(&mut rng);
        pseudo = Some(pp);
    } else {
        full = Some(FullPosterior::new(model, prior, eval, theta0));
    }

    let mut bright_sum: usize = 0;
    run_iters(warmup, q_db, &mut mh, &mut pseudo, &mut full, &mut theta, &mut rng, &mut bright_sum);
    mh.freeze_adaptation();
    bright_sum = 0;

    let allocs_before = ALLOC.allocations();
    let queries_before = counters.lik_queries();
    let timer = Timer::start();
    run_iters(iters, q_db, &mut mh, &mut pseudo, &mut full, &mut theta, &mut rng, &mut bright_sum);
    let secs = timer.elapsed_secs();
    let queries = counters.lik_queries() - queries_before;
    let allocs = ALLOC.allocations() - allocs_before;

    AlgoStats {
        label: algorithm.label(),
        wallclock_per_iter: secs / iters as f64,
        queries_per_iter: queries as f64 / iters as f64,
        allocs_per_iter: allocs as f64 / iters as f64,
        avg_bright: if flymc { bright_sum as f64 / iters as f64 } else { f64::NAN },
    }
}

fn main() {
    let args = Args::from_env();
    let smoke = args.has("smoke");
    let n = args.get_usize("n", if smoke { 400 } else { 5000 });
    let iters = args.get_usize("iters", if smoke { 150 } else { 2000 });
    let warmup = args.get_usize("warmup", if smoke { 50 } else { 500 });
    let seed = args.get_u64("seed", 0);

    println!(
        "hotpath bench: logistic N={n}, {warmup} warmup + {iters} measured iterations{}",
        if smoke { " (smoke)" } else { "" }
    );

    let mut report = Report::new(
        &format!("FlyMC hot path (logistic, N={n})"),
        &["algorithm", "wallclock/iter", "queries/iter", "allocs/iter", "avg bright"],
    );
    let mut results = Vec::new();
    for algorithm in [
        Algorithm::RegularMcmc,
        Algorithm::UntunedFlyMc,
        Algorithm::MapTunedFlyMc,
    ] {
        let r = run_algo(algorithm, n, warmup, iters, seed);
        report.row(&[
            r.label.to_string(),
            fmt_time(r.wallclock_per_iter),
            format!("{:.1}", r.queries_per_iter),
            format!("{:.2}", r.allocs_per_iter),
            if r.avg_bright.is_nan() { "-".into() } else { format!("{:.1}", r.avg_bright) },
        ]);
        results.push(r);
    }
    report.print();

    // JSON trajectory point (no serde in the offline build: hand-formatted).
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"hotpath\",\n  \"task\": \"logistic\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"warmup_iters\": {warmup},\n  \"measured_iters\": {iters},\n  \"smoke\": {smoke},\n"
    ));
    json.push_str("  \"algorithms\": [\n");
    for (i, r) in results.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"algorithm\": \"{}\", \"wallclock_per_iter_secs\": {:e}, \
             \"queries_per_iter\": {:.3}, \"allocs_per_iter\": {:.3}, \"avg_bright\": {}}}{}\n",
            r.label,
            r.wallclock_per_iter,
            r.queries_per_iter,
            r.allocs_per_iter,
            if r.avg_bright.is_nan() { "null".to_string() } else { format!("{:.2}", r.avg_bright) },
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_hotpath.json", &json).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");

    let fly_allocs: f64 = results[1].allocs_per_iter + results[2].allocs_per_iter;
    if fly_allocs > 0.0 {
        println!(
            "WARNING: FlyMC hot path allocated ({fly_allocs:.2} allocs/iter) — \
             the zero-alloc invariant regressed (see DESIGN.md §Perf)"
        );
    }
}
