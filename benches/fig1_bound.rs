//! Regenerates Fig 1: a single logistic-regression likelihood L_n(θ) split
//! into the Jaakkola–Jordan lower bound B_n(θ) (blue region in the paper)
//! and the remainder L_n - B_n (orange), over a θ grid, plus the implied
//! Bernoulli p(z=1 | θ) from the bottom panel.
//!
//!     cargo bench --bench fig1_bound [-- --xi 1.5]

use firefly::bench_harness::{ascii_plot, Report};
use firefly::cli::Args;
use firefly::models::logistic::jj_coeffs;
use firefly::util::math::log_sigmoid;

fn main() {
    let args = Args::from_env();
    let xi = args.get_f64("xi", 1.5);
    let (a, b, c) = jj_coeffs(xi);

    let mut rep = Report::new(
        &format!("Fig 1 data (xi = {xi})"),
        &["s", "likelihood", "bound", "remainder", "p_bright"],
    );
    let mut lik = Vec::new();
    let mut bound = Vec::new();
    let mut p_bright = Vec::new();
    let steps = 160;
    for i in 0..=steps {
        let s = -8.0 + 16.0 * i as f64 / steps as f64;
        let ll = log_sigmoid(s);
        let lb = (a * s * s + b * s + c).min(ll);
        let l = ll.exp();
        let bv = lb.exp();
        lik.push(l);
        bound.push(bv);
        p_bright.push(1.0 - bv / l);
        rep.row(&[
            format!("{s:.3}"),
            format!("{l:.6}"),
            format!("{bv:.6}"),
            format!("{:.6}", l - bv),
            format!("{:.6}", 1.0 - bv / l),
        ]);
    }
    rep.write_csv("target/bench_fig1_bound.csv").unwrap();
    println!("wrote target/bench_fig1_bound.csv");

    ascii_plot(
        "Fig 1 top: likelihood vs JJ bound (tight at s = ±xi)",
        &[("L(s)", &lik), ("B(s)", &bound)],
        72,
        14,
    );
    ascii_plot("Fig 1 bottom: p(z=1 | theta)", &[("p_bright", &p_bright)], 72, 10);

    // the paper's quantitative claim for xi = 1.5
    let mut max_p: f64 = 0.0;
    for i in 0..=steps {
        let s = -8.0 + 16.0 * i as f64 / steps as f64;
        let ll = log_sigmoid(s);
        let l = ll.exp();
        if l > 0.1 && l < 0.9 {
            let lb = (a * s * s + b * s + c).min(ll);
            max_p = max_p.max(1.0 - (lb - ll).exp());
        }
    }
    println!(
        "\nmax p(bright) in the region 0.1 < L < 0.9 with xi=1.5: {max_p:.4} (paper: < 0.02)"
    );
}
