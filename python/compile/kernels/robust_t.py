"""L1 Pallas kernel: fused student-t log-likelihood + tangent Gaussian bound.

For the OPV robust-regression experiment.  Per bright datum n with residual
r = y_n - x_n @ theta and u = r^2:

    llik = C(nu, sigma) - (nu+1)/2 log(1 + u / (nu sigma^2))
    lbnd = f(u0_n) + f'(u0_n) (u - u0_n)        (tangent in u at u0_n)

f is convex in u so the tangent is a global lower bound — as a function of r
it is a scaled Gaussian, hence collapsible via weighted second moments
(DESIGN.md, bounds::tmatch).  u0_n = 0 untuned, (y_n - x_n @ theta_MAP)^2
MAP-tuned.

interpret=True for CPU-PJRT execution; see logistic_jj.py for rationale.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _kernel(theta_ref, x_ref, y_ref, u0_ref, mask_ref, ll_ref, lb_ref, *, nu, sigma, logc):
    theta = theta_ref[...]  # [D]
    x = x_ref[...]  # [Bb, D]
    y = y_ref[...]  # [Bb]
    u0 = u0_ref[...]  # [Bb]
    mask = mask_ref[...]  # [Bb]

    r = y - x @ theta
    u = r * r
    c2 = nu * sigma * sigma
    ll = logc - (nu + 1.0) / 2.0 * jnp.log1p(u / c2)
    f0 = logc - (nu + 1.0) / 2.0 * jnp.log1p(u0 / c2)
    fp0 = -(nu + 1.0) / 2.0 / (c2 + u0)
    lb = f0 + fp0 * (u - u0)
    lb = jnp.minimum(lb, ll)  # guard the tangent point against fp epsilon

    ll_ref[...] = ll * mask
    lb_ref[...] = lb * mask


@functools.partial(jax.jit, static_argnames=("nu", "sigma", "block_b"))
def eval_batch(theta, x, y, u0, mask, *, nu=4.0, sigma=1.0, block_b=DEFAULT_BLOCK_B):
    """Fused (log L_n, log B_n) for student-t + tangent bound over a batch.

    theta: [D]; x: [B, D]; y, u0, mask: [B].  nu, sigma are compile-time
    constants (baked into the artifact).  Returns (loglik [B], logbound [B]).
    """
    b, d = x.shape
    assert b % block_b == 0, (b, block_b)
    logc = (
        math.lgamma((nu + 1.0) / 2.0)
        - math.lgamma(nu / 2.0)
        - 0.5 * math.log(nu * math.pi * sigma * sigma)
    )
    grid = (b // block_b,)
    spec_rows = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    spec_vec = pl.BlockSpec((block_b,), lambda i: (i,))
    spec_theta = pl.BlockSpec((d,), lambda i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct((b,), theta.dtype),
        jax.ShapeDtypeStruct((b,), theta.dtype),
    ]
    kernel = functools.partial(_kernel, nu=nu, sigma=sigma, logc=logc)
    return tuple(
        pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[spec_theta, spec_rows, spec_vec, spec_vec, spec_vec],
            out_specs=[spec_vec, spec_vec],
            out_shape=out_shape,
            interpret=True,
        )(theta, x, y, u0, mask)
    )
