"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness signal).

Every function here is the straightforward, unfused jnp implementation of the
per-datum log-likelihoods and collapsible log-lower-bounds used by Firefly
Monte Carlo (Maclaurin & Adams, 2015):

- logistic regression likelihood + Jaakkola–Jordan (1997) scaled-Gaussian bound
- softmax classification likelihood + Böhning (1992) quadratic bound
- student-t robust regression likelihood + tangent (value+gradient matching)
  scaled-Gaussian bound

The Pallas kernels in this package must match these to float64 tolerance
(pytest in python/tests/test_kernels.py), and the Rust CpuBackend re-implements
the same math (cross-checked through the HLO artifacts in rust integration
tests).
"""

import jax.numpy as jnp
from jax.scipy.special import gammaln

# ---------------------------------------------------------------------------
# Logistic regression + Jaakkola–Jordan bound
# ---------------------------------------------------------------------------


def logistic_loglik(theta, x, t):
    """Per-datum log L_n(theta) = log sigmoid(t_n * theta @ x_n).

    theta: [D], x: [B, D], t: [B] in {-1, +1}.  Returns [B].
    """
    s = t * (x @ theta)
    # log sigmoid(s) = -softplus(-s)
    return -jnp.logaddexp(0.0, -s)


def jj_coeffs(xi):
    """Jaakkola–Jordan coefficients (a, b, c) for log B(s) = a s^2 + b s + c.

    a = -tanh(xi/2) / (4 xi)  (even in xi; limit -1/8 at xi=0)
    b = 1/2
    c = -a xi^2 + xi/2 - log(e^xi + 1)   (tight at s = +/- xi)
    """
    axi = jnp.abs(xi)
    safe = jnp.maximum(axi, 1e-10)
    a = jnp.where(axi < 1e-6, -0.125 + axi**2 / 96.0, -jnp.tanh(safe / 2.0) / (4.0 * safe))
    b = 0.5
    c = -a * axi**2 + axi / 2.0 - jnp.logaddexp(0.0, axi)
    return a, b, c


def jj_logbound(theta, x, t, xi):
    """Per-datum log B_n(theta) under the JJ bound with per-datum xi. [B]."""
    s = t * (x @ theta)
    a, b, c = jj_coeffs(xi)
    return a * s**2 + b * s + c


# ---------------------------------------------------------------------------
# Softmax classification + Böhning bound
# ---------------------------------------------------------------------------


def jax_logsumexp(eta):
    m = jnp.max(eta, axis=1)
    return m + jnp.log(jnp.sum(jnp.exp(eta - m[:, None]), axis=1))


def softmax_loglik(theta, x, t):
    """Per-datum log L_n = eta_{t_n} - logsumexp(eta), eta = theta @ x_n.

    theta: [K, D], x: [B, D], t: [B] int in [0, K).  Returns [B].
    """
    eta = x @ theta.T  # [B, K]
    k = theta.shape[0]
    onehot = jnp.arange(k)[None, :] == t[:, None]
    picked = jnp.sum(jnp.where(onehot, eta, 0.0), axis=1)
    return picked - jax_logsumexp(eta)


def bohning_logbound(theta, x, t, psi):
    """Per-datum Böhning (1992) quadratic lower bound on the softmax log-lik.

    f(eta) = eta_t - lse(eta) satisfies, for A = 1/2 (I - 11^T/K):
      f(eta) >= f(psi) + g(psi)^T (eta - psi) - 1/2 (eta-psi)^T A (eta-psi)
    with g(psi) = onehot(t) - softmax(psi).  Tight at eta = psi.

    theta: [K, D], x: [B, D], t: [B], psi: [B, K] anchor logits.  Returns [B].
    """
    eta = x @ theta.T  # [B, K]
    k = theta.shape[0]
    onehot = (jnp.arange(k)[None, :] == t[:, None]).astype(eta.dtype)
    f_psi = jnp.sum(onehot * psi, axis=1) - jax_logsumexp(psi)
    g = onehot - jnp.exp(psi - jax_logsumexp(psi)[:, None])
    d = eta - psi
    quad = 0.5 * (jnp.sum(d * d, axis=1) - jnp.sum(d, axis=1) ** 2 / k)
    return f_psi + jnp.sum(g * d, axis=1) - 0.5 * quad


# ---------------------------------------------------------------------------
# Robust (student-t) regression + tangent Gaussian bound
# ---------------------------------------------------------------------------


def t_logconst(nu, sigma):
    return (
        gammaln((nu + 1.0) / 2.0)
        - gammaln(nu / 2.0)
        - 0.5 * jnp.log(nu * jnp.pi * sigma**2)
    )


def t_loglik(theta, x, y, nu, sigma):
    """Per-datum student-t log density of residual r = y - x @ theta. [B]."""
    r = y - x @ theta
    u = r * r
    return t_logconst(nu, sigma) - (nu + 1.0) / 2.0 * jnp.log1p(u / (nu * sigma**2))


def t_logbound(theta, x, y, u0, nu, sigma):
    """Tangent lower bound of the t log-density in u = r^2 at u = u0.

    f(u) = C - (nu+1)/2 log(1 + u/(nu sigma^2)) is convex in u, so the tangent
    line at u0 is a global lower bound; as a function of r it is a scaled
    Gaussian: log B = f(u0) + f'(u0) (r^2 - u0).  Tight at r^2 = u0.
    """
    r = y - x @ theta
    u = r * r
    c2 = nu * sigma**2
    f0 = t_logconst(nu, sigma) - (nu + 1.0) / 2.0 * jnp.log1p(u0 / c2)
    fp0 = -(nu + 1.0) / 2.0 / (c2 + u0)
    return f0 + fp0 * (u - u0)


# ---------------------------------------------------------------------------
# Pseudo-likelihood gradients (closed forms used by the L2 graphs)
# ---------------------------------------------------------------------------


def _bright_coeff(dll, dlb, delta):
    """d/ds [log(L - B) - log B] given dlogL/ds, dlogB/ds and delta=logB-logL.

    (L' - B')/(L - B) - B'/B with everything in log space:
      = (dll - e^delta dlb) / (1 - e^delta) - dlb
    delta <= 0; clamp away from 0 (a bright point exactly at the tangent has
    probability ~0, but padding lanes can hit it).
    """
    ed = jnp.exp(jnp.minimum(delta, -1e-12))
    return (dll - ed * dlb) / (1.0 - ed) - dlb


def logistic_pseudo_grad(theta, x, t, xi, mask):
    """grad_theta sum_n mask_n [log(L_n - B_n) - log B_n].  Returns [D]."""
    s = t * (x @ theta)
    ll = -jnp.logaddexp(0.0, -s)
    a, b, _ = jj_coeffs(xi)
    lb = jj_logbound(theta, x, t, xi)
    dll = 1.0 / (1.0 + jnp.exp(s))  # sigmoid(-s)
    dlb = 2.0 * a * s + b
    coeff = _bright_coeff(dll, dlb, lb - ll) * t * mask
    return x.T @ coeff


def softmax_pseudo_grad(theta, x, t, psi, mask):
    """grad_Theta sum_n mask_n [log(L_n - B_n) - log B_n].  Returns [K, D]."""
    eta = x @ theta.T
    k = theta.shape[0]
    onehot = (jnp.arange(k)[None, :] == t[:, None]).astype(eta.dtype)
    ll = softmax_loglik(theta, x, t)
    lb = bohning_logbound(theta, x, t, psi)
    soft = jnp.exp(eta - jax_logsumexp(eta)[:, None])
    dll = onehot - soft  # [B, K]
    g = onehot - jnp.exp(psi - jax_logsumexp(psi)[:, None])
    d = eta - psi
    # dlb/deta = g - A d, A = 1/2 (I - 11^T/K)
    dlb = g - 0.5 * (d - jnp.sum(d, axis=1, keepdims=True) / k)
    delta = (lb - ll)[:, None]
    ed = jnp.exp(jnp.minimum(delta, -1e-12))
    coeff = ((dll - ed * dlb) / (1.0 - ed) - dlb) * mask[:, None]  # [B, K]
    return coeff.T @ x


def t_pseudo_grad(theta, x, y, u0, nu, sigma, mask):
    """grad_theta sum_n mask_n [log(L_n - B_n) - log B_n].  Returns [D]."""
    r = y - x @ theta
    u = r * r
    c2 = nu * sigma**2
    ll = t_loglik(theta, x, y, nu, sigma)
    lb = t_logbound(theta, x, y, u0, nu, sigma)
    # d/dr of each log term, then chain through dr/dtheta = -x
    dll = -(nu + 1.0) * r / (c2 + u)
    dlb = -(nu + 1.0) * r / (c2 + u0)
    coeff = _bright_coeff(dll, dlb, lb - ll) * mask
    return -(x.T @ coeff)
