"""L1 Pallas kernel: fused logistic log-likelihood + Jaakkola–Jordan bound.

This is FlyMC's hot spot for the MNIST experiment: for a (padded) batch of
bright data points, compute in one pass over the feature block

    s_n  = t_n * (x_n @ theta)           -- MXU/VPU dot product
    llik = log sigmoid(s_n)              -- VPU elementwise
    lbnd = a(xi_n) s_n^2 + s_n/2 + c(xi_n)

so the coordinator gets both the likelihood and the bound for the price of a
single HBM->VMEM pass over the bright rows.  BlockSpec tiles the batch in
blocks of `block_b` rows; theta is broadcast to every block.

interpret=True: the CPU PJRT plugin cannot run Mosaic custom-calls; interpret
mode lowers to plain HLO so the same artifact runs under the Rust runtime.
TPU considerations (VMEM footprint, MXU usage) are discussed in
DESIGN.md §Hardware-adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _kernel(theta_ref, x_ref, t_ref, xi_ref, mask_ref, ll_ref, lb_ref):
    theta = theta_ref[...]  # [D]
    x = x_ref[...]  # [Bb, D]
    t = t_ref[...]  # [Bb]
    xi = xi_ref[...]  # [Bb]
    mask = mask_ref[...]  # [Bb]

    s = t * (x @ theta)  # [Bb]
    ll = -jnp.logaddexp(0.0, -s)

    axi = jnp.abs(xi)
    safe = jnp.maximum(axi, 1e-10)
    a = jnp.where(axi < 1e-6, -0.125 + axi**2 / 96.0, -jnp.tanh(safe / 2.0) / (4.0 * safe))
    c = -a * axi**2 + axi / 2.0 - jnp.logaddexp(0.0, axi)
    lb = a * s * s + 0.5 * s + c
    # The bound is tight at s = +/-xi; floating-point can land an epsilon
    # above the likelihood there, which would make log(L-B) NaN downstream.
    lb = jnp.minimum(lb, ll)

    ll_ref[...] = ll * mask
    lb_ref[...] = lb * mask


@functools.partial(jax.jit, static_argnames=("block_b",))
def eval_batch(theta, x, t, xi, mask, *, block_b=DEFAULT_BLOCK_B):
    """Fused (log L_n, log B_n) over a padded batch.

    theta: [D] f64; x: [B, D]; t, xi, mask: [B].  B must be a multiple of
    block_b.  Masked-out lanes yield 0 in both outputs.
    Returns (loglik [B], logbound [B]).
    """
    b, d = x.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    spec_rows = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    spec_vec = pl.BlockSpec((block_b,), lambda i: (i,))
    spec_theta = pl.BlockSpec((d,), lambda i: (0,))
    out_shape = [
        jax.ShapeDtypeStruct((b,), theta.dtype),
        jax.ShapeDtypeStruct((b,), theta.dtype),
    ]
    return tuple(
        pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[spec_theta, spec_rows, spec_vec, spec_vec, spec_vec],
            out_specs=[spec_vec, spec_vec],
            out_shape=out_shape,
            interpret=True,
        )(theta, x, t, xi, mask)
    )
