"""L1 Pallas kernel: fused softmax log-likelihood + Böhning (1992) bound.

For the CIFAR-3 softmax experiment.  Per bright datum n with logits
eta = Theta @ x_n (K classes):

    llik = eta_t - logsumexp(eta)
    lbnd = f(psi) + g(psi)^T (eta - psi) - 1/2 (eta-psi)^T A (eta-psi)

with A = 1/2 (I - 11^T/K) and g(psi) = onehot(t) - softmax(psi).  The anchor
logits psi_n are inputs (zeros for the untuned bound, Theta_MAP @ x_n for the
MAP-tuned bound) — everything the collapse needs is per-datum data, so this
kernel stays a pure map over rows.

interpret=True for CPU-PJRT execution; see logistic_jj.py for rationale.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _lse(eta):
    m = jnp.max(eta, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(eta - m[..., None]), axis=-1))


def _kernel(theta_ref, x_ref, onehot_ref, psi_ref, mask_ref, ll_ref, lb_ref):
    theta = theta_ref[...]  # [K, D]
    x = x_ref[...]  # [Bb, D]
    onehot = onehot_ref[...]  # [Bb, K]  (precomputed one-hot of t)
    psi = psi_ref[...]  # [Bb, K]
    mask = mask_ref[...]  # [Bb]
    k = theta.shape[0]

    eta = x @ theta.T  # [Bb, K] — the MXU matmul tile
    lse_eta = _lse(eta)
    ll = jnp.sum(onehot * eta, axis=1) - lse_eta

    lse_psi = _lse(psi)
    f_psi = jnp.sum(onehot * psi, axis=1) - lse_psi
    g = onehot - jnp.exp(psi - lse_psi[:, None])
    d = eta - psi
    quad = 0.5 * (jnp.sum(d * d, axis=1) - jnp.sum(d, axis=1) ** 2 / k)
    lb = f_psi + jnp.sum(g * d, axis=1) - 0.5 * quad
    lb = jnp.minimum(lb, ll)  # guard the tangent point against fp epsilon

    ll_ref[...] = ll * mask
    lb_ref[...] = lb * mask


@functools.partial(jax.jit, static_argnames=("block_b",))
def eval_batch(theta, x, onehot, psi, mask, *, block_b=DEFAULT_BLOCK_B):
    """Fused (log L_n, log B_n) for softmax + Böhning over a padded batch.

    theta: [K, D]; x: [B, D]; onehot: [B, K]; psi: [B, K]; mask: [B].
    Returns (loglik [B], logbound [B]).
    """
    b, d = x.shape
    k = theta.shape[0]
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    spec_rows = pl.BlockSpec((block_b, d), lambda i: (i, 0))
    spec_k = pl.BlockSpec((block_b, k), lambda i: (i, 0))
    spec_vec = pl.BlockSpec((block_b,), lambda i: (i,))
    spec_theta = pl.BlockSpec((k, d), lambda i: (0, 0))
    out_shape = [
        jax.ShapeDtypeStruct((b,), theta.dtype),
        jax.ShapeDtypeStruct((b,), theta.dtype),
    ]
    return tuple(
        pl.pallas_call(
            _kernel,
            grid=grid,
            in_specs=[spec_theta, spec_rows, spec_k, spec_k, spec_vec],
            out_specs=[spec_vec, spec_vec],
            out_shape=out_shape,
            interpret=True,
        )(theta, x, onehot, psi, mask)
    )
