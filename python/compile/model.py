"""L2: JAX evaluation graphs for the three FlyMC experiment models.

Each graph is the unit the Rust coordinator executes per MCMC step: given the
current parameters and a padded, fixed-shape batch of bright data points,
return

    (loglik [B], logbound [B], pseudo_grad [D] or [K,D], lik_grad [D] or [K,D])

where pseudo_grad = grad_theta sum_n mask_n [log(L_n - B_n) - log B_n] — the
bright-point term of the FlyMC pseudo-posterior gradient — and lik_grad =
grad_theta sum_n mask_n log L_n — the full-likelihood gradient that
regular-MCMC MALA needs. MH and slice sampling use only the first two.

The per-point (loglik, logbound) forward pass runs through the L1 Pallas
kernels (kernels/*.py); the gradient is the hand-derived closed form (checked
against jax.grad of the pure-jnp reference in python/tests/test_model.py).
Everything lowers into a single HLO module per (model, batch-bucket) —
python/compile/aot.py writes them to artifacts/.
"""

import jax
import jax.numpy as jnp

from .kernels import logistic_jj, robust_t, softmax_bohning
from .kernels.ref import jj_coeffs

jax.config.update("jax_enable_x64", True)


def _bright_coeff(dll, dlb, delta):
    """d/ds [log(L-B) - log B] from dlogL/ds, dlogB/ds, delta = logB - logL."""
    ed = jnp.exp(jnp.minimum(delta, -1e-12))
    return (dll - ed * dlb) / (1.0 - ed) - dlb


# ---------------------------------------------------------------------------
# Logistic regression + Jaakkola–Jordan
# ---------------------------------------------------------------------------


def logistic_eval(theta, x, t, xi, mask):
    """theta [D], x [B,D], t [B] (+-1), xi [B], mask [B] ->
    (loglik [B], logbound [B], pseudo_grad [D])."""
    ll, lb = logistic_jj.eval_batch(theta, x, t, xi, mask)
    s = t * (x @ theta)
    a, b, _ = jj_coeffs(xi)
    dll = 1.0 / (1.0 + jnp.exp(s))
    dlb = 2.0 * a * s + b
    # ll/lb are pre-masked; recover unmasked delta only where mask=1 (padding
    # lanes contribute 0 to the gradient through the mask factor below).
    coeff = _bright_coeff(dll, dlb, lb - ll) * t * mask
    grad = x.T @ coeff
    lik_grad = x.T @ (dll * t * mask)
    return ll, lb, grad, lik_grad


# ---------------------------------------------------------------------------
# Softmax classification + Böhning
# ---------------------------------------------------------------------------


def _lse(eta):
    m = jnp.max(eta, axis=1)
    return m + jnp.log(jnp.sum(jnp.exp(eta - m[:, None]), axis=1))


def softmax_eval(theta, x, onehot, psi, mask):
    """theta [K,D], x [B,D], onehot [B,K], psi [B,K], mask [B] ->
    (loglik [B], logbound [B], pseudo_grad [K,D])."""
    ll, lb = softmax_bohning.eval_batch(theta, x, onehot, psi, mask)
    k = theta.shape[0]
    eta = x @ theta.T
    soft = jnp.exp(eta - _lse(eta)[:, None])
    dll = onehot - soft  # [B, K]
    g = onehot - jnp.exp(psi - _lse(psi)[:, None])
    d = eta - psi
    dlb = g - 0.5 * (d - jnp.sum(d, axis=1, keepdims=True) / k)
    delta = (lb - ll)[:, None]
    ed = jnp.exp(jnp.minimum(delta, -1e-12))
    coeff = ((dll - ed * dlb) / (1.0 - ed) - dlb) * mask[:, None]
    grad = coeff.T @ x
    lik_grad = (dll * mask[:, None]).T @ x
    return ll, lb, grad, lik_grad


# ---------------------------------------------------------------------------
# Robust (student-t) regression + tangent bound
# ---------------------------------------------------------------------------


def robust_eval(theta, x, y, u0, mask, *, nu=4.0, sigma=1.0):
    """theta [D], x [B,D], y [B], u0 [B], mask [B] ->
    (loglik [B], logbound [B], pseudo_grad [D]).  nu/sigma are baked in."""
    ll, lb = robust_t.eval_batch(theta, x, y, u0, mask, nu=nu, sigma=sigma)
    r = y - x @ theta
    u = r * r
    c2 = nu * sigma * sigma
    dll = -(nu + 1.0) * r / (c2 + u)
    dlb = -(nu + 1.0) * r / (c2 + u0)
    coeff = _bright_coeff(dll, dlb, lb - ll) * mask
    grad = -(x.T @ coeff)
    lik_grad = -(x.T @ (dll * mask))
    return ll, lb, grad, lik_grad
