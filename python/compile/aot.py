"""AOT: lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

Run once at build time (`make artifacts`); Python never runs on the sampling
path.  For every (model, batch-bucket) pair in SPECS we jit-lower the L2
evaluation graph and write

    artifacts/<name>.hlo.txt      one HLO module, fixed shapes
    artifacts/manifest.txt        one line per artifact (key=value fields)

HLO text — NOT `lowered.compiler_ir().serialize()` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Batch buckets: the Rust runtime pads a variable-size bright set up to the
smallest bucket (chunking through the largest for full-data baselines), so a
handful of fixed shapes serves every bright count.

The robust artifact bakes nu=4 (paper's value) and sigma=1; the Rust runtime
reaches any sigma by feeding (x/sigma, y/sigma, u0/sigma^2) and shifting the
returned log-densities by -log(sigma) (exact — see runtime/backend.rs).
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)

from . import model  # noqa: E402

F = jnp.float64

# (name, builder, example-arg shapes) — one artifact per entry.
BUCKETS = (256, 2048, 16384)


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, F)


def logistic_args(d, b):
    return [_spec((d,)), _spec((b, d)), _spec((b,)), _spec((b,)), _spec((b,))]


def softmax_args(k, d, b):
    return [_spec((k, d)), _spec((b, d)), _spec((b, k)), _spec((b, k)), _spec((b,))]


def robust_args(d, b):
    return [_spec((d,)), _spec((b, d)), _spec((b,)), _spec((b,)), _spec((b,))]


def build_specs():
    specs = []
    for b in BUCKETS:
        specs.append((f"logistic.d51.b{b}", "logistic", 51, 1, b, model.logistic_eval, logistic_args(51, b)))
    specs.append(("logistic.d3.b256", "logistic", 3, 1, 256, model.logistic_eval, logistic_args(3, 256)))
    for b in BUCKETS:
        specs.append(
            (
                f"softmax.k3.d256.b{b}",
                "softmax",
                256,
                3,
                b,
                model.softmax_eval,
                softmax_args(3, 256, b),
            )
        )
    for b in BUCKETS:
        specs.append(
            (
                f"robust.d57.b{b}",
                "robust",
                57,
                1,
                b,
                functools.partial(model.robust_eval, nu=4.0, sigma=1.0),
                robust_args(57, b),
            )
        )
    return specs


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation (return_tuple=True) -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, kind, d, k, bucket, fn, arg_specs in build_specs():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest.append(
            f"name={name} kind={kind} d={d} k={k} bucket={bucket} path={fname}"
        )
        print(f"wrote {fname}: {len(text)} chars")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
