"""L2 correctness: evaluation-graph outputs and closed-form pseudo-gradients
vs jax.grad of the pure-jnp reference pseudo-likelihood."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def _rng(seed):
    return np.random.default_rng(seed)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 40))
def test_logistic_pseudo_grad_vs_autodiff(seed, d):
    r = _rng(seed)
    b = 256
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    t = jnp.array(r.choice([-1.0, 1.0], size=b))
    xi = jnp.array(np.abs(r.normal(size=b)) + 0.05)
    mask = jnp.array((r.random(b) < 0.5).astype(np.float64))

    _, _, g, gl = model.logistic_eval(theta, x, t, xi, mask)

    def pseudo(th):
        ll = ref.logistic_loglik(th, x, t)
        lb = ref.jj_logbound(th, x, t, xi)
        return jnp.sum(mask * (ll + jnp.log1p(-jnp.exp(lb - ll)) - lb))

    ag = jax.grad(pseudo)(theta)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ag), rtol=1e-6, atol=1e-8)
    agl = jax.grad(lambda th: jnp.sum(mask * ref.logistic_loglik(th, x, t)))(theta)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(agl), rtol=1e-8, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 5), d=st.integers(2, 24))
def test_softmax_pseudo_grad_vs_autodiff(seed, k, d):
    r = _rng(seed)
    b = 256
    theta = jnp.array(r.normal(size=(k, d)))
    x = jnp.array(r.normal(size=(b, d)))
    t = r.integers(0, k, size=b)
    onehot = jnp.array(np.eye(k)[t])
    psi = jnp.array(r.normal(size=(b, k)))
    mask = jnp.array((r.random(b) < 0.5).astype(np.float64))
    tj = jnp.array(t)

    _, _, g, gl = model.softmax_eval(theta, x, onehot, psi, mask)

    def pseudo(th):
        ll = ref.softmax_loglik(th, x, tj)
        lb = ref.bohning_logbound(th, x, tj, psi)
        return jnp.sum(mask * (ll + jnp.log1p(-jnp.exp(lb - ll)) - lb))

    ag = jax.grad(pseudo)(theta)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ag), rtol=1e-6, atol=1e-8)
    agl = jax.grad(lambda th: jnp.sum(mask * ref.softmax_loglik(th, x, tj)))(theta)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(agl), rtol=1e-8, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(1, 40), sigma=st.floats(0.3, 3.0))
def test_robust_pseudo_grad_vs_autodiff(seed, d, sigma):
    r = _rng(seed)
    b = 256
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    y = jnp.array(r.standard_t(df=4, size=b) * 2.0)
    u0 = jnp.array(np.abs(r.normal(size=b)) + 0.01)
    mask = jnp.array((r.random(b) < 0.5).astype(np.float64))

    _, _, g, gl = model.robust_eval(theta, x, y, u0, mask, nu=4.0, sigma=sigma)

    def pseudo(th):
        ll = ref.t_loglik(th, x, y, 4.0, sigma)
        lb = ref.t_logbound(th, x, y, u0, 4.0, sigma)
        return jnp.sum(mask * (ll + jnp.log1p(-jnp.exp(lb - ll)) - lb))

    ag = jax.grad(pseudo)(theta)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ag), rtol=1e-6, atol=1e-8)
    agl = jax.grad(lambda th: jnp.sum(mask * ref.t_loglik(th, x, y, 4.0, sigma)))(theta)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(agl), rtol=1e-8, atol=1e-10)


def test_masked_lanes_contribute_zero_grad():
    r = _rng(0)
    d, b = 8, 256
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    t = jnp.array(r.choice([-1.0, 1.0], size=b))
    xi = jnp.ones(b)
    m1 = jnp.zeros(b).at[:10].set(1.0)
    _, _, g1, _ = model.logistic_eval(theta, x, t, xi, m1)
    # Same 10 live lanes, garbage elsewhere: gradient must be identical.
    x2 = x.at[10:].set(1e6)
    _, _, g2, _ = model.logistic_eval(theta, x2, t, xi, m1)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-12)


def test_grad_finite_even_at_tight_bound():
    """A lane where B==L exactly (tangent) must not produce NaN/inf output."""
    r = _rng(2)
    d, b = 4, 256
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    t = jnp.ones(b)
    xi = jnp.abs(x @ theta)  # tight at every point
    mask = jnp.ones(b)
    _, _, g, gl = model.logistic_eval(theta, x, t, xi, mask)
    assert bool(jnp.all(jnp.isfinite(g)))
