"""AOT pipeline: lowering produces loadable HLO text with the right interface."""

import os
import subprocess
import sys

import jax
import pytest

from compile import aot, model

jax.config.update("jax_enable_x64", True)


def test_specs_cover_all_models_and_buckets():
    specs = aot.build_specs()
    names = [s[0] for s in specs]
    for b in aot.BUCKETS:
        assert f"logistic.d51.b{b}" in names
        assert f"softmax.k3.d256.b{b}" in names
        assert f"robust.d57.b{b}" in names
    assert "logistic.d3.b256" in names


def test_lowered_hlo_text_is_parseable_module():
    lowered = jax.jit(model.logistic_eval).lower(*aot.logistic_args(3, 256))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 5 f64 params and a 3-tuple result
    assert text.count("f64[256,3]") >= 1
    assert "(f64[256]" in text or "(f64[3]" in text


def test_aot_main_writes_artifacts_and_manifest(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--only", "logistic.d3"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 1
    fields = dict(kv.split("=") for kv in manifest[0].split())
    assert fields["kind"] == "logistic"
    assert fields["d"] == "3"
    assert fields["bucket"] == "256"
    assert (out / fields["path"]).exists()
    assert "HloModule" in (out / fields["path"]).read_text()[:200]
