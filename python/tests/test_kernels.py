"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps randomize shapes, scales and masks; every test asserts
allclose between the fused kernel outputs and the reference, plus the FlyMC
invariant 0 < B_n <= L_n (in log space: lb <= ll) that the whole algorithm
rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logistic_jj, robust_t, softmax_bohning
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# logistic + Jaakkola-Jordan
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(1, 64),
    blocks=st.integers(1, 3),
    scale=st.floats(0.1, 10.0),
)
def test_logistic_kernel_matches_ref(seed, d, blocks, scale):
    r = _rng(seed)
    b = 256 * blocks
    theta = jnp.array(r.normal(size=d) * scale)
    x = jnp.array(r.normal(size=(b, d)))
    t = jnp.array(r.choice([-1.0, 1.0], size=b))
    xi = jnp.array(np.abs(r.normal(size=b)) * scale)
    mask = jnp.array((r.random(b) < 0.8).astype(np.float64))

    ll, lb = logistic_jj.eval_batch(theta, x, t, xi, mask)
    rll = ref.logistic_loglik(theta, x, t)
    rlb = jnp.minimum(ref.jj_logbound(theta, x, t, xi), rll)
    np.testing.assert_allclose(ll, rll * mask, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(lb, rlb * mask, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 20.0))
def test_jj_bound_dominated_by_likelihood(seed, scale):
    """log B_n(theta) <= log L_n(theta) for every theta, xi (JJ validity)."""
    r = _rng(seed)
    s = jnp.array(r.normal(size=512) * scale)  # s = t * theta @ x directly
    xi = jnp.array(np.abs(r.normal(size=512)) * scale)
    a, b, c = ref.jj_coeffs(xi)
    lb = a * s**2 + b * s + c
    ll = -jnp.logaddexp(0.0, -s)
    assert bool(jnp.all(lb <= ll + 1e-10))


def test_jj_bound_tight_at_xi():
    """B(s=+/-xi) = L(s=+/-xi): the tangency the MAP-tuning relies on."""
    xi = jnp.array([0.0, 0.5, 1.5, 4.0, 20.0])
    a, b, c = ref.jj_coeffs(xi)
    for sgn in (1.0, -1.0):
        s = sgn * xi
        lb = a * s**2 + b * s + c
        ll = -jnp.logaddexp(0.0, -s)
        np.testing.assert_allclose(lb, ll, rtol=1e-12, atol=1e-12)


def test_jj_xi_zero_limit():
    a, _, _ = ref.jj_coeffs(jnp.array([0.0, 1e-12, 1e-7]))
    np.testing.assert_allclose(np.asarray(a), -0.125, rtol=1e-9)


def test_logistic_mask_zeroes_padding():
    r = _rng(7)
    theta = jnp.array(r.normal(size=5))
    x = jnp.array(r.normal(size=(256, 5)))
    t = jnp.ones(256)
    xi = jnp.ones(256)
    mask = jnp.zeros(256)
    ll, lb = logistic_jj.eval_batch(theta, x, t, xi, mask)
    assert float(jnp.abs(ll).max()) == 0.0
    assert float(jnp.abs(lb).max()) == 0.0


def test_logistic_rejects_unaligned_batch():
    with pytest.raises(AssertionError):
        logistic_jj.eval_batch(
            jnp.zeros(3), jnp.zeros((100, 3)), jnp.ones(100), jnp.ones(100), jnp.ones(100)
        )


# ---------------------------------------------------------------------------
# softmax + Böhning
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(2, 48),
    k=st.integers(2, 6),
    scale=st.floats(0.1, 5.0),
)
def test_softmax_kernel_matches_ref(seed, d, k, scale):
    r = _rng(seed)
    b = 256
    theta = jnp.array(r.normal(size=(k, d)) * scale)
    x = jnp.array(r.normal(size=(b, d)))
    t = r.integers(0, k, size=b)
    onehot = jnp.array(np.eye(k)[t])
    psi = jnp.array(r.normal(size=(b, k)) * scale)
    mask = jnp.array((r.random(b) < 0.8).astype(np.float64))
    tj = jnp.array(t)

    ll, lb = softmax_bohning.eval_batch(theta, x, onehot, psi, mask)
    rll = ref.softmax_loglik(theta, x, tj)
    rlb = jnp.minimum(ref.bohning_logbound(theta, x, tj, psi), rll)
    np.testing.assert_allclose(ll, rll * mask, rtol=1e-11, atol=1e-11)
    np.testing.assert_allclose(lb, rlb * mask, rtol=1e-11, atol=1e-11)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(2, 8), scale=st.floats(0.1, 8.0))
def test_bohning_bound_dominated(seed, k, scale):
    r = _rng(seed)
    b, d = 128, 16
    theta = jnp.array(r.normal(size=(k, d)) * scale)
    x = jnp.array(r.normal(size=(b, d)))
    t = jnp.array(r.integers(0, k, size=b))
    psi = jnp.array(r.normal(size=(b, k)) * scale)
    ll = ref.softmax_loglik(theta, x, t)
    lb = ref.bohning_logbound(theta, x, t, psi)
    assert bool(jnp.all(lb <= ll + 1e-9))


def test_bohning_tight_at_anchor():
    """psi = eta  =>  B_n = L_n (value match at the anchor)."""
    r = _rng(3)
    k, d, b = 3, 10, 64
    theta = jnp.array(r.normal(size=(k, d)))
    x = jnp.array(r.normal(size=(b, d)))
    t = jnp.array(r.integers(0, k, size=b))
    psi = x @ theta.T
    ll = ref.softmax_loglik(theta, x, t)
    lb = ref.bohning_logbound(theta, x, t, psi)
    np.testing.assert_allclose(np.asarray(lb), np.asarray(ll), rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# student-t + tangent bound
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    d=st.integers(1, 64),
    nu=st.sampled_from([1.0, 2.0, 4.0, 10.0]),
    sigma=st.floats(0.2, 5.0),
)
def test_robust_kernel_matches_ref(seed, d, nu, sigma):
    r = _rng(seed)
    b = 256
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    y = jnp.array(r.standard_t(df=4, size=b) * 2.0)
    u0 = jnp.array(np.abs(r.normal(size=b)))
    mask = jnp.array((r.random(b) < 0.8).astype(np.float64))

    ll, lb = robust_t.eval_batch(theta, x, y, u0, mask, nu=nu, sigma=sigma)
    rll = ref.t_loglik(theta, x, y, nu, sigma)
    rlb = jnp.minimum(ref.t_logbound(theta, x, y, u0, nu, sigma), rll)
    np.testing.assert_allclose(ll, rll * mask, rtol=1e-11, atol=1e-12)
    np.testing.assert_allclose(lb, rlb * mask, rtol=1e-11, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), nu=st.floats(0.5, 20.0), sigma=st.floats(0.1, 5.0))
def test_t_bound_dominated(seed, nu, sigma):
    r = _rng(seed)
    b, d = 128, 8
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    y = jnp.array(r.normal(size=b) * 5.0)
    u0 = jnp.array(np.abs(r.normal(size=b)) * 4.0)
    ll = ref.t_loglik(theta, x, y, nu, sigma)
    lb = ref.t_logbound(theta, x, y, u0, nu, sigma)
    assert bool(jnp.all(lb <= ll + 1e-10))


def test_t_bound_tight_at_u0():
    """u0 = r^2  =>  B_n = L_n (tangency used by MAP tuning)."""
    r = _rng(5)
    d, b = 6, 64
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    y = jnp.array(r.normal(size=b))
    resid = y - x @ theta
    u0 = resid * resid
    ll = ref.t_loglik(theta, x, y, 4.0, 1.0)
    lb = ref.t_logbound(theta, x, y, u0, 4.0, 1.0)
    np.testing.assert_allclose(np.asarray(lb), np.asarray(ll), rtol=1e-12, atol=1e-12)


def test_t_sigma_rescale_identity():
    """sigma!=1 via input scaling against the sigma=1 artifact (runtime trick)."""
    r = _rng(11)
    d, b, sig = 9, 256, 2.5
    theta = jnp.array(r.normal(size=d))
    x = jnp.array(r.normal(size=(b, d)))
    y = jnp.array(r.normal(size=b) * 3.0)
    u0 = jnp.array(np.abs(r.normal(size=b)))
    mask = jnp.ones(b)
    ll1, lb1 = robust_t.eval_batch(theta, x / sig, y / sig, u0 / sig**2, mask, nu=4.0, sigma=1.0)
    rll = ref.t_loglik(theta, x, y, 4.0, sig)
    rlb = ref.t_logbound(theta, x, y, u0, 4.0, sig)
    np.testing.assert_allclose(ll1 - np.log(sig), rll, rtol=1e-11)
    np.testing.assert_allclose(lb1 - np.log(sig), jnp.minimum(rlb, rll), rtol=1e-11)
