//! Quickstart: FlyMC vs regular MCMC on a small logistic-regression problem,
//! in ~30 lines of library usage.
//!
//!     cargo run --release --example quickstart [-- --n 2000 --iters 1500]

use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 2000);
    let iters = args.get_usize("iters", 1500);

    println!("FlyMC quickstart: logistic regression, N={n}, {iters} iterations\n");

    let mut regular_eff = 0.0;
    for algorithm in [Algorithm::RegularMcmc, Algorithm::MapTunedFlyMc] {
        let cfg = ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm,
            n_data: Some(n),
            iters,
            burnin: iters / 4,
            record_every: 0,
            ..Default::default()
        };
        let result = run_experiment(&cfg).expect("experiment");
        let row = result.table_row();
        println!(
            "{:<18} lik queries/iter: {:>9.1}   ESS/1000 iters: {:>6.2}",
            row.algorithm, row.avg_lik_queries_per_iter, row.ess_per_1000
        );
        if algorithm == Algorithm::RegularMcmc {
            regular_eff = row.efficiency();
        } else {
            println!(
                "\nFlyMC speedup (ESS per likelihood evaluation): {:.1}x",
                row.efficiency() / regular_eff
            );
            println!(
                "average bright points: {:.1} of {} ({:.1}%)",
                row.avg_bright,
                n,
                100.0 * row.avg_bright / n as f64
            );
        }
    }
}
