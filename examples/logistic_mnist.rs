//! Paper experiment §4.1: logistic regression on the MNIST-7v9-like task
//! (N=12,214, 50 PCA-like features + bias), random-walk Metropolis–Hastings
//! tuned to 0.234 acceptance — Table 1 rows 1–3 and Fig 4a, end to end.
//!
//!     cargo run --release --example logistic_mnist -- \
//!         [--iters 2000] [--burnin 500] [--chains 5] [--backend xla] [--n 12214]
//!
//! This is the repository's END-TO-END DRIVER: it exercises data synthesis,
//! MAP tuning, bound collapse, the implicit z-resampler, the sampler,
//! diagnostics, and (with --backend xla) the full AOT artifact path, and
//! prints the paper-format rows. Results are recorded in DESIGN.md §Perf.

use firefly::bench_harness::{ascii_plot, Report};
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let base = ExperimentConfig {
        task: Task::LogisticMnist,
        n_data: Some(args.get_usize("n", 12_214)),
        iters: args.get_usize("iters", 2000),
        burnin: args.get_usize("burnin", 500),
        chains: args.get_usize("chains", 1),
        backend: Backend::parse_or_exit(&args.get_str("backend", "cpu")),
        seed: args.get_u64("seed", 0),
        record_every: args.get_usize("record-every", 10),
        ..Default::default()
    };
    println!(
        "MNIST-like logistic regression: N={}, iters={}, chains={}, backend={:?}",
        base.n_data.unwrap(),
        base.iters,
        base.chains,
        base.backend
    );

    let mut report = Report::new(
        "Table 1 (MNIST / logistic regression / Metropolis-Hastings)",
        &["Algorithm", "Avg lik queries/iter", "ESS per 1000 iters", "Speedup"],
    );
    let mut regular: Option<TableRow> = None;
    let mut traces: Vec<(String, Vec<f64>)> = Vec::new();

    for algorithm in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc] {
        let mut cfg = base.clone();
        cfg.algorithm = algorithm;
        let result = run_experiment(&cfg).expect("experiment failed");
        let row = result.table_row();
        let speedup = match &regular {
            None => {
                regular = Some(row.clone());
                "(1)".to_string()
            }
            Some(reg) => format!("{:.1}", row.speedup_vs(reg)),
        };
        println!(
            "  {:<18} queries/iter {:>9.1}  M {:>8.1}  ESS/1k {:>6.2}  wallclock {:>6.2}s  (MAP setup: {} queries)",
            row.algorithm,
            row.avg_lik_queries_per_iter,
            row.avg_bright,
            row.ess_per_1000,
            row.wallclock_secs,
            result.map_lik_queries,
        );
        report.row(&[
            row.algorithm.clone(),
            format!("{:.0}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.ess_per_1000),
            speedup,
        ]);
        traces.push((
            row.algorithm.clone(),
            result.chains[0].full_logpost.iter().map(|&(_, l)| l).collect(),
        ));
    }
    report.print();

    let series: Vec<(&str, &[f64])> = traces
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    ascii_plot(
        "Fig 4a (top): full-data log posterior vs iteration",
        &series,
        72,
        14,
    );
}
