//! Fig 2 reproduction: FlyMC on a toy 2-d (+bias) logistic regression —
//! traces of every θ component and the z bit-vector over iterations, plus a
//! snapshot of one iteration (θ move, then one bright point going dark /
//! dark going bright). Writes CSV for plotting and prints an ASCII view.
//!
//!     cargo run --release --example toy_trajectory -- [--iters 60] [--n 30]

use std::sync::Arc;

use firefly::bench_harness::{ascii_plot, Report};
use firefly::cli::Args;
use firefly::data::synth;
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
use firefly::prelude::*;
use firefly::runtime::CpuBackend;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 30);
    let iters = args.get_usize("iters", 60);
    let seed = args.get_u64("seed", 0);

    let data = Arc::new(synth::synth_toy2d(n, seed));
    let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data.clone(), 1.5));
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 2.0 });
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters));
    let mut rng = Rng::new(seed + 1);
    let theta0 = prior.sample(3, &mut rng);
    let mut pp = PseudoPosterior::new(model.clone(), prior, eval, theta0.clone());
    pp.init_z(&mut rng);
    let mut mh = RandomWalkMh::adaptive(0.3);
    let mut theta = theta0;

    let mut theta_rows: Vec<Vec<f64>> = Vec::new();
    let mut z_rows: Vec<Vec<f64>> = Vec::new();
    for it in 0..iters {
        mh.step(&mut pp, &mut theta, &mut rng);
        let z = pp.implicit_resample(0.2, &mut rng);
        theta_rows.push(theta.clone());
        z_rows.push((0..n).map(|i| if pp.bright.is_bright(i) { 1.0 } else { 0.0 }).collect());
        if it == iters / 2 {
            println!(
                "iteration t={it}: theta = [{:.2}, {:.2}, {:.2}], bright = {} of {n} (this step: +{} bright, -{} dark)",
                theta[0], theta[1], theta[2], pp.n_bright(), z.brightened, z.darkened
            );
        }
    }

    // Fig 2 bottom: trajectories of all theta components and sum(z)
    let t0: Vec<f64> = theta_rows.iter().map(|r| r[0]).collect();
    let t1: Vec<f64> = theta_rows.iter().map(|r| r[1]).collect();
    let t2: Vec<f64> = theta_rows.iter().map(|r| r[2]).collect();
    ascii_plot(
        "Fig 2 (bottom): theta trajectories",
        &[("theta0", &t0), ("theta1", &t1), ("bias", &t2)],
        70,
        12,
    );
    let zsum: Vec<f64> = z_rows.iter().map(|r| r.iter().sum()).collect();
    ascii_plot("Fig 2 (bottom): number of bright points", &[("sum z", &zsum)], 70, 8);

    // CSV outputs for real plotting
    let mut rep = Report::new("theta trace", &["iter", "theta0", "theta1", "bias", "n_bright"]);
    for (i, (r, z)) in theta_rows.iter().zip(&z_rows).enumerate() {
        rep.row(&[
            i.to_string(),
            format!("{:.6}", r[0]),
            format!("{:.6}", r[1]),
            format!("{:.6}", r[2]),
            format!("{}", z.iter().sum::<f64>() as usize),
        ]);
    }
    rep.write_csv("target/fig2_toy_trajectory.csv").expect("csv");
    println!("\nwrote target/fig2_toy_trajectory.csv");
    println!(
        "final acceptance rate: {:.3} (adapting toward 0.234)",
        mh.acceptance_rate()
    );
}
