//! Out-of-core FlyMC end to end: convert a synthetic MNIST-like workload to
//! the `.fbin` binary dataset format, then sample it through a `BlockStore`
//! whose cache budget is deliberately smaller than the dataset — the
//! steady-state working set is the O(|bright|) rows FlyMC actually touches,
//! not the O(N·D) matrix (DESIGN.md §Storage).
//!
//!     cargo run --release --example logistic_fbin -- \
//!         [--n 30000] [--cache-rows 2048] [--iters 1500] [--burnin 300] [--seed 0]
//!
//! Prints the paper's cost unit (likelihood queries/iter), the bright count
//! M, and the block-cache hit rate from the new `metrics` counters.

use std::sync::Arc;

use firefly::cli::Args;
use firefly::data::fbin::{open_fbin, write_fbin};
use firefly::data::store::BlockCacheConfig;
use firefly::data::AnyData;
use firefly::engine::{run_chain, synth_dataset, ChainConfig, ChainTarget};
use firefly::flymc::PseudoPosterior;
use firefly::map_estimate::{map_estimate, MapConfig};
use firefly::metrics::Counters;
use firefly::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
use firefly::prelude::Task;
use firefly::runtime::CpuBackend;
use firefly::util::Rng;

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("n", 30_000);
    let cache_rows = args.get_usize("cache-rows", 2_048);
    let iters = args.get_usize("iters", 1_500);
    let burnin = args.get_usize("burnin", 300);
    let seed = args.get_u64("seed", 0);
    assert!(cache_rows < n, "the point of this example is cache budget < N");

    // 1. convert: synthesize and stream to .fbin
    let path = std::env::temp_dir()
        .join(format!("firefly_example_{}.fbin", std::process::id()))
        .to_string_lossy()
        .into_owned();
    let header = write_fbin(&path, &synth_dataset(Task::LogisticMnist, n, seed))
        .expect("write .fbin");
    let file_mb = (header.n * (header.d + 1)) as f64 * 8.0 / 1e6;
    let cache_mb = (cache_rows * header.d as usize) as f64 * 8.0 / 1e6;
    println!(
        "converted: {path} (N={} D={}, {:.1} MB on disk; cache budget {cache_rows} rows \
         = {:.2} MB per reader)",
        header.n, header.d, file_mb, cache_mb
    );

    // 2. open out of core and build the MAP-tuned model
    let data = match open_fbin(&path, BlockCacheConfig::with_budget(cache_rows)).unwrap() {
        AnyData::Logistic(d) => Arc::new(d),
        other => panic!("wrong kind {}", other.kind_name()),
    };
    assert!(data.x.is_out_of_core());
    let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
    let mut raw = LogisticJJ::new(data, 1.5);
    let map = map_estimate(
        &raw,
        prior.as_ref(),
        &MapConfig { steps: 300, seed: seed ^ 0xAD, ..Default::default() },
    );
    raw.tune_anchors_map(&map.theta);
    let model: Arc<dyn ModelBound> = Arc::new(raw);

    // 3. sample — one chain, RW-MH + implicit z-resampling (paper config)
    let counters = Counters::new();
    let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
    let mut rng = Rng::new(seed ^ 0x1217);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
    pp.init_z(&mut rng);
    let cfg = ChainConfig {
        iters,
        burnin,
        record_full_every: 0,
        q_dark_to_bright: 0.01,
        seed,
        ..Default::default()
    };
    let sampler: Box<dyn firefly::samplers::Sampler> =
        Box::new(firefly::samplers::RandomWalkMh::adaptive(0.02));
    let res = run_chain(ChainTarget::FlyMc(pp), sampler, theta0, &cfg);

    // 4. report: cost + cache behaviour
    let (hits, misses) = (counters.data_cache_hits(), counters.data_cache_misses());
    let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
    println!("\n=== out-of-core FlyMC (MAP-tuned, RW-MH) ===");
    println!("iterations:               {iters} ({burnin} burn-in)");
    println!("avg lik queries / iter:   {:.1}  (N = {n})", res.avg_queries_post_burnin(burnin));
    println!("avg bright points (M):    {:.1}", res.avg_bright_post_burnin(burnin));
    println!(
        "block cache:              {} hits / {} misses (hit rate {:.1}%)",
        hits,
        misses,
        100.0 * hit_rate
    );
    println!(
        "resident features:        {:.2} MB cache vs {:.1} MB dataset",
        cache_mb, file_mb
    );
    println!("wallclock:                {:.2}s", res.wallclock_secs);
    let _ = std::fs::remove_file(path);
}
