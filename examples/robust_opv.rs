//! Paper experiment §4.3: robust (student-t, ν=4) sparse linear regression on
//! the OPV-like molecular task (paper: N=1.8M, 57 features; default here
//! 200k — scale-free in N/M, use --n 1800000 for full scale), slice sampling,
//! Laplace prior — Table 1 rows 7–9 / Fig 4c.
//!
//!     cargo run --release --example robust_opv -- \
//!         [--n 200000] [--iters 600] [--burnin 150] [--backend xla]

use firefly::bench_harness::{ascii_plot, Report};
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let base = ExperimentConfig {
        task: Task::RobustOpv,
        n_data: Some(args.get_usize("n", 200_000)),
        iters: args.get_usize("iters", 3000),
        burnin: args.get_usize("burnin", 1500),
        chains: args.get_usize("chains", 1),
        backend: Backend::parse_or_exit(&args.get_str("backend", "cpu")),
        seed: args.get_u64("seed", 0),
        record_every: args.get_usize("record-every", 25),
        map_steps: args.get_usize("map-steps", 800),
        prior_scale: Some(0.5), // Laplace b (sparsity)
        ..Default::default()
    };
    println!(
        "OPV-like robust regression: N={}, D=57, student-t(4), slice sampling, backend={:?}",
        base.n_data.unwrap(),
        base.backend
    );
    println!("(regular MCMC evaluates ALL N likelihoods several times per slice update — expect it to be slow; that is the paper's point)\n");

    let mut report = Report::new(
        "Table 1 (OPV / robust regression / slice sampling)",
        &["Algorithm", "Avg lik queries/iter", "ESS per 1000 iters", "Speedup"],
    );
    let mut regular: Option<TableRow> = None;
    let mut traces: Vec<(String, Vec<f64>)> = Vec::new();

    for algorithm in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc] {
        let mut cfg = base.clone();
        cfg.algorithm = algorithm;
        if algorithm == Algorithm::RegularMcmc {
            // full-data slice sampling at N=200k is ~10 N-sized evals/iter;
            // keep the baseline run affordable but statistically useful
            cfg.iters = cfg.iters.min(args.get_usize("regular-iters", 300));
            cfg.burnin = cfg.iters / 3;
        }
        let result = run_experiment(&cfg).expect("experiment failed");
        let row = result.table_row();
        let speedup = match &regular {
            None => {
                regular = Some(row.clone());
                "(1)".to_string()
            }
            Some(reg) => format!("{:.1}", row.speedup_vs(reg)),
        };
        println!(
            "  {:<18} queries/iter {:>12.1}  M {:>9.1}  ESS/1k {:>6.2}  wallclock {:>7.2}s",
            row.algorithm,
            row.avg_lik_queries_per_iter,
            row.avg_bright,
            row.ess_per_1000,
            row.wallclock_secs,
        );
        report.row(&[
            row.algorithm.clone(),
            format!("{:.0}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.ess_per_1000),
            speedup,
        ]);
        traces.push((
            row.algorithm.clone(),
            result.chains[0].full_logpost.iter().map(|&(_, l)| l).collect(),
        ));
    }
    report.print();

    let series: Vec<(&str, &[f64])> =
        traces.iter().map(|(n, s)| (n.as_str(), s.as_slice())).collect();
    ascii_plot("Fig 4c (top): full-data log posterior vs iteration", &series, 72, 14);
}
