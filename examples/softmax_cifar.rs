//! Paper experiment §4.2: softmax classification on the 3-class CIFAR-10-like
//! task (N=18,000, 256 binary features), Langevin-adjusted Metropolis (MALA)
//! tuned to ~0.574 acceptance, Böhning bound — Table 1 rows 4–6 / Fig 4b.
//!
//!     cargo run --release --example softmax_cifar -- \
//!         [--iters 1500] [--burnin 400] [--backend xla] [--n 18000]

use firefly::bench_harness::{ascii_plot, Report};
use firefly::cli::Args;
use firefly::prelude::*;

fn main() {
    let args = Args::from_env();
    let base = ExperimentConfig {
        task: Task::SoftmaxCifar,
        n_data: Some(args.get_usize("n", 18_000)),
        iters: args.get_usize("iters", 2500),
        burnin: args.get_usize("burnin", 1000),
        chains: args.get_usize("chains", 1),
        backend: Backend::parse_or_exit(&args.get_str("backend", "cpu")),
        seed: args.get_u64("seed", 0),
        record_every: args.get_usize("record-every", 10),
        map_steps: args.get_usize("map-steps", 600),
        ..Default::default()
    };
    println!(
        "CIFAR-3-like softmax classification: N={}, K=3, D=256, iters={}, backend={:?}",
        base.n_data.unwrap(),
        base.iters,
        base.backend
    );

    let mut report = Report::new(
        "Table 1 (3-Class CIFAR-10 / softmax / Langevin)",
        &["Algorithm", "Avg lik queries/iter", "ESS per 1000 iters", "Speedup"],
    );
    let mut regular: Option<TableRow> = None;
    let mut traces: Vec<(String, Vec<f64>)> = Vec::new();

    for algorithm in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc] {
        let mut cfg = base.clone();
        cfg.algorithm = algorithm;
        let result = run_experiment(&cfg).expect("experiment failed");
        let row = result.table_row();
        let speedup = match &regular {
            None => {
                regular = Some(row.clone());
                "(1)".to_string()
            }
            Some(reg) => format!("{:.1}", row.speedup_vs(reg)),
        };
        println!(
            "  {:<18} queries/iter {:>9.1}  M {:>8.1}  ESS/1k {:>6.2}  wallclock {:>6.2}s",
            row.algorithm,
            row.avg_lik_queries_per_iter,
            row.avg_bright,
            row.ess_per_1000,
            row.wallclock_secs,
        );
        report.row(&[
            row.algorithm.clone(),
            format!("{:.0}", row.avg_lik_queries_per_iter),
            format!("{:.2}", row.ess_per_1000),
            speedup,
        ]);
        traces.push((
            row.algorithm.clone(),
            result.chains[0].full_logpost.iter().map(|&(_, l)| l).collect(),
        ));
    }
    report.print();

    let series: Vec<(&str, &[f64])> =
        traces.iter().map(|(n, s)| (n.as_str(), s.as_slice())).collect();
    ascii_plot("Fig 4b (top): full-data log posterior vs iteration", &series, 72, 14);
}
