//! Models + collapsible lower bounds (the FlyMC requirement).
//!
//! Each concrete type pairs one of the paper's likelihoods with its bound:
//!
//! | type            | likelihood              | bound                      |
//! |-----------------|-------------------------|----------------------------|
//! | [`LogisticJJ`]  | logistic regression     | Jaakkola–Jordan (1997)     |
//! | [`SoftmaxBohning`] | softmax classification | Böhning (1992)           |
//! | [`RobustT`]     | student-t regression    | tangent scaled Gaussian    |
//!
//! All three bounds are *collapsible*: `sum_n log B_n(theta)` reduces to a
//! quadratic form in theta with sufficient statistics computed once per
//! anchor-tuning (O(N dim^2) setup, O(dim^2) per evaluation) — this is what
//! makes the FlyMC pseudo-prior O(1) in N on the sampling path.

pub mod logistic;
pub mod priors;
pub mod robust;
pub mod softmax;

pub use logistic::LogisticJJ;
pub use priors::{IsoGaussian, Laplace, Prior};
pub use robust::RobustT;
pub use softmax::SoftmaxBohning;

use crate::data::store::RowCache;

/// Which XLA artifact family a model maps to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// Logistic regression + Jaakkola–Jordan bound ([`LogisticJJ`]).
    Logistic,
    /// Softmax classification + Böhning bound ([`SoftmaxBohning`]).
    Softmax,
    /// Student-t regression + tangent bound ([`RobustT`]).
    Robust,
}

impl ModelKind {
    /// The manifest / artifact-name spelling of the kind.
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Logistic => "logistic",
            ModelKind::Softmax => "softmax",
            ModelKind::Robust => "robust",
        }
    }
}

/// Reusable scratch buffers for model evaluations, owned by the caller
/// (backends allocate one per evaluator/worker group at construction;
/// samplers and the pseudo-posterior own their own).
///
/// Every batch, per-datum, and collapsed evaluation method on
/// [`ModelBound`] takes a `&mut EvalScratch` instead of allocating
/// temporaries, which is what makes steady-state FlyMC iterations —
/// including the gradient path (MALA on softmax) — perform **zero heap
/// allocations** (DESIGN.md §Perf). The scratch also carries the SoA
/// buffers of the batch kernel layer (`tile`, `lane_eta`, `lane_dlb`;
/// DESIGN.md §Kernels). Buffer contents are unspecified on entry:
/// implementations must overwrite before reading, and callers must not
/// rely on contents across calls.
///
/// The scratch also carries the [`RowCache`] through which the model reads
/// its feature rows from the [`crate::data::store::DataStore`]: zero-sized
/// for resident data, a preallocated direct-mapped block cache for
/// out-of-core `.fbin` data (DESIGN.md §Storage). Cache state, like the
/// buffers, only affects *where* a row is served from — never its bits.
///
/// Everything is sized for the worst consumer at construction
/// ([`EvalScratch::sized`] / [`ModelBound::new_scratch`]); methods only
/// slice into the buffers and block fills reuse the cache's staging arena,
/// so no call ever allocates.
#[derive(Clone, Debug)]
pub struct EvalScratch {
    /// per-class logit buffer (softmax η), length `n_classes`
    pub(crate) eta: Vec<f64>,
    /// per-class bound-gradient buffer (softmax d log B / d η), length `n_classes`
    pub(crate) dlb: Vec<f64>,
    /// dim-sized accumulator (`A·θ` matvecs; softmax `Θ·S` rows)
    pub(crate) acc: Vec<f64>,
    /// dim-sized column buffer (softmax class-sum / column-mean vectors)
    pub(crate) col: Vec<f64>,
    /// column-major SoA feature tile for the batch kernels, `feat × W`
    /// (feat = per-class feature dimension; DESIGN.md §Kernels)
    pub(crate) tile: Vec<f64>,
    /// lane-major per-lane logits for the softmax batch kernels,
    /// `W × n_classes` (lane `l`'s η vector at `[l*K .. (l+1)*K]`)
    pub(crate) lane_eta: Vec<f64>,
    /// lane-major per-lane bound gradients d log B / d η, `W × n_classes`
    pub(crate) lane_dlb: Vec<f64>,
    /// feature-row cache for the model's `DataStore` reads (zero-sized when
    /// the store is dense)
    pub(crate) rows: RowCache,
}

impl EvalScratch {
    /// Scratch sized for a model of `dim` flattened parameters and
    /// `classes` softmax classes (1 for non-softmax models), with a
    /// zero-sized row cache (resident data). Models over an out-of-core
    /// store attach a real cache via [`EvalScratch::with_rows`].
    pub fn sized(dim: usize, classes: usize) -> Self {
        let classes = classes.max(1);
        // per-class feature dimension D (softmax flattens theta to K*D)
        let feat = dim / classes;
        EvalScratch {
            eta: vec![0.0; classes],
            dlb: vec![0.0; classes],
            acc: vec![0.0; dim],
            col: vec![0.0; dim],
            tile: vec![0.0; feat * crate::kernels::W],
            lane_eta: vec![0.0; classes * crate::kernels::W],
            lane_dlb: vec![0.0; classes * crate::kernels::W],
            rows: RowCache::empty(),
        }
    }

    /// Attach a feature-row cache (from
    /// [`crate::data::store::DataStore::new_cache`]).
    pub fn with_rows(mut self, rows: RowCache) -> Self {
        self.rows = rows;
        self
    }

    /// Drain the row cache's (hits, misses) tallies — backends flush these
    /// into [`crate::metrics::Counters`] after each batch.
    pub fn take_cache_stats(&mut self) -> (u64, u64) {
        self.rows.take_stats()
    }
}

/// A likelihood with a collapsible lower bound — everything FlyMC needs from
/// the model, per datum and collapsed.
///
/// `theta` is always the flattened parameter vector (`K*D` row-major for
/// softmax). Gradient methods *accumulate* into `grad` so callers can sum
/// over data points without temporaries.
///
/// ## Allocation contract
///
/// Every evaluation method takes a caller-owned [`EvalScratch`] (create one
/// per evaluator/thread with [`Self::new_scratch`]) and must not allocate:
/// these methods sit inside the per-datum hot loop of the
/// [`BatchEval`](crate::runtime::BatchEval) backends, and the zero-alloc
/// hot-path invariant (DESIGN.md §Perf) covers them. Only the setup methods
/// ([`Self::tune_anchors_map`] and constructors) may allocate.
pub trait ModelBound: Send + Sync {
    /// Number of data points N.
    fn n(&self) -> usize;
    /// Flattened parameter dimension (`K*D` for softmax).
    fn dim(&self) -> usize;
    /// Which XLA artifact family this model maps to.
    fn kind(&self) -> ModelKind;

    /// Number of softmax classes K (1 for non-softmax models); sizes the
    /// per-class buffers of [`Self::new_scratch`].
    fn n_classes(&self) -> usize {
        1
    }

    /// Allocate an [`EvalScratch`] sized for this model. One-time setup per
    /// evaluator/worker group; the evaluation methods then never allocate.
    /// Models whose feature store can be out-of-core MUST override this to
    /// attach a row cache (`EvalScratch::sized(..).with_rows(store.new_cache())`)
    /// — the three paper models all do.
    fn new_scratch(&self) -> EvalScratch {
        EvalScratch::sized(self.dim(), self.n_classes())
    }

    /// log L_n(theta).
    fn log_lik(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> f64;

    /// grad += d log L_n / d theta.
    fn log_lik_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    );

    /// (log L_n, log B_n), with log B clamped to log L at the tangent point
    /// (matches the L1 kernel's `min(lb, ll)` guard).
    fn log_both(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> (f64, f64);

    /// grad += d [log(L_n - B_n) - log B_n] / d theta (bright-point term of
    /// the pseudo-posterior gradient).
    fn pseudo_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    );

    /// Fused [`Self::log_both`] + [`Self::pseudo_grad_acc`] — one feature-dot
    /// pass per datum instead of two (the CPU backend's gradient hot path).
    fn log_both_pseudo_grad(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) -> (f64, f64) {
        let out = self.log_both(theta, n, scratch);
        self.pseudo_grad_acc(theta, n, grad, scratch);
        out
    }

    // --- batch API (the backends' hot path; DESIGN.md §Kernels) ---
    //
    // The defaults below are per-datum loops: the executable specification
    // of the batch semantics, and what an exotic `ModelBound` gets for
    // free. The three paper models override every one of them with the SoA
    // tile kernels in `crate::kernels` (and implement their per-datum
    // methods as batch-of-1 wrappers), which keeps likelihood/bound values
    // bit-identical to these loops while gradients fold through the
    // canonical `tree8` reduction.

    /// Batched [`Self::log_lik`] over an index batch: `ll[i] = log
    /// L_{idx[i]}(theta)`. `ll.len() == idx.len()`; caller sizes it.
    fn log_lik_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        for (i, &n) in idx.iter().enumerate() {
            ll[i] = self.log_lik(theta, n as usize, scratch);
        }
    }

    /// Batched [`Self::log_both`]: fills `ll` and `lb` (both sized
    /// `idx.len()` by the caller).
    fn log_both_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        for (i, &n) in idx.iter().enumerate() {
            let (l, b) = self.log_both(theta, n as usize, scratch);
            ll[i] = l;
            lb[i] = b;
        }
    }

    /// Batched [`Self::log_both_pseudo_grad`]: fills `ll`/`lb` and
    /// accumulates the bright-point pseudo-posterior gradient over the
    /// whole batch into `grad`.
    fn pseudo_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        for (i, &n) in idx.iter().enumerate() {
            let (l, b) = self.log_both_pseudo_grad(theta, n as usize, grad, scratch);
            ll[i] = l;
            lb[i] = b;
        }
    }

    /// Batched [`Self::log_lik`] + [`Self::log_lik_grad_acc`]: fills `ll`
    /// and accumulates the likelihood gradient over the batch into `grad`.
    fn log_lik_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        for (i, &n) in idx.iter().enumerate() {
            ll[i] = self.log_lik(theta, n as usize, scratch);
            self.log_lik_grad_acc(theta, n as usize, grad, scratch);
        }
    }

    /// Batched [`Self::log_both`] + per-datum pseudo-gradient **product
    /// rows**: fills `ll`/`lb` exactly as [`Self::pseudo_grad_batch`] does
    /// and writes datum `i`'s raw gradient products into
    /// `rows[i * dim .. (i+1) * dim]` instead of folding them into a
    /// summed `grad`. The products must be the exact single multiplies the
    /// batch fold would perform (for softmax: component `kk·d + j` holds
    /// `coeff_kk · x[j]`), so that folding the rows through
    /// [`crate::kernels::fold_grad_rows`] in batch order reproduces
    /// [`Self::pseudo_grad_batch`]'s `grad` bit-for-bit — the contract the
    /// distributed backend's shard workers serve (DESIGN.md
    /// §Distribution). This per-datum default accumulates each row with
    /// [`Self::pseudo_grad_acc`] (spec-equivalent; the paper models
    /// override with the exact rows kernels).
    fn pseudo_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let dim = self.dim();
        debug_assert_eq!(rows.len(), idx.len() * dim);
        for (i, &n) in idx.iter().enumerate() {
            let seg = &mut rows[i * dim..(i + 1) * dim];
            seg.fill(0.0);
            let (l, b) = self.log_both_pseudo_grad(theta, n as usize, seg, scratch);
            ll[i] = l;
            lb[i] = b;
        }
    }

    /// Batched [`Self::log_lik`] + per-datum likelihood-gradient **product
    /// rows** — the `eval_lik_grad` companion of
    /// [`Self::pseudo_grad_rows_batch`], same row contract.
    fn log_lik_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let dim = self.dim();
        debug_assert_eq!(rows.len(), idx.len() * dim);
        for (i, &n) in idx.iter().enumerate() {
            let seg = &mut rows[i * dim..(i + 1) * dim];
            seg.fill(0.0);
            self.log_lik_grad_acc(theta, n as usize, seg, scratch);
            ll[i] = self.log_lik(theta, n as usize, scratch);
        }
    }

    /// A self-contained copy of this model restricted to data rows
    /// `start..end`: shard-local features, labels, **and per-datum bound
    /// parameters** (anchors are per-datum functions of the anchor θ and
    /// the datum, so slicing them is bit-identical to re-tuning the shard
    /// against the same anchor). Worker `n()` is `end - start` and indices
    /// are shard-local. `None` means the model does not support sharding;
    /// the three paper models all do. Setup-time; allocates. Used by the
    /// distributed backend's in-process worker mode (DESIGN.md
    /// §Distribution).
    fn shard_model(&self, start: usize, end: usize) -> Option<std::sync::Arc<dyn ModelBound>> {
        let _ = (start, end);
        None
    }

    /// `sum_i log B_{idx[i]}(theta)` over an explicit index batch (clamped
    /// bounds, as in [`Self::log_both`]) — the per-subset companion of the
    /// collapsed [`Self::log_bound_product`], agreeing with it to rounding
    /// when `idx` covers `0..N` and no clamp engages.
    fn log_bound_product_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        scratch: &mut EvalScratch,
    ) -> f64 {
        let mut acc = 0.0;
        for &n in idx {
            acc += self.log_both(theta, n as usize, scratch).1;
        }
        acc
    }

    /// Collapsed `sum_n log B_n(theta)` — O(dim^2), independent of N.
    fn log_bound_product(&self, theta: &[f64], scratch: &mut EvalScratch) -> f64;

    /// grad += d log_bound_product / d theta.
    fn grad_log_bound_product_acc(
        &self,
        theta: &[f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    );

    /// Batched [`Self::log_lik_grad_batch`] with a stronger contract: `ll`
    /// and `grad` are **bit-identical** to running the per-datum
    /// `log_lik` / `log_lik_grad_acc` pair over `idx` in order. The generic
    /// batch kernels fold gradients through the cross-lane `tree8` tree
    /// (different bits for multi-lane tiles); this entry point keeps the
    /// per-datum accumulation *order* while still gathering SoA tiles and
    /// computing values through the canonical `dot_lanes` contract — it is
    /// what lets `map_estimate` batch its minibatch pass without perturbing
    /// a single anchor bit (DESIGN.md §Bound-management). `ll` is cleared
    /// and refilled to `idx.len()`; `grad` accumulates.
    fn log_lik_grad_ordered_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        ll.clear();
        for &n in idx {
            self.log_lik_grad_acc(theta, n as usize, grad, scratch);
            ll.push(self.log_lik(theta, n as usize, scratch));
        }
    }

    /// Re-anchor the bounds to be tight at `theta_map` (paper §4: MAP-tuned)
    /// and rebuild the sufficient statistics. Setup-time; may allocate.
    fn tune_anchors_map(&mut self, theta_map: &[f64]);

    /// The θ the bounds were last anchored at ([`Self::tune_anchors_map`]),
    /// or `None` if the model still carries its construction-time (untuned)
    /// anchors. Lets the online re-anchoring layer detect a bitwise no-op
    /// (requested anchor == current anchor) and skip the restart entirely,
    /// preserving trace byte-identity.
    fn anchor_theta(&self) -> Option<&[f64]> {
        None
    }

    /// A copy of this model with its bounds re-anchored at `anchor`
    /// (equivalent to clone + [`Self::tune_anchors_map`]). `None` means the
    /// model does not support online re-anchoring; the three paper models
    /// all do. Setup-time; allocates. Returning a fresh `Arc` (instead of
    /// mutating in place) is what keeps re-anchoring sound while evaluators
    /// and the pseudo-posterior share the model behind `Arc<dyn ModelBound>`
    /// — the old bounds stay frozen for anyone still holding them.
    fn clone_reanchored(&self, anchor: &[f64]) -> Option<std::sync::Arc<dyn ModelBound>> {
        let _ = anchor;
        None
    }

    /// The collapsed bound as an explicit quadratic form
    /// `theta^T A theta + b^T theta + c` (A row-major dim×dim), when the
    /// model's collapse has that shape. Lets `PseudoPosterior` cache a fused
    /// packed lower-triangular layout for its base density
    /// ([`crate::linalg::PackedQuadForm`]); `None` (softmax, whose collapse
    /// factors through S and v instead) falls back to
    /// [`Self::log_bound_product`]. The returned statistics must stay valid
    /// until the next [`Self::tune_anchors_map`] — callers behind `Arc` can
    /// never observe a rebuild.
    fn collapsed_quadratic(&self) -> Option<(&crate::linalg::Matrix, &[f64], f64)> {
        None
    }
}

/// d/ds [log(L-B) - log B] from dlogL/ds, dlogB/ds and delta = logB - logL.
/// Mirrors `_bright_coeff` in python/compile/model.py (same clamp).
#[inline]
pub(crate) fn bright_coeff(dll: f64, dlb: f64, delta: f64) -> f64 {
    let ed = delta.min(-1e-12).exp();
    (dll - ed * dlb) / (1.0 - ed) - dlb
}

/// log( (L-B)/B ) = log L-tilde, the pseudo-likelihood of a bright point,
/// from (log L, log B). Guards delta=0 like `bright_coeff`.
#[inline]
pub fn log_pseudo_lik(ll: f64, lb: f64) -> f64 {
    // log(e^ll - e^lb) - lb = ll + log1mexp(lb - ll) - lb
    let delta = (lb - ll).min(-1e-12);
    ll + crate::util::math::log1mexp(delta) - lb
}

/// Exact brightness conditional `p(z=1 | theta) = 1 - B/L` from
/// (log L, log B), computed as `-expm1(lb - ll)`.
///
/// The naive `1.0 - (lb - ll).exp()` cancels catastrophically for tight
/// (MAP-tuned) bounds: at `lb - ll = -1e-15` it returns a value with no
/// correct digits, while `exp_m1` keeps full relative precision. Used by
/// `init_z` and the explicit Gibbs z-resampler, which draw Bernoulli(p)
/// directly from this conditional.
///
/// ```
/// use firefly::models::p_bright;
///
/// // moderately loose bound: agrees with the direct 1 - B/L
/// assert!((p_bright(-0.2, -1.4) - (1.0 - (-1.2f64).exp())).abs() < 1e-14);
///
/// // tight (MAP-tuned) bound: full relative precision where 1 - exp(..)
/// // would cancel to garbage
/// let (ll, lb) = (-0.5, -0.5 + -1e-15);
/// let delta = lb - ll; // the representable gap
/// let p = p_bright(ll, lb);
/// assert!(p > 0.0);
/// assert!(((p - (-delta)) / -delta).abs() < 1e-9);
/// ```
#[inline]
pub fn p_bright(ll: f64, lb: f64) -> f64 {
    -(lb - ll).exp_m1()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bright_coeff_matches_direct_formula() {
        // compare against the direct (L'-B')/(L-B) - B'/B with exp arithmetic
        let (ll, lb) = (-0.3f64, -0.9f64);
        let (dll, dlb) = (0.4f64, 0.25f64);
        let (l, b) = (ll.exp(), lb.exp());
        let direct = (l * dll - b * dlb) / (l - b) - dlb;
        let ours = bright_coeff(dll, dlb, lb - ll);
        assert!((direct - ours).abs() < 1e-12, "{direct} vs {ours}");
    }

    #[test]
    fn log_pseudo_lik_matches_direct() {
        let (ll, lb) = (-0.2f64, -1.4f64);
        let direct = ((ll.exp() - lb.exp()) / lb.exp()).ln();
        assert!((log_pseudo_lik(ll, lb) - direct).abs() < 1e-12);
    }

    #[test]
    fn log_pseudo_lik_finite_at_tight_bound() {
        let v = log_pseudo_lik(-0.5, -0.5);
        assert!(v.is_finite());
        assert!(v < -20.0); // essentially "never bright"
    }

    #[test]
    fn p_bright_matches_direct_formula_at_moderate_gaps() {
        for &(ll, lb) in &[(-0.2f64, -1.4f64), (-3.0, -3.7), (-0.01, -0.02)] {
            let direct = 1.0 - (lb - ll).exp();
            let ours = p_bright(ll, lb);
            assert!((direct - ours).abs() < 1e-14, "{direct} vs {ours}");
        }
    }

    #[test]
    fn p_bright_keeps_precision_for_tight_bounds() {
        // For delta = lb - ll -> 0-, p = 1 - e^delta = -delta + O(delta^2).
        // The naive form loses all significant digits below ~1e-16; exp_m1
        // keeps full relative precision.
        for &delta in &[-1e-10f64, -1e-13, -1e-15] {
            let (ll, lb) = (-0.5, -0.5 + delta);
            let p = p_bright(ll, lb);
            assert!(p > 0.0, "delta={delta}: p={p}");
            let rel = (p - (-delta)).abs() / (-delta);
            assert!(rel < 1e-9, "delta={delta}: p={p}, rel err {rel}");
        }
        // exactly tight bound: p must be exactly 0, never negative
        assert_eq!(p_bright(-0.5, -0.5), 0.0);
    }
}
