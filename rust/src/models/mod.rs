//! Models + collapsible lower bounds (the FlyMC requirement).
//!
//! Each concrete type pairs one of the paper's likelihoods with its bound:
//!
//! | type            | likelihood              | bound                      |
//! |-----------------|-------------------------|----------------------------|
//! | [`LogisticJJ`]  | logistic regression     | Jaakkola–Jordan (1997)     |
//! | [`SoftmaxBohning`] | softmax classification | Böhning (1992)           |
//! | [`RobustT`]     | student-t regression    | tangent scaled Gaussian    |
//!
//! All three bounds are *collapsible*: `sum_n log B_n(theta)` reduces to a
//! quadratic form in theta with sufficient statistics computed once per
//! anchor-tuning (O(N dim^2) setup, O(dim^2) per evaluation) — this is what
//! makes the FlyMC pseudo-prior O(1) in N on the sampling path.

pub mod logistic;
pub mod priors;
pub mod robust;
pub mod softmax;

pub use logistic::LogisticJJ;
pub use priors::{IsoGaussian, Laplace, Prior};
pub use robust::RobustT;
pub use softmax::SoftmaxBohning;

/// Which XLA artifact family a model maps to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    Logistic,
    Softmax,
    Robust,
}

impl ModelKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelKind::Logistic => "logistic",
            ModelKind::Softmax => "softmax",
            ModelKind::Robust => "robust",
        }
    }
}

/// A likelihood with a collapsible lower bound — everything FlyMC needs from
/// the model, per datum and collapsed.
///
/// `theta` is always the flattened parameter vector (`K*D` row-major for
/// softmax). Gradient methods *accumulate* into `grad` so callers can sum
/// over data points without temporaries.
pub trait ModelBound: Send + Sync {
    fn n(&self) -> usize;
    fn dim(&self) -> usize;
    fn kind(&self) -> ModelKind;

    /// log L_n(theta).
    fn log_lik(&self, theta: &[f64], n: usize) -> f64;

    /// grad += d log L_n / d theta.
    fn log_lik_grad_acc(&self, theta: &[f64], n: usize, grad: &mut [f64]);

    /// (log L_n, log B_n), with log B clamped to log L at the tangent point
    /// (matches the L1 kernel's `min(lb, ll)` guard).
    fn log_both(&self, theta: &[f64], n: usize) -> (f64, f64);

    /// grad += d [log(L_n - B_n) - log B_n] / d theta (bright-point term of
    /// the pseudo-posterior gradient).
    fn pseudo_grad_acc(&self, theta: &[f64], n: usize, grad: &mut [f64]);

    /// Fused [`Self::log_both`] + [`Self::pseudo_grad_acc`] — one feature-dot
    /// pass per datum instead of two (the CPU backend's gradient hot path).
    fn log_both_pseudo_grad(&self, theta: &[f64], n: usize, grad: &mut [f64]) -> (f64, f64) {
        let out = self.log_both(theta, n);
        self.pseudo_grad_acc(theta, n, grad);
        out
    }

    /// Collapsed `sum_n log B_n(theta)` — O(dim^2), independent of N.
    fn log_bound_product(&self, theta: &[f64]) -> f64;

    /// grad += d log_bound_product / d theta.
    fn grad_log_bound_product_acc(&self, theta: &[f64], grad: &mut [f64]);

    /// Re-anchor the bounds to be tight at `theta_map` (paper §4: MAP-tuned)
    /// and rebuild the sufficient statistics.
    fn tune_anchors_map(&mut self, theta_map: &[f64]);
}

/// d/ds [log(L-B) - log B] from dlogL/ds, dlogB/ds and delta = logB - logL.
/// Mirrors `_bright_coeff` in python/compile/model.py (same clamp).
#[inline]
pub(crate) fn bright_coeff(dll: f64, dlb: f64, delta: f64) -> f64 {
    let ed = delta.min(-1e-12).exp();
    (dll - ed * dlb) / (1.0 - ed) - dlb
}

/// log( (L-B)/B ) = log L-tilde, the pseudo-likelihood of a bright point,
/// from (log L, log B). Guards delta=0 like `bright_coeff`.
#[inline]
pub fn log_pseudo_lik(ll: f64, lb: f64) -> f64 {
    // log(e^ll - e^lb) - lb = ll + log1mexp(lb - ll) - lb
    let delta = (lb - ll).min(-1e-12);
    ll + crate::util::math::log1mexp(delta) - lb
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bright_coeff_matches_direct_formula() {
        // compare against the direct (L'-B')/(L-B) - B'/B with exp arithmetic
        let (ll, lb) = (-0.3f64, -0.9f64);
        let (dll, dlb) = (0.4f64, 0.25f64);
        let (l, b) = (ll.exp(), lb.exp());
        let direct = (l * dll - b * dlb) / (l - b) - dlb;
        let ours = bright_coeff(dll, dlb, lb - ll);
        assert!((direct - ours).abs() < 1e-12, "{direct} vs {ours}");
    }

    #[test]
    fn log_pseudo_lik_matches_direct() {
        let (ll, lb) = (-0.2f64, -1.4f64);
        let direct = ((ll.exp() - lb.exp()) / lb.exp()).ln();
        assert!((log_pseudo_lik(ll, lb) - direct).abs() < 1e-12);
    }

    #[test]
    fn log_pseudo_lik_finite_at_tight_bound() {
        let v = log_pseudo_lik(-0.5, -0.5);
        assert!(v.is_finite());
        assert!(v < -20.0); // essentially "never bright"
    }
}
