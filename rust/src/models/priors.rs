//! Priors over the flattened parameter vector.

use crate::util::Rng;

/// A prior density over the flattened parameter vector.
pub trait Prior: Send + Sync {
    /// log p(theta), normalized.
    fn log_density(&self, theta: &[f64]) -> f64;
    /// grad += d log p / d theta.
    fn grad_acc(&self, theta: &[f64], grad: &mut [f64]);
    /// Draw from the prior (chain initialization, as in the paper).
    fn sample(&self, dim: usize, rng: &mut Rng) -> Vec<f64>;

    /// `(a, c)` such that `log_density(theta) == a * ||theta||^2 + c` for
    /// every `dim`-vector, when the prior is an isotropic quadratic
    /// (Gaussian). Lets `PseudoPosterior` fold the prior into its cached
    /// collapsed-bound quadratic and evaluate the whole base density in one
    /// pass. Non-quadratic priors (Laplace) return `None`.
    fn iso_quadratic(&self, _dim: usize) -> Option<(f64, f64)> {
        None
    }
}

/// Isotropic Gaussian N(0, scale^2 I). Used for the MNIST and CIFAR weights.
#[derive(Clone, Debug)]
pub struct IsoGaussian {
    /// standard deviation of every component
    pub scale: f64,
}

impl Prior for IsoGaussian {
    fn log_density(&self, theta: &[f64]) -> f64 {
        let s2 = self.scale * self.scale;
        let d = theta.len() as f64;
        let ss: f64 = theta.iter().map(|t| t * t).sum();
        -0.5 * d * (2.0 * std::f64::consts::PI * s2).ln() - 0.5 * ss / s2
    }

    fn grad_acc(&self, theta: &[f64], grad: &mut [f64]) {
        let inv_s2 = 1.0 / (self.scale * self.scale);
        for (g, t) in grad.iter_mut().zip(theta) {
            *g -= t * inv_s2;
        }
    }

    fn sample(&self, dim: usize, rng: &mut Rng) -> Vec<f64> {
        (0..dim).map(|_| rng.normal() * self.scale).collect()
    }

    fn iso_quadratic(&self, dim: usize) -> Option<(f64, f64)> {
        let s2 = self.scale * self.scale;
        let d = dim as f64;
        Some((
            -0.5 / s2,
            -0.5 * d * (2.0 * std::f64::consts::PI * s2).ln(),
        ))
    }
}

/// Laplace(0, b) per component — the sparsity-inducing prior of the OPV
/// experiment. Sub-gradient 0 at the (measure-zero) kink.
#[derive(Clone, Debug)]
pub struct Laplace {
    /// Laplace scale parameter b
    pub b: f64,
}

impl Prior for Laplace {
    fn log_density(&self, theta: &[f64]) -> f64 {
        let d = theta.len() as f64;
        let l1: f64 = theta.iter().map(|t| t.abs()).sum();
        -d * (2.0 * self.b).ln() - l1 / self.b
    }

    fn grad_acc(&self, theta: &[f64], grad: &mut [f64]) {
        let inv_b = 1.0 / self.b;
        for (g, t) in grad.iter_mut().zip(theta) {
            *g -= t.signum() * inv_b * if *t == 0.0 { 0.0 } else { 1.0 };
        }
    }

    fn sample(&self, dim: usize, rng: &mut Rng) -> Vec<f64> {
        (0..dim).map(|_| rng.laplace(self.b)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_grad(f: impl Fn(&[f64]) -> f64, theta: &[f64]) -> Vec<f64> {
        let h = 1e-6;
        let mut g = vec![0.0; theta.len()];
        let mut tp = theta.to_vec();
        for i in 0..theta.len() {
            tp[i] = theta[i] + h;
            let fp = f(&tp);
            tp[i] = theta[i] - h;
            let fm = f(&tp);
            tp[i] = theta[i];
            g[i] = (fp - fm) / (2.0 * h);
        }
        g
    }

    #[test]
    fn gaussian_normalization_and_grad() {
        let p = IsoGaussian { scale: 2.0 };
        // at theta=0, density integrates: check logp(0) = -d/2 log(2 pi s^2)
        let lp0 = p.log_density(&[0.0, 0.0]);
        assert!((lp0 + (2.0 * std::f64::consts::PI * 4.0).ln()).abs() < 1e-12);
        let theta = [0.3, -1.7, 2.2];
        let mut g = vec![0.0; 3];
        p.grad_acc(&theta, &mut g);
        let fd = fd_grad(|t| p.log_density(t), &theta);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn laplace_grad_matches_fd_away_from_kink() {
        let p = Laplace { b: 0.7 };
        let theta = [0.5, -0.4, 1.1];
        let mut g = vec![0.0; 3];
        p.grad_acc(&theta, &mut g);
        let fd = fd_grad(|t| p.log_density(t), &theta);
        for (a, b) in g.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn iso_quadratic_reproduces_log_density() {
        let p = IsoGaussian { scale: 1.7 };
        let theta = [0.3, -1.7, 2.2, 0.0];
        let (a, c) = p.iso_quadratic(theta.len()).unwrap();
        let ss: f64 = theta.iter().map(|t| t * t).sum();
        assert!((a * ss + c - p.log_density(&theta)).abs() < 1e-12);
        assert!(Laplace { b: 1.0 }.iso_quadratic(4).is_none());
    }

    #[test]
    fn samples_match_scale() {
        let mut rng = Rng::new(0);
        let p = IsoGaussian { scale: 3.0 };
        let s = p.sample(10_000, &mut rng);
        let var = s.iter().map(|x| x * x).sum::<f64>() / s.len() as f64;
        assert!((var - 9.0).abs() < 0.5, "var {var}");
        let l = Laplace { b: 2.0 };
        let s = l.sample(10_000, &mut rng);
        let mean_abs = s.iter().map(|x| x.abs()).sum::<f64>() / s.len() as f64;
        assert!((mean_abs - 2.0).abs() < 0.1, "mean|x| {mean_abs}");
    }
}
