//! Softmax classification + Böhning bound (paper §4.2, CIFAR experiment).
//!
//! Likelihood  : log L_n = eta_{t_n} - lse(eta),  eta = Theta x_n (K logits)
//! Bound       : log B_n = f(psi_n) + g_n^T (eta - psi_n)
//!               - 1/2 (eta-psi_n)^T A (eta-psi_n),
//!               A = 1/2 (I - 11^T/K), g_n = onehot(t_n) - softmax(psi_n).
//!               Tight (value + gradient) at eta = psi_n.
//! Collapse    : sum_n log B_n = c0 + sum_k <G_k, theta_k>
//!               - 1/2 sum_n eta_n^T A eta_n, the quadratic collapsing via
//!               S = sum_n x_n x_n^T:
//!               sum_n eta^T A eta = 1/2 [ sum_k theta_k^T S theta_k
//!                                         - (1/K) v^T S v ],  v = sum_k theta_k.
//!
//! `theta` is flattened row-major [K, D]. Evaluation routes through the
//! batched SoA tile kernels in [`crate::kernels::softmax`] (feature rows
//! gathered `W = 8` lanes at a time from the dataset's
//! [`crate::data::store::DataStore`], logits scattered into the lane-major
//! `scratch.lane_eta` buffer so each lane's η is contiguous); the
//! per-datum `ModelBound` methods are batch-of-1 views of the same
//! kernels, and the per-lane dot product reproduces
//! [`crate::linalg::dot`]'s association exactly, so likelihood/bound
//! values are bit-identical for every batch composition (DESIGN.md
//! §Kernels).

use std::sync::Arc;

use super::{EvalScratch, ModelBound, ModelKind};
use crate::data::store::RowCache;
use crate::data::SoftmaxData;
use crate::kernels::{self, dispatch_path};
use crate::linalg::{axpy, dot, Matrix};
use crate::util::math::logsumexp;

/// Softmax-classification likelihood with the Böhning lower bound (the
/// paper's CIFAR-3 experiment model). `theta` is flattened row-major [K, D].
#[derive(Clone)]
pub struct SoftmaxBohning {
    /// the multi-class dataset (features + integer labels)
    pub data: Arc<SoftmaxData>,
    /// per-datum anchor logits psi_n, flattened [N, K] (zeros = untuned)
    pub psi: Vec<f64>,
    /// the θ the anchors were last tuned at (None = untuned, psi = 0)
    anchor: Option<Vec<f64>>,
    // collapsed sufficient statistics
    s_mat: Matrix,    // sum x x^T, anchor-independent
    g_mat: Matrix,    // [K, D]: sum (g_n + A psi_n) x_n^T
    c0: f64,
    /// number of classes K (cached from the data)
    pub(crate) k: usize,
}

impl SoftmaxBohning {
    /// Untuned: anchors psi_n = 0.
    pub fn new(data: Arc<SoftmaxData>) -> Self {
        let k = data.k;
        let n = data.n();
        let d = data.d();
        let mut s_mat = Matrix::zeros(d, d);
        data.x.for_each_row(|_, row| {
            s_mat.add_weighted_outer(1.0, row);
        });
        let mut m = SoftmaxBohning {
            data,
            psi: vec![0.0; n * k],
            anchor: None,
            s_mat,
            g_mat: Matrix::zeros(k, d),
            c0: 0.0,
            k,
        };
        m.rebuild_stats();
        m
    }

    /// logits eta = Theta x_n into `out` (len K), reading the feature row
    /// through `rows`.
    #[inline]
    pub fn logits(&self, theta: &[f64], n: usize, rows: &mut RowCache, out: &mut [f64]) {
        let d = self.data.d();
        let row = self.data.x.row(n, rows);
        for (kk, o) in out.iter_mut().enumerate() {
            *o = dot(&theta[kk * d..(kk + 1) * d], row);
        }
    }

    #[inline]
    fn psi_of(&self, n: usize) -> &[f64] {
        &self.psi[n * self.k..(n + 1) * self.k]
    }

    /// (f(psi), g + A psi) for datum n; g = onehot(t_n) - softmax(psi).
    fn anchor_terms(&self, n: usize) -> (f64, Vec<f64>) {
        let k = self.k;
        let psi = self.psi_of(n);
        let lse = logsumexp(psi);
        let label = self.data.labels[n];
        let f_psi = psi[label] - lse;
        let psi_mean: f64 = psi.iter().sum::<f64>() / k as f64;
        let mut ga = vec![0.0; k];
        for kk in 0..k {
            let g = (if kk == label { 1.0 } else { 0.0 }) - (psi[kk] - lse).exp();
            // A psi = 1/2 (psi - mean(psi))
            ga[kk] = g + 0.5 * (psi[kk] - psi_mean);
        }
        (f_psi, ga)
    }

    /// Rebuild G and c0 (S is anchor-independent) — one streaming pass over
    /// the feature store, O(N K D) (setup-time; may allocate).
    pub fn rebuild_stats(&mut self) {
        let (k, d) = (self.k, self.data.d());
        let mut g_mat = Matrix::zeros(k, d);
        let mut c0 = 0.0;
        self.data.x.for_each_row(|i, row| {
            let (f_psi, ga) = self.anchor_terms(i);
            let psi = self.psi_of(i);
            // c0_n = f(psi) - (g + A psi)^T psi + 1/2 psi^T A psi
            let psi_mean: f64 = psi.iter().sum::<f64>() / k as f64;
            let quad: f64 = psi
                .iter()
                .map(|&p| 0.5 * (p - psi_mean) * p)
                .sum();
            c0 += f_psi - dot(&ga, psi) + 0.5 * quad;
            for kk in 0..k {
                axpy(ga[kk], row, g_mat.row_mut(kk));
            }
        });
        self.g_mat = g_mat;
        self.c0 = c0;
    }

    /// log B_n (unclamped) and d logB/d eta into `dlb`.
    pub(crate) fn log_bound_and_deta(&self, eta: &[f64], n: usize, dlb: Option<&mut [f64]>) -> f64 {
        let k = self.k;
        let psi = self.psi_of(n);
        let lse_psi = logsumexp(psi);
        let label = self.data.labels[n];
        let f_psi = psi[label] - lse_psi;
        let mut lin = 0.0;
        let mut dsum = 0.0;
        let mut dsq = 0.0;
        for kk in 0..k {
            let dkk = eta[kk] - psi[kk];
            let g = (if kk == label { 1.0 } else { 0.0 }) - (psi[kk] - lse_psi).exp();
            lin += g * dkk;
            dsum += dkk;
            dsq += dkk * dkk;
        }
        let quad = 0.5 * (dsq - dsum * dsum / k as f64);
        let lb = f_psi + lin - 0.5 * quad;
        if let Some(out) = dlb {
            let dmean = dsum / k as f64;
            for kk in 0..k {
                let dkk = eta[kk] - psi[kk];
                let g = (if kk == label { 1.0 } else { 0.0 }) - (psi[kk] - lse_psi).exp();
                out[kk] = g - 0.5 * (dkk - dmean);
            }
        }
        lb
    }
}

impl ModelBound for SoftmaxBohning {
    fn n(&self) -> usize {
        self.data.n()
    }
    fn dim(&self) -> usize {
        self.k * self.data.d()
    }
    fn kind(&self) -> ModelKind {
        ModelKind::Softmax
    }

    fn n_classes(&self) -> usize {
        self.k
    }

    fn new_scratch(&self) -> EvalScratch {
        EvalScratch::sized(self.dim(), self.n_classes()).with_rows(self.data.x.new_cache())
    }

    // --- per-datum API: batch-of-1 views of the kernel layer ---

    // lint: zero-alloc
    fn log_lik(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> f64 {
        let mut ll = [0.0];
        self.log_lik_batch(theta, &[n as u32], &mut ll, scratch);
        ll[0]
    }

    // lint: zero-alloc
    fn log_lik_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let mut ll = [0.0];
        self.log_lik_grad_batch(theta, &[n as u32], &mut ll, grad, scratch);
    }

    // lint: zero-alloc
    fn log_both(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> (f64, f64) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.log_both_batch(theta, &[n as u32], &mut ll, &mut lb, scratch);
        (ll[0], lb[0])
    }

    // lint: zero-alloc
    fn pseudo_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.pseudo_grad_batch(theta, &[n as u32], &mut ll, &mut lb, grad, scratch);
    }

    // lint: zero-alloc
    fn log_both_pseudo_grad(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) -> (f64, f64) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.pseudo_grad_batch(theta, &[n as u32], &mut ll, &mut lb, grad, scratch);
        (ll[0], lb[0])
    }

    // --- batch API: dispatch to the SoA tile kernels (DESIGN.md §Kernels) ---

    // lint: zero-alloc
    fn log_lik_batch(&self, theta: &[f64], idx: &[u32], ll: &mut [f64], scratch: &mut EvalScratch) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::log_lik_batch,
            (self, theta, idx, ll, scratch)
        );
    }

    // lint: zero-alloc
    fn log_both_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::log_both_batch,
            (self, theta, idx, ll, lb, scratch)
        );
    }

    // lint: zero-alloc
    fn pseudo_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::pseudo_grad_batch,
            (self, theta, idx, ll, lb, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::log_lik_grad_batch,
            (self, theta, idx, ll, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_ordered_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        ll.clear();
        ll.resize(idx.len(), 0.0);
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::log_lik_grad_ordered,
            (self, theta, idx, ll, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn pseudo_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::pseudo_grad_rows,
            (self, theta, idx, ll, lb, rows, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::log_lik_grad_rows,
            (self, theta, idx, ll, rows, scratch)
        );
    }

    fn shard_model(&self, start: usize, end: usize) -> Option<Arc<dyn ModelBound>> {
        let k = self.k;
        let data = Arc::new(crate::data::SoftmaxData {
            x: self.data.x.slice_rows(start, end),
            labels: self.data.labels[start..end].to_vec(),
            k,
        });
        let d = data.d();
        let mut s_mat = Matrix::zeros(d, d);
        data.x.for_each_row(|_, row| {
            s_mat.add_weighted_outer(1.0, row);
        });
        let mut m = SoftmaxBohning {
            data,
            psi: self.psi[start * k..end * k].to_vec(),
            anchor: self.anchor.clone(),
            s_mat,
            g_mat: Matrix::zeros(k, d),
            c0: 0.0,
            k,
        };
        m.rebuild_stats();
        Some(Arc::new(m))
    }

    // lint: zero-alloc
    fn log_bound_product_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        scratch: &mut EvalScratch,
    ) -> f64 {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::softmax::log_bound_product_batch,
            (self, theta, idx, scratch)
        )
    }

    // lint: zero-alloc
    fn log_bound_product(&self, theta: &[f64], scratch: &mut EvalScratch) -> f64 {
        let (k, d) = (self.k, self.data.d());
        // linear term + c0
        let mut acc = self.c0;
        for kk in 0..k {
            acc += dot(self.g_mat.row(kk), &theta[kk * d..(kk + 1) * d]);
        }
        // quadratic: -1/2 sum_n eta^T A eta
        //          = -1/4 [ sum_k theta_k^T S theta_k - (1/K) v^T S v ]
        let v = &mut scratch.col[..d];
        v.fill(0.0);
        let mut quad_k = 0.0;
        for kk in 0..k {
            let tk = &theta[kk * d..(kk + 1) * d];
            quad_k += self.s_mat.quad_form(tk);
            axpy(1.0, tk, v);
        }
        let quad_v = self.s_mat.quad_form(v);
        acc - 0.25 * (quad_k - quad_v / k as f64)
    }

    // lint: zero-alloc
    fn grad_log_bound_product_acc(
        &self,
        theta: &[f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let (k, d) = (self.k, self.data.d());
        // grad = G - A Theta S with (A W)_k = 1/2 (W_k - mean_j W_j);
        // W = Theta S lives in scratch.acc ([K, D] row-major), the column
        // means in scratch.col[..d].
        for kk in 0..k {
            self.s_mat.matvec(
                &theta[kk * d..(kk + 1) * d],
                &mut scratch.acc[kk * d..(kk + 1) * d],
            );
        }
        let colmean = &mut scratch.col[..d];
        colmean.fill(0.0);
        for kk in 0..k {
            axpy(1.0 / k as f64, &scratch.acc[kk * d..(kk + 1) * d], colmean);
        }
        for kk in 0..k {
            let gk = &mut grad[kk * d..(kk + 1) * d];
            for j in 0..d {
                gk[j] += self.g_mat[(kk, j)] - 0.5 * (scratch.acc[kk * d + j] - scratch.col[j]);
            }
        }
    }

    fn tune_anchors_map(&mut self, theta_map: &[f64]) {
        let (k, d) = (self.k, self.data.d());
        let psi = &mut self.psi;
        self.data.x.for_each_row(|n, row| {
            for kk in 0..k {
                psi[n * k + kk] = dot(&theta_map[kk * d..(kk + 1) * d], row);
            }
        });
        self.anchor = Some(theta_map.to_vec());
        self.rebuild_stats();
    }

    fn anchor_theta(&self) -> Option<&[f64]> {
        self.anchor.as_deref()
    }

    fn clone_reanchored(&self, anchor: &[f64]) -> Option<Arc<dyn ModelBound>> {
        let mut m = self.clone();
        m.tune_anchors_map(anchor);
        Some(Arc::new(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::testing;
    use crate::util::Rng;

    fn small() -> SoftmaxBohning {
        let data = Arc::new(synth::synth_cifar3(150, 12, 2));
        SoftmaxBohning::new(data)
    }

    #[test]
    fn bound_below_likelihood_everywhere() {
        let mut m = small();
        let mut anchor_rng = Rng::new(77);
        let anchor: Vec<f64> = (0..m.dim()).map(|_| anchor_rng.normal() * 0.3).collect();
        m.tune_anchors_map(&anchor); // non-trivial anchors
        let mut sc = m.new_scratch();
        testing::check(
            "bohning bound <= lik",
            200,
            |r| {
                let theta = testing::gen::vec_normal(r, m.dim(), 1.0);
                let n = r.below(m.n());
                (theta, n)
            },
            |(theta, n)| {
                let (ll, lb) = m.log_both(theta, *n, &mut sc);
                lb <= ll && lb.is_finite()
            },
        );
    }

    #[test]
    fn bound_tight_at_anchor() {
        let mut m = small();
        let mut rng = Rng::new(8);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.5).collect();
        m.tune_anchors_map(&theta);
        let mut sc = m.new_scratch();
        for n in 0..m.n() {
            let (ll, lb) = m.log_both(&theta, n, &mut sc);
            assert!((ll - lb).abs() < 1e-10, "n={n}: {ll} vs {lb}");
        }
    }

    #[test]
    fn collapsed_product_matches_pointwise_sum() {
        let mut m = small();
        let mut anchor_rng = Rng::new(9);
        let anchor: Vec<f64> = (0..m.dim()).map(|_| anchor_rng.normal() * 0.4).collect();
        m.tune_anchors_map(&anchor);
        let mut sc = m.new_scratch();
        let mut rows = m.data.x.new_cache();
        testing::check_msg(
            "softmax collapse == sum",
            15,
            |r| testing::gen::vec_normal(r, m.dim(), 0.8),
            |theta| {
                let mut sum = 0.0;
                let mut eta = vec![0.0; m.k];
                for n in 0..m.n() {
                    m.logits(theta, n, &mut rows, &mut eta);
                    sum += m.log_bound_and_deta(&eta, n, None);
                }
                let col = m.log_bound_product(theta, &mut sc);
                if (sum - col).abs() < 1e-7 * (1.0 + sum.abs()) {
                    Ok(())
                } else {
                    Err(format!("sum {sum} vs collapsed {col}"))
                }
            },
        );
    }

    #[test]
    fn collapsed_grad_matches_fd() {
        let mut m = small();
        let mut rng = Rng::new(10);
        let anchor: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.3).collect();
        m.tune_anchors_map(&anchor);
        let mut sc = m.new_scratch();
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.5).collect();
        let mut g = vec![0.0; m.dim()];
        m.grad_log_bound_product_acc(&theta, &mut g, &mut sc);
        let h = 1e-5;
        let mut tp = theta.clone();
        for i in (0..m.dim()).step_by(7) {
            tp[i] = theta[i] + h;
            let fp = m.log_bound_product(&tp, &mut sc);
            tp[i] = theta[i] - h;
            let fm = m.log_bound_product(&tp, &mut sc);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-3 * (1.0 + fd.abs()), "i={i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn lik_and_pseudo_grads_match_fd() {
        let m = small();
        let mut sc = m.new_scratch();
        let mut rng = Rng::new(11);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.4).collect();
        for n in [0, 33] {
            let mut g = vec![0.0; m.dim()];
            m.log_lik_grad_acc(&theta, n, &mut g, &mut sc);
            let mut gp = vec![0.0; m.dim()];
            m.pseudo_grad_acc(&theta, n, &mut gp, &mut sc);
            let h = 1e-6;
            let mut tp = theta.clone();
            for i in (0..m.dim()).step_by(5) {
                tp[i] = theta[i] + h;
                let fp = m.log_lik(&tp, n, &mut sc);
                let (llp, lbp) = m.log_both(&tp, n, &mut sc);
                let pp = super::super::log_pseudo_lik(llp, lbp);
                tp[i] = theta[i] - h;
                let fm = m.log_lik(&tp, n, &mut sc);
                let (llm, lbm) = m.log_both(&tp, n, &mut sc);
                let pm = super::super::log_pseudo_lik(llm, lbm);
                tp[i] = theta[i];
                assert!((g[i] - (fp - fm) / (2.0 * h)).abs() < 1e-5, "lik n={n} i={i}");
                let fd = (pp - pm) / (2.0 * h);
                assert!(
                    (gp[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "pseudo n={n} i={i}: {} vs {fd}",
                    gp[i]
                );
            }
        }
    }

    #[test]
    fn loglik_is_proper_distribution() {
        // sum over classes of exp(loglik with label=k) = 1
        let m = small();
        let mut rng = Rng::new(12);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal()).collect();
        let mut rows = m.data.x.new_cache();
        let mut eta = vec![0.0; m.k];
        m.logits(&theta, 3, &mut rows, &mut eta);
        let lse = logsumexp(&eta);
        let total: f64 = (0..m.k).map(|k| (eta[k] - lse).exp()).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }
}
