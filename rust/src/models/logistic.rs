//! Logistic regression + Jaakkola–Jordan bound (paper §3.1, MNIST experiment).
//!
//! Likelihood  : L_n = sigmoid(t_n theta^T x_n)
//! Bound       : log B_n(s) = a(xi_n) s^2 + s/2 + c(xi_n), s = t_n theta^T x_n
//!               a = -tanh(xi/2)/(4 xi), c = -a xi^2 + xi/2 - log(e^xi + 1),
//!               tight at s = ±xi.
//! Collapse    : sum_n log B_n = theta^T A theta + b^T theta + c0 with
//!               A = sum a_n x_n x_n^T,  b = 1/2 sum t_n x_n,  c0 = sum c_n —
//!               O(D^2) per evaluation after O(N D^2) setup.
//!
//! Evaluation routes through the batched SoA tile kernels in
//! [`crate::kernels::logistic`] (feature rows gathered `W = 8` lanes at a
//! time from the dataset's [`crate::data::store::DataStore`], resident or
//! block-cached out-of-core); the per-datum `ModelBound` methods are
//! batch-of-1 views of the same kernels, and the per-lane dot product
//! reproduces [`crate::linalg::dot`]'s association exactly, so
//! likelihood/bound values are bit-identical for every batch composition
//! (DESIGN.md §Kernels).

use std::sync::Arc;

use super::{EvalScratch, ModelBound, ModelKind};
#[cfg(test)]
use crate::data::store::RowCache;
use crate::data::LogisticData;
use crate::kernels::{self, dispatch_path};
use crate::linalg::{axpy, dot, Matrix};
use crate::util::math::log1p_exp;

/// JJ coefficients for a given xi (mirrors `jj_coeffs` in ref.py).
#[inline]
pub fn jj_coeffs(xi: f64) -> (f64, f64, f64) {
    let axi = xi.abs();
    let a = if axi < 1e-6 {
        -0.125 + axi * axi / 96.0
    } else {
        -(axi / 2.0).tanh() / (4.0 * axi)
    };
    let c = -a * axi * axi + axi / 2.0 - log1p_exp(axi);
    (a, 0.5, c)
}

/// Logistic-regression likelihood with the Jaakkola–Jordan lower bound
/// (the paper's MNIST experiment model).
#[derive(Clone)]
pub struct LogisticJJ {
    /// the binary-classification dataset (features + ±1 labels)
    pub data: Arc<LogisticData>,
    /// per-datum bound anchor xi_n (paper: 1.5 untuned; |theta_MAP^T x_n| tuned)
    pub xi: Vec<f64>,
    /// the θ the anchors were last tuned at (None = constant-xi untuned)
    anchor: Option<Vec<f64>>,
    // collapsed sufficient statistics
    a_mat: Matrix,
    b_vec: Vec<f64>,
    c_sum: f64,
}

impl LogisticJJ {
    /// Build with a constant anchor xi (paper's untuned variant uses 1.5).
    pub fn new(data: Arc<LogisticData>, xi_const: f64) -> Self {
        let n = data.n();
        let mut m = LogisticJJ {
            data,
            xi: vec![xi_const; n],
            anchor: None,
            a_mat: Matrix::zeros(0, 0),
            b_vec: Vec::new(),
            c_sum: 0.0,
        };
        m.rebuild_stats();
        m
    }

    /// Recompute the collapsed sufficient statistics — one streaming pass
    /// over the feature store, O(N D^2) (setup-time; may allocate).
    pub fn rebuild_stats(&mut self) {
        let d = self.data.d();
        let mut a_mat = Matrix::zeros(d, d);
        let mut b_vec = vec![0.0; d];
        let mut c_sum = 0.0;
        let xi = &self.xi;
        let t = &self.data.t;
        self.data.x.for_each_row(|i, row| {
            let (a, _, c) = jj_coeffs(xi[i]);
            a_mat.add_weighted_outer(a, row);
            axpy(0.5 * t[i], row, &mut b_vec);
            c_sum += c;
        });
        self.a_mat = a_mat;
        self.b_vec = b_vec;
        self.c_sum = c_sum;
    }

    /// Margin s = t_n θᵀx_n — test oracle for the kernel layer (production
    /// reads go through `crate::kernels::logistic`).
    #[cfg(test)]
    fn s(&self, theta: &[f64], n: usize, rows: &mut RowCache) -> f64 {
        self.data.t[n] * dot(self.data.x.row(n, rows), theta)
    }
}

impl ModelBound for LogisticJJ {
    fn n(&self) -> usize {
        self.data.n()
    }
    fn dim(&self) -> usize {
        self.data.d()
    }
    fn kind(&self) -> ModelKind {
        ModelKind::Logistic
    }

    fn new_scratch(&self) -> EvalScratch {
        EvalScratch::sized(self.dim(), self.n_classes()).with_rows(self.data.x.new_cache())
    }

    // --- per-datum API: batch-of-1 views of the kernel layer ---

    // lint: zero-alloc
    fn log_lik(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> f64 {
        let mut ll = [0.0];
        self.log_lik_batch(theta, &[n as u32], &mut ll, scratch);
        ll[0]
    }

    // lint: zero-alloc
    fn log_lik_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let mut ll = [0.0];
        self.log_lik_grad_batch(theta, &[n as u32], &mut ll, grad, scratch);
    }

    // lint: zero-alloc
    fn log_both(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> (f64, f64) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.log_both_batch(theta, &[n as u32], &mut ll, &mut lb, scratch);
        (ll[0], lb[0])
    }

    // lint: zero-alloc
    fn pseudo_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.pseudo_grad_batch(theta, &[n as u32], &mut ll, &mut lb, grad, scratch);
    }

    // lint: zero-alloc
    fn log_both_pseudo_grad(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) -> (f64, f64) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.pseudo_grad_batch(theta, &[n as u32], &mut ll, &mut lb, grad, scratch);
        (ll[0], lb[0])
    }

    // --- batch API: dispatch to the SoA tile kernels (DESIGN.md §Kernels) ---

    // lint: zero-alloc
    fn log_lik_batch(&self, theta: &[f64], idx: &[u32], ll: &mut [f64], scratch: &mut EvalScratch) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::log_lik_batch,
            (self, theta, idx, ll, scratch)
        );
    }

    // lint: zero-alloc
    fn log_both_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::log_both_batch,
            (self, theta, idx, ll, lb, scratch)
        );
    }

    // lint: zero-alloc
    fn pseudo_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::pseudo_grad_batch,
            (self, theta, idx, ll, lb, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::log_lik_grad_batch,
            (self, theta, idx, ll, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_ordered_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        ll.clear();
        ll.resize(idx.len(), 0.0);
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::log_lik_grad_ordered,
            (self, theta, idx, ll, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn pseudo_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::pseudo_grad_rows,
            (self, theta, idx, ll, lb, rows, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::log_lik_grad_rows,
            (self, theta, idx, ll, rows, scratch)
        );
    }

    fn shard_model(&self, start: usize, end: usize) -> Option<Arc<dyn ModelBound>> {
        let data = Arc::new(crate::data::LogisticData {
            x: self.data.x.slice_rows(start, end),
            t: self.data.t[start..end].to_vec(),
        });
        let mut m = LogisticJJ {
            data,
            xi: self.xi[start..end].to_vec(),
            anchor: self.anchor.clone(),
            a_mat: Matrix::zeros(0, 0),
            b_vec: Vec::new(),
            c_sum: 0.0,
        };
        m.rebuild_stats();
        Some(Arc::new(m))
    }

    // lint: zero-alloc
    fn log_bound_product_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        scratch: &mut EvalScratch,
    ) -> f64 {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::logistic::log_bound_product_batch,
            (self, theta, idx, scratch)
        )
    }

    // lint: zero-alloc
    fn log_bound_product(&self, theta: &[f64], _scratch: &mut EvalScratch) -> f64 {
        self.a_mat.quad_form(theta) + dot(&self.b_vec, theta) + self.c_sum
    }

    // lint: zero-alloc
    fn grad_log_bound_product_acc(
        &self,
        theta: &[f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        // d/dtheta [theta^T A theta + b^T theta] = 2 A theta + b (A symmetric)
        let d = theta.len();
        let ax = &mut scratch.acc[..d];
        self.a_mat.matvec(theta, ax);
        for i in 0..d {
            grad[i] += 2.0 * ax[i] + self.b_vec[i];
        }
    }

    fn tune_anchors_map(&mut self, theta_map: &[f64]) {
        let t = &self.data.t;
        let xi = &mut self.xi;
        self.data.x.for_each_row(|n, row| {
            xi[n] = (t[n] * dot(row, theta_map)).abs();
        });
        self.anchor = Some(theta_map.to_vec());
        self.rebuild_stats();
    }

    fn anchor_theta(&self) -> Option<&[f64]> {
        self.anchor.as_deref()
    }

    fn clone_reanchored(&self, anchor: &[f64]) -> Option<Arc<dyn ModelBound>> {
        let mut m = self.clone();
        m.tune_anchors_map(anchor);
        Some(Arc::new(m))
    }

    fn collapsed_quadratic(&self) -> Option<(&Matrix, &[f64], f64)> {
        Some((&self.a_mat, &self.b_vec, self.c_sum))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::testing;
    use crate::util::math::log_sigmoid;
    use crate::util::Rng;

    fn small() -> LogisticJJ {
        let data = Arc::new(synth::synth_mnist(200, 10, 1));
        LogisticJJ::new(data, 1.5)
    }

    #[test]
    fn bound_below_likelihood_everywhere() {
        let m = small();
        let mut sc = m.new_scratch();
        testing::check(
            "jj bound <= lik",
            200,
            |r| {
                let theta = testing::gen::vec_normal(r, m.dim(), 2.0);
                let n = r.below(m.n());
                (theta, n)
            },
            |(theta, n)| {
                let (ll, lb) = m.log_both(theta, *n, &mut sc);
                lb <= ll && lb.is_finite()
            },
        );
    }

    #[test]
    fn bound_tight_at_anchor_after_map_tuning() {
        let mut m = small();
        let mut rng = Rng::new(2);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal()).collect();
        m.tune_anchors_map(&theta);
        let mut sc = m.new_scratch();
        for n in 0..m.n() {
            let (ll, lb) = m.log_both(&theta, n, &mut sc);
            assert!((ll - lb).abs() < 1e-10, "n={n}: {ll} vs {lb}");
        }
    }

    #[test]
    fn collapsed_product_matches_pointwise_sum() {
        let m = small();
        let mut sc = m.new_scratch();
        let mut rows = m.data.x.new_cache();
        testing::check_msg(
            "collapse == sum of bounds",
            25,
            |r| testing::gen::vec_normal(r, m.dim(), 1.0),
            |theta| {
                // pointwise sum without the min() clamp (collapse can't clamp)
                let mut sum = 0.0;
                for n in 0..m.n() {
                    let s = m.s(theta, n, &mut rows);
                    let (a, b, c) = jj_coeffs(m.xi[n]);
                    sum += a * s * s + b * s + c;
                }
                let col = m.log_bound_product(theta, &mut sc);
                if (sum - col).abs() < 1e-8 * (1.0 + sum.abs()) {
                    Ok(())
                } else {
                    Err(format!("sum {sum} vs collapsed {col}"))
                }
            },
        );
    }

    #[test]
    fn collapsed_grad_matches_fd() {
        let m = small();
        let mut sc = m.new_scratch();
        let mut rng = Rng::new(3);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal()).collect();
        let mut g = vec![0.0; m.dim()];
        m.grad_log_bound_product_acc(&theta, &mut g, &mut sc);
        let h = 1e-6;
        let mut tp = theta.clone();
        for i in 0..m.dim() {
            tp[i] = theta[i] + h;
            let fp = m.log_bound_product(&tp, &mut sc);
            tp[i] = theta[i] - h;
            let fm = m.log_bound_product(&tp, &mut sc);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()), "i={i}: {} vs {fd}", g[i]);
        }
    }

    #[test]
    fn lik_grad_matches_fd() {
        let m = small();
        let mut sc = m.new_scratch();
        let mut rng = Rng::new(4);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal()).collect();
        for n in [0, 7, 100] {
            let mut g = vec![0.0; m.dim()];
            m.log_lik_grad_acc(&theta, n, &mut g, &mut sc);
            let h = 1e-6;
            let mut tp = theta.clone();
            for i in 0..m.dim() {
                tp[i] = theta[i] + h;
                let fp = m.log_lik(&tp, n, &mut sc);
                tp[i] = theta[i] - h;
                let fm = m.log_lik(&tp, n, &mut sc);
                tp[i] = theta[i];
                let fd = (fp - fm) / (2.0 * h);
                assert!((g[i] - fd).abs() < 1e-5, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn pseudo_grad_matches_fd() {
        let m = small();
        let mut sc = m.new_scratch();
        let mut rng = Rng::new(5);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.5).collect();
        for n in [1, 13, 55] {
            let mut g = vec![0.0; m.dim()];
            m.pseudo_grad_acc(&theta, n, &mut g, &mut sc);
            let mut f = |t: &[f64], sc: &mut crate::models::EvalScratch| {
                let (ll, lb) = m.log_both(t, n, sc);
                super::super::log_pseudo_lik(ll, lb)
            };
            let h = 1e-6;
            let mut tp = theta.clone();
            for i in 0..m.dim() {
                tp[i] = theta[i] + h;
                let fp = f(&tp, &mut sc);
                tp[i] = theta[i] - h;
                let fm = f(&tp, &mut sc);
                tp[i] = theta[i];
                let fd = (fp - fm) / (2.0 * h);
                assert!(
                    (g[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "n={n} i={i}: {} vs {fd}",
                    g[i]
                );
            }
        }
    }

    #[test]
    fn untuned_xi_15_bright_probability_small_in_confident_region() {
        // Paper: with xi = 1.5, P(bright) < 0.02 where 0.1 < L < 0.9.
        let (a, b, c) = jj_coeffs(1.5);
        for s in [-2.0f64, -1.0, 0.0, 1.0, 2.0] {
            let ll = log_sigmoid(s);
            let l = ll.exp();
            if l > 0.1 && l < 0.9 {
                let lb = a * s * s + b * s + c;
                let p_bright = 1.0 - (lb - ll).exp();
                assert!(p_bright < 0.02, "s={s}: p_bright={p_bright}");
            }
        }
    }
}
