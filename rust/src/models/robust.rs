//! Robust (student-t) regression + tangent Gaussian bound (paper §4.3, OPV).
//!
//! Likelihood : log L_n = C(nu, sigma) - (nu+1)/2 log(1 + u/(nu sigma^2)),
//!              u = r^2, r = y_n - theta^T x_n.
//! Bound      : tangent to the (convex in u) log-density at u = u0_n:
//!              log B_n = f(u0_n) + f'(u0_n)(u - u0_n) — a scaled Gaussian
//!              in r, tight at r^2 = u0_n (u0 = 0 untuned; residual^2 at
//!              theta_MAP tuned — paper's xi = theta_MAP^T x choice).
//! Collapse   : sum_n log B_n = theta^T A theta + b^T theta + c0 with
//!              A = sum fp_n x x^T, b = -2 sum fp_n y_n x_n,
//!              c0 = sum [f(u0_n) - fp_n u0_n + fp_n y_n^2].
//!
//! Evaluation routes through the batched SoA tile kernels in
//! [`crate::kernels::robust`] (feature rows gathered `W = 8` lanes at a
//! time from the dataset's [`crate::data::store::DataStore`]); the
//! per-datum `ModelBound` methods are batch-of-1 views of the same
//! kernels, and the per-lane dot product reproduces
//! [`crate::linalg::dot`]'s association exactly, so likelihood/bound
//! values are bit-identical for every batch composition (DESIGN.md
//! §Kernels).

use std::sync::Arc;

use super::{EvalScratch, ModelBound, ModelKind};
#[cfg(test)]
use crate::data::store::RowCache;
use crate::data::RegressionData;
use crate::kernels::{self, dispatch_path};
use crate::linalg::{axpy, dot, Matrix};
use crate::util::math::t_logconst;

/// Student-t regression likelihood with the tangent scaled-Gaussian lower
/// bound (the paper's OPV experiment model).
#[derive(Clone)]
pub struct RobustT {
    /// the regression dataset (features + targets)
    pub data: Arc<RegressionData>,
    /// student-t degrees of freedom (paper: 4)
    pub nu: f64,
    /// noise scale σ
    pub sigma: f64,
    /// per-datum tangent location u0_n (in u = r^2 space)
    pub u0: Vec<f64>,
    /// the θ the tangents were last tuned at (None = untuned, u0 = 0)
    anchor: Option<Vec<f64>>,
    pub(crate) logc: f64,
    // collapsed sufficient statistics
    a_mat: Matrix,
    b_vec: Vec<f64>,
    c_sum: f64,
}

impl RobustT {
    /// Untuned: u0_n = 0 for all n (paper's xi = 0 case).
    pub fn new(data: Arc<RegressionData>, nu: f64, sigma: f64) -> Self {
        let n = data.n();
        let mut m = RobustT {
            data,
            nu,
            sigma,
            u0: vec![0.0; n],
            anchor: None,
            logc: t_logconst(nu, sigma),
            a_mat: Matrix::zeros(0, 0),
            b_vec: Vec::new(),
            c_sum: 0.0,
        };
        m.rebuild_stats();
        m
    }

    #[inline]
    pub(crate) fn c2(&self) -> f64 {
        self.nu * self.sigma * self.sigma
    }

    /// Residual r = y_n − θᵀx_n — test oracle for the kernel layer
    /// (production reads go through [`crate::kernels::robust`]).
    #[cfg(test)]
    fn resid(&self, theta: &[f64], n: usize, rows: &mut RowCache) -> f64 {
        self.data.y[n] - dot(self.data.x.row(n, rows), theta)
    }

    /// f(u0) and f'(u0) of the log-density as a function of u.
    #[inline]
    pub(crate) fn tangent(&self, u0: f64) -> (f64, f64) {
        let c2 = self.c2();
        let f0 = self.logc - (self.nu + 1.0) / 2.0 * (u0 / c2).ln_1p();
        let fp0 = -(self.nu + 1.0) / 2.0 / (c2 + u0);
        (f0, fp0)
    }

    /// Recompute the collapsed sufficient statistics — one streaming pass
    /// over the feature store, O(N D^2) (setup-time; may allocate).
    pub fn rebuild_stats(&mut self) {
        let d = self.data.d();
        let mut a_mat = Matrix::zeros(d, d);
        let mut b_vec = vec![0.0; d];
        let mut c_sum = 0.0;
        let y = &self.data.y;
        self.data.x.for_each_row(|i, row| {
            let (f0, fp0) = self.tangent(self.u0[i]);
            a_mat.add_weighted_outer(fp0, row);
            axpy(-2.0 * fp0 * y[i], row, &mut b_vec);
            c_sum += f0 - fp0 * self.u0[i] + fp0 * y[i] * y[i];
        });
        self.a_mat = a_mat;
        self.b_vec = b_vec;
        self.c_sum = c_sum;
    }
}

impl ModelBound for RobustT {
    fn n(&self) -> usize {
        self.data.n()
    }
    fn dim(&self) -> usize {
        self.data.d()
    }
    fn kind(&self) -> ModelKind {
        ModelKind::Robust
    }

    fn new_scratch(&self) -> EvalScratch {
        EvalScratch::sized(self.dim(), self.n_classes()).with_rows(self.data.x.new_cache())
    }

    // --- per-datum API: batch-of-1 views of the kernel layer ---

    // lint: zero-alloc
    fn log_lik(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> f64 {
        let mut ll = [0.0];
        self.log_lik_batch(theta, &[n as u32], &mut ll, scratch);
        ll[0]
    }

    // lint: zero-alloc
    fn log_lik_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let mut ll = [0.0];
        self.log_lik_grad_batch(theta, &[n as u32], &mut ll, grad, scratch);
    }

    // lint: zero-alloc
    fn log_both(&self, theta: &[f64], n: usize, scratch: &mut EvalScratch) -> (f64, f64) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.log_both_batch(theta, &[n as u32], &mut ll, &mut lb, scratch);
        (ll[0], lb[0])
    }

    // lint: zero-alloc
    fn pseudo_grad_acc(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.pseudo_grad_batch(theta, &[n as u32], &mut ll, &mut lb, grad, scratch);
    }

    // lint: zero-alloc
    fn log_both_pseudo_grad(
        &self,
        theta: &[f64],
        n: usize,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) -> (f64, f64) {
        let (mut ll, mut lb) = ([0.0], [0.0]);
        self.pseudo_grad_batch(theta, &[n as u32], &mut ll, &mut lb, grad, scratch);
        (ll[0], lb[0])
    }

    // --- batch API: dispatch to the SoA tile kernels (DESIGN.md §Kernels) ---

    // lint: zero-alloc
    fn log_lik_batch(&self, theta: &[f64], idx: &[u32], ll: &mut [f64], scratch: &mut EvalScratch) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::log_lik_batch,
            (self, theta, idx, ll, scratch)
        );
    }

    // lint: zero-alloc
    fn log_both_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::log_both_batch,
            (self, theta, idx, ll, lb, scratch)
        );
    }

    // lint: zero-alloc
    fn pseudo_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::pseudo_grad_batch,
            (self, theta, idx, ll, lb, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::log_lik_grad_batch,
            (self, theta, idx, ll, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_ordered_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        ll.clear();
        ll.resize(idx.len(), 0.0);
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::log_lik_grad_ordered,
            (self, theta, idx, ll, grad, scratch)
        );
    }

    // lint: zero-alloc
    fn pseudo_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        lb: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::pseudo_grad_rows,
            (self, theta, idx, ll, lb, rows, scratch)
        );
    }

    // lint: zero-alloc
    fn log_lik_grad_rows_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut [f64],
        rows: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::log_lik_grad_rows,
            (self, theta, idx, ll, rows, scratch)
        );
    }

    fn shard_model(&self, start: usize, end: usize) -> Option<Arc<dyn ModelBound>> {
        let data = Arc::new(crate::data::RegressionData {
            x: self.data.x.slice_rows(start, end),
            y: self.data.y[start..end].to_vec(),
        });
        let mut m = RobustT {
            data,
            nu: self.nu,
            sigma: self.sigma,
            u0: self.u0[start..end].to_vec(),
            anchor: self.anchor.clone(),
            logc: self.logc,
            a_mat: Matrix::zeros(0, 0),
            b_vec: Vec::new(),
            c_sum: 0.0,
        };
        m.rebuild_stats();
        Some(Arc::new(m))
    }

    // lint: zero-alloc
    fn log_bound_product_batch(
        &self,
        theta: &[f64],
        idx: &[u32],
        scratch: &mut EvalScratch,
    ) -> f64 {
        dispatch_path!(
            kernels::kernel_path(),
            kernels::robust::log_bound_product_batch,
            (self, theta, idx, scratch)
        )
    }

    // lint: zero-alloc
    fn log_bound_product(&self, theta: &[f64], _scratch: &mut EvalScratch) -> f64 {
        self.a_mat.quad_form(theta) + dot(&self.b_vec, theta) + self.c_sum
    }

    // lint: zero-alloc
    fn grad_log_bound_product_acc(
        &self,
        theta: &[f64],
        grad: &mut [f64],
        scratch: &mut EvalScratch,
    ) {
        let d = theta.len();
        let ax = &mut scratch.acc[..d];
        self.a_mat.matvec(theta, ax);
        for i in 0..d {
            grad[i] += 2.0 * ax[i] + self.b_vec[i];
        }
    }

    fn tune_anchors_map(&mut self, theta_map: &[f64]) {
        let y = &self.data.y;
        let u0 = &mut self.u0;
        self.data.x.for_each_row(|n, row| {
            let r = y[n] - dot(row, theta_map);
            u0[n] = r * r;
        });
        self.anchor = Some(theta_map.to_vec());
        self.rebuild_stats();
    }

    fn anchor_theta(&self) -> Option<&[f64]> {
        self.anchor.as_deref()
    }

    fn clone_reanchored(&self, anchor: &[f64]) -> Option<Arc<dyn ModelBound>> {
        let mut m = self.clone();
        m.tune_anchors_map(anchor);
        Some(Arc::new(m))
    }

    fn collapsed_quadratic(&self) -> Option<(&Matrix, &[f64], f64)> {
        Some((&self.a_mat, &self.b_vec, self.c_sum))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::testing;
    use crate::util::Rng;

    fn small() -> RobustT {
        let data = Arc::new(synth::synth_opv(300, 9, 3));
        RobustT::new(data, 4.0, 0.8)
    }

    #[test]
    fn bound_below_likelihood_everywhere() {
        let mut m = small();
        let mut rng = Rng::new(21);
        let anchor: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.5).collect();
        m.tune_anchors_map(&anchor);
        let mut sc = m.new_scratch();
        testing::check(
            "t bound <= lik",
            200,
            |r| {
                let theta = testing::gen::vec_normal(r, m.dim(), 1.5);
                let n = r.below(m.n());
                (theta, n)
            },
            |(theta, n)| {
                let (ll, lb) = m.log_both(theta, *n, &mut sc);
                lb <= ll && lb.is_finite()
            },
        );
    }

    #[test]
    fn bound_tight_at_anchor() {
        let mut m = small();
        let mut rng = Rng::new(22);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal()).collect();
        m.tune_anchors_map(&theta);
        let mut sc = m.new_scratch();
        for n in 0..m.n() {
            let (ll, lb) = m.log_both(&theta, n, &mut sc);
            assert!((ll - lb).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn collapsed_product_matches_pointwise_sum() {
        let mut m = small();
        let mut rng = Rng::new(23);
        let anchor: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.3).collect();
        m.tune_anchors_map(&anchor);
        let mut sc = m.new_scratch();
        let mut rows = m.data.x.new_cache();
        testing::check_msg(
            "t collapse == sum",
            20,
            |r| testing::gen::vec_normal(r, m.dim(), 1.0),
            |theta| {
                let mut sum = 0.0;
                for n in 0..m.n() {
                    let r = m.resid(theta, n, &mut rows);
                    let (f0, fp0) = m.tangent(m.u0[n]);
                    sum += f0 + fp0 * (r * r - m.u0[n]);
                }
                let col = m.log_bound_product(theta, &mut sc);
                if (sum - col).abs() < 1e-7 * (1.0 + sum.abs()) {
                    Ok(())
                } else {
                    Err(format!("sum {sum} vs collapsed {col}"))
                }
            },
        );
    }

    #[test]
    fn grads_match_fd() {
        let m = small();
        let mut sc = m.new_scratch();
        let mut rng = Rng::new(24);
        let theta: Vec<f64> = (0..m.dim()).map(|_| rng.normal() * 0.5).collect();
        let h = 1e-6;
        // collapsed grad
        let mut g = vec![0.0; m.dim()];
        m.grad_log_bound_product_acc(&theta, &mut g, &mut sc);
        let mut tp = theta.clone();
        for i in 0..m.dim() {
            tp[i] = theta[i] + h;
            let fp = m.log_bound_product(&tp, &mut sc);
            tp[i] = theta[i] - h;
            let fm = m.log_bound_product(&tp, &mut sc);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * h);
            assert!((g[i] - fd).abs() < 1e-3 * (1.0 + fd.abs()), "collapse i={i}");
        }
        // per-point lik + pseudo grads
        for n in [2, 41] {
            let mut gl = vec![0.0; m.dim()];
            m.log_lik_grad_acc(&theta, n, &mut gl, &mut sc);
            let mut gp = vec![0.0; m.dim()];
            m.pseudo_grad_acc(&theta, n, &mut gp, &mut sc);
            for i in 0..m.dim() {
                tp[i] = theta[i] + h;
                let lp = m.log_lik(&tp, n, &mut sc);
                let (lla, lba) = m.log_both(&tp, n, &mut sc);
                let pa = super::super::log_pseudo_lik(lla, lba);
                tp[i] = theta[i] - h;
                let lm = m.log_lik(&tp, n, &mut sc);
                let (llb, lbb) = m.log_both(&tp, n, &mut sc);
                let pb = super::super::log_pseudo_lik(llb, lbb);
                tp[i] = theta[i];
                assert!((gl[i] - (lp - lm) / (2.0 * h)).abs() < 1e-5, "lik n={n} i={i}");
                let fd = (pa - pb) / (2.0 * h);
                assert!(
                    (gp[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                    "pseudo n={n} i={i}: {} vs {fd}",
                    gp[i]
                );
            }
        }
    }

    #[test]
    fn heavier_tail_than_gaussian_bound() {
        // Far from the anchor the t-likelihood dominates the Gaussian bound
        // by a growing margin — that's exactly why outliers go bright.
        let m = small();
        let mut sc = m.new_scratch();
        let theta = vec![0.0; m.dim()];
        let mut last_gap: f64 = 0.0;
        for n in 0..5 {
            let (ll, lb) = m.log_both(&theta, n, &mut sc);
            let gap = ll - lb;
            assert!(gap >= 0.0);
            last_gap = last_gap.max(gap);
        }
        assert!(last_gap.is_finite());
    }
}
