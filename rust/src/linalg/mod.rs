//! Dense linear-algebra substrate (no external crates in the offline build).
//!
//! Row-major `Matrix` plus the handful of kernels the sampler hot path and
//! the bound sufficient-statistics collapse need: `y = A x`, `y = A^T x`,
//! symmetric rank-1 accumulation `S += w x x^T`, quadratic forms
//! `x^T S x`, and a Cholesky factorization used by tests and by the
//! Gaussian-proposal machinery.

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// number of rows
    pub rows: usize,
    /// number of columns
    pub cols: usize,
    /// row-major storage, `rows * cols` elements
    pub data: Vec<f64>,
}

impl Matrix {
    /// All-zero `rows x cols` matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from a list of equal-length rows.
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    /// Matrix over pre-flattened row-major storage.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    /// The n x n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// y = self @ x  (rows x cols) @ (cols) -> (rows)
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
    }

    /// y = self^T @ x  (cols) <- (rows)
    pub fn matvec_t(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            axpy(x[i], self.row(i), y);
        }
    }

    /// C = self @ other.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut c = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let crow = c.row_mut(i);
                axpy(a, orow, crow);
            }
        }
        c
    }

    /// The transposed matrix (new allocation).
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// S += w * x x^T (full square update; S must be cols==rows==x.len()).
    pub fn add_weighted_outer(&mut self, w: f64, x: &[f64]) {
        let n = x.len();
        assert_eq!(self.rows, n);
        assert_eq!(self.cols, n);
        for i in 0..n {
            let wxi = w * x[i];
            if wxi == 0.0 {
                continue;
            }
            let row = self.row_mut(i);
            axpy(wxi, x, row);
        }
    }

    /// x^T self x for square self.
    pub fn quad_form(&self, x: &[f64]) -> f64 {
        assert_eq!(self.rows, self.cols);
        assert_eq!(x.len(), self.rows);
        let mut acc = 0.0;
        for i in 0..self.rows {
            acc += x[i] * dot(self.row(i), x);
        }
        acc
    }

    /// Cholesky factor L (lower) with self = L L^T. Errors if not SPD.
    pub fn cholesky(&self) -> Result<Matrix, String> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 {
                        return Err(format!("not SPD at pivot {i}: {sum}"));
                    }
                    l[(i, i)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(l)
    }

    /// Frobenius-norm distance to another matrix (test helper).
    pub fn frob_dist(&self, other: &Matrix) -> f64 {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Packed symmetric quadratic form `q(x) = x^T A x + b^T x + c`, evaluated
/// in a single pass over a lower-triangular layout.
///
/// Row `i` stores `[A_i0 + A_0i, ..., A_i(i-1) + A_(i-1)i, A_ii]` (off-
/// diagonal pairs pre-folded via symmetry), so
///
///   q(x) = c + Σ_i x_i · (dot(row_i, x[..=i]) + b_i)
///
/// touches each of the n(n+1)/2 packed coefficients exactly once — half the
/// memory traffic of `Matrix::quad_form` on the dense square — and fuses the
/// linear term and constant into the same sweep. `PseudoPosterior` caches one
/// of these per chain for the collapsed-bound + Gaussian-prior base density,
/// making the FlyMC base evaluation a single allocation-free pass.
#[derive(Clone, Debug)]
pub struct PackedQuadForm {
    n: usize,
    /// packed lower-triangular rows, row-major: lengths 1, 2, ..., n
    tri: Vec<f64>,
    /// linear coefficients b
    lin: Vec<f64>,
    /// constant offset c
    c: f64,
}

impl PackedQuadForm {
    /// Build from a dense (symmetric up to storage) matrix `a`, linear term
    /// `b`, and constant `c`. Off-diagonal pairs are folded as `A_ij + A_ji`,
    /// so a non-symmetric `a` still yields the correct quadratic form.
    pub fn from_symmetric(a: &Matrix, b: &[f64], c: f64) -> Self {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        assert_eq!(b.len(), n);
        let mut tri = Vec::with_capacity(n * (n + 1) / 2);
        for i in 0..n {
            for j in 0..i {
                tri.push(a[(i, j)] + a[(j, i)]);
            }
            tri.push(a[(i, i)]);
        }
        PackedQuadForm { n, tri, lin: b.to_vec(), c }
    }

    /// Dimension n of the quadratic form.
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Add `w` to every diagonal coefficient (folds an isotropic quadratic
    /// such as a Gaussian prior's `-||x||^2 / 2s^2` into the form).
    pub fn add_diag(&mut self, w: f64) {
        let mut off = 0;
        for i in 0..self.n {
            off += i + 1;
            self.tri[off - 1] += w;
        }
    }

    /// Add to the constant offset.
    pub fn add_const(&mut self, c: f64) {
        self.c += c;
    }

    /// Evaluate `x^T A x + b^T x + c` — one pass, no allocation.
    pub fn eval(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.n);
        let mut acc = self.c;
        let mut off = 0;
        for i in 0..self.n {
            let row = &self.tri[off..off + i + 1];
            off += i + 1;
            acc += x[i] * (dot(row, &x[..=i]) + self.lin[i]);
        }
        acc
    }
}

// The blessed inner-loop idioms — `dot`'s canonical association tree and
// `axpy` — live in `crate::kernels` (one copy repo-wide, shared by the
// scalar and vector lane paths); re-exported here because linear algebra
// is where every other consumer historically imported them from.
pub use crate::kernels::{axpy, dot};

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean distance.
#[inline]
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dot_matches_naive() {
        let mut r = Rng::new(1);
        for len in [0, 1, 3, 4, 7, 51, 256] {
            let a: Vec<f64> = (0..len).map(|_| r.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| r.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10, "len {len}");
        }
    }

    #[test]
    fn matvec_and_transpose() {
        let m = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let mut y = vec![0.0; 3];
        m.matvec(&[1.0, -1.0], &mut y);
        assert_eq!(y, vec![-1.0, -1.0, -1.0]);
        let mut z = vec![0.0; 2];
        m.matvec_t(&[1.0, 1.0, 1.0], &mut z);
        assert_eq!(z, vec![9.0, 12.0]);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(2);
        let data: Vec<f64> = (0..12).map(|_| r.normal()).collect();
        let m = Matrix::from_vec(3, 4, data);
        let i3 = Matrix::identity(3);
        assert!(i3.matmul(&m).frob_dist(&m) < 1e-14);
    }

    #[test]
    fn outer_accumulation_matches_matmul() {
        let mut r = Rng::new(3);
        let rows: Vec<Vec<f64>> = (0..10)
            .map(|_| (0..5).map(|_| r.normal()).collect())
            .collect();
        let w: Vec<f64> = (0..10).map(|_| r.f64() + 0.1).collect();
        let mut s = Matrix::zeros(5, 5);
        for (row, &wi) in rows.iter().zip(&w) {
            s.add_weighted_outer(wi, row);
        }
        // compare with X^T diag(w) X
        let x = Matrix::from_rows(rows);
        let mut wx = x.clone();
        for i in 0..10 {
            let wi = w[i];
            for v in wx.row_mut(i) {
                *v *= wi;
            }
        }
        let expect = x.transpose().matmul(&wx);
        assert!(s.frob_dist(&expect) < 1e-12);
    }

    #[test]
    fn quad_form_matches_matvec() {
        let mut r = Rng::new(4);
        let mut s = Matrix::zeros(6, 6);
        for _ in 0..8 {
            let v: Vec<f64> = (0..6).map(|_| r.normal()).collect();
            s.add_weighted_outer(1.0, &v);
        }
        let x: Vec<f64> = (0..6).map(|_| r.normal()).collect();
        let mut sx = vec![0.0; 6];
        s.matvec(&x, &mut sx);
        assert!((s.quad_form(&x) - dot(&x, &sx)).abs() < 1e-10);
        assert!(s.quad_form(&x) >= 0.0); // PSD by construction
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut r = Rng::new(5);
        let mut s = Matrix::identity(5);
        for _ in 0..10 {
            let v: Vec<f64> = (0..5).map(|_| r.normal()).collect();
            s.add_weighted_outer(0.5, &v);
        }
        let l = s.cholesky().unwrap();
        let recon = l.matmul(&l.transpose());
        assert!(recon.frob_dist(&s) < 1e-10);
        // strictly upper entries are zero
        for i in 0..5 {
            for j in i + 1..5 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn packed_quad_form_matches_dense_evaluation() {
        let mut r = Rng::new(6);
        for n in [1usize, 2, 5, 13] {
            // symmetric PSD-ish A from rank-1 accumulation
            let mut a = Matrix::zeros(n, n);
            for _ in 0..n + 2 {
                let v: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                a.add_weighted_outer(r.normal(), &v);
            }
            let b: Vec<f64> = (0..n).map(|_| r.normal()).collect();
            let c = r.normal();
            let q = PackedQuadForm::from_symmetric(&a, &b, c);
            assert_eq!(q.dim(), n);
            for _ in 0..10 {
                let x: Vec<f64> = (0..n).map(|_| r.normal()).collect();
                let dense = a.quad_form(&x) + dot(&b, &x) + c;
                let packed = q.eval(&x);
                assert!(
                    (dense - packed).abs() < 1e-10 * (1.0 + dense.abs()),
                    "n={n}: dense {dense} vs packed {packed}"
                );
            }
        }
    }

    #[test]
    fn packed_quad_form_diag_and_const_folding() {
        let a = Matrix::from_rows(vec![vec![2.0, 1.0], vec![1.0, 3.0]]);
        let mut q = PackedQuadForm::from_symmetric(&a, &[0.5, -1.0], 4.0);
        q.add_diag(-0.25);
        q.add_const(1.5);
        let x = [1.0, 2.0];
        // x^T A x = 2 + 2*2 + 4*3 = 18; diag adds -0.25*(1+4) = -1.25
        // b^T x = 0.5 - 2 = -1.5; c = 5.5
        let expect = 18.0 - 1.25 - 1.5 + 5.5;
        assert!((q.eval(&x) - expect).abs() < 1e-12, "{}", q.eval(&x));
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let m = Matrix::from_rows(vec![vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(m.cholesky().is_err());
    }
}
