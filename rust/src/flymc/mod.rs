//! Firefly Monte Carlo core: the auxiliary-variable machinery of the paper.

pub mod bright_set;
pub mod pseudo;
pub mod reanchor;

pub use bright_set::BrightSet;
pub use pseudo::{FullPosterior, PseudoPosterior, ZStats};
pub use reanchor::ReanchorState;
