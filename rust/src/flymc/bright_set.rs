//! The O(1) bright/dark index structure of paper §3.3 / Fig 3.
//!
//! Two arrays of length N: `arr` holds a permutation of 0..N with all bright
//! indices before all dark ones (`nb` marks the boundary); `tab[n]` is the
//! position of datum n inside `arr`. `brighten`/`darken` are a swap + two
//! table updates; `ith_bright`/`ith_dark`/`is_bright` are direct lookups.

/// O(1) bright/dark membership structure over data indices 0..N (see the
/// module docs for the permutation/table layout).
///
/// `brighten`/`darken` are idempotent O(1) flips, and the bright set is
/// always readable as a contiguous `u32` prefix without copying:
///
/// ```
/// use firefly::flymc::BrightSet;
///
/// let mut z = BrightSet::new(5); // all dark
/// z.brighten(3);
/// z.brighten(3); // idempotent
/// assert!(z.is_bright(3));
/// assert_eq!(z.n_bright(), 1);
/// assert_eq!(z.bright_slice(), &[3]); // the u32 prefix, no copy
/// z.darken(3);
/// assert_eq!(z.n_bright(), 0);
/// assert_eq!(z.n_dark(), 5);
/// ```
#[derive(Clone, Debug)]
pub struct BrightSet {
    arr: Vec<u32>,
    tab: Vec<u32>,
    nb: usize,
}

impl BrightSet {
    /// All-dark initial state over n data points.
    pub fn new(n: usize) -> Self {
        assert!(n <= u32::MAX as usize);
        BrightSet {
            arr: (0..n as u32).collect(),
            tab: (0..n as u32).collect(),
            nb: 0,
        }
    }

    /// Total number of data points N.
    #[inline]
    pub fn len(&self) -> usize {
        self.arr.len()
    }

    /// Whether the structure tracks zero data points.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.arr.is_empty()
    }

    /// Number of bright points M.
    #[inline]
    pub fn n_bright(&self) -> usize {
        self.nb
    }

    /// Number of dark points N - M.
    #[inline]
    pub fn n_dark(&self) -> usize {
        self.arr.len() - self.nb
    }

    /// Whether datum `n` is currently bright (z_n = 1).
    #[inline]
    pub fn is_bright(&self, n: usize) -> bool {
        (self.tab[n] as usize) < self.nb
    }

    /// The i-th bright datum (arbitrary but stable-between-mutations order).
    #[inline]
    pub fn ith_bright(&self, i: usize) -> usize {
        debug_assert!(i < self.nb);
        self.arr[i] as usize
    }

    /// The i-th dark datum.
    #[inline]
    pub fn ith_dark(&self, i: usize) -> usize {
        debug_assert!(i < self.n_dark());
        self.arr[self.nb + i] as usize
    }

    /// All bright indices (prefix of `arr`).
    #[inline]
    pub fn bright_slice(&self) -> &[u32] {
        &self.arr[..self.nb]
    }

    /// Set z_n = 1. O(1). No-op if already bright.
    // lint: zero-alloc
    pub fn brighten(&mut self, n: usize) {
        let pos = self.tab[n] as usize;
        if pos < self.nb {
            return;
        }
        let boundary = self.nb;
        self.swap_positions(pos, boundary);
        self.nb += 1;
    }

    /// Set z_n = 0. O(1). No-op if already dark.
    // lint: zero-alloc
    pub fn darken(&mut self, n: usize) {
        let pos = self.tab[n] as usize;
        if pos >= self.nb {
            return;
        }
        let boundary = self.nb - 1;
        self.swap_positions(pos, boundary);
        self.nb -= 1;
    }

    #[inline]
    // lint: zero-alloc
    fn swap_positions(&mut self, a: usize, b: usize) {
        let (na, nbv) = (self.arr[a], self.arr[b]);
        self.arr.swap(a, b);
        self.tab[na as usize] = b as u32;
        self.tab[nbv as usize] = a as u32;
    }

    /// Serialize the exact permutation state (`arr` + boundary). The
    /// membership *set* alone is not enough for bit-identical resume: the
    /// order of `arr` determines which dark points the geometric-skip
    /// z-resampler visits and how future `brighten`/`darken` swaps permute
    /// the array, so the whole permutation is captured (`tab` is derived
    /// from `arr` on restore).
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.usize(self.nb);
        w.u32_slice(&self.arr);
    }

    /// Rebuild a set from [`Self::save_state`] bytes, validating that the
    /// payload is a permutation of `0..n` with a sane boundary.
    pub fn load_state(r: &mut crate::util::codec::ByteReader) -> Result<BrightSet, String> {
        let nb = r.usize()?;
        let arr = r.u32_vec()?;
        if nb > arr.len() {
            return Err(format!("bright boundary {nb} exceeds n = {}", arr.len()));
        }
        let mut tab = vec![u32::MAX; arr.len()];
        for (pos, &v) in arr.iter().enumerate() {
            let vu = v as usize;
            if vu >= arr.len() || tab[vu] != u32::MAX {
                return Err(format!("arr is not a permutation at position {pos}"));
            }
            tab[vu] = pos as u32;
        }
        Ok(BrightSet { arr, tab, nb })
    }

    /// Debug invariant check (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.arr.len();
        if self.tab.len() != n {
            return Err("tab length mismatch".into());
        }
        let mut seen = vec![false; n];
        for (pos, &v) in self.arr.iter().enumerate() {
            let v = v as usize;
            if v >= n || seen[v] {
                return Err(format!("arr is not a permutation at pos {pos}"));
            }
            seen[v] = true;
            if self.tab[v] as usize != pos {
                return Err(format!("tab[{v}] != position {pos}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;
    use crate::util::Rng;

    #[test]
    fn fig3_example() {
        // Fig 3: data points 1 and 3 bright, rest dark (N=6).
        let mut z = BrightSet::new(6);
        z.brighten(1);
        z.brighten(3);
        assert_eq!(z.n_bright(), 2);
        assert!(z.is_bright(1) && z.is_bright(3));
        assert!(!z.is_bright(0) && !z.is_bright(2) && !z.is_bright(4) && !z.is_bright(5));
        let brights: Vec<usize> = (0..2).map(|i| z.ith_bright(i)).collect();
        let mut sorted = brights.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![1, 3]);
        z.check_invariants().unwrap();
    }

    #[test]
    fn brighten_darken_idempotent() {
        let mut z = BrightSet::new(4);
        z.brighten(2);
        z.brighten(2);
        assert_eq!(z.n_bright(), 1);
        z.darken(2);
        z.darken(2);
        assert_eq!(z.n_bright(), 0);
        z.check_invariants().unwrap();
    }

    #[test]
    fn random_ops_preserve_invariants_and_match_reference() {
        // Miri runs this too (it is exactly the index-juggling code Miri is
        // for) but with fewer random cases to keep the nightly job fast.
        testing::check_msg(
            "bright_set vs naive reference",
            if cfg!(miri) { 3 } else { 30 },
            |r| {
                let n = 1 + r.below(200);
                let ops: Vec<(bool, usize)> =
                    (0..500).map(|_| (r.bernoulli(0.5), r.below(n))).collect();
                (n, ops)
            },
            |(n, ops)| {
                let mut z = BrightSet::new(*n);
                let mut reference = vec![false; *n];
                for &(brighten, idx) in ops {
                    if brighten {
                        z.brighten(idx);
                        reference[idx] = true;
                    } else {
                        z.darken(idx);
                        reference[idx] = false;
                    }
                    z.check_invariants()?;
                }
                let want: usize = reference.iter().filter(|&&b| b).count();
                if z.n_bright() != want {
                    return Err(format!("count {} vs {}", z.n_bright(), want));
                }
                for i in 0..*n {
                    if z.is_bright(i) != reference[i] {
                        return Err(format!("membership mismatch at {i}"));
                    }
                }
                // bright_slice enumerates exactly the bright set
                let mut got: Vec<u32> = z.bright_slice().to_vec();
                got.sort_unstable();
                let mut expect: Vec<u32> = (0..*n as u32)
                    .filter(|&i| reference[i as usize])
                    .collect();
                expect.sort_unstable();
                if got != expect {
                    return Err("bright_slice mismatch".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn state_roundtrip_preserves_exact_permutation() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let mut rng = Rng::new(11);
        let mut z = BrightSet::new(64);
        for _ in 0..200 {
            let i = rng.below(64);
            if rng.bernoulli(0.5) {
                z.brighten(i);
            } else {
                z.darken(i);
            }
        }
        let mut w = ByteWriter::new();
        z.save_state(&mut w);
        let bytes = w.into_bytes();
        let got = BrightSet::load_state(&mut ByteReader::new(&bytes)).unwrap();
        got.check_invariants().unwrap();
        assert_eq!(got.n_bright(), z.n_bright());
        // exact permutation, not just the same set: ith_dark order matters
        for i in 0..z.n_bright() {
            assert_eq!(got.ith_bright(i), z.ith_bright(i));
        }
        for i in 0..z.n_dark() {
            assert_eq!(got.ith_dark(i), z.ith_dark(i));
        }

        // corrupt payloads are rejected
        let mut w = ByteWriter::new();
        w.usize(1);
        w.u32_slice(&[0, 0, 2]); // duplicate => not a permutation
        let bytes = w.into_bytes();
        assert!(BrightSet::load_state(&mut ByteReader::new(&bytes)).is_err());
        let mut w = ByteWriter::new();
        w.usize(5); // boundary beyond n
        w.u32_slice(&[0, 1]);
        let bytes = w.into_bytes();
        assert!(BrightSet::load_state(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn ith_dark_enumerates_dark_set() {
        let mut rng = Rng::new(3);
        let mut z = BrightSet::new(50);
        for _ in 0..20 {
            z.brighten(rng.below(50));
        }
        let mut darks: Vec<usize> = (0..z.n_dark()).map(|i| z.ith_dark(i)).collect();
        darks.sort_unstable();
        let expect: Vec<usize> = (0..50).filter(|&i| !z.is_bright(i)).collect();
        assert_eq!(darks, expect);
    }
}
