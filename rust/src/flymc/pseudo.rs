//! The FlyMC augmented posterior and the regular full-data posterior.
//!
//! [`PseudoPosterior`] is the paper's Eq. (2): conditioned on the brightness
//! vector z, the θ-density is
//!
//!   log p(θ | z, x) = log p(θ) + Σ_n log B_n(θ)   [collapsed, O(dim²)]
//!                   + Σ_{n bright} log[(L_n-B_n)/B_n]   [M likelihoods]
//!
//! It owns the [`BrightSet`], the per-bright-point likelihood cache, and the
//! two z-resampling schemes (explicit Alg 1, implicit Alg 2). The cache is
//! what makes `q_{b→d} = 1` free: bright points' pseudo-likelihoods at the
//! committed θ are always in `ll`/`lb`.
//!
//! ## Zero-allocation hot path
//!
//! Steady-state iterations with **any** of the paper's θ-samplers —
//! gradient-free (random-walk MH, slice) *and* gradient-based (MALA) —
//! perform no heap allocation:
//!
//! * the bright index set reaches the backend as
//!   [`BrightSet::bright_slice`] — the `u32` prefix of the set's own
//!   permutation array, never a widened copy;
//! * every buffer the θ-eval and z-resampling paths write (`memo_*`,
//!   `scratch_*`) is owned by the posterior and reserved to its worst-case
//!   size (N elements) at construction, so `clear`/`extend` never reallocate;
//! * the gradient path writes into caller-owned buffers end to end:
//!   [`Target::grad_log_density`](crate::samplers::Target::grad_log_density)
//!   fills the sampler-owned `grad` slice, the backends accumulate per-datum
//!   gradients through their own [`EvalScratch`] arenas, and the collapsed
//!   bound-product gradient uses the posterior-owned scratch instead of a
//!   dim-sized temporary;
//! * the base density (prior + collapsed bound product) is one pass over a
//!   cached [`PackedQuadForm`] whenever the model exposes its collapse as a
//!   quadratic and the prior is an isotropic Gaussian (logistic/robust +
//!   IsoGaussian); otherwise it falls back to the two-call form, which is
//!   also allocation-free (softmax evaluates through the same scratch).
//!
//! The invariant is enforced per paper task by counting-allocator tests
//! (`rust/tests/integration_hotpath*.rs`, one binary per scenario because
//! the counter is process-global) and tracked by `benches/hotpath.rs`.
//!
//! The eval/memo/resample sweeps are storage-agnostic: every feature read
//! goes through the backend's [`crate::data::store::DataStore`] access
//! (scratch-owned row caches, gathered `W = 8` lanes at a time into the
//! SoA kernel tiles — [`crate::kernels`], DESIGN.md §Kernels), so the same
//! zero-allocation guarantees — and byte-identical traces — hold whether
//! the dataset is resident or block-cached out of core, and whether the
//! kernels run the scalar or the vector lane path (DESIGN.md §Storage;
//! the hotpath binaries measure both stores).
//!
//! [`FullPosterior`] is the regular-MCMC baseline: log p(θ) + Σ_n log L_n
//! evaluated over all N data at every query.

use std::sync::Arc;

use super::bright_set::BrightSet;
use crate::linalg::PackedQuadForm;
use crate::models::{log_pseudo_lik, p_bright, EvalScratch, ModelBound, Prior};
use crate::runtime::evaluator::BatchEval;
use crate::samplers::target::{SubsampleTarget, Target};

/// Outcome of one z-resampling sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct ZStats {
    /// z-flips proposed this sweep
    pub proposals: usize,
    /// dark→bright transitions accepted
    pub brightened: usize,
    /// bright→dark transitions accepted
    pub darkened: usize,
}

/// The FlyMC augmented posterior over θ conditioned on the brightness
/// vector z (paper Eq. 2) — see the module docs for the invariants.
pub struct PseudoPosterior {
    /// likelihood + collapsible bound
    pub model: Arc<dyn ModelBound>,
    /// prior over the flattened parameter vector
    pub prior: Arc<dyn Prior>,
    /// likelihood evaluation backend
    pub eval: Box<dyn BatchEval>,
    /// the O(1) bright/dark index structure
    pub bright: BrightSet,
    theta: Vec<f64>,
    /// per-datum cached log L / log B at the committed theta (valid where bright)
    ll: Vec<f64>,
    lb: Vec<f64>,
    pseudo_sum: f64,
    base: f64, // prior + collapsed bound product at committed theta
    /// fused prior + collapsed-bound quadratic, cached at construction
    /// (sufficient statistics cannot change behind the `Arc`)
    base_quad: Option<PackedQuadForm>,
    // memo of the last off-state evaluation (same bright set)
    memo_theta: Vec<f64>,
    memo_ll: Vec<f64>,
    memo_lb: Vec<f64>,
    memo_pseudo_sum: f64,
    memo_base: f64,
    memo_valid: bool,
    // reusable scratch arena for the z-resampling sweeps (reserved to N)
    scratch_idx: Vec<u32>,
    scratch_bright: Vec<u32>,
    scratch_ll: Vec<f64>,
    scratch_lb: Vec<f64>,
    /// model-evaluation scratch for the posterior's own direct model calls
    /// (collapsed bound-product value/gradient on the non-quadratic base
    /// path) — allocated once here so the gradient path never allocates
    model_scratch: EvalScratch,
    version: u64,
}

impl PseudoPosterior {
    /// Start at `theta0` with an all-dark z (call [`Self::init_z`] next, or
    /// let burn-in brighten points through resampling).
    pub fn new(
        model: Arc<dyn ModelBound>,
        prior: Arc<dyn Prior>,
        eval: Box<dyn BatchEval>,
        theta0: Vec<f64>,
    ) -> Self {
        let n = model.n();
        let dim = model.dim();
        assert_eq!(theta0.len(), dim);
        let mut model_scratch = model.new_scratch();
        let base_quad = model.collapsed_quadratic().and_then(|(a, b, c)| {
            prior.iso_quadratic(dim).map(|(pa, pc)| {
                let mut q = PackedQuadForm::from_symmetric(a, b, c + pc);
                q.add_diag(pa);
                q
            })
        });
        let base = match &base_quad {
            Some(q) => q.eval(&theta0),
            None => {
                prior.log_density(&theta0) + model.log_bound_product(&theta0, &mut model_scratch)
            }
        };
        PseudoPosterior {
            model,
            prior,
            eval,
            bright: BrightSet::new(n),
            theta: theta0,
            ll: vec![0.0; n],
            lb: vec![0.0; n],
            pseudo_sum: 0.0,
            base,
            base_quad,
            memo_theta: Vec::with_capacity(dim),
            memo_ll: Vec::with_capacity(n),
            memo_lb: Vec::with_capacity(n),
            memo_pseudo_sum: 0.0,
            memo_base: 0.0,
            memo_valid: false,
            scratch_idx: Vec::with_capacity(n),
            scratch_bright: Vec::with_capacity(n),
            scratch_ll: Vec::with_capacity(n),
            scratch_lb: Vec::with_capacity(n),
            model_scratch,
            version: 0,
        }
    }

    /// The committed chain state.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// Current number of bright points M (the paper's per-iteration cost).
    pub fn n_bright(&self) -> usize {
        self.bright.n_bright()
    }

    /// Gibbs-initialize z from its exact conditional at the current theta —
    /// one full pass (N likelihood queries, counted; one-time setup).
    pub fn init_z(&mut self, rng: &mut crate::util::Rng) {
        let n = self.model.n();
        self.scratch_idx.clear();
        self.scratch_idx.extend(0..n as u32);
        self.eval.eval(
            &self.theta,
            &self.scratch_idx,
            &mut self.scratch_ll,
            &mut self.scratch_lb,
        );
        self.pseudo_sum = 0.0;
        for i in 0..n {
            // p(z=1 | theta) = (L - B)/L = -expm1(lb - ll)
            if rng.bernoulli(p_bright(self.scratch_ll[i], self.scratch_lb[i])) {
                self.bright.brighten(i);
                self.ll[i] = self.scratch_ll[i];
                self.lb[i] = self.scratch_lb[i];
                self.pseudo_sum += log_pseudo_lik(self.scratch_ll[i], self.scratch_lb[i]);
            } else {
                self.bright.darken(i);
            }
        }
        self.memo_valid = false;
        self.version += 1;
    }

    /// Prior + collapsed-bound log density at `theta` — a single pass over
    /// the cached packed quadratic when available, and the allocation-free
    /// two-call form (through the posterior-owned scratch) otherwise.
    fn base_at(&mut self, theta: &[f64]) -> f64 {
        self.eval.counters().add_collapsed(1);
        Self::base_density(
            &self.base_quad,
            &*self.prior,
            &*self.model,
            &mut self.model_scratch,
            theta,
        )
    }

    /// [`Self::base_at`] over explicitly-borrowed fields, so callers holding
    /// other borrows of `self` (e.g. `&self.theta`) can still evaluate.
    fn base_density(
        base_quad: &Option<PackedQuadForm>,
        prior: &dyn Prior,
        model: &dyn ModelBound,
        scratch: &mut EvalScratch,
        theta: &[f64],
    ) -> f64 {
        match base_quad {
            Some(q) => q.eval(theta),
            None => prior.log_density(theta) + model.log_bound_product(theta, scratch),
        }
    }

    /// Evaluate at `theta` and memoize. Costs n_bright likelihood queries;
    /// the bright index set is the `BrightSet`'s own u32 prefix (no copy).
    // lint: zero-alloc
    fn eval_and_memo(&mut self, theta: &[f64]) -> f64 {
        self.eval.eval(
            theta,
            self.bright.bright_slice(),
            &mut self.memo_ll,
            &mut self.memo_lb,
        );
        let pseudo: f64 = self
            .memo_ll
            .iter()
            .zip(&self.memo_lb)
            .map(|(&l, &b)| log_pseudo_lik(l, b))
            .sum();
        let base = self.base_at(theta);
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_pseudo_sum = pseudo;
        self.memo_base = base;
        self.memo_valid = true;
        base + pseudo
    }

    // lint: zero-alloc
    fn promote_memo(&mut self) {
        debug_assert!(self.memo_valid);
        let brights = self.bright.bright_slice();
        debug_assert_eq!(brights.len(), self.memo_ll.len());
        for (i, &n) in brights.iter().enumerate() {
            self.ll[n as usize] = self.memo_ll[i];
            self.lb[n as usize] = self.memo_lb[i];
        }
        self.pseudo_sum = self.memo_pseudo_sum;
        self.base = self.memo_base;
        self.theta.clear();
        self.theta.extend_from_slice(&self.memo_theta);
        self.memo_valid = false;
    }

    /// Full-data log posterior (instrumentation only: NOT counted as
    /// queries, used for the Fig-4 convergence traces; allocates its own
    /// scratch, so it is deliberately NOT part of the zero-alloc hot path).
    pub fn true_log_posterior(&self, theta: &[f64]) -> f64 {
        let mut scratch = self.model.new_scratch();
        let mut acc = self.prior.log_density(theta);
        for n in 0..self.model.n() {
            acc += self.model.log_lik(theta, n, &mut scratch);
        }
        acc
    }

    // -- z updates ---------------------------------------------------------

    /// Implicit MH resampling of z (paper Alg 2) with q_{b→d} = 1 and the
    /// given q_{d→b}. Bright→dark uses only cached values (no queries);
    /// dark→bright proposes a geometric-skip subset and evaluates just those.
    // lint: zero-alloc
    pub fn implicit_resample(&mut self, q_db: f64, rng: &mut crate::util::Rng) -> ZStats {
        let mut stats = ZStats::default();
        let ln_q = q_db.ln();

        // Every point gets AT MOST ONE proposal per sweep (paper Alg 2's
        // single pass over n): snapshot the dark candidates BEFORE the
        // bright->dark phase, otherwise a point darkened below would receive
        // a second (dark->bright) proposal in the same sweep — that composed
        // kernel is not stationary for p(z | theta) and biases the chain.
        let nd = self.bright.n_dark();
        self.scratch_idx.clear();
        let mut pos = rng.geometric_skip(q_db);
        while pos < nd {
            self.scratch_idx.push(self.bright.ith_dark(pos) as u32);
            pos = pos.saturating_add(1 + rng.geometric_skip(q_db));
        }

        // bright -> dark: accept with min(1, q_db / L~_n). The bright prefix
        // is snapshotted into the scratch arena because darken() permutes it.
        self.scratch_bright.clear();
        self.scratch_bright.extend_from_slice(self.bright.bright_slice());
        for &n in &self.scratch_bright {
            let n = n as usize;
            stats.proposals += 1;
            let lt = log_pseudo_lik(self.ll[n], self.lb[n]);
            if rng.f64_open().ln() < ln_q - lt {
                self.bright.darken(n);
                self.pseudo_sum -= lt;
                stats.darkened += 1;
            }
        }

        // dark -> bright over the pre-phase snapshot (all still dark: the
        // phase above only darkens): accept with min(1, L~_n / q_db).
        self.eval.eval(
            &self.theta,
            &self.scratch_idx,
            &mut self.scratch_ll,
            &mut self.scratch_lb,
        );
        for i in 0..self.scratch_idx.len() {
            let n = self.scratch_idx[i] as usize;
            stats.proposals += 1;
            let lt = log_pseudo_lik(self.scratch_ll[i], self.scratch_lb[i]);
            if rng.f64_open().ln() < lt - ln_q {
                self.bright.brighten(n);
                self.ll[n] = self.scratch_ll[i];
                self.lb[n] = self.scratch_lb[i];
                self.pseudo_sum += lt;
                stats.brightened += 1;
            }
        }
        self.memo_valid = false;
        self.version += 1;
        stats
    }

    /// Explicit Gibbs resampling (paper Alg 1 lines 3–6): `fraction·N`
    /// uniform draws with replacement, each z_n redrawn from its exact
    /// conditional. Every draw costs one likelihood query.
    // lint: zero-alloc
    pub fn explicit_resample(&mut self, fraction: f64, rng: &mut crate::util::Rng) -> ZStats {
        let n = self.model.n();
        let k = ((fraction * n as f64).ceil() as usize).min(n.max(1));
        self.scratch_idx.clear();
        for _ in 0..k {
            self.scratch_idx.push(rng.below(n) as u32);
        }
        self.eval.eval(
            &self.theta,
            &self.scratch_idx,
            &mut self.scratch_ll,
            &mut self.scratch_lb,
        );
        let mut stats = ZStats { proposals: k, ..Default::default() };
        for i in 0..self.scratch_idx.len() {
            let ni = self.scratch_idx[i] as usize;
            let want_bright =
                rng.bernoulli(p_bright(self.scratch_ll[i], self.scratch_lb[i]));
            let is_bright = self.bright.is_bright(ni);
            if want_bright && !is_bright {
                self.bright.brighten(ni);
                self.ll[ni] = self.scratch_ll[i];
                self.lb[ni] = self.scratch_lb[i];
                self.pseudo_sum += log_pseudo_lik(self.scratch_ll[i], self.scratch_lb[i]);
                stats.brightened += 1;
            } else if !want_bright && is_bright {
                self.bright.darken(ni);
                self.pseudo_sum -= log_pseudo_lik(self.ll[ni], self.lb[ni]);
                stats.darkened += 1;
            }
        }
        self.memo_valid = false;
        self.version += 1;
        stats
    }

    /// Serialize every piece of chain state this posterior owns: θ, the
    /// exact [`BrightSet`] permutation, the cached `ll`/`lb` values at the
    /// bright prefix (dark entries are never read before being rewritten,
    /// so they are not captured), the incremental `pseudo_sum`/`base`
    /// accumulators, the distribution-version counter, and the off-state
    /// memo (it determines whether the next evaluation costs queries).
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.usize(self.model.n());
        w.usize(self.model.dim());
        w.f64_slice(&self.theta);
        w.f64(self.pseudo_sum);
        w.f64(self.base);
        w.u64(self.version);
        self.bright.save_state(w);
        let brights = self.bright.bright_slice();
        w.usize(brights.len());
        for &n in brights {
            w.f64(self.ll[n as usize]);
        }
        for &n in brights {
            w.f64(self.lb[n as usize]);
        }
        w.bool(self.memo_valid);
        if self.memo_valid {
            w.f64_slice(&self.memo_theta);
            w.f64_slice(&self.memo_ll);
            w.f64_slice(&self.memo_lb);
            w.f64(self.memo_pseudo_sum);
            w.f64(self.memo_base);
        }
    }

    /// Restore [`Self::save_state`] bytes into a freshly-constructed
    /// posterior over the *same* model/prior/backend (shape-checked).
    /// Restoring never grows the pre-reserved scratch buffers, so the
    /// zero-allocation steady state resumes intact.
    pub fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        let n = r.usize()?;
        let dim = r.usize()?;
        if n != self.model.n() || dim != self.model.dim() {
            return Err(format!(
                "checkpoint is for a {n}×{dim} model, this chain is {}×{}",
                self.model.n(),
                self.model.dim()
            ));
        }
        r.f64_slice_into(&mut self.theta)?;
        if self.theta.len() != dim {
            return Err(format!("theta has {} components, expected {dim}", self.theta.len()));
        }
        self.pseudo_sum = r.f64()?;
        self.base = r.f64()?;
        self.version = r.u64()?;
        let bright = BrightSet::load_state(r)?;
        if bright.len() != n {
            return Err(format!("bright set covers {} points, expected {n}", bright.len()));
        }
        self.bright = bright;
        let nb = r.usize()?;
        if nb != self.bright.n_bright() {
            return Err(format!(
                "cache block has {nb} entries, bright set has {}",
                self.bright.n_bright()
            ));
        }
        for i in 0..nb {
            let idx = self.bright.ith_bright(i);
            self.ll[idx] = r.f64()?;
        }
        for i in 0..nb {
            let idx = self.bright.ith_bright(i);
            self.lb[idx] = r.f64()?;
        }
        self.memo_valid = r.bool()?;
        if self.memo_valid {
            r.f64_slice_into(&mut self.memo_theta)?;
            r.f64_slice_into(&mut self.memo_ll)?;
            r.f64_slice_into(&mut self.memo_lb)?;
            self.memo_pseudo_sum = r.f64()?;
            self.memo_base = r.f64()?;
            if self.memo_theta.len() != dim || self.memo_ll.len() != self.memo_lb.len() {
                return Err("memo block shape mismatch".to_string());
            }
        } else {
            self.memo_theta.clear();
            self.memo_ll.clear();
            self.memo_lb.clear();
        }
        Ok(())
    }

    /// Re-anchor the model's bounds at `anchor` and restart the auxiliary
    /// chain (DESIGN.md §Bound-management). Returns `false` — consuming no
    /// randomness and touching no state — when `anchor` is bitwise equal to
    /// the model's current anchor (the no-op case; trace byte-identity is
    /// preserved). Otherwise:
    ///
    /// 1. swaps in a freshly tuned model clone
    ///    ([`ModelBound::clone_reanchored`]) behind a new `Arc`, so any
    ///    other holder of the old model keeps its frozen bounds;
    /// 2. points the backend at it ([`BatchEval::set_model`]) and rebuilds
    ///    the posterior-owned model scratch and the collapsed
    ///    [`PackedQuadForm`] base exactly as construction does;
    /// 3. recomputes the committed base density under the new bounds;
    /// 4. resamples **all** z from the exact conditional under the new
    ///    bounds via [`Self::init_z`] — one batched full-N pass (N metered
    ///    likelihood queries), which also rebuilds `pseudo_sum`,
    ///    invalidates the memo, and bumps the distribution version so
    ///    gradient samplers drop their caches.
    ///
    /// Together these make the restart a legal Markov transition targeting
    /// the new augmented model (exactness argument in `flymc::reanchor` and
    /// DESIGN.md). Panics if the model cannot re-anchor or the backend
    /// cannot swap models (the XLA backend; configx rejects that pairing up
    /// front).
    pub fn reanchor(&mut self, anchor: &[f64], rng: &mut crate::util::Rng) -> bool {
        if self.model.anchor_theta() == Some(anchor) {
            return false;
        }
        let model = self
            .model
            .clone_reanchored(anchor)
            .expect("model does not support online re-anchoring");
        assert!(
            self.eval.set_model(model.clone()),
            "backend cannot swap models (re-anchoring needs the cpu/parcpu backend)"
        );
        self.model_scratch = model.new_scratch();
        let dim = model.dim();
        self.base_quad = model.collapsed_quadratic().and_then(|(a, b, c)| {
            self.prior.iso_quadratic(dim).map(|(pa, pc)| {
                let mut q = PackedQuadForm::from_symmetric(a, b, c + pc);
                q.add_diag(pa);
                q
            })
        });
        self.model = model;
        self.eval.counters().add_collapsed(1);
        self.base = Self::base_density(
            &self.base_quad,
            &*self.prior,
            &*self.model,
            &mut self.model_scratch,
            &self.theta,
        );
        self.init_z(rng);
        true
    }

    /// Recompute state sums from scratch (test hook: verifies the
    /// incremental bookkeeping).
    pub fn recompute_state(&mut self) -> f64 {
        self.eval.eval(
            &self.theta,
            self.bright.bright_slice(),
            &mut self.scratch_ll,
            &mut self.scratch_lb,
        );
        let pseudo: f64 = self
            .scratch_ll
            .iter()
            .zip(&self.scratch_lb)
            .map(|(&l, &b)| log_pseudo_lik(l, b))
            .sum();
        self.eval.counters().add_collapsed(1);
        let base = Self::base_density(
            &self.base_quad,
            &*self.prior,
            &*self.model,
            &mut self.model_scratch,
            &self.theta,
        );
        self.pseudo_sum = pseudo;
        self.base = base;
        base + pseudo
    }
}

impl Target for PseudoPosterior {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn log_density(&mut self, theta: &[f64]) -> f64 {
        if theta == self.theta.as_slice() {
            return self.current_log_density();
        }
        if self.memo_valid && theta == self.memo_theta.as_slice() {
            return self.memo_base + self.memo_pseudo_sum;
        }
        self.eval_and_memo(theta)
    }

    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        grad.fill(0.0);
        self.eval.eval_pseudo_grad(
            theta,
            self.bright.bright_slice(),
            &mut self.memo_ll,
            &mut self.memo_lb,
            grad,
        );
        let pseudo: f64 = self
            .memo_ll
            .iter()
            .zip(&self.memo_lb)
            .map(|(&l, &b)| log_pseudo_lik(l, b))
            .sum();
        let base = self.base_at(theta);
        self.prior.grad_acc(theta, grad);
        self.model.grad_log_bound_product_acc(theta, grad, &mut self.model_scratch);
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_pseudo_sum = pseudo;
        self.memo_base = base;
        self.memo_valid = true;
        base + pseudo
    }

    fn commit(&mut self, theta: &[f64]) {
        if theta == self.theta.as_slice() {
            return;
        }
        if !(self.memo_valid && theta == self.memo_theta.as_slice()) {
            self.eval_and_memo(theta);
        }
        self.promote_memo();
    }

    fn current_log_density(&self) -> f64 {
        self.base + self.pseudo_sum
    }

    fn version(&self) -> u64 {
        self.version
    }
}

// ---------------------------------------------------------------------------

/// Regular full-data posterior (the paper's baseline): every evaluation
/// queries all N likelihoods.
pub struct FullPosterior {
    /// the likelihood model (bounds unused on this baseline)
    pub model: Arc<dyn ModelBound>,
    /// prior over the flattened parameter vector
    pub prior: Arc<dyn Prior>,
    /// likelihood evaluation backend
    pub eval: Box<dyn BatchEval>,
    idx_all: Vec<u32>,
    theta: Vec<f64>,
    cur_logp: f64,
    memo_theta: Vec<f64>,
    memo_logp: f64,
    memo_valid: bool,
    scratch_ll: Vec<f64>,
}

impl FullPosterior {
    /// Build the baseline posterior and evaluate it at `theta0` (costs N
    /// likelihood queries).
    pub fn new(
        model: Arc<dyn ModelBound>,
        prior: Arc<dyn Prior>,
        mut eval: Box<dyn BatchEval>,
        theta0: Vec<f64>,
    ) -> Self {
        let n = model.n();
        let idx_all: Vec<u32> = (0..n as u32).collect();
        let mut ll = Vec::new();
        eval.eval_lik(&theta0, &idx_all, &mut ll);
        let cur_logp = prior.log_density(&theta0) + ll.iter().sum::<f64>();
        FullPosterior {
            model,
            prior,
            eval,
            idx_all,
            theta: theta0,
            cur_logp,
            memo_theta: Vec::new(),
            memo_logp: 0.0,
            memo_valid: false,
            scratch_ll: ll,
        }
    }

    /// The committed chain state.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// Full-data log posterior (instrumentation; allocates its own scratch).
    pub fn true_log_posterior(&self, theta: &[f64]) -> f64 {
        let mut scratch = self.model.new_scratch();
        let mut acc = self.prior.log_density(theta);
        for n in 0..self.model.n() {
            acc += self.model.log_lik(theta, n, &mut scratch);
        }
        acc
    }

    /// Serialize the baseline's chain state (θ, cached log posterior, memo).
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.usize(self.model.n());
        w.usize(self.model.dim());
        w.f64_slice(&self.theta);
        w.f64(self.cur_logp);
        w.bool(self.memo_valid);
        if self.memo_valid {
            w.f64_slice(&self.memo_theta);
            w.f64(self.memo_logp);
        }
    }

    /// Restore [`Self::save_state`] bytes into a posterior over the same
    /// model/prior/backend (shape-checked).
    pub fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        let n = r.usize()?;
        let dim = r.usize()?;
        if n != self.model.n() || dim != self.model.dim() {
            return Err(format!(
                "checkpoint is for a {n}×{dim} model, this chain is {}×{}",
                self.model.n(),
                self.model.dim()
            ));
        }
        r.f64_slice_into(&mut self.theta)?;
        if self.theta.len() != dim {
            return Err(format!("theta has {} components, expected {dim}", self.theta.len()));
        }
        self.cur_logp = r.f64()?;
        self.memo_valid = r.bool()?;
        if self.memo_valid {
            r.f64_slice_into(&mut self.memo_theta)?;
            self.memo_logp = r.f64()?;
            if self.memo_theta.len() != dim {
                return Err("memo block shape mismatch".to_string());
            }
        } else {
            self.memo_theta.clear();
        }
        Ok(())
    }
}

impl Target for FullPosterior {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn log_density(&mut self, theta: &[f64]) -> f64 {
        if theta == self.theta.as_slice() {
            return self.cur_logp;
        }
        if self.memo_valid && theta == self.memo_theta.as_slice() {
            return self.memo_logp;
        }
        self.eval.eval_lik(theta, &self.idx_all, &mut self.scratch_ll);
        let logp = self.prior.log_density(theta) + self.scratch_ll.iter().sum::<f64>();
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_logp = logp;
        self.memo_valid = true;
        logp
    }

    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        grad.fill(0.0);
        self.eval
            .eval_lik_grad(theta, &self.idx_all, &mut self.scratch_ll, grad);
        let logp = self.prior.log_density(theta) + self.scratch_ll.iter().sum::<f64>();
        self.prior.grad_acc(theta, grad);
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_logp = logp;
        self.memo_valid = true;
        logp
    }

    fn commit(&mut self, theta: &[f64]) {
        if theta == self.theta.as_slice() {
            return;
        }
        let logp = if self.memo_valid && theta == self.memo_theta.as_slice() {
            self.memo_logp
        } else {
            self.log_density(theta)
        };
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self.cur_logp = logp;
        self.memo_valid = false;
    }

    fn current_log_density(&self) -> f64 {
        self.cur_logp
    }

    fn as_subsample(&mut self) -> Option<&mut dyn SubsampleTarget> {
        Some(self)
    }
}

impl SubsampleTarget for FullPosterior {
    fn n_data(&self) -> usize {
        self.model.n()
    }

    // lint: zero-alloc
    fn minibatch_log_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>) {
        self.eval.eval_lik(theta, idx, ll);
    }

    // lint: zero-alloc
    fn minibatch_grad_acc(&mut self, theta: &[f64], idx: &[u32], grad: &mut [f64]) -> f64 {
        self.eval.eval_lik_grad(theta, idx, &mut self.scratch_ll, grad);
        self.scratch_ll.iter().sum()
    }

    fn prior_log_density(&self, theta: &[f64]) -> f64 {
        self.prior.log_density(theta)
    }

    fn prior_grad_acc(&self, theta: &[f64], grad: &mut [f64]) {
        self.prior.grad_acc(theta, grad);
    }

    // lint: zero-alloc
    fn set_state(&mut self, theta: &[f64], log_density_estimate: f64) {
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self.cur_logp = log_density_estimate;
        // The estimate was formed from a subsample, so the memo (an exact
        // full-data evaluation, when valid) must not survive a state whose
        // log density is approximate.
        self.memo_valid = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::Counters;
    use crate::models::{IsoGaussian, Laplace, LogisticJJ};
    use crate::runtime::cpu_backend::CpuBackend;
    use crate::util::Rng;

    fn setup(n: usize, seed: u64) -> (PseudoPosterior, Counters) {
        let data = Arc::new(synth::synth_mnist(n, 8, seed));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(seed);
        let theta0: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.3).collect();
        (PseudoPosterior::new(model, prior, eval, theta0), counters)
    }

    #[test]
    fn incremental_state_matches_recompute_after_resampling() {
        let (mut pp, _) = setup(300, 1);
        let mut rng = Rng::new(42);
        pp.init_z(&mut rng);
        for it in 0..20 {
            if it % 2 == 0 {
                pp.implicit_resample(0.05, &mut rng);
            } else {
                pp.explicit_resample(0.1, &mut rng);
            }
            let cached = pp.current_log_density();
            let fresh = pp.recompute_state();
            assert!(
                (cached - fresh).abs() < 1e-8 * (1.0 + fresh.abs()),
                "iter {it}: cached {cached} vs fresh {fresh}"
            );
        }
    }

    #[test]
    fn fused_base_matches_two_call_form() {
        // The cached packed quadratic must agree with
        // prior.log_density + model.log_bound_product to float tolerance,
        // and the non-quadratic (Laplace) prior must take the fallback and
        // agree trivially.
        let data = Arc::new(synth::synth_mnist(200, 10, 17));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let mut rng = Rng::new(3);
        for gaussian in [true, false] {
            let prior: Arc<dyn Prior> = if gaussian {
                Arc::new(IsoGaussian { scale: 0.8 })
            } else {
                Arc::new(Laplace { b: 0.8 })
            };
            let counters = Counters::new();
            let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
            let theta0: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.3).collect();
            let mut pp = PseudoPosterior::new(model.clone(), prior.clone(), eval, theta0);
            assert_eq!(pp.base_quad.is_some(), gaussian);
            let mut sc = model.new_scratch();
            for _ in 0..10 {
                let theta: Vec<f64> =
                    (0..model.dim()).map(|_| rng.normal() * 0.5).collect();
                let fused = pp.base_at(&theta);
                let direct =
                    prior.log_density(&theta) + model.log_bound_product(&theta, &mut sc);
                assert!(
                    (fused - direct).abs() < 1e-8 * (1.0 + direct.abs()),
                    "fused {fused} vs direct {direct}"
                );
            }
        }
    }

    #[test]
    fn commit_after_eval_is_query_free() {
        let (mut pp, counters) = setup(200, 2);
        let mut rng = Rng::new(7);
        pp.init_z(&mut rng);
        let m = pp.n_bright();
        let theta2: Vec<f64> = pp.theta().iter().map(|t| t + 0.01).collect();
        let before = counters.lik_queries();
        let lp = pp.log_density(&theta2);
        assert_eq!(counters.lik_queries() - before, m as u64);
        let mid = counters.lik_queries();
        pp.commit(&theta2); // memo hit: no new queries
        assert_eq!(counters.lik_queries(), mid);
        assert!((pp.current_log_density() - lp).abs() < 1e-12);
        // and the cache is consistent
        let fresh = pp.recompute_state();
        assert!((fresh - lp).abs() < 1e-8 * (1.0 + lp.abs()));
    }

    /// Shared harness: after many implicit sweeps at fixed theta, the
    /// empirical bright frequency of each datum must match the exact
    /// conditional p(z=1|theta) = 1 - B/L.
    fn check_marginal_matches_conditional(pp: &mut PseudoPosterior, seed: u64, tol: f64) {
        let n = pp.model.n();
        let mut rng = Rng::new(seed);
        pp.init_z(&mut rng);
        let sweeps = 4000;
        let mut freq = vec![0usize; n];
        for _ in 0..sweeps {
            pp.implicit_resample(0.3, &mut rng);
            for i in 0..n {
                if pp.bright.is_bright(i) {
                    freq[i] += 1;
                }
            }
        }
        let theta = pp.theta().to_vec();
        let mut sc = pp.model.new_scratch();
        let mut max_err: f64 = 0.0;
        for i in 0..n {
            let (ll, lb) = pp.model.log_both(&theta, i, &mut sc);
            let p = p_bright(ll, lb);
            let emp = freq[i] as f64 / sweeps as f64;
            max_err = max_err.max((emp - p).abs());
        }
        assert!(max_err < tol, "max |emp - exact| = {max_err}");
    }

    #[test]
    fn marginal_bright_probability_matches_conditional() {
        let (mut pp, _) = setup(60, 3);
        check_marginal_matches_conditional(&mut pp, 9, 0.05);
    }

    #[test]
    fn marginal_bright_probability_matches_conditional_map_tuned() {
        // MAP-tuned bounds are tight near the committed theta, exercising
        // the p_bright cancellation fix and the u32/scratch resampling path
        // in the near-zero-probability regime: the stationary distribution
        // must still match the conditional.
        let data = Arc::new(synth::synth_mnist(60, 8, 4));
        let mut raw = LogisticJJ::new(data, 1.5);
        let mut rng = Rng::new(31);
        let theta0: Vec<f64> = (0..raw.dim()).map(|_| rng.normal() * 0.3).collect();
        // anchor slightly off the committed point: p_bright small but nonzero
        let anchor: Vec<f64> = theta0.iter().map(|t| t + 0.05).collect();
        raw.tune_anchors_map(&anchor);
        let model: Arc<dyn ModelBound> = Arc::new(raw);
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters));
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0);
        check_marginal_matches_conditional(&mut pp, 33, 0.03);
    }

    #[test]
    fn pseudo_state_roundtrip_resumes_bit_identically() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let (mut pp, counters) = setup(250, 21);
        let mut rng = Rng::new(99);
        pp.init_z(&mut rng);
        for _ in 0..15 {
            pp.implicit_resample(0.05, &mut rng);
        }
        // leave a live memo so the memo block is exercised
        let theta2: Vec<f64> = pp.theta().iter().map(|t| t + 0.02).collect();
        let _ = pp.log_density(&theta2);
        let mut w = ByteWriter::new();
        pp.save_state(&mut w);
        let bytes = w.into_bytes();

        // twin over the same model/prior/backend, then restore
        let (mut twin, twin_counters) = setup(250, 21);
        let mut r = ByteReader::new(&bytes);
        twin.load_state(&mut r).unwrap();
        r.finish().unwrap();
        twin_counters.restore_totals(&counters.totals());

        assert_eq!(
            twin.current_log_density().to_bits(),
            pp.current_log_density().to_bits()
        );
        assert_eq!(twin.n_bright(), pp.n_bright());
        // memo survives: committing the memoized point costs zero queries
        let before = twin_counters.lik_queries();
        twin.commit(&theta2);
        assert_eq!(twin_counters.lik_queries(), before);
        pp.commit(&theta2);
        // identical evolution from the restored state, bit for bit
        let mut ra = Rng::new(7);
        let mut rb = Rng::new(7);
        for it in 0..10 {
            let sa = pp.implicit_resample(0.1, &mut ra);
            let sb = twin.implicit_resample(0.1, &mut rb);
            assert_eq!(sa.brightened, sb.brightened, "iter {it}");
            assert_eq!(sa.darkened, sb.darkened, "iter {it}");
            assert_eq!(pp.n_bright(), twin.n_bright(), "iter {it}");
            assert_eq!(
                pp.current_log_density().to_bits(),
                twin.current_log_density().to_bits(),
                "iter {it}"
            );
        }
        assert_eq!(counters.lik_queries(), twin_counters.lik_queries());

        // shape mismatch rejected
        let (mut other, _) = setup(100, 3);
        assert!(other.load_state(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn reanchor_restarts_exactly_and_noop_is_free() {
        let data = Arc::new(synth::synth_mnist(200, 8, 12));
        let mut raw = LogisticJJ::new(data, 1.5);
        let mut rng = Rng::new(5);
        let theta0: Vec<f64> = (0..raw.dim()).map(|_| rng.normal() * 0.3).collect();
        // deliberately mis-tuned initial anchor, far from the committed point
        let anchor0: Vec<f64> = theta0.iter().map(|t| t + 0.4).collect();
        raw.tune_anchors_map(&anchor0);
        let model: Arc<dyn ModelBound> = Arc::new(raw);
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
        pp.init_z(&mut rng);
        for _ in 0..10 {
            pp.implicit_resample(0.05, &mut rng);
        }
        let v0 = pp.version();

        // no-op: bitwise-equal anchor consumes no randomness, touches nothing
        let mut rng_noop = Rng::new(77);
        let before = counters.lik_queries();
        assert!(!pp.reanchor(&anchor0, &mut rng_noop));
        assert_eq!(counters.lik_queries(), before);
        assert_eq!(pp.version(), v0);

        // real re-anchor: exactly one metered full-N pass, a version bump so
        // gradient caches drop, the new anchor visible on the model, and the
        // incremental state consistent with a from-scratch recomputation
        assert!(pp.reanchor(&theta0, &mut rng));
        assert_eq!(counters.lik_queries() - before, 200);
        assert!(pp.version() > v0);
        assert_eq!(pp.model.anchor_theta(), Some(theta0.as_slice()));
        let cached = pp.current_log_density();
        let fresh = pp.recompute_state();
        assert!(
            (cached - fresh).abs() < 1e-8 * (1.0 + fresh.abs()),
            "cached {cached} vs fresh {fresh}"
        );
    }

    #[test]
    fn explicit_resample_counts_fraction_of_n_queries() {
        let (mut pp, counters) = setup(500, 4);
        let mut rng = Rng::new(11);
        pp.init_z(&mut rng);
        let before = counters.lik_queries();
        pp.explicit_resample(0.1, &mut rng);
        assert_eq!(counters.lik_queries() - before, 50);
    }

    #[test]
    fn implicit_resample_queries_scale_with_q() {
        let (mut pp, counters) = setup(2000, 5);
        let mut rng = Rng::new(13);
        pp.init_z(&mut rng);
        let before = counters.lik_queries();
        let mut proposals = 0;
        let reps = 50;
        for _ in 0..reps {
            let s = pp.implicit_resample(0.01, &mut rng);
            proposals += s.proposals;
        }
        let queries = (counters.lik_queries() - before) as f64 / reps as f64;
        // ~ q * n_dark per sweep; n_dark ~ 2000 - M
        assert!(queries < 60.0, "queries/sweep {queries}");
        assert!(proposals > 0);
    }

    #[test]
    fn full_posterior_counts_n_per_eval_and_matches_direct() {
        let data = Arc::new(synth::synth_mnist(150, 6, 6));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 2.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let theta0 = vec![0.05; model.dim()];
        let mut fp = FullPosterior::new(model, prior, eval, theta0.clone());
        assert_eq!(counters.lik_queries(), 150);
        let direct = fp.true_log_posterior(&theta0);
        assert!((fp.current_log_density() - direct).abs() < 1e-9);
        let theta1 = vec![0.1; fp.dim()];
        let lp = fp.log_density(&theta1);
        assert_eq!(counters.lik_queries(), 300);
        fp.commit(&theta1);
        assert_eq!(counters.lik_queries(), 300); // memo hit
        assert!((fp.current_log_density() - lp).abs() < 1e-12);
    }
}
