//! The FlyMC augmented posterior and the regular full-data posterior.
//!
//! [`PseudoPosterior`] is the paper's Eq. (2): conditioned on the brightness
//! vector z, the θ-density is
//!
//!   log p(θ | z, x) = log p(θ) + Σ_n log B_n(θ)   [collapsed, O(dim²)]
//!                   + Σ_{n bright} log[(L_n-B_n)/B_n]   [M likelihoods]
//!
//! It owns the [`BrightSet`], the per-bright-point likelihood cache, and the
//! two z-resampling schemes (explicit Alg 1, implicit Alg 2). The cache is
//! what makes `q_{b→d} = 1` free: bright points' pseudo-likelihoods at the
//! committed θ are always in `ll`/`lb`.
//!
//! [`FullPosterior`] is the regular-MCMC baseline: log p(θ) + Σ_n log L_n
//! evaluated over all N data at every query.

use std::sync::Arc;

use super::bright_set::BrightSet;
use crate::models::{log_pseudo_lik, ModelBound, Prior};
use crate::runtime::evaluator::BatchEval;
use crate::samplers::target::Target;

/// Outcome of one z-resampling sweep.
#[derive(Clone, Copy, Debug, Default)]
pub struct ZStats {
    pub proposals: usize,
    pub brightened: usize,
    pub darkened: usize,
}

pub struct PseudoPosterior {
    pub model: Arc<dyn ModelBound>,
    pub prior: Arc<dyn Prior>,
    pub eval: Box<dyn BatchEval>,
    pub bright: BrightSet,
    theta: Vec<f64>,
    /// per-datum cached log L / log B at the committed theta (valid where bright)
    ll: Vec<f64>,
    lb: Vec<f64>,
    pseudo_sum: f64,
    base: f64, // prior + collapsed bound product at committed theta
    // memo of the last off-state evaluation (same bright set)
    memo_theta: Vec<f64>,
    memo_ll: Vec<f64>,
    memo_lb: Vec<f64>,
    memo_pseudo_sum: f64,
    memo_base: f64,
    memo_valid: bool,
    scratch_idx: Vec<usize>,
    scratch_ll: Vec<f64>,
    scratch_lb: Vec<f64>,
    version: u64,
}

impl PseudoPosterior {
    /// Start at `theta0` with an all-dark z (call [`Self::init_z`] next, or
    /// let burn-in brighten points through resampling).
    pub fn new(
        model: Arc<dyn ModelBound>,
        prior: Arc<dyn Prior>,
        eval: Box<dyn BatchEval>,
        theta0: Vec<f64>,
    ) -> Self {
        let n = model.n();
        assert_eq!(theta0.len(), model.dim());
        let base = prior.log_density(&theta0) + model.log_bound_product(&theta0);
        PseudoPosterior {
            model,
            prior,
            eval,
            bright: BrightSet::new(n),
            theta: theta0,
            ll: vec![0.0; n],
            lb: vec![0.0; n],
            pseudo_sum: 0.0,
            base,
            memo_theta: Vec::new(),
            memo_ll: Vec::new(),
            memo_lb: Vec::new(),
            memo_pseudo_sum: 0.0,
            memo_base: 0.0,
            memo_valid: false,
            scratch_idx: Vec::new(),
            scratch_ll: Vec::new(),
            scratch_lb: Vec::new(),
            version: 0,
        }
    }

    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    pub fn n_bright(&self) -> usize {
        self.bright.n_bright()
    }

    /// Gibbs-initialize z from its exact conditional at the current theta —
    /// one full pass (N likelihood queries, counted; one-time setup).
    pub fn init_z(&mut self, rng: &mut crate::util::Rng) {
        let n = self.model.n();
        self.scratch_idx.clear();
        self.scratch_idx.extend(0..n);
        let idx = std::mem::take(&mut self.scratch_idx);
        let mut tll = std::mem::take(&mut self.scratch_ll);
        let mut tlb = std::mem::take(&mut self.scratch_lb);
        self.eval.eval(&self.theta, &idx, &mut tll, &mut tlb);
        self.pseudo_sum = 0.0;
        for i in 0..n {
            // p(z=1 | theta) = (L - B)/L = 1 - e^{lb - ll}
            let p_bright = 1.0 - (tlb[i] - tll[i]).exp();
            if rng.bernoulli(p_bright) {
                self.bright.brighten(i);
                self.ll[i] = tll[i];
                self.lb[i] = tlb[i];
                self.pseudo_sum += log_pseudo_lik(tll[i], tlb[i]);
            } else {
                self.bright.darken(i);
            }
        }
        self.scratch_idx = idx;
        self.scratch_ll = tll;
        self.scratch_lb = tlb;
        self.memo_valid = false;
        self.version += 1;
    }

    fn bright_indices(&self) -> Vec<usize> {
        self.bright.bright_slice().iter().map(|&i| i as usize).collect()
    }

    fn base_at(&self, theta: &[f64]) -> f64 {
        self.eval.counters().add_collapsed(1);
        self.prior.log_density(theta) + self.model.log_bound_product(theta)
    }

    /// Evaluate at `theta` and memoize. Costs n_bright likelihood queries.
    fn eval_and_memo(&mut self, theta: &[f64]) -> f64 {
        let idx = self.bright_indices();
        let mut tll = std::mem::take(&mut self.memo_ll);
        let mut tlb = std::mem::take(&mut self.memo_lb);
        self.eval.eval(theta, &idx, &mut tll, &mut tlb);
        let pseudo: f64 = tll
            .iter()
            .zip(&tlb)
            .map(|(&l, &b)| log_pseudo_lik(l, b))
            .sum();
        let base = self.base_at(theta);
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_ll = tll;
        self.memo_lb = tlb;
        self.memo_pseudo_sum = pseudo;
        self.memo_base = base;
        self.memo_valid = true;
        base + pseudo
    }

    fn promote_memo(&mut self) {
        debug_assert!(self.memo_valid);
        let idx = self.bright_indices();
        debug_assert_eq!(idx.len(), self.memo_ll.len());
        for (i, &n) in idx.iter().enumerate() {
            self.ll[n] = self.memo_ll[i];
            self.lb[n] = self.memo_lb[i];
        }
        self.pseudo_sum = self.memo_pseudo_sum;
        self.base = self.memo_base;
        self.theta.clear();
        self.theta.extend_from_slice(&self.memo_theta);
        self.memo_valid = false;
    }

    /// Full-data log posterior (instrumentation only: NOT counted as
    /// queries, used for the Fig-4 convergence traces).
    pub fn true_log_posterior(&self, theta: &[f64]) -> f64 {
        let mut acc = self.prior.log_density(theta);
        for n in 0..self.model.n() {
            acc += self.model.log_lik(theta, n);
        }
        acc
    }

    // -- z updates ---------------------------------------------------------

    /// Implicit MH resampling of z (paper Alg 2) with q_{b→d} = 1 and the
    /// given q_{d→b}. Bright→dark uses only cached values (no queries);
    /// dark→bright proposes a geometric-skip subset and evaluates just those.
    pub fn implicit_resample(&mut self, q_db: f64, rng: &mut crate::util::Rng) -> ZStats {
        let mut stats = ZStats::default();
        let ln_q = q_db.ln();

        // Every point gets AT MOST ONE proposal per sweep (paper Alg 2's
        // single pass over n): snapshot the dark candidates BEFORE the
        // bright->dark phase, otherwise a point darkened below would receive
        // a second (dark->bright) proposal in the same sweep — that composed
        // kernel is not stationary for p(z | theta) and biases the chain.
        let nd = self.bright.n_dark();
        self.scratch_idx.clear();
        let mut pos = rng.geometric_skip(q_db);
        while pos < nd {
            self.scratch_idx.push(self.bright.ith_dark(pos));
            pos = pos.saturating_add(1 + rng.geometric_skip(q_db));
        }

        // bright -> dark: accept with min(1, q_db / L~_n)
        let brights = self.bright_indices();
        for n in brights {
            stats.proposals += 1;
            let lt = log_pseudo_lik(self.ll[n], self.lb[n]);
            if rng.f64_open().ln() < ln_q - lt {
                self.bright.darken(n);
                self.pseudo_sum -= lt;
                stats.darkened += 1;
            }
        }

        // dark -> bright over the pre-phase snapshot (all still dark: the
        // phase above only darkens): accept with min(1, L~_n / q_db).
        let idx = std::mem::take(&mut self.scratch_idx);
        let mut tll = std::mem::take(&mut self.scratch_ll);
        let mut tlb = std::mem::take(&mut self.scratch_lb);
        self.eval.eval(&self.theta, &idx, &mut tll, &mut tlb);
        for (i, &n) in idx.iter().enumerate() {
            stats.proposals += 1;
            let lt = log_pseudo_lik(tll[i], tlb[i]);
            if rng.f64_open().ln() < lt - ln_q {
                self.bright.brighten(n);
                self.ll[n] = tll[i];
                self.lb[n] = tlb[i];
                self.pseudo_sum += lt;
                stats.brightened += 1;
            }
        }
        self.scratch_idx = idx;
        self.scratch_ll = tll;
        self.scratch_lb = tlb;
        self.memo_valid = false;
        self.version += 1;
        stats
    }

    /// Explicit Gibbs resampling (paper Alg 1 lines 3–6): `fraction·N`
    /// uniform draws with replacement, each z_n redrawn from its exact
    /// conditional. Every draw costs one likelihood query.
    pub fn explicit_resample(&mut self, fraction: f64, rng: &mut crate::util::Rng) -> ZStats {
        let n = self.model.n();
        let k = ((fraction * n as f64).ceil() as usize).min(n.max(1));
        self.scratch_idx.clear();
        for _ in 0..k {
            self.scratch_idx.push(rng.below(n));
        }
        let idx = std::mem::take(&mut self.scratch_idx);
        let mut tll = std::mem::take(&mut self.scratch_ll);
        let mut tlb = std::mem::take(&mut self.scratch_lb);
        self.eval.eval(&self.theta, &idx, &mut tll, &mut tlb);
        let mut stats = ZStats { proposals: k, ..Default::default() };
        for (i, &ni) in idx.iter().enumerate() {
            let p_bright = 1.0 - (tlb[i] - tll[i]).exp();
            let want_bright = rng.bernoulli(p_bright);
            let is_bright = self.bright.is_bright(ni);
            if want_bright && !is_bright {
                self.bright.brighten(ni);
                self.ll[ni] = tll[i];
                self.lb[ni] = tlb[i];
                self.pseudo_sum += log_pseudo_lik(tll[i], tlb[i]);
                stats.brightened += 1;
            } else if !want_bright && is_bright {
                self.bright.darken(ni);
                self.pseudo_sum -= log_pseudo_lik(self.ll[ni], self.lb[ni]);
                stats.darkened += 1;
            }
        }
        self.scratch_idx = idx;
        self.scratch_ll = tll;
        self.scratch_lb = tlb;
        self.memo_valid = false;
        self.version += 1;
        stats
    }

    /// Recompute state sums from scratch (test hook: verifies the
    /// incremental bookkeeping).
    pub fn recompute_state(&mut self) -> f64 {
        let idx = self.bright_indices();
        let mut tll = Vec::new();
        let mut tlb = Vec::new();
        self.eval.eval(&self.theta, &idx, &mut tll, &mut tlb);
        let pseudo: f64 = tll
            .iter()
            .zip(&tlb)
            .map(|(&l, &b)| log_pseudo_lik(l, b))
            .sum();
        let base = self.base_at(&self.theta);
        self.pseudo_sum = pseudo;
        self.base = base;
        base + pseudo
    }
}

impl Target for PseudoPosterior {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn log_density(&mut self, theta: &[f64]) -> f64 {
        if theta == self.theta.as_slice() {
            return self.current_log_density();
        }
        if self.memo_valid && theta == self.memo_theta.as_slice() {
            return self.memo_base + self.memo_pseudo_sum;
        }
        self.eval_and_memo(theta)
    }

    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        grad.fill(0.0);
        let idx = self.bright_indices();
        let mut tll = std::mem::take(&mut self.memo_ll);
        let mut tlb = std::mem::take(&mut self.memo_lb);
        self.eval
            .eval_pseudo_grad(theta, &idx, &mut tll, &mut tlb, grad);
        let pseudo: f64 = tll
            .iter()
            .zip(&tlb)
            .map(|(&l, &b)| log_pseudo_lik(l, b))
            .sum();
        let base = self.base_at(theta);
        self.prior.grad_acc(theta, grad);
        self.model.grad_log_bound_product_acc(theta, grad);
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_ll = tll;
        self.memo_lb = tlb;
        self.memo_pseudo_sum = pseudo;
        self.memo_base = base;
        self.memo_valid = true;
        base + pseudo
    }

    fn commit(&mut self, theta: &[f64]) {
        if theta == self.theta.as_slice() {
            return;
        }
        if !(self.memo_valid && theta == self.memo_theta.as_slice()) {
            self.eval_and_memo(theta);
        }
        self.promote_memo();
    }

    fn current_log_density(&self) -> f64 {
        self.base + self.pseudo_sum
    }

    fn version(&self) -> u64 {
        self.version
    }
}

// ---------------------------------------------------------------------------

/// Regular full-data posterior (the paper's baseline): every evaluation
/// queries all N likelihoods.
pub struct FullPosterior {
    pub model: Arc<dyn ModelBound>,
    pub prior: Arc<dyn Prior>,
    pub eval: Box<dyn BatchEval>,
    idx_all: Vec<usize>,
    theta: Vec<f64>,
    cur_logp: f64,
    memo_theta: Vec<f64>,
    memo_logp: f64,
    memo_valid: bool,
    scratch_ll: Vec<f64>,
}

impl FullPosterior {
    pub fn new(
        model: Arc<dyn ModelBound>,
        prior: Arc<dyn Prior>,
        mut eval: Box<dyn BatchEval>,
        theta0: Vec<f64>,
    ) -> Self {
        let n = model.n();
        let idx_all: Vec<usize> = (0..n).collect();
        let mut ll = Vec::new();
        eval.eval_lik(&theta0, &idx_all, &mut ll);
        let cur_logp = prior.log_density(&theta0) + ll.iter().sum::<f64>();
        FullPosterior {
            model,
            prior,
            eval,
            idx_all,
            theta: theta0,
            cur_logp,
            memo_theta: Vec::new(),
            memo_logp: 0.0,
            memo_valid: false,
            scratch_ll: ll,
        }
    }

    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    pub fn true_log_posterior(&self, theta: &[f64]) -> f64 {
        let mut acc = self.prior.log_density(theta);
        for n in 0..self.model.n() {
            acc += self.model.log_lik(theta, n);
        }
        acc
    }
}

impl Target for FullPosterior {
    fn dim(&self) -> usize {
        self.model.dim()
    }

    fn log_density(&mut self, theta: &[f64]) -> f64 {
        if theta == self.theta.as_slice() {
            return self.cur_logp;
        }
        if self.memo_valid && theta == self.memo_theta.as_slice() {
            return self.memo_logp;
        }
        let mut ll = std::mem::take(&mut self.scratch_ll);
        self.eval.eval_lik(theta, &self.idx_all, &mut ll);
        let logp = self.prior.log_density(theta) + ll.iter().sum::<f64>();
        self.scratch_ll = ll;
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_logp = logp;
        self.memo_valid = true;
        logp
    }

    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        grad.fill(0.0);
        let mut ll = std::mem::take(&mut self.scratch_ll);
        self.eval.eval_lik_grad(theta, &self.idx_all, &mut ll, grad);
        let logp = self.prior.log_density(theta) + ll.iter().sum::<f64>();
        self.prior.grad_acc(theta, grad);
        self.scratch_ll = ll;
        self.memo_theta.clear();
        self.memo_theta.extend_from_slice(theta);
        self.memo_logp = logp;
        self.memo_valid = true;
        logp
    }

    fn commit(&mut self, theta: &[f64]) {
        if theta == self.theta.as_slice() {
            return;
        }
        let logp = if self.memo_valid && theta == self.memo_theta.as_slice() {
            self.memo_logp
        } else {
            self.log_density(theta)
        };
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self.cur_logp = logp;
        self.memo_valid = false;
    }

    fn current_log_density(&self) -> f64 {
        self.cur_logp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::Counters;
    use crate::models::{IsoGaussian, LogisticJJ};
    use crate::runtime::cpu_backend::CpuBackend;
    use crate::util::Rng;

    fn setup(n: usize, seed: u64) -> (PseudoPosterior, Counters) {
        let data = Arc::new(synth::synth_mnist(n, 8, seed));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(seed);
        let theta0: Vec<f64> = (0..model.dim()).map(|_| rng.normal() * 0.3).collect();
        (PseudoPosterior::new(model, prior, eval, theta0), counters)
    }

    #[test]
    fn incremental_state_matches_recompute_after_resampling() {
        let (mut pp, _) = setup(300, 1);
        let mut rng = Rng::new(42);
        pp.init_z(&mut rng);
        for it in 0..20 {
            if it % 2 == 0 {
                pp.implicit_resample(0.05, &mut rng);
            } else {
                pp.explicit_resample(0.1, &mut rng);
            }
            let cached = pp.current_log_density();
            let fresh = pp.recompute_state();
            assert!(
                (cached - fresh).abs() < 1e-8 * (1.0 + fresh.abs()),
                "iter {it}: cached {cached} vs fresh {fresh}"
            );
        }
    }

    #[test]
    fn commit_after_eval_is_query_free() {
        let (mut pp, counters) = setup(200, 2);
        let mut rng = Rng::new(7);
        pp.init_z(&mut rng);
        let m = pp.n_bright();
        let theta2: Vec<f64> = pp.theta().iter().map(|t| t + 0.01).collect();
        let before = counters.lik_queries();
        let lp = pp.log_density(&theta2);
        assert_eq!(counters.lik_queries() - before, m as u64);
        let mid = counters.lik_queries();
        pp.commit(&theta2); // memo hit: no new queries
        assert_eq!(counters.lik_queries(), mid);
        assert!((pp.current_log_density() - lp).abs() < 1e-12);
        // and the cache is consistent
        let fresh = pp.recompute_state();
        assert!((fresh - lp).abs() < 1e-8 * (1.0 + lp.abs()));
    }

    #[test]
    fn marginal_bright_probability_matches_conditional() {
        // After many implicit sweeps at fixed theta, the empirical bright
        // frequency of each datum must match p(z=1|theta) = 1 - B/L.
        let (mut pp, _) = setup(60, 3);
        let mut rng = Rng::new(9);
        pp.init_z(&mut rng);
        let sweeps = 4000;
        let mut freq = vec![0usize; 60];
        for _ in 0..sweeps {
            pp.implicit_resample(0.3, &mut rng);
            for n in 0..60 {
                if pp.bright.is_bright(n) {
                    freq[n] += 1;
                }
            }
        }
        let theta = pp.theta().to_vec();
        let mut max_err: f64 = 0.0;
        for n in 0..60 {
            let (ll, lb) = pp.model.log_both(&theta, n);
            let p = 1.0 - (lb - ll).exp();
            let emp = freq[n] as f64 / sweeps as f64;
            max_err = max_err.max((emp - p).abs());
        }
        assert!(max_err < 0.05, "max |emp - exact| = {max_err}");
    }

    #[test]
    fn explicit_resample_counts_fraction_of_n_queries() {
        let (mut pp, counters) = setup(500, 4);
        let mut rng = Rng::new(11);
        pp.init_z(&mut rng);
        let before = counters.lik_queries();
        pp.explicit_resample(0.1, &mut rng);
        assert_eq!(counters.lik_queries() - before, 50);
    }

    #[test]
    fn implicit_resample_queries_scale_with_q() {
        let (mut pp, counters) = setup(2000, 5);
        let mut rng = Rng::new(13);
        pp.init_z(&mut rng);
        let before = counters.lik_queries();
        let mut proposals = 0;
        let reps = 50;
        for _ in 0..reps {
            let s = pp.implicit_resample(0.01, &mut rng);
            proposals += s.proposals;
        }
        let queries = (counters.lik_queries() - before) as f64 / reps as f64;
        // ~ q * n_dark per sweep; n_dark ~ 2000 - M
        assert!(queries < 60.0, "queries/sweep {queries}");
        assert!(proposals > 0);
    }

    #[test]
    fn full_posterior_counts_n_per_eval_and_matches_direct() {
        let data = Arc::new(synth::synth_mnist(150, 6, 6));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 2.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let theta0 = vec![0.05; model.dim()];
        let mut fp = FullPosterior::new(model, prior, eval, theta0.clone());
        assert_eq!(counters.lik_queries(), 150);
        let direct = fp.true_log_posterior(&theta0);
        assert!((fp.current_log_density() - direct).abs() < 1e-9);
        let theta1 = vec![0.1; fp.dim()];
        let lp = fp.log_density(&theta1);
        assert_eq!(counters.lik_queries(), 300);
        fp.commit(&theta1);
        assert_eq!(counters.lik_queries(), 300); // memo hit
        assert!((fp.current_log_density() - lp).abs() < 1e-12);
    }
}
