//! Online bound re-anchoring state (DESIGN.md §Bound-management).
//!
//! FlyMC's per-iteration cost is the bright count, and the bright count is
//! governed by how tight the bounds are where the chain actually lives. The
//! one-shot MAP pre-pass anchors the bounds at an *optimizer's* guess; this
//! module carries the state for re-anchoring them once, at a deterministic
//! iteration, at the chain's own running posterior mean — an O(dim) Welford
//! accumulator folded over every committed θ of the pre-re-anchor window.
//!
//! ## Exactness
//!
//! Re-anchoring changes the augmented distribution p(θ, z): the bounds
//! B_n, the collapsed base quadratic, and the brightness conditional all
//! move. It is nevertheless a legal *Markov restart*, because all three of
//! the following hold (the argument lives in DESIGN.md §Bound-management):
//!
//! 1. the trigger is a fixed, config-declared iteration — never a function
//!    of the chain's future;
//! 2. the new anchor is a measurable function of the *past* trajectory
//!    (the running mean up to the trigger), used only once;
//! 3. immediately after swapping bounds, **every** z_n is redrawn from its
//!    exact conditional p(z_n = 1 | θ) = 1 − B_n(θ)/L_n(θ) under the NEW
//!    bounds (`PseudoPosterior::init_z`) — so the post-restart state is a
//!    draw from the new augmented model's exact z-conditional at the
//!    current θ, and the subsequent chain targets the new p(θ, z), whose
//!    θ-marginal is the same exact posterior.
//!
//! The marginal p(θ) is invariant to the bound choice (the paper's central
//! identity), so samples from before and after the restart may be pooled;
//! only z-statistics (bright counts) change regime, which is why the
//! streaming observer keeps separate pre/post bright series.

use crate::diagnostics::streaming::WelfordVec;
use crate::util::codec::{ByteReader, ByteWriter};

/// Per-chain online re-anchoring state: the trigger iteration, the running
/// θ mean it will anchor at, and whether the restart has fired. Owned by
/// the chain (`ChainState`) and checkpointed in the `RANC` section so a
/// kill/resume straddling the trigger replays it bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct ReanchorState {
    /// iteration the restart fires at (start of iteration `at`, before the
    /// θ-step; config-validated to lie inside burn-in)
    pub at: usize,
    /// Welford accumulator over every committed θ so far (O(dim) memory)
    pub mean: WelfordVec,
    /// whether the restart has already fired (exactly-once across resumes)
    pub applied: bool,
}

impl ReanchorState {
    /// Fresh state firing at iteration `at` for a `dim`-parameter chain.
    pub fn new(at: usize, dim: usize) -> Self {
        ReanchorState { at, mean: WelfordVec::new(dim), applied: false }
    }

    /// Fold one committed θ into the running mean (O(dim), no allocation).
    // lint: zero-alloc
    pub fn observe(&mut self, theta: &[f64]) {
        if !self.applied {
            self.mean.update(theta);
        }
    }

    /// Whether the restart should fire now, at the start of iteration
    /// `completed` (fires exactly once, and only with ≥1 observation).
    pub fn due(&self, completed: usize) -> bool {
        !self.applied && completed == self.at && self.mean.count() > 0
    }

    /// The anchor the restart will use: the running mean of the observed
    /// trajectory.
    pub fn anchor(&self) -> &[f64] {
        self.mean.means()
    }

    /// Serialize (trigger, accumulator, fired flag — bit-exact).
    pub fn save_state(&self, w: &mut ByteWriter) {
        w.usize(self.at);
        self.mean.save_state(w);
        w.bool(self.applied);
    }

    /// Restore [`Self::save_state`] bytes (dimension must match).
    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        self.at = r.usize()?;
        self.mean.load_state(r)?;
        self.applied = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_exactly_once_at_the_trigger() {
        let mut s = ReanchorState::new(5, 2);
        assert!(!s.due(5), "no observations yet");
        for i in 0..5 {
            s.observe(&[i as f64, 1.0]);
            assert!(!s.due(i), "early fire at {i}");
        }
        assert!(s.due(5));
        assert_eq!(s.anchor(), &[2.0, 1.0]);
        s.applied = true;
        assert!(!s.due(5), "must not re-fire");
        let before = s.mean.count();
        s.observe(&[9.0, 9.0]); // post-restart observations are ignored
        assert_eq!(s.mean.count(), before);
    }

    #[test]
    fn codec_roundtrip_is_exact() {
        let mut s = ReanchorState::new(40, 3);
        for i in 0..7 {
            s.observe(&[i as f64, -0.5 * i as f64, 0.25]);
        }
        let mut w = ByteWriter::new();
        s.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut d = ReanchorState::new(0, 3);
        let mut r = ByteReader::new(&bytes);
        d.load_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(s, d);
    }
}
