//! MAP estimation for bound tuning (paper §3.1/§4.1: "perform a quick
//! [stochastic gradient] optimization to find an approximate MAP value of θ
//! and construct the bounds to be tight there").
//!
//! Minibatch Adam ascent on log p(θ) + (N/B) Σ_batch log L_n. The cost is
//! one-time setup, reported separately from the per-iteration likelihood
//! queries (as in the paper).
//!
//! Gradients flow through the models' **ordered batch** entry point
//! (`ModelBound::log_lik_grad_ordered_batch`, DESIGN.md §Kernels): one
//! SoA-tiled kernel call per minibatch whose `ll`/`grad` outputs are
//! bit-identical to the historical per-datum `log_lik_grad_acc` /
//! `log_lik` loop — so MAP tuning, and therefore every MAP-anchored
//! bound, is bit-identical across backends, kernel paths, and the
//! batched-vs-per-datum choice (`map_batches_like_per_datum_reference`
//! below pins this).

use crate::models::{ModelBound, Prior};
use crate::util::Rng;

/// Minibatch-Adam configuration for the MAP pre-pass.
#[derive(Clone, Debug)]
pub struct MapConfig {
    /// number of Adam steps
    pub steps: usize,
    /// minibatch size (clamped to N)
    pub batch: usize,
    /// base learning rate (decays as 1/sqrt(t))
    pub lr: f64,
    /// Adam first-moment decay
    pub beta1: f64,
    /// Adam second-moment decay
    pub beta2: f64,
    /// Adam denominator stabilizer
    pub eps: f64,
    /// minibatch-sampling seed
    pub seed: u64,
}

impl Default for MapConfig {
    fn default() -> Self {
        MapConfig {
            steps: 400,
            batch: 256,
            lr: 0.05,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            seed: 12345,
        }
    }
}

/// Output of [`map_estimate`].
#[derive(Clone, Debug)]
pub struct MapResult {
    /// the approximate MAP point
    pub theta: Vec<f64>,
    /// likelihood queries spent (one-time setup cost, reported separately)
    pub lik_queries: u64,
    /// last minibatch estimate of the log posterior
    pub final_log_post_estimate: f64,
}

/// Run minibatch Adam and return the approximate MAP point.
pub fn map_estimate(model: &dyn ModelBound, prior: &dyn Prior, cfg: &MapConfig) -> MapResult {
    let dim = model.dim();
    let n = model.n();
    let mut rng = Rng::new(cfg.seed);
    let mut scratch = model.new_scratch();
    let mut theta = vec![0.0; dim];
    let mut m = vec![0.0; dim];
    let mut v = vec![0.0; dim];
    let mut grad = vec![0.0; dim];
    let batch = cfg.batch.min(n);
    let scale = n as f64 / batch as f64;
    let mut queries = 0u64;
    let mut last_obj = f64::NEG_INFINITY;
    // reused across steps: the minibatch index list (same `rng.below` draw
    // order as the historical per-datum loop — evaluations never touch the
    // rng) and the per-datum log-likelihood output buffer
    let mut idx: Vec<u32> = Vec::with_capacity(batch);
    let mut ll: Vec<f64> = Vec::with_capacity(batch);

    for t in 1..=cfg.steps {
        grad.fill(0.0);
        idx.clear();
        for _ in 0..batch {
            idx.push(rng.below(n) as u32);
        }
        model.log_lik_grad_ordered_batch(&theta, &idx, &mut ll, &mut grad, &mut scratch);
        queries += batch as u64;
        // in-order sum: same bits as the historical per-datum accumulation
        let mut batch_ll = 0.0;
        for &l in &ll {
            batch_ll += l;
        }
        for g in grad.iter_mut() {
            *g *= scale;
        }
        prior.grad_acc(&theta, &mut grad);
        last_obj = prior.log_density(&theta) + scale * batch_ll;

        // Adam ascent with bias correction and 1/sqrt(t) decay
        let lr_t = cfg.lr / (1.0 + (t as f64 / cfg.steps as f64)).sqrt();
        let (b1, b2) = (cfg.beta1, cfg.beta2);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        for i in 0..dim {
            m[i] = b1 * m[i] + (1.0 - b1) * grad[i];
            v[i] = b2 * v[i] + (1.0 - b2) * grad[i] * grad[i];
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            theta[i] += lr_t * mhat / (vhat.sqrt() + cfg.eps);
        }
    }
    MapResult { theta, lik_queries: queries, final_log_post_estimate: last_obj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::models::{IsoGaussian, LogisticJJ, RobustT};
    use std::sync::Arc;

    #[test]
    fn map_improves_log_posterior_logistic() {
        let data = Arc::new(synth::synth_mnist(2000, 10, 1));
        let model = LogisticJJ::new(data, 1.5);
        let prior = IsoGaussian { scale: 2.0 };
        let cfg = MapConfig { steps: 300, ..Default::default() };
        let res = map_estimate(&model, &prior, &cfg);
        let mut sc = crate::models::ModelBound::new_scratch(&model);
        let mut full = |theta: &[f64]| {
            let mut acc = prior.log_density(theta);
            for i in 0..2000 {
                acc += crate::models::ModelBound::log_lik(&model, theta, i, &mut sc);
            }
            acc
        };
        let at_zero = full(&vec![0.0; 11]);
        let at_map = full(&res.theta);
        assert!(at_map > at_zero + 100.0, "MAP {at_map} vs zero {at_zero}");
        assert_eq!(res.lik_queries, 300 * 256);
    }

    /// Forwards only the *required* per-datum `ModelBound` methods, so every
    /// batch entry point — including `log_lik_grad_ordered_batch` — falls
    /// back to the trait's default per-datum loop: the pre-batching
    /// reference implementation of the MAP pass.
    struct PerDatumRef<M: ModelBound>(M);

    impl<M: ModelBound> ModelBound for PerDatumRef<M> {
        fn n(&self) -> usize {
            self.0.n()
        }
        fn dim(&self) -> usize {
            self.0.dim()
        }
        fn kind(&self) -> crate::models::ModelKind {
            self.0.kind()
        }
        fn n_classes(&self) -> usize {
            self.0.n_classes()
        }
        fn new_scratch(&self) -> crate::models::EvalScratch {
            self.0.new_scratch()
        }
        fn log_lik(&self, t: &[f64], n: usize, sc: &mut crate::models::EvalScratch) -> f64 {
            self.0.log_lik(t, n, sc)
        }
        fn log_lik_grad_acc(
            &self,
            t: &[f64],
            n: usize,
            g: &mut [f64],
            sc: &mut crate::models::EvalScratch,
        ) {
            self.0.log_lik_grad_acc(t, n, g, sc)
        }
        fn log_both(
            &self,
            t: &[f64],
            n: usize,
            sc: &mut crate::models::EvalScratch,
        ) -> (f64, f64) {
            self.0.log_both(t, n, sc)
        }
        fn pseudo_grad_acc(
            &self,
            t: &[f64],
            n: usize,
            g: &mut [f64],
            sc: &mut crate::models::EvalScratch,
        ) {
            self.0.pseudo_grad_acc(t, n, g, sc)
        }
        fn log_bound_product(&self, t: &[f64], sc: &mut crate::models::EvalScratch) -> f64 {
            self.0.log_bound_product(t, sc)
        }
        fn grad_log_bound_product_acc(
            &self,
            t: &[f64],
            g: &mut [f64],
            sc: &mut crate::models::EvalScratch,
        ) {
            self.0.grad_log_bound_product_acc(t, g, sc)
        }
        fn tune_anchors_map(&mut self, t: &[f64]) {
            self.0.tune_anchors_map(t)
        }
    }

    /// Satellite invariance gate: routing the MAP minibatch pass through the
    /// ordered batch kernel must not perturb a single bit of the MAP point —
    /// and therefore not a single anchor bit — vs the per-datum reference.
    #[test]
    fn map_batches_like_per_datum_reference() {
        let prior = IsoGaussian { scale: 2.0 };
        let cfg = MapConfig { steps: 60, batch: 100, ..Default::default() };
        // logistic
        let data = Arc::new(synth::synth_mnist(500, 8, 4));
        let batched = map_estimate(&LogisticJJ::new(data.clone(), 1.5), &prior, &cfg);
        let reference = map_estimate(&PerDatumRef(LogisticJJ::new(data, 1.5)), &prior, &cfg);
        assert_eq!(batched.lik_queries, reference.lik_queries);
        for (a, b) in batched.theta.iter().zip(&reference.theta) {
            assert_eq!(a.to_bits(), b.to_bits(), "logistic MAP bits differ");
        }
        // robust
        let data = Arc::new(synth::synth_opv(400, 7, 5));
        let batched = map_estimate(&RobustT::new(data.clone(), 4.0, 0.7), &prior, &cfg);
        let reference = map_estimate(&PerDatumRef(RobustT::new(data, 4.0, 0.7)), &prior, &cfg);
        for (a, b) in batched.theta.iter().zip(&reference.theta) {
            assert_eq!(a.to_bits(), b.to_bits(), "robust MAP bits differ");
        }
        // softmax (class-outer per-datum order is the subtle one)
        let data = Arc::new(synth::synth_cifar3(300, 9, 6));
        let batched = map_estimate(&crate::models::SoftmaxBohning::new(data.clone()), &prior, &cfg);
        let reference =
            map_estimate(&PerDatumRef(crate::models::SoftmaxBohning::new(data)), &prior, &cfg);
        for (a, b) in batched.theta.iter().zip(&reference.theta) {
            assert_eq!(a.to_bits(), b.to_bits(), "softmax MAP bits differ");
        }
    }

    #[test]
    fn map_recovers_robust_regression_weights_roughly() {
        let (data, w_true) = synth::synth_opv_with_truth(5000, 8, 2);
        let data = Arc::new(data);
        let model = RobustT::new(data, 4.0, 0.5);
        let prior = IsoGaussian { scale: 5.0 };
        let cfg = MapConfig { steps: 800, lr: 0.1, ..Default::default() };
        let res = map_estimate(&model, &prior, &cfg);
        // should be much closer to the truth than the origin
        let d_map = crate::linalg::dist2(&res.theta, &w_true).sqrt();
        let d_zero = crate::linalg::norm2(&w_true);
        assert!(d_map < 0.4 * d_zero, "dist {d_map} vs |w| {d_zero}");
    }
}
