//! MAP estimation for bound tuning (paper §3.1/§4.1: "perform a quick
//! [stochastic gradient] optimization to find an approximate MAP value of θ
//! and construct the bounds to be tight there").
//!
//! Minibatch Adam ascent on log p(θ) + (N/B) Σ_batch log L_n. The cost is
//! one-time setup, reported separately from the per-iteration likelihood
//! queries (as in the paper).
//!
//! Gradients are accumulated datum by datum through the per-datum
//! `ModelBound` methods (batch-of-1 wrappers since the kernel refactor,
//! DESIGN.md §Kernels), which keep the pre-kernel accumulation order —
//! so MAP tuning, and therefore every MAP-anchored bound, is bit-identical
//! across backends and kernel paths.

use crate::models::{ModelBound, Prior};
use crate::util::Rng;

/// Minibatch-Adam configuration for the MAP pre-pass.
#[derive(Clone, Debug)]
pub struct MapConfig {
    /// number of Adam steps
    pub steps: usize,
    /// minibatch size (clamped to N)
    pub batch: usize,
    /// base learning rate (decays as 1/sqrt(t))
    pub lr: f64,
    /// Adam first-moment decay
    pub beta1: f64,
    /// Adam second-moment decay
    pub beta2: f64,
    /// Adam denominator stabilizer
    pub eps: f64,
    /// minibatch-sampling seed
    pub seed: u64,
}

impl Default for MapConfig {
    fn default() -> Self {
        MapConfig {
            steps: 400,
            batch: 256,
            lr: 0.05,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            seed: 12345,
        }
    }
}

/// Output of [`map_estimate`].
#[derive(Clone, Debug)]
pub struct MapResult {
    /// the approximate MAP point
    pub theta: Vec<f64>,
    /// likelihood queries spent (one-time setup cost, reported separately)
    pub lik_queries: u64,
    /// last minibatch estimate of the log posterior
    pub final_log_post_estimate: f64,
}

/// Run minibatch Adam and return the approximate MAP point.
pub fn map_estimate(model: &dyn ModelBound, prior: &dyn Prior, cfg: &MapConfig) -> MapResult {
    let dim = model.dim();
    let n = model.n();
    let mut rng = Rng::new(cfg.seed);
    let mut scratch = model.new_scratch();
    let mut theta = vec![0.0; dim];
    let mut m = vec![0.0; dim];
    let mut v = vec![0.0; dim];
    let mut grad = vec![0.0; dim];
    let batch = cfg.batch.min(n);
    let scale = n as f64 / batch as f64;
    let mut queries = 0u64;
    let mut last_obj = f64::NEG_INFINITY;

    for t in 1..=cfg.steps {
        grad.fill(0.0);
        let mut batch_ll = 0.0;
        for _ in 0..batch {
            let i = rng.below(n);
            model.log_lik_grad_acc(&theta, i, &mut grad, &mut scratch);
            batch_ll += model.log_lik(&theta, i, &mut scratch);
            queries += 1;
        }
        for g in grad.iter_mut() {
            *g *= scale;
        }
        prior.grad_acc(&theta, &mut grad);
        last_obj = prior.log_density(&theta) + scale * batch_ll;

        // Adam ascent with bias correction and 1/sqrt(t) decay
        let lr_t = cfg.lr / (1.0 + (t as f64 / cfg.steps as f64)).sqrt();
        let (b1, b2) = (cfg.beta1, cfg.beta2);
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        for i in 0..dim {
            m[i] = b1 * m[i] + (1.0 - b1) * grad[i];
            v[i] = b2 * v[i] + (1.0 - b2) * grad[i] * grad[i];
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            theta[i] += lr_t * mhat / (vhat.sqrt() + cfg.eps);
        }
    }
    MapResult { theta, lik_queries: queries, final_log_post_estimate: last_obj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::models::{IsoGaussian, LogisticJJ, RobustT};
    use std::sync::Arc;

    #[test]
    fn map_improves_log_posterior_logistic() {
        let data = Arc::new(synth::synth_mnist(2000, 10, 1));
        let model = LogisticJJ::new(data, 1.5);
        let prior = IsoGaussian { scale: 2.0 };
        let cfg = MapConfig { steps: 300, ..Default::default() };
        let res = map_estimate(&model, &prior, &cfg);
        let mut sc = crate::models::ModelBound::new_scratch(&model);
        let mut full = |theta: &[f64]| {
            let mut acc = prior.log_density(theta);
            for i in 0..2000 {
                acc += crate::models::ModelBound::log_lik(&model, theta, i, &mut sc);
            }
            acc
        };
        let at_zero = full(&vec![0.0; 11]);
        let at_map = full(&res.theta);
        assert!(at_map > at_zero + 100.0, "MAP {at_map} vs zero {at_zero}");
        assert_eq!(res.lik_queries, 300 * 256);
    }

    #[test]
    fn map_recovers_robust_regression_weights_roughly() {
        let (data, w_true) = synth::synth_opv_with_truth(5000, 8, 2);
        let data = Arc::new(data);
        let model = RobustT::new(data, 4.0, 0.5);
        let prior = IsoGaussian { scale: 5.0 };
        let cfg = MapConfig { steps: 800, lr: 0.1, ..Default::default() };
        let res = map_estimate(&model, &prior, &cfg);
        // should be much closer to the truth than the origin
        let d_map = crate::linalg::dist2(&res.theta, &w_true).sqrt();
        let d_zero = crate::linalg::norm2(&w_true);
        assert!(d_map < 0.4 * d_zero, "dist {d_map} vs |w| {d_zero}");
    }
}
