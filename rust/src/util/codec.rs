//! Little-endian byte codec for chain-state serialization.
//!
//! The checkpoint layer (`engine::checkpoint`) serializes every stateful
//! component of a running chain — RNG, bright set, posterior caches, sampler
//! adaptation, observer accumulators — through this one writer/reader pair,
//! so the `.fckpt` byte layout has a single source of truth. Everything is
//! explicit little-endian (the same discipline as `data::fbin`), length-
//! prefixed where variable, and read back with bounds checking: a truncated
//! or corrupt checkpoint surfaces as a `String` error, never a panic or a
//! silently-wrong state.

/// FNV-1a 64-bit offset basis (the hash state before any input byte).
pub const FNV1A_BASIS: u64 = 0xCBF2_9CE4_8422_2325;

/// FNV-1a 64-bit hash — used for checkpoint payload checksums, wire-frame
/// checksums, config fingerprints, and shard-file checksums (stable across
/// platforms; not cryptographic).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    fnv1a_continue(FNV1A_BASIS, bytes)
}

/// Fold more bytes into an FNV-1a state — the streaming form of
/// [`fnv1a`]: start from [`FNV1A_BASIS`] and feed chunks in order;
/// `fnv1a(ab) == fnv1a_continue(fnv1a_continue(BASIS, a), b)`.
pub fn fnv1a_continue(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Append-only little-endian byte sink.
#[derive(Clone, Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Empty writer.
    pub fn new() -> Self {
        ByteWriter { buf: Vec::new() }
    }

    /// Bytes written so far.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Consume the writer, returning the serialized bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Borrow the serialized bytes.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Write one byte.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Write a bool as one byte (0/1).
    pub fn bool(&mut self, v: bool) {
        self.u8(u8::from(v));
    }

    /// Write a `u32` little-endian.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `u64` little-endian.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a `usize` as `u64` little-endian.
    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    /// Write an `f64` little-endian (bit-exact).
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Write a length-prefixed `f64` slice.
    pub fn f64_slice(&mut self, vs: &[f64]) {
        self.usize(vs.len());
        for &v in vs {
            self.f64(v);
        }
    }

    /// Write a length-prefixed `u32` slice.
    pub fn u32_slice(&mut self, vs: &[u32]) {
        self.usize(vs.len());
        for &v in vs {
            self.u32(v);
        }
    }

    /// Write a length-prefixed `u64` slice.
    pub fn u64_slice(&mut self, vs: &[u64]) {
        self.usize(vs.len());
        for &v in vs {
            self.u64(v);
        }
    }

    /// Write a length-prefixed raw byte slice.
    pub fn bytes(&mut self, vs: &[u8]) {
        self.usize(vs.len());
        self.buf.extend_from_slice(vs);
    }
}

/// Bounds-checked little-endian reader over a byte slice.
#[derive(Clone, Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Reader positioned at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Error unless every byte has been consumed (trailing-garbage guard).
    pub fn finish(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!("{} unread trailing bytes", self.remaining()));
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated: need {n} bytes at offset {}, only {} remain",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    /// Read a bool (one byte; values other than 0/1 are rejected).
    pub fn bool(&mut self) -> Result<bool, String> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(format!("bad bool byte {v}")),
        }
    }

    /// Read a `u32` little-endian.
    pub fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a `u64` little-endian.
    pub fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a `u64` and narrow it to `usize`.
    pub fn usize(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("value {v} exceeds usize"))
    }

    /// Read an `f64` little-endian (bit-exact).
    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn check_len(&self, len: usize, width: usize) -> Result<(), String> {
        match len.checked_mul(width) {
            Some(bytes) if bytes <= self.remaining() => Ok(()),
            _ => Err(format!(
                "truncated: slice of {len} × {width}-byte elements exceeds the \
                 {} remaining bytes",
                self.remaining()
            )),
        }
    }

    /// Read a length-prefixed `f64` slice into `out` (cleared first; keeps
    /// `out`'s existing capacity, so restoring into a pre-reserved buffer
    /// does not reallocate when the payload fits).
    pub fn f64_slice_into(&mut self, out: &mut Vec<f64>) -> Result<(), String> {
        let len = self.usize()?;
        self.check_len(len, 8)?;
        out.clear();
        for _ in 0..len {
            out.push(self.f64()?);
        }
        Ok(())
    }

    /// Read a length-prefixed `f64` slice as a fresh vector.
    pub fn f64_vec(&mut self) -> Result<Vec<f64>, String> {
        let mut v = Vec::new();
        self.f64_slice_into(&mut v)?;
        Ok(v)
    }

    /// Read a length-prefixed `u32` slice into `out` (cleared first).
    pub fn u32_slice_into(&mut self, out: &mut Vec<u32>) -> Result<(), String> {
        let len = self.usize()?;
        self.check_len(len, 4)?;
        out.clear();
        for _ in 0..len {
            out.push(self.u32()?);
        }
        Ok(())
    }

    /// Read a length-prefixed `u32` slice as a fresh vector.
    pub fn u32_vec(&mut self) -> Result<Vec<u32>, String> {
        let mut v = Vec::new();
        self.u32_slice_into(&mut v)?;
        Ok(v)
    }

    /// Read a length-prefixed `u64` slice into `out` (cleared first).
    pub fn u64_slice_into(&mut self, out: &mut Vec<u64>) -> Result<(), String> {
        let len = self.usize()?;
        self.check_len(len, 8)?;
        out.clear();
        for _ in 0..len {
            out.push(self.u64()?);
        }
        Ok(())
    }

    /// Read a length-prefixed raw byte slice (borrowed, zero-copy).
    pub fn bytes(&mut self) -> Result<&'a [u8], String> {
        let len = self.usize()?;
        self.take(len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.bool(true);
        w.bool(false);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.usize(42);
        w.f64(-0.0);
        w.f64(f64::NAN);
        w.f64_slice(&[1.5, -2.5]);
        w.u32_slice(&[3, 2, 1]);
        w.u64_slice(&[9, 10]);
        w.bytes(b"abc");
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.usize().unwrap(), 42);
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.f64_vec().unwrap(), vec![1.5, -2.5]);
        assert_eq!(r.u32_vec().unwrap(), vec![3, 2, 1]);
        let mut u = Vec::new();
        r.u64_slice_into(&mut u).unwrap();
        assert_eq!(u, vec![9, 10]);
        assert_eq!(r.bytes().unwrap(), b"abc");
        r.finish().unwrap();
    }

    #[test]
    fn truncated_reads_error_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.u64(5);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes[..4]);
        assert!(r.u64().unwrap_err().contains("truncated"));
        // a huge length prefix must be rejected before allocation
        let mut w = ByteWriter::new();
        w.u64(u64::MAX / 2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f64_vec().is_err());
    }

    #[test]
    fn slice_into_preserves_capacity() {
        let mut w = ByteWriter::new();
        w.f64_slice(&[1.0, 2.0, 3.0]);
        let bytes = w.into_bytes();
        let mut out: Vec<f64> = Vec::with_capacity(64);
        let cap = out.capacity();
        ByteReader::new(&bytes).f64_slice_into(&mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.u32(1);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        let _ = r.u8().unwrap();
        assert!(r.finish().is_err());
    }

    /// Deterministic exhaustive truncation sweep: every strict prefix of a
    /// serialized stream must fail with an error (never panic, never succeed)
    /// when read back with the full read sequence.
    #[test]
    fn every_truncation_point_errors() {
        let mut w = ByteWriter::new();
        w.u8(9);
        w.bool(true);
        w.u32(77);
        w.u64(1 << 40);
        w.usize(3);
        w.f64(2.5);
        w.f64_slice(&[1.0, 2.0]);
        w.u32_slice(&[5]);
        w.u64_slice(&[6, 7]);
        w.bytes(b"xy");
        let bytes = w.into_bytes();

        let read_all = |buf: &[u8]| -> Result<(), String> {
            let mut r = ByteReader::new(buf);
            r.u8()?;
            r.bool()?;
            r.u32()?;
            r.u64()?;
            r.usize()?;
            r.f64()?;
            r.f64_vec()?;
            r.u32_vec()?;
            let mut u = Vec::new();
            r.u64_slice_into(&mut u)?;
            r.bytes()?;
            r.finish()
        };
        read_all(&bytes).expect("full buffer must round-trip");
        for cut in 0..bytes.len() {
            assert!(
                read_all(&bytes[..cut]).is_err(),
                "prefix of {cut} bytes must error"
            );
        }
    }

    /// Randomized property sweep: oversized or corrupted length prefixes on
    /// every slice type must be rejected before any allocation attempt.
    #[test]
    fn oversized_section_lengths_rejected() {
        let mut rng_state = 0x00C0_FFEEu64;
        let mut next = move || {
            // xorshift64 — independent of util::Rng so codec tests stand alone
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            rng_state
        };
        for _ in 0..200 {
            // a length prefix far beyond the remaining payload
            let huge = (next() | (1 << 62)).max(1);
            let mut w = ByteWriter::new();
            w.u64(huge);
            w.f64(1.0); // a little trailing payload, far short of `huge`
            let bytes = w.into_bytes();

            let mut r = ByteReader::new(&bytes);
            assert!(r.f64_vec().is_err(), "huge f64 len {huge} must be rejected");
            let mut r = ByteReader::new(&bytes);
            assert!(r.u32_vec().is_err(), "huge u32 len {huge} must be rejected");
            let mut r = ByteReader::new(&bytes);
            let mut out = Vec::new();
            assert!(r.u64_slice_into(&mut out).is_err(), "huge u64 len");
            let mut r = ByteReader::new(&bytes);
            assert!(r.bytes().is_err(), "huge byte len {huge} must be rejected");
        }
        // length * width overflow must not wrap around the bounds check
        let mut w = ByteWriter::new();
        w.u64(u64::MAX / 4);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert!(r.f64_vec().is_err(), "len*8 overflow must be caught");
    }

    /// A flipped byte anywhere in a payload changes its FNV-1a checksum —
    /// the property the checkpoint layer's corruption rejection rests on.
    #[test]
    fn checksum_detects_any_single_byte_flip() {
        let mut w = ByteWriter::new();
        w.u64(0xDEAD_BEEF);
        w.f64_slice(&[0.25, -1.5, 3.75]);
        w.bytes(b"checksum me");
        let bytes = w.into_bytes();
        let clean = fnv1a(&bytes);
        let mut flip_state = 0x5EED_u64;
        for _ in 0..100 {
            flip_state ^= flip_state << 13;
            flip_state ^= flip_state >> 7;
            flip_state ^= flip_state << 17;
            let pos = (flip_state as usize) % bytes.len();
            let bit = 1u8 << ((flip_state >> 32) % 8);
            let mut corrupt = bytes.clone();
            corrupt[pos] ^= bit;
            assert_ne!(
                fnv1a(&corrupt),
                clean,
                "flip at byte {pos} bit {bit} must change the checksum"
            );
        }
    }

    #[test]
    fn fnv1a_is_stable_and_discriminating() {
        assert_eq!(fnv1a(b""), 0xCBF2_9CE4_8422_2325);
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
        assert_eq!(fnv1a(b"firefly"), fnv1a(b"firefly"));
    }
}
