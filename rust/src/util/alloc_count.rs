//! Counting global allocator for the zero-allocation hot-path invariant.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! `alloc` / `alloc_zeroed` / `realloc` call (the events the hot-path
//! invariant forbids; `dealloc` is tracked separately). Install it per
//! binary — benches and integration tests are separate crates, so each can
//! carry its own:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: firefly::util::alloc_count::CountingAlloc = CountingAlloc::new();
//! ...
//! let before = ALLOC.allocations();
//! run_hot_loop();
//! assert_eq!(ALLOC.allocations() - before, 0);
//! ```
//!
//! Counters are relaxed atomics: the measured windows are single-threaded
//! (the FlyMC chain loop on the serial CPU backend), so exact deltas are
//! well-defined; under concurrency the counts are still total, just not
//! attributable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Counting wrapper around the system allocator (see module docs).
pub struct CountingAlloc {
    allocs: AtomicU64,
    deallocs: AtomicU64,
}

impl CountingAlloc {
    /// A zeroed counter pair (const: usable in `#[global_allocator]` statics).
    pub const fn new() -> Self {
        CountingAlloc { allocs: AtomicU64::new(0), deallocs: AtomicU64::new(0) }
    }

    /// Total alloc + alloc_zeroed + realloc calls since process start.
    pub fn allocations(&self) -> u64 {
        self.allocs.load(Relaxed)
    }

    /// Total dealloc calls since process start.
    pub fn deallocations(&self) -> u64 {
        self.deallocs.load(Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: delegates verbatim to `System`; the counters have no effect on
// allocation behavior.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: the caller upholds `GlobalAlloc::alloc`'s contract (valid,
    // non-zero-sized `layout`); we pass it unchanged to `System.alloc`, which
    // has the same contract, and the counter bump touches no memory.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Relaxed);
        System.alloc(layout)
    }

    // SAFETY: same contract delegation as `alloc`; `System.alloc_zeroed`
    // receives the caller's `layout` unchanged.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: the caller guarantees `ptr` was allocated by this allocator
    // with `layout` and that `new_size` is non-zero; since every allocation
    // path here delegates to `System`, `ptr` is a valid `System` allocation
    // and may be handed to `System.realloc` under the same layout.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.allocs.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    // SAFETY: the caller guarantees `ptr` came from this allocator with
    // `layout`; all our allocations come from `System`, so releasing through
    // `System.dealloc` with the same layout is sound.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        self.deallocs.fetch_add(1, Relaxed);
        System.dealloc(ptr, layout)
    }
}
