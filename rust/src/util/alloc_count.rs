//! Counting global allocator for the zero-allocation hot-path invariant.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! `alloc` / `alloc_zeroed` / `realloc` call (the events the hot-path
//! invariant forbids; `dealloc` is tracked separately). Install it per
//! binary — benches and integration tests are separate crates, so each can
//! carry its own:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: firefly::util::alloc_count::CountingAlloc = CountingAlloc::new();
//! ...
//! let before = ALLOC.allocations();
//! run_hot_loop();
//! assert_eq!(ALLOC.allocations() - before, 0);
//! ```
//!
//! Counters are relaxed atomics: the measured windows are single-threaded
//! (the FlyMC chain loop on the serial CPU backend), so exact deltas are
//! well-defined; under concurrency the counts are still total, just not
//! attributable.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

/// Counting wrapper around the system allocator (see module docs).
pub struct CountingAlloc {
    allocs: AtomicU64,
    deallocs: AtomicU64,
}

impl CountingAlloc {
    /// A zeroed counter pair (const: usable in `#[global_allocator]` statics).
    pub const fn new() -> Self {
        CountingAlloc { allocs: AtomicU64::new(0), deallocs: AtomicU64::new(0) }
    }

    /// Total alloc + alloc_zeroed + realloc calls since process start.
    pub fn allocations(&self) -> u64 {
        self.allocs.load(Relaxed)
    }

    /// Total dealloc calls since process start.
    pub fn deallocations(&self) -> u64 {
        self.deallocs.load(Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: delegates verbatim to `System`; the counters have no effect on
// allocation behavior.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        self.allocs.fetch_add(1, Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.allocs.fetch_add(1, Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        self.deallocs.fetch_add(1, Relaxed);
        System.dealloc(ptr, layout)
    }
}
