//! Scalar special functions and numerically-careful log-space helpers.
//!
//! These mirror the jnp primitives used by the L1/L2 Python layers so that
//! the Rust `CpuBackend` reproduces the XLA artifacts bit-for-bit at f64
//! tolerance (verified in `rust/tests/integration_backend.rs`).

/// log(1 + e^x) (softplus), stable for large |x|.
#[inline]
pub fn log1p_exp(x: f64) -> f64 {
    if x > 0.0 {
        x + (-x).exp().ln_1p()
    } else {
        x.exp().ln_1p()
    }
}

/// log(e^a + e^b).
#[inline]
pub fn logaddexp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// log(sigmoid(x)) = -softplus(-x).
#[inline]
pub fn log_sigmoid(x: f64) -> f64 {
    -log1p_exp(-x)
}

/// sigmoid(x), stable in both tails.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// log(1 - e^x) for x < 0, stable near 0 and -inf (Mächler 2012).
#[inline]
pub fn log1mexp(x: f64) -> f64 {
    debug_assert!(x <= 0.0);
    if x > -std::f64::consts::LN_2 {
        (-x.exp_m1()).ln()
    } else {
        (-x.exp()).ln_1p()
    }
}

/// log-sum-exp over a slice.
pub fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + xs.iter().map(|&x| (x - m).exp()).sum::<f64>().ln()
}

/// ln Γ(x) via the Lanczos approximation (g=7, n=9), |err| < 1e-13 for x > 0.
pub fn lgamma(x: f64) -> f64 {
    const COEFFS: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection: Γ(x)Γ(1-x) = π / sin(πx)
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - lgamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEFFS[0];
    let t = x + 7.5;
    for (i, &c) in COEFFS.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Student-t log normalizing constant: lgamma((nu+1)/2) - lgamma(nu/2)
/// - 0.5 log(nu pi sigma^2).
#[inline]
pub fn t_logconst(nu: f64, sigma: f64) -> f64 {
    lgamma((nu + 1.0) / 2.0)
        - lgamma(nu / 2.0)
        - 0.5 * (nu * std::f64::consts::PI * sigma * sigma).ln()
}

/// Error function erf(x), Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7 —
/// ample for the statistical test thresholds built on it).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    const A1: f64 = 0.254_829_592;
    const A2: f64 = -0.284_496_736;
    const A3: f64 = 1.421_413_741;
    const A4: f64 = -1.453_152_027;
    const A5: f64 = 1.061_405_429;
    const P: f64 = 0.327_591_1;
    let t = 1.0 / (1.0 + P * x);
    let poly = ((((A5 * t + A4) * t + A3) * t + A2) * t + A1) * t;
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF Φ(x).
#[inline]
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal quantile Φ⁻¹(p) for p ∈ (0, 1), Acklam's rational
/// approximation (|rel err| < 1.15e-9). Used to turn significance levels
/// into z thresholds in `testing::posterior_check`.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "normal_quantile: p={p} outside (0,1)");
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_690e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    const P_LOW: f64 = 0.024_25;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn log1p_exp_matches_naive_and_is_stable() {
        for &x in &[-30.0, -1.0, 0.0, 1.0, 30.0] {
            assert!(close(log1p_exp(x), (1.0 + x.exp()).ln().max(x), 1e-12));
        }
        assert_eq!(log1p_exp(1000.0), 1000.0); // no overflow
        assert!(log1p_exp(-1000.0).abs() < 1e-300);
    }

    #[test]
    fn sigmoid_and_log_sigmoid_consistent() {
        for &x in &[-20.0, -3.0, 0.0, 0.7, 15.0] {
            assert!(close(sigmoid(x).ln(), log_sigmoid(x), 1e-12));
            assert!(close(sigmoid(x) + sigmoid(-x), 1.0, 1e-14));
        }
    }

    #[test]
    fn log1mexp_stable() {
        assert!(close(log1mexp(-1e-10), (1e-10f64).ln(), 1e-4));
        assert!(close(log1mexp(-50.0), -(50.0f64.exp()).recip(), 1e-10));
        // identity: log(1 - e^x) with x = ln(0.5) = ln 0.5
        assert!(close(log1mexp((0.5f64).ln()), (0.5f64).ln(), 1e-14));
    }

    #[test]
    fn logsumexp_matches_naive() {
        let xs = [0.3f64, -2.0, 1.7, 0.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f64>().ln();
        assert!(close(logsumexp(&xs), naive, 1e-13));
        // huge values don't overflow
        let big = [700.0, 701.0];
        assert!(close(logsumexp(&big), 701.0 + (1.0f64 + (-1.0f64).exp()).ln(), 1e-12));
        assert_eq!(logsumexp(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn lgamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(3)=2, Γ(4)=6, Γ(0.5)=sqrt(pi)
        assert!(close(lgamma(1.0), 0.0, 1e-12));
        assert!(close(lgamma(2.0), 0.0, 1e-12));
        assert!(close(lgamma(3.0), 2.0f64.ln(), 1e-12));
        assert!(close(lgamma(4.0), 6.0f64.ln(), 1e-12));
        assert!(close(lgamma(0.5), std::f64::consts::PI.sqrt().ln(), 1e-12));
        assert!(close(lgamma(2.5), (1.329_340_388_179_137f64).ln(), 1e-12));
    }

    #[test]
    fn lgamma_recurrence() {
        // Γ(x+1) = x Γ(x)
        for &x in &[0.3, 1.7, 4.2, 9.9] {
            assert!(close(lgamma(x + 1.0), lgamma(x) + x.ln(), 1e-12), "x={x}");
        }
    }

    #[test]
    fn t_logconst_nu4() {
        // scipy.stats.t(df=4).logpdf(0) = log Γ(2.5)/Γ(2) - 0.5 log(4π)
        let expect = -0.980_829_253_011_726_2;
        assert!(close(t_logconst(4.0, 1.0), expect, 1e-10));
    }

    #[test]
    fn normal_cdf_known_values() {
        assert!(close(normal_cdf(0.0), 0.5, 1e-7));
        assert!((normal_cdf(1.959_963_985) - 0.975).abs() < 1e-6);
        assert!((normal_cdf(-1.959_963_985) - 0.025).abs() < 1e-6);
        assert!(normal_cdf(8.0) > 1.0 - 1e-6);
        assert!(normal_cdf(-8.0) < 1e-6);
        // symmetry
        for &x in &[0.3, 1.1, 2.7] {
            assert!((normal_cdf(x) + normal_cdf(-x) - 1.0).abs() < 1e-7);
        }
    }

    #[test]
    fn normal_quantile_inverts_cdf() {
        for &p in &[0.001, 0.01, 0.025, 0.2, 0.5, 0.8, 0.975, 0.99, 0.999] {
            let z = normal_quantile(p);
            assert!((normal_cdf(z) - p).abs() < 2e-7, "p={p} z={z}");
        }
        assert!((normal_quantile(0.975) - 1.959_963_985).abs() < 1e-6);
        assert_eq!(normal_quantile(0.5), 0.0);
    }

    #[test]
    #[should_panic(expected = "normal_quantile")]
    fn normal_quantile_rejects_boundary() {
        normal_quantile(0.0);
    }

    #[test]
    fn moments_helpers() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!(close(mean(&xs), 2.5, 1e-15));
        assert!(close(variance(&xs), 5.0 / 3.0, 1e-15));
    }
}
