//! Deterministic pseudo-random generation substrate.
//!
//! The offline build has no `rand` crate, so this module provides the
//! generator the whole framework uses: xoshiro256++ seeded through
//! splitmix64, plus the distributions MCMC needs (uniform, normal via
//! Box–Muller, exponential, gamma via Marsaglia–Tsang, student-t,
//! Bernoulli, geometric, categorical, shuffling).
//!
//! Everything is reproducible: a chain is fully determined by its seed, which
//! is what lets the experiment harness re-run the paper's 5-replica protocol
//! bit-identically.

/// splitmix64 — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Different seeds give independent
    /// streams for all practical purposes (seeded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive a child generator (for per-chain streams) without correlation.
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if `n == 0` — in release builds a
    /// `debug_assert` would vanish and Lemire's multiply-shift silently
    /// returns 0, handing callers an out-of-bounds index into an empty
    /// collection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0): empty range");
        // Lemire's method without bias for our n << 2^64 use-cases.
        ((u128::from(self.next_u64()) * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli(p) draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the spare draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Fill a slice with iid standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Exponential(1).
    #[inline]
    pub fn exponential(&mut self) -> f64 {
        -self.f64_open().ln()
    }

    /// Laplace(0, b).
    pub fn laplace(&mut self, b: f64) -> f64 {
        let e = self.exponential() * b;
        if self.bernoulli(0.5) {
            e
        } else {
            -e
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (shape >= 0 handled via boost).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: G(a) = G(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            return g * self.f64_open().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3;
            }
        }
    }

    /// Student-t with `nu` degrees of freedom (normal / sqrt(chi2/nu)).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        let z = self.normal();
        let g = self.gamma(nu / 2.0) * 2.0; // chi^2_nu
        z / (g / nu).sqrt()
    }

    /// Number of dark points skipped before the next d->b proposal when each
    /// is proposed independently with probability p (geometric skip used by
    /// implicit z-resampling; returns usize::MAX when p ~ 0).
    pub fn geometric_skip(&mut self, p: f64) -> usize {
        if p >= 1.0 {
            return 0;
        }
        if p <= 0.0 {
            return usize::MAX;
        }
        let u = self.f64_open();
        let k = (u.ln() / (1.0 - p).ln()).floor();
        if k >= usize::MAX as f64 {
            usize::MAX
        } else {
            k as usize
        }
    }

    /// Serialize the full generator state (xoshiro words + the cached
    /// Box–Muller spare) so a restored generator continues the exact output
    /// stream — the substrate of the chain checkpoint's bit-identical-resume
    /// guarantee (`engine::checkpoint`).
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        for &s in &self.s {
            w.u64(s);
        }
        // straight-line presence-flag encoding (mirrors `load_state` exactly,
        // which the codec-symmetry lint checks at the source level)
        w.bool(self.spare_normal.is_some());
        if let Some(z) = self.spare_normal {
            w.f64(z);
        }
    }

    /// Rebuild a generator from [`Self::save_state`] bytes. The restored
    /// generator's future output is bit-identical to the saved one's.
    ///
    /// ```
    /// use firefly::util::codec::{ByteReader, ByteWriter};
    /// use firefly::util::Rng;
    ///
    /// let mut a = Rng::new(9);
    /// let _ = a.normal(); // leaves a cached Box–Muller spare
    /// let mut w = ByteWriter::new();
    /// a.save_state(&mut w);
    /// let bytes = w.into_bytes();
    /// let mut b = Rng::load_state(&mut ByteReader::new(&bytes)).unwrap();
    /// assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    /// assert_eq!(a.next_u64(), b.next_u64());
    /// ```
    pub fn load_state(r: &mut crate::util::codec::ByteReader) -> Result<Rng, String> {
        let mut s = [0u64; 4];
        for v in &mut s {
            *v = r.u64()?;
        }
        let spare_normal = if r.bool()? { Some(r.f64()?) } else { None };
        if s == [0, 0, 0, 0] {
            return Err("all-zero xoshiro state (corrupt checkpoint)".to_string());
        }
        Ok(Rng { s, spare_normal })
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Partial Fisher–Yates: after the call, `slice[..k]` is a uniform
    /// ordered sample of `k` distinct elements of the slice (the remaining
    /// elements hold the rest of the permutation in unspecified order).
    ///
    /// Uniformity holds from *any* starting permutation, so callers drawing
    /// repeated minibatches may keep one persistent index pool and re-prefix
    /// it every iteration without resetting — that is what makes the
    /// subsample hot path allocation-free. `k >= slice.len()` degrades to a
    /// full shuffle.
    // lint: zero-alloc
    pub fn shuffle_prefix<T>(&mut self, slice: &mut [T], k: usize) {
        let n = slice.len();
        for i in 0..k.min(n) {
            let j = i + self.below(n - i);
            slice.swap(i, j);
        }
    }

    /// Draw `out.len()` distinct indices uniformly without replacement from
    /// the values held in `pool`, writing them into the caller-owned `out`
    /// buffer. `pool` must contain the candidate universe (typically a
    /// persistent `0..n` permutation); it is re-prefixed in place, never
    /// reallocated.
    ///
    /// Panics if `out.len() > pool.len()`.
    ///
    /// ```
    /// use firefly::util::Rng;
    ///
    /// let mut rng = Rng::new(7);
    /// let mut pool: Vec<u32> = (0..100).collect();
    /// let mut batch = [0u32; 10];
    /// rng.sample_without_replacement_into(&mut pool, &mut batch);
    /// let mut seen = batch.to_vec();
    /// seen.sort_unstable();
    /// seen.dedup();
    /// assert_eq!(seen.len(), 10, "indices are distinct");
    /// assert!(batch.iter().all(|&i| i < 100));
    /// ```
    // lint: zero-alloc
    pub fn sample_without_replacement_into(&mut self, pool: &mut [u32], out: &mut [u32]) {
        let k = out.len();
        assert!(
            k <= pool.len(),
            "sample_without_replacement_into: k={} exceeds pool {}",
            k,
            pool.len()
        );
        self.shuffle_prefix(pool, k);
        out.copy_from_slice(&pool[..k]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let y = r.f64_open();
            assert!(y > 0.0 && y <= 1.0);
        }
    }

    #[test]
    #[should_panic(expected = "Rng::below(0)")]
    fn below_zero_panics_in_all_builds() {
        Rng::new(1).below(0);
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(4);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000usize;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let nf = n as f64;
        assert!((s1 / nf).abs() < 0.02, "mean {}", s1 / nf);
        assert!((s2 / nf - 1.0).abs() < 0.03, "var {}", s2 / nf);
        assert!((s4 / nf - 3.0).abs() < 0.15, "kurt {}", s4 / nf);
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn gamma_moments() {
        let mut r = Rng::new(6);
        for &shape in &[0.5, 1.0, 2.5, 7.0] {
            let n = 100_000usize;
            let mut s1 = 0.0;
            let mut s2 = 0.0;
            for _ in 0..n {
                let g = r.gamma(shape);
                assert!(g > 0.0);
                s1 += g;
                s2 += g * g;
            }
            let mean = s1 / n as f64;
            let var = s2 / n as f64 - mean * mean;
            assert!((mean - shape).abs() / shape < 0.05, "shape {shape} mean {mean}");
            assert!((var - shape).abs() / shape < 0.12, "shape {shape} var {var}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn student_t_symmetric_heavy_tail() {
        let mut r = Rng::new(7);
        let n = 100_000usize;
        let mut mean = 0.0;
        let mut beyond3 = 0usize;
        for _ in 0..n {
            let t = r.student_t(4.0);
            mean += t;
            if t.abs() > 3.0 {
                beyond3 += 1;
            }
        }
        mean /= n as f64;
        assert!(mean.abs() < 0.03);
        // P(|t4| > 3) ~ 0.02 >> P(|z| > 3) ~ 0.0027
        let frac = beyond3 as f64 / n as f64;
        assert!(frac > 0.01 && frac < 0.06, "tail frac {frac}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn geometric_skip_mean() {
        let mut r = Rng::new(8);
        let p = 0.1;
        let n = 50_000usize;
        let total: usize = (0..n).map(|_| r.geometric_skip(p)).sum();
        let mean = total as f64 / n as f64;
        // E[skips] = (1-p)/p = 9
        assert!((mean - 9.0).abs() < 0.35, "mean {mean}");
        assert_eq!(r.geometric_skip(1.0), 0);
        assert_eq!(r.geometric_skip(0.0), usize::MAX);
    }

    #[test]
    fn geometric_skip_boundaries_degenerate_safely() {
        // q_{d->b} at or beyond the open interval (0, 1) degenerates the
        // geometric skip — these are exactly the values the config layer
        // rejects at parse time (configx::ExperimentConfig::validate); the
        // generator itself must still never panic or return junk indices.
        let mut r = Rng::new(17);
        // p = 1: every dark point is proposed (skip 0)
        assert_eq!(r.geometric_skip(1.0), 0);
        // p > 1 clamps to the p = 1 behavior
        assert_eq!(r.geometric_skip(1.5), 0);
        // p = 0 / p < 0: no proposal ever (MAX sentinel, loop terminates)
        assert_eq!(r.geometric_skip(0.0), usize::MAX);
        assert_eq!(r.geometric_skip(-0.25), usize::MAX);
        // denormal-small p: (1-p) rounds to 1.0, ln(1-p) = 0, k = inf -> MAX
        assert_eq!(r.geometric_skip(1e-300), usize::MAX);
        // p just inside 1: skips are essentially always 0
        for _ in 0..100 {
            assert_eq!(r.geometric_skip(1.0 - 1e-12), 0);
        }
        // p just inside 0 (but representable in 1-p): finite, huge mean
        let k = r.geometric_skip(1e-9);
        assert!(k < usize::MAX, "skip {k}");
    }

    #[test]
    fn state_roundtrip_continues_the_stream() {
        use crate::util::codec::{ByteReader, ByteWriter};
        for consume_normals in [0usize, 1, 2, 3] {
            let mut a = Rng::new(123);
            for _ in 0..consume_normals {
                let _ = a.normal(); // odd counts leave a cached spare
            }
            let mut w = ByteWriter::new();
            a.save_state(&mut w);
            let bytes = w.into_bytes();
            let mut reader = ByteReader::new(&bytes);
            let mut b = Rng::load_state(&mut reader).unwrap();
            reader.finish().unwrap();
            for _ in 0..64 {
                assert_eq!(a.normal().to_bits(), b.normal().to_bits());
                assert_eq!(a.next_u64(), b.next_u64());
                assert_eq!(a.geometric_skip(0.1), b.geometric_skip(0.1));
            }
        }
        // truncated state errors
        let mut a = Rng::new(5);
        let mut w = ByteWriter::new();
        a.save_state(&mut w);
        let bytes = w.into_bytes();
        assert!(Rng::load_state(&mut ByteReader::new(&bytes[..10])).is_err());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_without_replacement_distinct_in_range_pool_preserved() {
        // Draws must be duplicate-free and in-range on every round, and the
        // persistent pool must remain a permutation of 0..n across rounds
        // (the minibatch hot path relies on never resetting it).
        let mut r = Rng::new(crate::testing::prop_seed() ^ 0x5eed);
        let n = 64usize;
        let mut pool: Vec<u32> = (0..n as u32).collect();
        let mut out = vec![0u32; 0];
        for round in 0..500 {
            let k = 1 + round % n;
            out.resize(k, 0);
            r.sample_without_replacement_into(&mut pool, &mut out);
            let mut seen = out.clone();
            seen.sort_unstable();
            seen.dedup();
            assert_eq!(seen.len(), k, "round {round}: duplicate index drawn");
            assert!(out.iter().all(|&i| (i as usize) < n), "round {round}");
        }
        let mut sorted = pool.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n as u32).collect::<Vec<_>>(), "pool corrupted");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn sample_without_replacement_uniform_chi_square() {
        // Position-wise uniformity: the first drawn index is uniform over
        // 0..n. Chi-square over n=8 cells with 7 dof; the 1e-4 upper critical
        // value is ~27.9, so 30 gives headroom while still having power —
        // a sampler that favored low indices by 10% would blow far past it.
        let mut r = Rng::new(crate::testing::prop_seed() ^ 0xC41);
        let n = 8usize;
        let k = 3usize;
        let draws = 40_000usize;
        let mut pool: Vec<u32> = (0..n as u32).collect();
        let mut out = [0u32; 3];
        let mut first = vec![0usize; n];
        let mut incl = vec![0usize; n];
        for _ in 0..draws {
            r.sample_without_replacement_into(&mut pool, &mut out);
            first[out[0] as usize] += 1;
            for &i in &out {
                incl[i as usize] += 1;
            }
        }
        let expect = draws as f64 / n as f64;
        let chi2: f64 = first
            .iter()
            .map(|&c| {
                let d = c as f64 - expect;
                d * d / expect
            })
            .sum();
        assert!(chi2 < 30.0, "chi2 {chi2} (counts {first:?})");
        // Inclusion probability k/n for every index, within 5% relative.
        let expect_incl = draws as f64 * k as f64 / n as f64;
        for (i, &c) in incl.iter().enumerate() {
            let rel = (c as f64 - expect_incl).abs() / expect_incl;
            assert!(rel < 0.05, "index {i}: inclusion {c} vs {expect_incl}");
        }
    }

    #[test]
    fn sample_without_replacement_full_range_coverage() {
        // Every index of 0..n must eventually appear: 400 draws of k=4 from
        // n=16 miss a fixed index with probability (3/4)^400 ~ 1e-50.
        let mut r = Rng::new(crate::testing::prop_seed() ^ 0xC0FE);
        let n = 16usize;
        let mut pool: Vec<u32> = (0..n as u32).collect();
        let mut out = [0u32; 4];
        let mut hit = vec![false; n];
        for _ in 0..400 {
            r.sample_without_replacement_into(&mut pool, &mut out);
            for &i in &out {
                hit[i as usize] = true;
            }
        }
        assert!(hit.iter().all(|&h| h), "coverage gap: {hit:?}");
    }

    #[test]
    fn shuffle_prefix_degenerate_k() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..10).collect();
        // k = 0 is a no-op
        r.shuffle_prefix(&mut v, 0);
        assert_eq!(v, (0..10).collect::<Vec<_>>());
        // k >= len degrades to a full shuffle (still a permutation)
        r.shuffle_prefix(&mut v, 99);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
        // k = len on an empty slice must not panic
        r.shuffle_prefix::<u32>(&mut [], 5);
    }

    #[test]
    #[should_panic(expected = "sample_without_replacement_into")]
    fn sample_without_replacement_oversized_k_panics() {
        let mut r = Rng::new(12);
        let mut pool = [0u32, 1, 2];
        let mut out = [0u32; 4];
        r.sample_without_replacement_into(&mut pool, &mut out);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }
}
