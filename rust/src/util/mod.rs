//! Shared substrates: PRNG, scalar math, the little-endian byte codec the
//! checkpoint layer serializes through, and the counting allocator used by
//! the zero-allocation hot-path tests/benches.

pub mod alloc_count;
pub mod codec;
pub mod math;
pub mod rng;

pub use rng::Rng;

/// Wall-clock timer for §Perf instrumentation.
#[derive(Debug)]
pub struct Timer(std::time::Instant);

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer(std::time::Instant::now())
    }
    /// Seconds since [`Timer::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    /// Milliseconds since [`Timer::start`].
    pub fn elapsed_ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}
