//! CLI substrate (offline stand-in for `clap`): subcommands + `--flag value`
//! / `--flag=value` / boolean flags, with generated usage text.

/// Parsed command line: subcommand + flags + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// the first non-flag token
    pub subcommand: Option<String>,
    /// `--key [value]` flag pairs in order of appearance
    pub flags: Vec<(String, Option<String>)>,
    /// non-flag tokens after the subcommand
    pub positional: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (without argv[0]). The first non-flag token is
    /// the subcommand; `--key value`, `--key=value`, and bare `--key` are all
    /// accepted (a following token starting with `--` is not consumed).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.flags.push((
                        stripped[..eq].to_string(),
                        Some(stripped[eq + 1..].to_string()),
                    ));
                } else {
                    let val = match iter.peek() {
                        Some(next) if !next.starts_with("--") => iter.next(),
                        _ => None,
                    };
                    out.flags.push((stripped.to_string(), val));
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments (argv[0] skipped).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Last value given for `--key` (None if absent or bare).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Whether `--key` appeared at all (with or without a value).
    pub fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }

    /// Float flag with a default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// Unsigned-integer flag with a default.
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// u64 flag with a default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// String flag with a default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        // NB: a bare boolean flag followed by a non-flag token would absorb
        // it as a value (`--verbose extra`) — boolean flags go last or use
        // `=`; the positional comes before.
        let a = parse("run --config exp.toml --iters=500 extra --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("config"), Some("exp.toml"));
        assert_eq!(a.get_usize("iters", 0), 500);
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), None);
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn flag_does_not_eat_next_flag() {
        let a = parse("bench --quick --seed 7");
        assert!(a.has("quick"));
        assert_eq!(a.get("quick"), None);
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn last_flag_wins() {
        let a = parse("x --n 1 --n 2");
        assert_eq!(a.get_usize("n", 0), 2);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get_f64("missing", 1.25), 1.25);
        assert_eq!(a.get_str("missing", "d"), "d");
    }
}
