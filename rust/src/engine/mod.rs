//! Chain orchestration: single-chain driver, threaded multi-chain runner,
//! and the experiment builder that assembles data + model + bound-tuning +
//! sampler + backend from an [`ExperimentConfig`].

pub mod chain;
pub mod experiment;

pub use chain::{run_chain, ChainConfig, ChainResult, ChainTarget};
pub use experiment::{build_chain, run_experiment, ExperimentResult, TableRow};
