//! Chain orchestration: single-chain driver, the threaded multi-chain
//! replica engine (per-replica seed derivation, split-R̂ / pooled-ESS
//! reporting), and the experiment builder that assembles data + model +
//! bound-tuning + sampler + backend from an [`ExperimentConfig`].
//!
//! [`ExperimentConfig`]: crate::configx::ExperimentConfig

pub mod chain;
pub mod experiment;
pub mod multi_chain;

pub use chain::{
    derive_replica_seed, run_chain, run_chain_replicas, ChainConfig, ChainResult, ChainTarget,
};
pub use experiment::{build_chain, run_experiment, synth_dataset, ExperimentResult, TableRow};
pub use multi_chain::{run_multi_chain, summarize_chains, MultiChainSummary};
