//! Chain orchestration: the resumable single-chain runtime and its
//! streaming observer pipeline, the `.fckpt` checkpoint layer, the threaded
//! multi-chain replica engine (per-replica seed derivation, split-R̂ /
//! pooled-ESS reporting), and the experiment builder that assembles data +
//! model + bound-tuning + sampler + backend from an [`ExperimentConfig`].
//!
//! [`ExperimentConfig`]: crate::configx::ExperimentConfig

pub mod chain;
pub mod checkpoint;
pub mod experiment;
pub mod multi_chain;
pub mod observer;

pub use chain::{
    derive_replica_seed, run_chain, run_chain_replicas, run_chain_replicas_ckpt,
    run_chain_segments, ChainConfig, ChainResult, ChainState, ChainTarget,
};
pub use checkpoint::{
    read_checkpoint, replica_checkpoint_path, write_checkpoint, ChainCheckpointSpec,
    CheckpointImage, CheckpointObserver, ExperimentCheckpointSpec,
};
pub use experiment::{
    build_chain, run_experiment, run_experiment_resume, synth_dataset, ExperimentResult, TableRow,
};
pub use multi_chain::{run_multi_chain, summarize_chains, MultiChainSummary};
pub use observer::{ChainObserver, IterRecord, RecordingObserver, StreamingObserver};
