//! Experiment assembly: config → data → model (+ bound tuning) → prior →
//! backend → sampler → chains, and the Table-1 row computation.

use std::sync::Arc;

use crate::configx::{Algorithm, ExperimentConfig, Task};
use crate::data::store::BlockCacheConfig;
use crate::data::{fbin, synth, AnyData};
use crate::diagnostics;
use crate::engine::chain::{ChainConfig, ChainResult, ChainTarget};
use crate::flymc::{FullPosterior, PseudoPosterior};
use crate::map_estimate::{map_estimate, MapConfig};
use crate::metrics::Counters;
use crate::models::{
    IsoGaussian, Laplace, LogisticJJ, ModelBound, Prior, RobustT, SoftmaxBohning,
};
use crate::runtime::{make_backend, DistOptions, XlaSource};
use crate::samplers::{AusterityMh, Mala, RandomWalkMh, Sampler, Sgld, SliceSampler};
use crate::util::{Rng, Timer};

/// Default problem sizes (paper-scale for MNIST/CIFAR; OPV default scaled,
/// see DESIGN.md §Scaling-defaults).
pub fn default_n(task: Task) -> usize {
    match task {
        Task::LogisticMnist => synth::MNIST_N,
        Task::SoftmaxCifar => synth::CIFAR_N,
        Task::RobustOpv => synth::OPV_N_DEFAULT,
        Task::Toy => 30,
    }
}

/// Per-task default prior scale (paper: tuned on held-out performance).
pub fn default_prior_scale(task: Task) -> f64 {
    match task {
        Task::LogisticMnist | Task::Toy => 1.0,
        Task::SoftmaxCifar => 0.15,
        Task::RobustOpv => 0.5,
    }
}

/// Synthesize the task's workload at size `n` — the single source of truth
/// for the per-task generator and its feature dimensions, shared by
/// [`build_model`] and the CLI `convert` subcommand (so a converted `.fbin`
/// holds exactly the dataset the in-RAM path would have synthesized).
pub fn synth_dataset(task: Task, n: usize, seed: u64) -> AnyData {
    match task {
        Task::Toy => AnyData::Logistic(synth::synth_toy2d(n, seed)),
        Task::LogisticMnist => AnyData::Logistic(synth::synth_mnist(n, 50, seed)),
        Task::SoftmaxCifar => AnyData::Softmax(synth::synth_cifar3(n, 256, seed)),
        Task::RobustOpv => AnyData::Regression(synth::synth_opv(n, 57, seed)),
    }
}

/// MAP-tune (when the algorithm asks for it) and wrap a freshly built model.
/// SGLD-CV also needs the MAP point (as its control-variate anchor) but must
/// NOT re-tune the model's bound anchors — bounds play no role in SGLD.
fn tune_and_wrap<M: XlaSource + 'static>(
    mut model: M,
    prior: Arc<dyn Prior>,
    cfg: &ExperimentConfig,
    lr: Option<f64>,
) -> (Arc<dyn XlaSource>, Arc<dyn Prior>, Option<Vec<f64>>, u64) {
    let wants_map = cfg.algorithm == Algorithm::MapTunedFlyMc
        || (cfg.algorithm == Algorithm::Sgld && cfg.sgld_cv);
    let (map, q) = if wants_map {
        let mut mc = MapConfig {
            steps: cfg.map_steps,
            seed: cfg.seed ^ 0xAD,
            ..Default::default()
        };
        if let Some(lr) = lr {
            mc.lr = lr;
        }
        let res = map_estimate(&model, prior.as_ref(), &mc);
        if cfg.algorithm == Algorithm::MapTunedFlyMc {
            model.tune_anchors_map(&res.theta);
        }
        (Some(res.theta), res.lik_queries)
    } else {
        (None, 0)
    };
    (Arc::new(model), prior, map, q)
}

/// Build the tuned model + prior for a task. Returns the model (already
/// MAP-tuned if requested), the prior, the MAP point (if tuned) and the
/// number of likelihood queries the tuning cost (reported separately, as in
/// the paper).
///
/// With `cfg.data_path` set, the dataset is read out of core from the
/// `.fbin` file (its label kind must match the task; `n_data` is ignored —
/// the file defines N) and sampled through block-cached reads sized by
/// `cfg.cache_rows`; otherwise the task's workload is synthesized in RAM.
pub fn build_model(
    cfg: &ExperimentConfig,
) -> anyhow::Result<(Arc<dyn XlaSource>, Arc<dyn Prior>, Option<Vec<f64>>, u64)> {
    let data = match &cfg.data_path {
        Some(path) => fbin::open_fbin(path, BlockCacheConfig::with_budget(cfg.cache_rows))
            .map_err(|e| anyhow::anyhow!(e))?,
        None => {
            let n = cfg.n_data.unwrap_or_else(|| default_n(cfg.task));
            synth_dataset(cfg.task, n, cfg.seed)
        }
    };
    let scale = cfg.prior_scale.unwrap_or_else(|| default_prior_scale(cfg.task));
    Ok(match (cfg.task, data) {
        (Task::LogisticMnist | Task::Toy, AnyData::Logistic(d)) => {
            let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale });
            tune_and_wrap(LogisticJJ::new(Arc::new(d), cfg.untuned_xi), prior, cfg, None)
        }
        (Task::SoftmaxCifar, AnyData::Softmax(d)) => {
            let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale });
            tune_and_wrap(SoftmaxBohning::new(Arc::new(d)), prior, cfg, None)
        }
        (Task::RobustOpv, AnyData::Regression(d)) => {
            let prior: Arc<dyn Prior> = Arc::new(Laplace { b: scale });
            tune_and_wrap(RobustT::new(Arc::new(d), 4.0, 0.5), prior, cfg, Some(0.1))
        }
        (task, data) => anyhow::bail!(
            "{} holds a {} dataset, which does not feed task {task:?}",
            cfg.data_path.as_deref().unwrap_or("dataset"),
            data.kind_name()
        ),
    })
}

/// Distributed-backend topology options from the `[dist]` config section.
/// The model constants (`untuned_xi`, the robust ν/σ) mirror
/// [`build_model`] exactly: a shard worker rebuilding its model from shard
/// data must land on the same bits the coordinator's full model holds.
pub fn dist_options(cfg: &ExperimentConfig) -> DistOptions {
    DistOptions {
        workers: cfg.dist_workers,
        connect: cfg.dist_connect.clone(),
        timeout_ms: cfg.dist_timeout_ms,
        retries: cfg.dist_retries,
        retry_backoff_ms: cfg.dist_retry_backoff_ms,
        manifest: cfg.dist_manifest.clone(),
        untuned_xi: cfg.untuned_xi,
        nu: 4.0,
        sigma: 0.5,
        ..DistOptions::default()
    }
}

/// The paper's sampler per task, with the paper's target acceptance rates.
pub fn build_sampler(task: Task) -> Box<dyn Sampler> {
    match task {
        Task::LogisticMnist | Task::Toy => Box::new(RandomWalkMh::adaptive(0.02)),
        Task::SoftmaxCifar => Box::new(Mala::adaptive(0.005)),
        Task::RobustOpv => Box::new(SliceSampler::new(0.05)),
    }
}

/// The experiment's θ-update operator for its configured algorithm. The
/// exact algorithms (regular MCMC and both FlyMC variants) delegate to
/// [`build_sampler`] unchanged — their sampler construction is part of the
/// byte-identity contract. The approximate competitors get their own
/// operators, parameterized by the `[approx]` config knobs; SGLD-CV anchors
/// its control variate at the MAP point computed during model setup.
pub fn build_algo_sampler(cfg: &ExperimentConfig, map: Option<&[f64]>) -> Box<dyn Sampler> {
    match cfg.algorithm {
        Algorithm::Sgld => {
            let mut s =
                Sgld::new(cfg.minibatch, cfg.sgld_step_a, cfg.sgld_step_b, cfg.sgld_step_gamma);
            if cfg.sgld_cv {
                let anchor = map.expect("sgld_cv requires the MAP point from model setup");
                s = s.with_anchor(anchor.to_vec());
            }
            Box::new(s)
        }
        Algorithm::Austerity => {
            // reuse the task's random-walk scale as the proposal step; the
            // Robbins–Monro adapter retunes it toward 0.234 during burn-in
            let step = match cfg.task {
                Task::LogisticMnist | Task::Toy => 0.02,
                Task::SoftmaxCifar => 0.005,
                Task::RobustOpv => 0.05,
            };
            Box::new(AusterityMh::adaptive(step, cfg.austerity_eps, cfg.minibatch))
        }
        _ => build_sampler(cfg.task),
    }
}

/// Assemble a ready-to-run chain target (posterior with committed initial
/// state) + initial theta, drawing theta0 from the prior (as in the paper).
pub fn build_chain(
    cfg: &ExperimentConfig,
    model: Arc<dyn XlaSource>,
    prior: Arc<dyn Prior>,
    chain_seed: u64,
) -> anyhow::Result<(ChainTarget, Vec<f64>)> {
    let counters = Counters::new();
    // Shard pool: a dedicated `threads`-sized pool only when this chain runs
    // alone; concurrent replicas share rayon's global pool so the total
    // worker count stays bounded by the machine, not chains × threads.
    let shard_threads = if cfg.chains > 1 { 0 } else { cfg.threads };
    let eval = make_backend(
        model.clone(),
        cfg.backend,
        counters,
        &cfg.artifacts_dir,
        shard_threads,
        &dist_options(cfg),
    )?;
    let mut rng = Rng::new(chain_seed ^ 0x1217);
    let theta0 = prior.sample(model.dim(), &mut rng);
    let model_mb: Arc<dyn ModelBound> = model.as_model_bound();
    Ok(match cfg.algorithm {
        // SGLD and austerity MH drive the full-data posterior through its
        // SubsampleTarget face — no auxiliary z-state, same target type as
        // regular MCMC
        Algorithm::RegularMcmc | Algorithm::Sgld | Algorithm::Austerity => (
            ChainTarget::Regular(FullPosterior::new(model_mb, prior, eval, theta0.clone())),
            theta0,
        ),
        _ => {
            let mut pp = PseudoPosterior::new(model_mb, prior, eval, theta0.clone());
            pp.init_z(&mut rng);
            (ChainTarget::FlyMc(pp), theta0)
        }
    })
}

/// All chains of one experiment plus its setup costs.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    /// the configuration the experiment ran with
    pub config: ExperimentConfig,
    /// per-replica chain outputs (replica order)
    pub chains: Vec<ChainResult>,
    /// likelihood queries spent on MAP tuning (one-time setup)
    pub map_lik_queries: u64,
    /// wall-clock seconds of data/model/tuning setup
    pub setup_secs: f64,
    /// dataset size N actually used
    pub n_data: usize,
}

impl ExperimentResult {
    /// Bright-count time-series summary pooled across replicas: min of the
    /// per-chain minima, mean of the per-chain means, max of the maxima,
    /// and the last chain's final count — all fed by the streaming
    /// observer, so it is available even when no trace is kept. `None` for
    /// regular MCMC (no bright set).
    pub fn bright_stats(&self) -> Option<(usize, f64, usize, usize)> {
        let with: Vec<&crate::diagnostics::BrightStats> = self
            .chains
            .iter()
            .map(|c| &c.stats.bright)
            .filter(|b| b.count > 0)
            .collect();
        if with.is_empty() {
            return None;
        }
        let min = with.iter().map(|b| b.min).min().unwrap();
        let max = with.iter().map(|b| b.max).max().unwrap();
        let mean = with.iter().map(|b| b.mean()).sum::<f64>() / with.len() as f64;
        let last = with.last().unwrap().last;
        Some((min, mean, max, last))
    }

    /// [`Self::bright_stats`] over the *pre-re-anchor* window (the bound
    /// regime before the online restart) — `None` unless re-anchoring ran
    /// and recorded at least one pre-trigger iteration, so summaries only
    /// ever show the split when there is a split to show.
    pub fn bright_pre_stats(&self) -> Option<(usize, f64, usize, usize)> {
        let with: Vec<&crate::diagnostics::BrightStats> = self
            .chains
            .iter()
            .map(|c| &c.stats.bright_pre)
            .filter(|b| b.count > 0)
            .collect();
        if with.is_empty() {
            return None;
        }
        let min = with.iter().map(|b| b.min).min().unwrap();
        let max = with.iter().map(|b| b.max).max().unwrap();
        let mean = with.iter().map(|b| b.mean()).sum::<f64>() / with.len() as f64;
        let last = with.last().unwrap().last;
        Some((min, mean, max, last))
    }

    /// Table-1 style summary over all chains.
    pub fn table_row(&self) -> TableRow {
        let burnin = self.config.burnin;
        let queries: Vec<f64> = self
            .chains
            .iter()
            .map(|c| c.avg_queries_post_burnin(burnin))
            .collect();
        // ess_per_1000 falls back to the streaming batch-means estimate in
        // streaming-only runs (no trace); same for the bright/queries means
        let ess: Vec<f64> = self.chains.iter().map(|c| c.ess_per_1000()).collect();
        let bright: Vec<f64> = self
            .chains
            .iter()
            .map(|c| c.avg_bright_post_burnin(burnin))
            .collect();
        let traces: Vec<&diagnostics::TraceMatrix> =
            self.chains.iter().map(|c| &c.theta_trace).collect();
        TableRow {
            algorithm: self.config.algorithm.label().to_string(),
            avg_lik_queries_per_iter: crate::util::math::mean(&queries),
            ess_per_1000: crate::util::math::mean(&ess),
            // mean over NaNs (regular MCMC: no bright set) stays NaN
            avg_bright: crate::util::math::mean(&bright),
            split_rhat: diagnostics::split_rhat_max_components(&traces),
            wallclock_secs: self.chains.iter().map(|c| c.wallclock_secs).sum::<f64>()
                / self.chains.len() as f64,
        }
    }
}

/// One row of the paper's Table 1 (speedup is filled in relative to the
/// regular-MCMC row by the caller).
#[derive(Clone, Debug)]
pub struct TableRow {
    /// algorithm label
    pub algorithm: String,
    /// mean post-burnin likelihood queries per iteration (Table 1 col 1)
    pub avg_lik_queries_per_iter: f64,
    /// minimum component-wise ESS per 1000 iterations
    pub ess_per_1000: f64,
    /// mean post-burnin bright count M (NaN for regular MCMC)
    pub avg_bright: f64,
    /// worst-component split-R̂ across replica chains (NaN for 1 chain)
    pub split_rhat: f64,
    /// mean wall-clock seconds per chain
    pub wallclock_secs: f64,
}

impl TableRow {
    /// ESS per likelihood query — the implementation-independent efficiency
    /// the paper's "speedup" column is the ratio of.
    pub fn efficiency(&self) -> f64 {
        self.ess_per_1000 / (self.avg_lik_queries_per_iter * 1000.0)
    }

    /// Efficiency ratio against the regular-MCMC row (the paper's speedup).
    pub fn speedup_vs(&self, regular: &TableRow) -> f64 {
        self.efficiency() / regular.efficiency()
    }
}

/// The per-chain driver configuration for an experiment; `seed` is the base
/// seed (replicas derive their own via [`ChainConfig::for_replica`]).
pub fn chain_config(cfg: &ExperimentConfig, seed: u64) -> ChainConfig {
    ChainConfig {
        iters: cfg.iters,
        burnin: cfg.burnin,
        record_full_every: cfg.record_every,
        thin: 1,
        q_dark_to_bright: cfg.effective_q_db(),
        explicit_resample: cfg.explicit_resample,
        resample_fraction: cfg.resample_fraction,
        seed,
        record_trace: cfg.record_trace,
        reanchor_at: cfg.effective_reanchor_at(),
        adapt_q: cfg.adapt_q,
        adapt_window: cfg.effective_adapt_window(),
    }
}

/// Run all chains of one experiment. Replicas fan out across worker threads
/// through [`crate::engine::multi_chain::run_replica_chains`] (capped by
/// `cfg.threads`; XLA runs are serialized there — one PJRT client per chain
/// keeps memory bounded). With `cfg.checkpoint_dir` set, each replica also
/// writes periodic `.fckpt` checkpoints (see [`run_experiment_resume`]).
pub fn run_experiment(cfg: &ExperimentConfig) -> anyhow::Result<ExperimentResult> {
    run_experiment_resume(cfg, false)
}

/// [`run_experiment`] with a resume switch: with `resume`, every replica
/// whose `chain_NNNN.fckpt` exists in `cfg.checkpoint_dir` continues from
/// it (fingerprint-checked) instead of starting over, and the completed
/// experiment's traces, diagnostics inputs, and query counters are
/// byte-identical to a never-interrupted run's. The model/prior deck is
/// rebuilt deterministically from the config (including MAP tuning), so
/// checkpoints stay small — O(N) for the bright set, not O(N·D) for data.
pub fn run_experiment_resume(
    cfg: &ExperimentConfig,
    resume: bool,
) -> anyhow::Result<ExperimentResult> {
    cfg.validate().map_err(|e| anyhow::anyhow!("config error: {e}"))?;
    let timer = Timer::start();
    let (model, prior, map, map_queries) = build_model(cfg)?;
    let setup_secs = timer.elapsed_secs();
    let n_data = model.n();
    let chains = crate::engine::multi_chain::run_replica_chains_resume(
        cfg,
        model,
        prior,
        map.as_deref(),
        resume,
    )?;
    Ok(ExperimentResult {
        config: cfg.clone(),
        chains,
        map_lik_queries: map_queries,
        setup_secs,
        n_data,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(task: Task, algorithm: Algorithm) -> ExperimentConfig {
        ExperimentConfig {
            task,
            algorithm,
            n_data: Some(300),
            iters: 60,
            burnin: 20,
            map_steps: 60,
            chains: 1,
            record_every: 20,
            ..Default::default()
        }
    }

    #[test]
    fn flymc_queries_fewer_than_regular_logistic() {
        let reg = run_experiment(&tiny_cfg(Task::LogisticMnist, Algorithm::RegularMcmc)).unwrap();
        let fly = run_experiment(&tiny_cfg(Task::LogisticMnist, Algorithm::MapTunedFlyMc)).unwrap();
        let rq = reg.table_row().avg_lik_queries_per_iter;
        let fq = fly.table_row().avg_lik_queries_per_iter;
        assert!((rq - 300.0).abs() < 1.0, "regular queries/iter {rq}");
        assert!(fq < 150.0, "flymc queries/iter {fq}");
    }

    #[test]
    fn all_tasks_and_algorithms_run() {
        for task in [Task::LogisticMnist, Task::SoftmaxCifar, Task::RobustOpv, Task::Toy] {
            for alg in [
                Algorithm::RegularMcmc,
                Algorithm::UntunedFlyMc,
                Algorithm::MapTunedFlyMc,
                Algorithm::Sgld,
                Algorithm::Austerity,
            ] {
                let mut cfg = tiny_cfg(task, alg);
                cfg.iters = 25;
                cfg.burnin = 10;
                cfg.minibatch = 30;
                if task == Task::SoftmaxCifar {
                    cfg.n_data = Some(120); // keep D=256 setup cheap in tests
                    cfg.map_steps = 20;
                }
                let res = run_experiment(&cfg).unwrap_or_else(|e| panic!("{task:?}/{alg:?}: {e}"));
                let row = res.table_row();
                assert!(
                    row.avg_lik_queries_per_iter.is_finite(),
                    "{task:?} {alg:?} queries"
                );
                assert!(res.chains[0].logpost_joint.iter().all(|l| l.is_finite()));
            }
        }
    }

    #[test]
    fn sgld_cv_runs_through_the_engine_with_a_map_anchor() {
        // sgld_cv forces a MAP estimate during setup (reported separately,
        // like FlyMC's tuning cost) without touching the model's bound
        // anchors, and the chain runs with finite minibatch log-density
        let mut cfg = tiny_cfg(Task::Toy, Algorithm::Sgld);
        cfg.sgld_cv = true;
        cfg.minibatch = 10;
        let res = run_experiment(&cfg).unwrap();
        assert!(res.map_lik_queries > 0, "CV anchor needs the MAP pass");
        assert!(res.chains[0].logpost_joint.iter().all(|l| l.is_finite()));
        // plain SGLD skips the MAP pass entirely
        let res = run_experiment(&tiny_cfg(Task::Toy, Algorithm::Sgld)).unwrap();
        assert_eq!(res.map_lik_queries, 0);
    }

    #[test]
    fn approx_samplers_query_fewer_than_full_mh() {
        let full = run_experiment(&tiny_cfg(Task::LogisticMnist, Algorithm::RegularMcmc)).unwrap();
        let fq = full.table_row().avg_lik_queries_per_iter;
        let mut cfg = tiny_cfg(Task::LogisticMnist, Algorithm::Sgld);
        cfg.minibatch = 30;
        let sgld = run_experiment(&cfg).unwrap();
        let sq = sgld.table_row().avg_lik_queries_per_iter;
        assert!((sq - 30.0).abs() < 1.0, "SGLD queries/iter {sq} != minibatch");
        assert!(sq < fq, "SGLD {sq} vs full {fq}");
        let mut cfg = tiny_cfg(Task::LogisticMnist, Algorithm::Austerity);
        cfg.minibatch = 30;
        let aus = run_experiment(&cfg).unwrap();
        let aq = aus.table_row().avg_lik_queries_per_iter;
        assert!(aq < fq, "austerity {aq} vs full {fq}");
    }

    #[test]
    fn table_row_ess_routes_through_shared_helper() {
        // TableRow's ESS column must be exactly the shared diagnostics
        // helper (it used to reimplement the formula inline with a
        // different empty-trace guard).
        let res = run_experiment(&tiny_cfg(Task::LogisticMnist, Algorithm::UntunedFlyMc)).unwrap();
        let row = res.table_row();
        let expect = diagnostics::ess_per_1000_min_components(&res.chains[0].theta_trace);
        assert!(
            (row.ess_per_1000 - expect).abs() < 1e-12,
            "{} vs {expect}",
            row.ess_per_1000
        );
    }

    #[test]
    fn bright_stats_aggregate_matches_recorded_series() {
        // pins the experiment-level aggregation of the streaming
        // bright-count summary against the recorded per-iteration series
        let mut cfg = tiny_cfg(Task::LogisticMnist, Algorithm::UntunedFlyMc);
        cfg.chains = 2;
        let res = run_experiment(&cfg).unwrap();
        let (min, mean, max, last) = res.bright_stats().expect("FlyMC exposes bright stats");
        let burnin = cfg.burnin;
        let series_min = res
            .chains
            .iter()
            .map(|c| *c.bright[burnin..].iter().min().unwrap())
            .min()
            .unwrap();
        let series_max = res
            .chains
            .iter()
            .map(|c| *c.bright[burnin..].iter().max().unwrap())
            .max()
            .unwrap();
        assert_eq!(min, series_min);
        assert_eq!(max, series_max);
        assert_eq!(last, *res.chains.last().unwrap().bright.last().unwrap());
        let series_mean = res
            .chains
            .iter()
            .map(|c| c.avg_bright_post_burnin(burnin))
            .sum::<f64>()
            / res.chains.len() as f64;
        assert!((mean - series_mean).abs() < 1e-9, "{mean} vs {series_mean}");
        // regular MCMC has no bright set
        let res = run_experiment(&tiny_cfg(Task::LogisticMnist, Algorithm::RegularMcmc)).unwrap();
        assert!(res.bright_stats().is_none());
    }

    #[test]
    fn multichain_threads_give_independent_chains() {
        let mut cfg = tiny_cfg(Task::LogisticMnist, Algorithm::UntunedFlyMc);
        cfg.chains = 3;
        cfg.iters = 30;
        let res = run_experiment(&cfg).unwrap();
        assert_eq!(res.chains.len(), 3);
        assert_ne!(res.chains[0].logpost_joint, res.chains[1].logpost_joint);
        assert_ne!(res.chains[1].logpost_joint, res.chains[2].logpost_joint);
    }
}
