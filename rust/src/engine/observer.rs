//! The streaming chain-observer pipeline.
//!
//! The chain driver ([`crate::engine::chain::ChainState`]) no longer owns
//! its recording logic: each completed iteration is published as an
//! [`IterRecord`] to a pluggable list of [`ChainObserver`]s. The built-ins:
//!
//! * [`RecordingObserver`] — the classic in-memory series (θ trace, joint
//!   log-posterior, bright counts, per-iteration queries, full-log-posterior
//!   instrumentation points), O(iters × dim) memory;
//! * [`StreamingObserver`] — Welford moments, batch-means ESS and split-R̂
//!   inputs, and the bright-count summary in O(dim) memory
//!   ([`crate::diagnostics::streaming`]), so ten-million-iteration chains
//!   don't need a trace;
//! * [`crate::engine::checkpoint::CheckpointObserver`] — periodic `.fckpt`
//!   snapshots for bit-identical resume.
//!
//! Observers are checkpointable: each contributes a tagged state section to
//! the [`CheckpointImage`] and restores from it on resume, so a resumed
//! chain's recorded output is byte-identical to an uninterrupted run's.
//! `on_iter` runs inside the zero-allocation steady-state window — the
//! built-ins only write into buffers reserved at construction (checkpoint
//! writes are boundary events, excluded from that window).

use crate::diagnostics::streaming::StreamingStats;
use crate::diagnostics::{StreamingSummary, TraceMatrix};
use crate::engine::chain::ChainConfig;
use crate::engine::checkpoint::CheckpointImage;
use crate::flymc::ZStats;
use crate::util::codec::{ByteReader, ByteWriter};

/// Everything one completed chain iteration publishes to the observers.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord<'a> {
    /// 0-based index of the iteration just completed
    pub iter: usize,
    /// the chain position after the θ- and z-updates
    pub theta: &'a [f64],
    /// whether the θ-proposal was accepted
    pub accepted: bool,
    /// joint (pseudo-)posterior log density at the post-step state
    pub logpost_joint: f64,
    /// bright count (None for the regular posterior)
    pub n_bright: Option<usize>,
    /// likelihood queries spent by this iteration
    pub queries_delta: u64,
    /// z-resampling sweep outcome (None for the regular posterior)
    pub z: Option<ZStats>,
    /// full-data log posterior, present only on `record_full_every` ticks
    pub full_logpost: Option<f64>,
    /// whether this iteration is on the θ-trace cadence (post-burn-in,
    /// thinned) — precomputed by the driver so every observer agrees
    pub record_theta: bool,
}

/// A consumer of per-iteration chain records, checkpointable alongside the
/// chain (see the module docs).
pub trait ChainObserver {
    /// 4-byte section tag identifying this observer's state inside a
    /// [`CheckpointImage`] (unique within one chain's observer list).
    fn tag(&self) -> [u8; 4];

    /// Consume one completed iteration. Runs on the hot path: must not
    /// allocate (write only into buffers reserved at construction).
    fn on_iter(&mut self, rec: &IterRecord<'_>);

    /// Serialize this observer's accumulated state (bit-exact).
    fn save_state(&self, w: &mut ByteWriter);

    /// Restore [`ChainObserver::save_state`] bytes into an observer
    /// constructed for the same chain configuration.
    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String>;

    /// Whether the driver should assemble a checkpoint image after the
    /// iteration that brought the chain to `completed` total iterations
    /// (`finished` marks the final one). Default: never.
    fn wants_checkpoint(&self, _completed: usize, _finished: bool) -> bool {
        false
    }

    /// Receive an assembled checkpoint image (all observers see every
    /// image; only writers act on it). Default: no-op.
    fn on_checkpoint(&mut self, _image: &CheckpointImage) -> anyhow::Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// The classic in-memory recorder: everything [`crate::engine::ChainResult`]
/// reports, reserved up front so recording never allocates mid-chain. Can
/// be constructed **disabled** (`ChainConfig::record_trace = false`, the
/// CLI's `--streaming-only`): it then records nothing and holds no
/// reservations, so very long chains keep bounded memory and small
/// checkpoints — the streaming observer carries the summary instead.
#[derive(Clone, Debug)]
pub struct RecordingObserver {
    enabled: bool,
    pub(crate) theta_trace: TraceMatrix,
    pub(crate) logpost_joint: Vec<f64>,
    pub(crate) full_logpost: Vec<(usize, f64)>,
    pub(crate) bright: Vec<usize>,
    pub(crate) queries_per_iter: Vec<u64>,
}

impl RecordingObserver {
    /// Recorder for one chain. When `cfg.record_trace` is set, every series
    /// is reserved to its final length (the zero-alloc hot-path invariant,
    /// DESIGN.md §Perf); otherwise the recorder is disabled and empty.
    pub fn new(cfg: &ChainConfig, dim: usize) -> Self {
        if !cfg.record_trace {
            return RecordingObserver {
                enabled: false,
                theta_trace: TraceMatrix::new(dim),
                logpost_joint: Vec::new(),
                full_logpost: Vec::new(),
                bright: Vec::new(),
                queries_per_iter: Vec::new(),
            };
        }
        let full_rows = if cfg.record_full_every > 0 {
            cfg.iters / cfg.record_full_every + 1
        } else {
            0
        };
        let trace_rows = cfg.iters.saturating_sub(cfg.burnin) / cfg.thin.max(1) + 1;
        RecordingObserver {
            enabled: true,
            theta_trace: TraceMatrix::with_capacity(dim, trace_rows),
            logpost_joint: Vec::with_capacity(cfg.iters),
            full_logpost: Vec::with_capacity(full_rows),
            bright: Vec::with_capacity(cfg.iters),
            queries_per_iter: Vec::with_capacity(cfg.iters),
        }
    }

    /// Whether this recorder stores anything at all.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// The recorded θ trace (post-burn-in, thinned).
    pub fn theta_trace(&self) -> &TraceMatrix {
        &self.theta_trace
    }

    /// Iterations recorded so far.
    pub fn iters_recorded(&self) -> usize {
        self.logpost_joint.len()
    }
}

impl ChainObserver for RecordingObserver {
    fn tag(&self) -> [u8; 4] {
        *b"RECD"
    }

    fn on_iter(&mut self, rec: &IterRecord<'_>) {
        if !self.enabled {
            return;
        }
        self.queries_per_iter.push(rec.queries_delta);
        self.logpost_joint.push(rec.logpost_joint);
        if let Some(b) = rec.n_bright {
            self.bright.push(b);
        }
        if let Some(f) = rec.full_logpost {
            self.full_logpost.push((rec.iter, f));
        }
        if rec.record_theta {
            self.theta_trace.push_row(rec.theta);
        }
    }

    fn save_state(&self, w: &mut ByteWriter) {
        w.bool(self.enabled);
        if !self.enabled {
            return;
        }
        w.usize(self.theta_trace.dim());
        w.f64_slice(self.theta_trace.raw());
        w.f64_slice(&self.logpost_joint);
        w.usize(self.full_logpost.len());
        for &(it, v) in &self.full_logpost {
            w.usize(it);
            w.f64(v);
        }
        w.usize(self.bright.len());
        for &b in &self.bright {
            w.usize(b);
        }
        w.u64_slice(&self.queries_per_iter);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let enabled = r.bool()?;
        if enabled != self.enabled {
            return Err(
                "checkpoint recording mode does not match this chain's (streaming-only \
                 toggled between sessions?)"
                    .to_string(),
            );
        }
        if !enabled {
            return Ok(());
        }
        let dim = r.usize()?;
        let raw = r.f64_vec()?;
        self.theta_trace.restore_raw(dim, &raw)?;
        r.f64_slice_into(&mut self.logpost_joint)?;
        let n_full = r.usize()?;
        self.full_logpost.clear();
        for _ in 0..n_full {
            let it = r.usize()?;
            let v = r.f64()?;
            self.full_logpost.push((it, v));
        }
        let n_bright = r.usize()?;
        self.bright.clear();
        for _ in 0..n_bright {
            self.bright.push(r.usize()?);
        }
        r.u64_slice_into(&mut self.queries_per_iter)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------

/// Bounded-memory statistics observer: folds the trace-cadence θ rows and
/// the post-burn-in bright counts into a [`StreamingStats`] engine
/// (O(dim) memory regardless of chain length — see
/// [`crate::diagnostics::streaming`] for the estimators and their
/// documented tolerances).
#[derive(Clone, Debug)]
pub struct StreamingObserver {
    stats: StreamingStats,
    burnin: usize,
    /// re-anchor trigger iteration: bright counts before it are folded into
    /// the separate pre-re-anchor summary so the two bound regimes are
    /// never conflated (None = feature off, `bright_pre` stays empty and
    /// the legacy summary is untouched)
    split_at: Option<usize>,
}

impl StreamingObserver {
    /// Streaming statistics for one chain. The θ-moment window is exactly
    /// the trace cadence (post-burn-in, thinned); bright counts are folded
    /// for every post-burn-in iteration, plus — when re-anchoring is on —
    /// a separate pre-re-anchor bright summary over iterations before the
    /// trigger.
    pub fn new(cfg: &ChainConfig, dim: usize) -> Self {
        let post = cfg.iters.saturating_sub(cfg.burnin);
        let rows = post.div_ceil(cfg.thin.max(1));
        StreamingObserver {
            stats: StreamingStats::new(dim, rows),
            burnin: cfg.burnin,
            split_at: cfg.reanchor_at,
        }
    }

    /// The underlying streaming engine.
    pub fn stats(&self) -> &StreamingStats {
        &self.stats
    }

    /// Materialize the exportable summary (allocates; end-of-run only).
    pub fn into_summary(self) -> StreamingSummary {
        self.stats.summary()
    }
}

impl ChainObserver for StreamingObserver {
    fn tag(&self) -> [u8; 4] {
        *b"STAT"
    }

    fn on_iter(&mut self, rec: &IterRecord<'_>) {
        if rec.record_theta {
            self.stats.record_row(rec.theta);
        }
        if let (Some(split), Some(b)) = (self.split_at, rec.n_bright) {
            if rec.iter < split {
                self.stats.record_bright_pre(b);
            }
        }
        if rec.iter >= self.burnin {
            self.stats.record_queries(rec.queries_delta);
            if let Some(b) = rec.n_bright {
                self.stats.record_bright(b);
            }
        }
    }

    fn save_state(&self, w: &mut ByteWriter) {
        self.stats.save_state(w);
    }

    fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        self.stats.load_state(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, theta: &[f64], record_theta: bool) -> IterRecord<'_> {
        IterRecord {
            iter,
            theta,
            accepted: iter % 2 == 0,
            logpost_joint: -(iter as f64),
            n_bright: Some(iter % 5),
            queries_delta: iter as u64,
            z: None,
            full_logpost: if iter % 10 == 0 { Some(-2.0 * iter as f64) } else { None },
            record_theta,
        }
    }

    #[test]
    fn recording_observer_roundtrips_through_checkpoint_state() {
        let cfg = ChainConfig { iters: 40, burnin: 10, thin: 3, ..Default::default() };
        let mut a = RecordingObserver::new(&cfg, 2);
        for it in 0..25 {
            let theta = [it as f64, -1.0];
            let record = it >= 10 && (it - 10) % 3 == 0;
            a.on_iter(&rec(it, &theta, record));
        }
        let mut w = ByteWriter::new();
        a.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut b = RecordingObserver::new(&cfg, 2);
        let mut r = ByteReader::new(&bytes);
        b.load_state(&mut r).unwrap();
        r.finish().unwrap();
        // continue both; the final series must be identical
        for it in 25..40 {
            let theta = [it as f64, -1.0];
            let record = (it - 10) % 3 == 0;
            a.on_iter(&rec(it, &theta, record));
            b.on_iter(&rec(it, &theta, record));
        }
        assert_eq!(a.theta_trace, b.theta_trace);
        assert_eq!(a.logpost_joint, b.logpost_joint);
        assert_eq!(a.full_logpost, b.full_logpost);
        assert_eq!(a.bright, b.bright);
        assert_eq!(a.queries_per_iter, b.queries_per_iter);
        assert_eq!(a.iters_recorded(), 40);
    }

    #[test]
    fn streaming_observer_burnin_and_cadence() {
        let cfg = ChainConfig { iters: 30, burnin: 10, thin: 2, ..Default::default() };
        let mut o = StreamingObserver::new(&cfg, 2);
        for it in 0..30 {
            let theta = [1.0 + it as f64, 0.0];
            let record = it >= 10 && (it - 10) % 2 == 0;
            o.on_iter(&rec(it, &theta, record));
        }
        // rows = ceil((30-10)/2) = 10; bright folded for the 20 post-burnin iters
        assert_eq!(o.stats().rows(), 10);
        let s = o.into_summary();
        assert_eq!(s.bright.count, 20);
        assert_eq!(s.bright.min, 0);
        assert_eq!(s.bright.max, 4);
        assert_eq!(s.bright.last, 29 % 5);
        // recorded iters 10,12,...,28 -> theta[0] mean = 1 + 19 = 20
        assert!((s.mean[0] - 20.0).abs() < 1e-12);
        // re-anchoring off: the pre-re-anchor series stays empty
        assert_eq!(s.bright_pre.count, 0);
    }

    #[test]
    fn streaming_observer_splits_bright_at_the_reanchor_trigger() {
        let cfg = ChainConfig {
            iters: 30,
            burnin: 10,
            thin: 2,
            reanchor_at: Some(6),
            ..Default::default()
        };
        let mut o = StreamingObserver::new(&cfg, 2);
        for it in 0..30 {
            let theta = [1.0 + it as f64, 0.0];
            let record = it >= 10 && (it - 10) % 2 == 0;
            o.on_iter(&rec(it, &theta, record));
        }
        let s = o.into_summary();
        // iters 0..6 (n_bright = it % 5) feed the pre-re-anchor series ...
        assert_eq!(s.bright_pre.count, 6);
        assert_eq!(s.bright_pre.min, 0);
        assert_eq!(s.bright_pre.max, 4);
        assert_eq!(s.bright_pre.last, 5 % 5);
        // ... and the post-burn-in series is exactly what it always was
        assert_eq!(s.bright.count, 20);
        assert_eq!(s.bright.min, 0);
        assert_eq!(s.bright.max, 4);
    }
}
