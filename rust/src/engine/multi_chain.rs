//! Threaded multi-chain replica engine + cross-chain convergence reporting.
//!
//! `run_replica_chains` fans an experiment's R replica chains across worker
//! threads (each chain builds its own backend and RNG stream in-thread via
//! [`crate::engine::chain::run_chain_replicas`], with per-replica seeds from
//! [`crate::engine::chain::derive_replica_seed`]). `summarize_chains` then
//! feeds the replica traces to the cross-chain machinery in
//! [`crate::diagnostics`] — split-R̂ (worst θ component and joint
//! log-density) and pooled ESS — which a single chain can never exercise.
//!
//! Determinism: replica r's chain depends only on (config, base seed, r),
//! never on the thread cap or scheduling, so a multi-chain run is bit-for-
//! bit reproducible at any `--threads` setting (verified in
//! `rust/tests/integration_parallel.rs`).

use std::sync::Arc;

use crate::configx::{Backend, ExperimentConfig};
use crate::diagnostics;
use crate::engine::chain::{ChainConfig, ChainResult};
use crate::engine::experiment::{
    build_algo_sampler, build_chain, chain_config, run_experiment, ExperimentResult,
};
use crate::models::Prior;
use crate::runtime::XlaSource;
use crate::samplers::Sampler;

/// Cross-chain summary computed from R replica chains.
#[derive(Clone, Debug)]
pub struct MultiChainSummary {
    /// number of replica chains summarized
    pub replicas: usize,
    /// worst (max over θ components) split-R̂ across replicas
    pub split_rhat_max: f64,
    /// split-R̂ of the post-burnin joint log-density trace
    pub split_rhat_logpost: f64,
    /// pooled (summed over replicas) minimum-component ESS
    pub pooled_ess: f64,
    /// post-burnin likelihood queries per iteration, averaged over replicas
    pub avg_queries_per_iter: f64,
    /// total likelihood queries across all replicas (setup + sampling)
    pub total_lik_queries: u64,
}

/// Run all replica chains of one experiment concurrently.
///
/// The thread cap is `cfg.threads` (0 = one thread per replica). XLA-backed
/// runs are serialized — each chain holds its own PJRT client, so running
/// them one at a time keeps memory bounded.
pub fn run_replica_chains(
    cfg: &ExperimentConfig,
    model: Arc<dyn XlaSource>,
    prior: Arc<dyn Prior>,
) -> anyhow::Result<Vec<ChainResult>> {
    run_replica_chains_resume(cfg, model, prior, None, false)
}

/// Assemble the experiment's checkpoint wiring from its config: `None`
/// when checkpointing is off, otherwise a spec over `cfg.checkpoint_dir`
/// (created if missing) stamped with the config fingerprint.
fn checkpoint_spec(
    cfg: &ExperimentConfig,
    resume: bool,
) -> anyhow::Result<Option<crate::engine::checkpoint::ExperimentCheckpointSpec>> {
    let Some(dir) = &cfg.checkpoint_dir else {
        if resume {
            anyhow::bail!(
                "resume needs a checkpoint directory (--checkpoint-dir / [checkpoint] dir)"
            );
        }
        return Ok(None);
    };
    std::fs::create_dir_all(dir).map_err(|e| anyhow::anyhow!("{dir}: {e}"))?;
    Ok(Some(crate::engine::checkpoint::ExperimentCheckpointSpec {
        dir: dir.clone(),
        every: cfg.checkpoint_every,
        fingerprint: cfg.fingerprint(),
        resume,
        stop_after: cfg.stop_after,
    }))
}

/// [`run_replica_chains`] with checkpoint/resume wiring taken from the
/// config: with `cfg.checkpoint_dir` set each replica writes (and, with
/// `resume`, restores) its own `chain_NNNN.fckpt`; replicas without a
/// checkpoint file start fresh, so one `resume` call heals a partially
/// interrupted experiment. The resumed experiment's chains are
/// byte-identical to a never-interrupted run's (DESIGN.md §Checkpointing).
pub fn run_replica_chains_resume(
    cfg: &ExperimentConfig,
    model: Arc<dyn XlaSource>,
    prior: Arc<dyn Prior>,
    map: Option<&[f64]>,
    resume: bool,
) -> anyhow::Result<Vec<ChainResult>> {
    let threads = if cfg.backend == Backend::Xla { 1 } else { cfg.threads };
    let base = chain_config(cfg, cfg.seed);
    let spec = checkpoint_spec(cfg, resume)?;
    crate::engine::chain::run_chain_replicas_ckpt(
        cfg.chains.max(1),
        threads,
        &base,
        spec.as_ref(),
        |ccfg: &ChainConfig| {
            let (target, theta0) = build_chain(cfg, model.clone(), prior.clone(), ccfg.seed)?;
            let sampler: Box<dyn Sampler> = build_algo_sampler(cfg, map);
            Ok((target, sampler, theta0))
        },
    )
}

/// Cross-chain diagnostics over finished replicas. `burnin` indexes the raw
/// per-iteration series (`logpost_joint`, `queries_per_iter`); the θ traces
/// are already post-burnin.
pub fn summarize_chains(chains: &[ChainResult], burnin: usize) -> MultiChainSummary {
    let traces: Vec<&diagnostics::TraceMatrix> =
        chains.iter().map(|c| &c.theta_trace).collect();
    // post-burnin log-posterior series are borrowed in place — the old
    // collection copied every chain's tail into a Vec<Vec<f64>>
    let logpost: Vec<&[f64]> = chains
        .iter()
        .map(|c| &c.logpost_joint[burnin.min(c.logpost_joint.len())..])
        .collect();
    let queries: Vec<f64> = chains.iter().map(|c| c.avg_queries_post_burnin(burnin)).collect();
    MultiChainSummary {
        replicas: chains.len(),
        split_rhat_max: diagnostics::split_rhat_max_components(&traces),
        split_rhat_logpost: diagnostics::split_rhat_slices(&logpost),
        pooled_ess: diagnostics::pooled_ess_min_components(&traces),
        avg_queries_per_iter: crate::util::math::mean(&queries),
        total_lik_queries: chains.iter().map(|c| c.final_counters.lik_queries).sum(),
    }
}

/// Run an experiment's replicas concurrently and report convergence: the
/// one-call entry point for R ≥ 2 chains with split-R̂ / pooled-ESS output.
pub fn run_multi_chain(
    cfg: &ExperimentConfig,
) -> anyhow::Result<(ExperimentResult, MultiChainSummary)> {
    let result = run_experiment(cfg)?;
    let summary = summarize_chains(&result.chains, cfg.burnin);
    Ok((result, summary))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{Algorithm, Task};

    fn cfg(chains: usize, threads: usize) -> ExperimentConfig {
        ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm: Algorithm::MapTunedFlyMc,
            n_data: Some(300),
            iters: 60,
            burnin: 20,
            map_steps: 50,
            chains,
            threads,
            record_every: 0,
            seed: 1,
            ..Default::default()
        }
    }

    #[test]
    fn four_replicas_report_rhat_and_flymc_cost() {
        let (result, summary) = run_multi_chain(&cfg(4, 0)).unwrap();
        assert_eq!(result.chains.len(), 4);
        assert_eq!(summary.replicas, 4);
        assert!(summary.split_rhat_max.is_finite(), "rhat {}", summary.split_rhat_max);
        assert!(summary.split_rhat_logpost.is_finite());
        assert!(summary.pooled_ess > 0.0);
        // FlyMC's defining property must survive the multi-chain engine:
        // queries/iter far below N for every replica, not just on average.
        for c in &result.chains {
            let q = c.avg_queries_post_burnin(20);
            assert!(q < 150.0, "N=300 but {q} q/iter");
        }
        assert!(summary.avg_queries_per_iter < 150.0);
        assert!(summary.total_lik_queries > 0);
    }

    #[test]
    fn thread_cap_does_not_change_results() {
        let (serial, _) = run_multi_chain(&cfg(3, 1)).unwrap();
        let (parallel, _) = run_multi_chain(&cfg(3, 3)).unwrap();
        for (a, b) in serial.chains.iter().zip(&parallel.chains) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.logpost_joint, b.logpost_joint);
            assert_eq!(a.bright, b.bright);
            assert_eq!(a.queries_per_iter, b.queries_per_iter);
        }
    }
}
