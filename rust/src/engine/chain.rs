//! The FlyMC / regular-MCMC chain runtime (paper Alg 1 at the top level):
//! alternate a θ-update (any sampler) with a z-update (FlyMC only),
//! publishing each completed iteration to the observer pipeline
//! ([`crate::engine::observer`]).
//!
//! The runtime is **resumable**: [`ChainState`] owns the complete mutable
//! state of a running chain (target, sampler, θ, RNG, counters, tallies)
//! and is driven in segments via [`ChainState::run_for`]; at checkpoint
//! boundaries it assembles a [`CheckpointImage`] capturing itself plus
//! every observer, which the checkpoint-writer observer persists as a
//! `.fckpt` file ([`crate::engine::checkpoint`]). A chain restored from a
//! checkpoint and run to completion produces byte-identical traces,
//! diagnostics inputs, and query counters to the never-interrupted run.
//!
//! [`run_chain`] is the one-shot convenience wrapper (recording + streaming
//! observers, no checkpointing) the examples and benches use.

use crate::diagnostics::StreamingSummary;
use crate::engine::checkpoint::{
    read_checkpoint, ChainCheckpointSpec, CheckpointImage, CheckpointObserver,
    ExperimentCheckpointSpec,
};
use crate::engine::observer::{ChainObserver, IterRecord, RecordingObserver, StreamingObserver};
use crate::flymc::{FullPosterior, PseudoPosterior, ReanchorState, ZStats};
use crate::metrics::{CounterSnapshot, Counters};
use crate::samplers::{QController, Sampler, Target};
use crate::util::codec::{ByteReader, ByteWriter};
use crate::util::rng::splitmix64;
use crate::util::{Rng, Timer};

use crate::diagnostics::TraceMatrix;

/// Either posterior, so the chain driver is shared between the baseline and
/// FlyMC (z-updates are a no-op for the regular posterior).
pub enum ChainTarget {
    /// the augmented FlyMC pseudo-posterior (z-updates active)
    FlyMc(PseudoPosterior),
    /// the regular full-data posterior (z-updates are a no-op)
    Regular(FullPosterior),
}

impl ChainTarget {
    /// The θ-density the sampler drives.
    pub fn as_target(&mut self) -> &mut dyn Target {
        match self {
            ChainTarget::FlyMc(p) => p,
            ChainTarget::Regular(p) => p,
        }
    }

    /// Current bright count (None for the regular posterior).
    pub fn n_bright(&self) -> Option<usize> {
        match self {
            ChainTarget::FlyMc(p) => Some(p.n_bright()),
            ChainTarget::Regular(_) => None,
        }
    }

    /// The committed chain state.
    pub fn theta(&self) -> &[f64] {
        match self {
            ChainTarget::FlyMc(p) => p.theta(),
            ChainTarget::Regular(p) => p.theta(),
        }
    }

    /// The query counters of the underlying backend (shared handle).
    pub fn counters(&self) -> crate::metrics::Counters {
        match self {
            ChainTarget::FlyMc(p) => p.eval.counters().clone(),
            ChainTarget::Regular(p) => p.eval.counters().clone(),
        }
    }

    /// Full-data log posterior (uncounted Fig-4 instrumentation).
    pub fn true_log_posterior(&self, theta: &[f64]) -> f64 {
        match self {
            ChainTarget::FlyMc(p) => p.true_log_posterior(theta),
            ChainTarget::Regular(p) => p.true_log_posterior(theta),
        }
    }

    /// Serialize the posterior's chain state (kind-tagged).
    pub fn save_state(&self, w: &mut ByteWriter) {
        match self {
            ChainTarget::FlyMc(p) => {
                w.u8(1);
                p.save_state(w);
            }
            ChainTarget::Regular(p) => {
                w.u8(2);
                p.save_state(w);
            }
        }
    }

    /// Restore [`Self::save_state`] bytes (the posterior kind must match).
    pub fn load_state(&mut self, r: &mut ByteReader) -> Result<(), String> {
        let tag = r.u8()?;
        match (self, tag) {
            (ChainTarget::FlyMc(p), 1) => p.load_state(r),
            (ChainTarget::Regular(p), 2) => p.load_state(r),
            (_, t) => Err(format!(
                "checkpoint target kind {t} does not match this chain's posterior"
            )),
        }
    }

    /// One z-resampling sweep under the chain's *working* knobs (the
    /// adaptive controller may have moved them off their configured values).
    fn z_step(
        &mut self,
        explicit: bool,
        q_db: f64,
        fraction: f64,
        rng: &mut Rng,
    ) -> Option<ZStats> {
        match self {
            ChainTarget::FlyMc(p) => Some(if explicit {
                p.explicit_resample(fraction, rng)
            } else {
                p.implicit_resample(q_db, rng)
            }),
            ChainTarget::Regular(_) => None,
        }
    }

    /// Re-anchor the FlyMC bounds ([`PseudoPosterior::reanchor`]); no-op
    /// (and `false`) on the regular posterior.
    fn reanchor(&mut self, anchor: &[f64], rng: &mut Rng) -> bool {
        match self {
            ChainTarget::FlyMc(p) => p.reanchor(anchor, rng),
            ChainTarget::Regular(_) => false,
        }
    }
}

/// Bright-set turnover the adaptive q-controller drives toward (~5% of the
/// bright set replaced per z-update; DESIGN.md §Bound-management).
pub const Q_TARGET_TURNOVER: f64 = 0.05;

/// Per-chain driver configuration.
#[derive(Clone, Debug)]
pub struct ChainConfig {
    /// total iterations
    pub iters: usize,
    /// burn-in iterations (excluded from the θ trace)
    pub burnin: usize,
    /// record the (expensive, uncounted) full-data log posterior every k
    /// iterations; 0 disables
    pub record_full_every: usize,
    /// thinning for the θ trace used by ESS
    pub thin: usize,
    /// q_{d->b} for implicit (Alg 2) z-resampling
    pub q_dark_to_bright: f64,
    /// use explicit (Alg 1) instead of implicit z-resampling
    pub explicit_resample: bool,
    /// fraction of N redrawn per explicit sweep
    pub resample_fraction: f64,
    /// RNG seed for this chain
    pub seed: u64,
    /// keep the O(iters × dim) in-memory series (θ trace, per-iteration
    /// series); false = streaming-only bounded memory — the recording
    /// observer is disabled and only the O(dim) streaming summary survives
    pub record_trace: bool,
    /// re-anchor the FlyMC bounds at the chain's running posterior mean at
    /// the start of this iteration (must lie inside burn-in; None disables
    /// — the chain is then byte-identical to one without the feature)
    pub reanchor_at: Option<usize>,
    /// adapt `q_dark_to_bright` toward [`Q_TARGET_TURNOVER`] with a
    /// Robbins–Monro controller during the adapt window
    pub adapt_q: bool,
    /// iterations the q-controller adapts for before freezing (must lie
    /// inside burn-in; meaningful only with `adapt_q`)
    pub adapt_window: usize,
}

impl Default for ChainConfig {
    fn default() -> Self {
        ChainConfig {
            iters: 2000,
            burnin: 500,
            record_full_every: 10,
            thin: 1,
            q_dark_to_bright: 0.01,
            explicit_resample: false,
            resample_fraction: 0.1,
            seed: 0,
            record_trace: true,
            reanchor_at: None,
            adapt_q: false,
            adapt_window: 0,
        }
    }
}

impl ChainConfig {
    /// The replica-`i` configuration: identical settings, statistically
    /// independent seed stream derived from (base seed, replica id).
    pub fn for_replica(&self, replica: usize) -> ChainConfig {
        let mut c = self.clone();
        c.seed = derive_replica_seed(self.seed, replica);
        c
    }
}

/// Derive a per-replica seed. Injective in `replica` for a fixed base —
/// `base ^ replica·odd` is injective and each splitmix64 output is a
/// bijection of its input state — and scrambled so nearby bases and replica
/// ids give uncorrelated xoshiro streams.
///
/// Deterministic: a replica's seed is a pure function of (base, replica),
/// so multi-chain runs are reproducible at any thread cap.
///
/// ```
/// use firefly::engine::derive_replica_seed;
///
/// // stable across calls ...
/// assert_eq!(derive_replica_seed(7, 3), derive_replica_seed(7, 3));
/// // ... distinct across replicas and bases
/// assert_ne!(derive_replica_seed(7, 0), derive_replica_seed(7, 1));
/// assert_ne!(derive_replica_seed(7, 0), derive_replica_seed(8, 0));
/// ```
pub fn derive_replica_seed(base: u64, replica: usize) -> u64 {
    let mut s = base ^ (replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let _ = splitmix64(&mut s); // extra scramble round; state advance is bijective
    splitmix64(&mut s)
}

/// Everything one chain records (see [`run_chain`]).
#[derive(Clone, Debug, Default)]
pub struct ChainResult {
    /// post-burnin θ samples (thinned), flat row-major
    pub theta_trace: TraceMatrix,
    /// joint (pseudo-)posterior log density at every iteration
    pub logpost_joint: Vec<f64>,
    /// (iter, full-data log posterior) instrumentation points
    pub full_logpost: Vec<(usize, f64)>,
    /// bright count per iteration (FlyMC only)
    pub bright: Vec<usize>,
    /// likelihood queries per iteration
    pub queries_per_iter: Vec<u64>,
    /// accepted θ-proposals
    pub accepted: usize,
    /// total dark→bright z-flips
    pub z_brightened: usize,
    /// total bright→dark z-flips
    pub z_darkened: usize,
    /// wall-clock duration of the chain loop (accumulated across resumed
    /// sessions; excluded from the byte-identity contract — time is not
    /// resumable)
    pub wallclock_secs: f64,
    /// counter totals at chain end
    pub final_counters: CounterSnapshot,
    /// the seed this chain ran with
    pub seed: u64,
    /// O(dim) streaming statistics (Welford moments, batch-means ESS,
    /// split-R̂ halves, bright min/mean/max/last)
    pub stats: StreamingSummary,
}

impl ChainResult {
    /// Mean likelihood queries per iteration after burn-in (Table 1 col 1).
    /// In streaming-only mode (no per-iteration series) the streaming
    /// observer's O(1) post-burn-in aggregate answers instead — that
    /// aggregate is fixed to the run's configured burn-in window, so the
    /// `burnin` argument only slices the recorded series and is ignored
    /// when none exists.
    pub fn avg_queries_post_burnin(&self, burnin: usize) -> f64 {
        if self.queries_per_iter.is_empty() && self.stats.iters_post_burnin > 0 {
            return self.stats.queries_post_burnin as f64 / self.stats.iters_post_burnin as f64;
        }
        let tail = &self.queries_per_iter[burnin.min(self.queries_per_iter.len())..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().sum::<u64>() as f64 / tail.len() as f64
    }

    /// Mean bright count after burn-in (the paper's M). Falls back to the
    /// streaming bright summary when the per-iteration series is absent —
    /// like [`Self::avg_queries_post_burnin`], the fallback is fixed to
    /// the run's configured burn-in window and ignores the argument.
    pub fn avg_bright_post_burnin(&self, burnin: usize) -> f64 {
        if self.bright.is_empty() && self.stats.bright.count > 0 {
            return self.stats.bright.mean();
        }
        let tail = &self.bright[burnin.min(self.bright.len())..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().sum::<usize>() as f64 / tail.len() as f64
    }

    /// Minimum component-wise ESS per 1000 recorded iterations: the Geyer
    /// trace estimator when a trace exists, the streaming batch-means
    /// estimate otherwise (documented tolerances in
    /// [`crate::diagnostics::streaming`]).
    pub fn ess_per_1000(&self) -> f64 {
        if !self.theta_trace.is_empty() {
            return crate::diagnostics::ess_per_1000_min_components(&self.theta_trace);
        }
        if self.stats.rows > 0 && self.stats.ess_bm_min.is_finite() {
            return self.stats.ess_bm_min * 1000.0 / self.stats.rows as f64;
        }
        f64::NAN
    }
}

// ---------------------------------------------------------------------------
// Resumable chain state
// ---------------------------------------------------------------------------

const TAG_CORE: [u8; 4] = *b"CORE";
const TAG_TARGET: [u8; 4] = *b"TGT0";
const TAG_SAMPLER: [u8; 4] = *b"SMPL";
const TAG_REANCHOR: [u8; 4] = *b"RANC";

/// The complete mutable state of a running chain, driven in segments.
///
/// Construction commits the target at `theta0` and seeds the RNG from
/// `cfg.seed` — exactly the old monolithic loop's preamble — after which
/// [`Self::run_for`] advances the chain while publishing [`IterRecord`]s
/// to the observers. [`Self::restore`] overwrites every piece of state
/// from a [`CheckpointImage`] (the chain must have been *constructed* the
/// same way first, which rebuilds the immutable model/backend deck).
pub struct ChainState {
    target: ChainTarget,
    sampler: Box<dyn Sampler>,
    theta: Vec<f64>,
    rng: Rng,
    cfg: ChainConfig,
    completed: usize,
    accepted: usize,
    z_brightened: usize,
    z_darkened: usize,
    counters: Counters,
    snap: CounterSnapshot,
    wallclock_secs: f64,
    /// working dark→bright rate: starts at `cfg.q_dark_to_bright`, moved by
    /// the q-controller during the adapt window, frozen after
    q_db: f64,
    /// working resampling mode: starts at `cfg.explicit_resample`, may be
    /// switched to explicit by the controller's freeze-time recommendation
    explicit: bool,
    /// online re-anchoring state (None = feature disabled)
    reanchor: Option<ReanchorState>,
    /// adaptive q_dark_to_bright controller (None = feature disabled)
    qctl: Option<QController>,
}

impl ChainState {
    /// Assemble a runnable chain at iteration 0 (commits the target at
    /// `theta0`).
    pub fn new(
        mut target: ChainTarget,
        sampler: Box<dyn Sampler>,
        theta0: Vec<f64>,
        cfg: &ChainConfig,
    ) -> Self {
        let rng = Rng::new(cfg.seed);
        let counters = target.counters();
        target.as_target().commit(&theta0);
        let snap = counters.snapshot();
        let dim = theta0.len();
        ChainState {
            target,
            sampler,
            theta: theta0,
            rng,
            completed: 0,
            accepted: 0,
            z_brightened: 0,
            z_darkened: 0,
            counters,
            snap,
            wallclock_secs: 0.0,
            q_db: cfg.q_dark_to_bright,
            explicit: cfg.explicit_resample,
            reanchor: cfg.reanchor_at.map(|at| ReanchorState::new(at, dim)),
            qctl: if cfg.adapt_q { Some(QController::new(Q_TARGET_TURNOVER)) } else { None },
            cfg: cfg.clone(),
        }
    }

    /// Dimension of the chain position.
    pub fn dim(&self) -> usize {
        self.theta.len()
    }

    /// Iterations completed so far (across sessions).
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Whether the configured iteration budget has been reached.
    pub fn is_finished(&self) -> bool {
        self.completed >= self.cfg.iters
    }

    /// The current chain position.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// Advance at most `k` iterations (stopping at `cfg.iters`), publishing
    /// each to `observers` in order and assembling a checkpoint image
    /// whenever any observer requests one. Returns the number of
    /// iterations actually run. Errors only from observer checkpoint I/O.
    pub fn run_for(
        &mut self,
        k: usize,
        observers: &mut [&mut dyn ChainObserver],
    ) -> anyhow::Result<usize> {
        let mut timer = Timer::start();
        let end = self.cfg.iters.min(self.completed.saturating_add(k));
        let start = self.completed;
        let thin = self.cfg.thin.max(1);
        while self.completed < end {
            let it = self.completed;
            // Online bound re-anchoring (DESIGN.md §Bound-management): at
            // the config-declared trigger, retune the bounds at the running
            // posterior mean and redraw every z from its exact conditional
            // under the new bounds — a legal Markov restart
            // (`flymc::reanchor`). Fires before the θ-step so the restart
            // sits on a committed state; its full-N pass lands in this
            // iteration's query meter.
            if let Some(rst) = self.reanchor.as_mut() {
                if rst.due(it) {
                    self.target.reanchor(rst.anchor(), &mut self.rng);
                    rst.applied = true;
                }
            }
            let info = self.sampler.step(self.target.as_target(), &mut self.theta, &mut self.rng);
            if info.accepted {
                self.accepted += 1;
            }
            let z = self.target.z_step(
                self.explicit,
                self.q_db,
                self.cfg.resample_fraction,
                &mut self.rng,
            );
            if let Some(z) = z {
                self.z_brightened += z.brightened;
                self.z_darkened += z.darkened;
                // Adaptive bright-set control: Robbins–Monro on q_{d→b}
                // toward the target turnover during the adapt window, then
                // freeze (exactly inert afterwards) and apply the
                // explicit-resampling recommendation once.
                if let Some(qc) = self.qctl.as_mut() {
                    if it < self.cfg.adapt_window {
                        let nb = self.target.n_bright().unwrap_or(0);
                        self.q_db = qc.update(self.q_db, z.brightened, z.darkened, nb);
                        if it + 1 == self.cfg.adapt_window {
                            qc.freeze();
                            if qc.recommend_explicit(self.q_db) {
                                self.explicit = true;
                            }
                        }
                    }
                }
            }
            let now = self.counters.snapshot();
            let queries_delta = self.snap.delta(&now).lik_queries;
            self.snap = now;
            let logpost_joint = self.target.as_target().current_log_density();
            let n_bright = self.target.n_bright();
            let full_logpost =
                if self.cfg.record_full_every > 0 && it % self.cfg.record_full_every == 0 {
                    Some(self.target.true_log_posterior(&self.theta))
                } else {
                    None
                };
            let record_theta = it >= self.cfg.burnin && (it - self.cfg.burnin) % thin == 0;
            let rec = IterRecord {
                iter: it,
                theta: &self.theta,
                accepted: info.accepted,
                logpost_joint,
                n_bright,
                queries_delta,
                z,
                full_logpost,
                record_theta,
            };
            for obs in observers.iter_mut() {
                obs.on_iter(&rec);
            }
            // fold the committed position into the re-anchor accumulator so
            // the anchor is a function of the trajectory *before* the
            // trigger only (observe is a no-op once applied)
            if let Some(rst) = self.reanchor.as_mut() {
                rst.observe(&self.theta);
            }
            self.completed += 1;
            let finished = self.completed == self.cfg.iters;
            if observers
                .iter()
                .any(|o| o.wants_checkpoint(self.completed, finished))
            {
                // fold the elapsed time in first so the image carries the
                // wall-clock spent up to this boundary
                self.wallclock_secs += timer.elapsed_secs();
                timer = Timer::start();
                let image = self.checkpoint_image(observers);
                for obs in observers.iter_mut() {
                    obs.on_checkpoint(&image).map_err(|e| {
                        anyhow::anyhow!("checkpoint at iteration {}: {e:#}", self.completed)
                    })?;
                }
            }
        }
        self.wallclock_secs += timer.elapsed_secs();
        Ok(end - start)
    }

    /// Run until `cfg.iters` iterations have completed.
    pub fn run_to_end(&mut self, observers: &mut [&mut dyn ChainObserver]) -> anyhow::Result<()> {
        while !self.is_finished() {
            self.run_for(self.cfg.iters - self.completed, observers)?;
        }
        Ok(())
    }

    /// Assemble a checkpoint image right now and deliver it to every
    /// observer, regardless of cadence — called at voluntary session stops
    /// (`stop_after`) so a bounded session never loses the iterations it
    /// ran past the last cadence boundary.
    pub fn force_checkpoint(
        &mut self,
        observers: &mut [&mut dyn ChainObserver],
    ) -> anyhow::Result<()> {
        let image = self.checkpoint_image(observers);
        for obs in observers.iter_mut() {
            obs.on_checkpoint(&image).map_err(|e| {
                anyhow::anyhow!("checkpoint at iteration {}: {e:#}", self.completed)
            })?;
        }
        Ok(())
    }

    /// Assemble a checkpoint image of the entire chain: driver core (θ,
    /// RNG, tallies, counter totals), posterior, sampler, and one section
    /// per observer. Allocates — a boundary event, never per-iteration.
    ///
    /// # Panics
    ///
    /// Panics if two observers share a section tag (a pipeline wiring bug
    /// — see [`CheckpointImage::push_section`]).
    pub fn checkpoint_image(&self, observers: &[&mut dyn ChainObserver]) -> CheckpointImage {
        let mut image = CheckpointImage::new(self.completed as u64);
        let mut core = ByteWriter::new();
        core.usize(self.completed);
        core.f64(self.wallclock_secs);
        core.usize(self.accepted);
        core.usize(self.z_brightened);
        core.usize(self.z_darkened);
        core.f64_slice(&self.theta);
        self.rng.save_state(&mut core);
        self.counters.totals().save_state(&mut core);
        image.push_section(TAG_CORE, core.into_bytes());
        let mut tgt = ByteWriter::new();
        self.target.save_state(&mut tgt);
        image.push_section(TAG_TARGET, tgt.into_bytes());
        let mut smp = ByteWriter::new();
        self.sampler.save_state(&mut smp);
        image.push_section(TAG_SAMPLER, smp.into_bytes());
        let mut ran = ByteWriter::new();
        ran.f64(self.q_db);
        ran.bool(self.explicit);
        ran.bool(self.reanchor.is_some());
        if let Some(rst) = &self.reanchor {
            rst.save_state(&mut ran);
        }
        ran.bool(self.qctl.is_some());
        if let Some(qc) = &self.qctl {
            qc.save_state(&mut ran);
        }
        image.push_section(TAG_REANCHOR, ran.into_bytes());
        for obs in observers {
            let mut w = ByteWriter::new();
            obs.save_state(&mut w);
            image.push_section(obs.tag(), w.into_bytes());
        }
        image
    }

    /// Overwrite this freshly-constructed chain (and its observers) with a
    /// checkpointed state. The chain must have been built from the same
    /// configuration — callers validate the image fingerprint first.
    pub fn restore(
        &mut self,
        image: &CheckpointImage,
        observers: &mut [&mut dyn ChainObserver],
    ) -> Result<(), String> {
        let core = image
            .section(TAG_CORE)
            .ok_or_else(|| "missing CORE section".to_string())?;
        let mut r = ByteReader::new(core);
        let completed = r.usize()?;
        if completed > self.cfg.iters {
            return Err(format!(
                "checkpoint is {completed} iterations deep, config runs only {}",
                self.cfg.iters
            ));
        }
        let wallclock_secs = r.f64()?;
        let accepted = r.usize()?;
        let z_brightened = r.usize()?;
        let z_darkened = r.usize()?;
        let dim = self.theta.len();
        r.f64_slice_into(&mut self.theta)?;
        if self.theta.len() != dim {
            return Err(format!(
                "checkpoint theta has {} components, this chain has {dim}",
                self.theta.len()
            ));
        }
        self.rng = Rng::load_state(&mut r)?;
        let totals = crate::metrics::CounterTotals::load_state(&mut r)?;
        r.finish().map_err(|e| format!("CORE section: {e}"))?;

        let tgt = image
            .section(TAG_TARGET)
            .ok_or_else(|| "missing TGT0 section".to_string())?;
        let mut r = ByteReader::new(tgt);
        self.target.load_state(&mut r)?;
        r.finish().map_err(|e| format!("TGT0 section: {e}"))?;
        if self.target.theta() != self.theta.as_slice() {
            return Err("posterior θ disagrees with chain θ (corrupt checkpoint)".to_string());
        }

        let smp = image
            .section(TAG_SAMPLER)
            .ok_or_else(|| "missing SMPL section".to_string())?;
        let mut r = ByteReader::new(smp);
        self.sampler.load_state(&mut r)?;
        r.finish().map_err(|e| format!("SMPL section: {e}"))?;

        let ran = image
            .section(TAG_REANCHOR)
            .ok_or_else(|| "missing RANC section".to_string())?;
        let mut r = ByteReader::new(ran);
        let q_db = r.f64()?;
        let explicit = r.bool()?;
        let has_reanchor = r.bool()?;
        match (self.reanchor.as_mut(), has_reanchor) {
            (Some(rst), true) => rst.load_state(&mut r)?,
            (None, false) => {}
            _ => {
                return Err(
                    "checkpoint re-anchor state does not match this chain's configuration"
                        .to_string(),
                )
            }
        }
        let has_qctl = r.bool()?;
        match (self.qctl.as_mut(), has_qctl) {
            (Some(qc), true) => qc.load_state(&mut r)?,
            (None, false) => {}
            _ => {
                return Err(
                    "checkpoint q-controller state does not match this chain's configuration"
                        .to_string(),
                )
            }
        }
        r.finish().map_err(|e| format!("RANC section: {e}"))?;
        self.q_db = q_db;
        self.explicit = explicit;

        for obs in observers.iter_mut() {
            let tag = obs.tag();
            let bytes = image.section(tag).ok_or_else(|| {
                format!(
                    "missing observer section {:?} (observer lineup changed?)",
                    String::from_utf8_lossy(&tag)
                )
            })?;
            let mut r = ByteReader::new(bytes);
            obs.load_state(&mut r)
                .map_err(|e| format!("{:?} section: {e}", String::from_utf8_lossy(&tag)))?;
            r.finish()
                .map_err(|e| format!("{:?} section: {e}", String::from_utf8_lossy(&tag)))?;
        }

        self.completed = completed;
        self.accepted = accepted;
        self.z_brightened = z_brightened;
        self.z_darkened = z_darkened;
        self.wallclock_secs = wallclock_secs;
        self.counters.restore_totals(&totals);
        self.snap = self.counters.snapshot();
        Ok(())
    }

    /// Consume the chain and the two standard observers into the classic
    /// [`ChainResult`].
    pub fn into_result(self, rec: RecordingObserver, stats: StreamingObserver) -> ChainResult {
        ChainResult {
            theta_trace: rec.theta_trace,
            logpost_joint: rec.logpost_joint,
            full_logpost: rec.full_logpost,
            bright: rec.bright,
            queries_per_iter: rec.queries_per_iter,
            accepted: self.accepted,
            z_brightened: self.z_brightened,
            z_darkened: self.z_darkened,
            wallclock_secs: self.wallclock_secs,
            final_counters: self.counters.snapshot(),
            seed: self.cfg.seed,
            stats: stats.into_summary(),
        }
    }
}

// ---------------------------------------------------------------------------
// Drivers
// ---------------------------------------------------------------------------

/// Run one chain: θ-step then z-step per iteration, with per-iteration
/// query accounting, Fig-4-style instrumentation, and streaming statistics.
/// One-shot wrapper over [`ChainState`] with the standard recording +
/// streaming observers and no checkpointing.
pub fn run_chain(
    target: ChainTarget,
    sampler: Box<dyn Sampler>,
    theta: Vec<f64>,
    cfg: &ChainConfig,
) -> ChainResult {
    run_chain_segments(target, sampler, theta, cfg, None)
        .expect("checkpoint-free chain run cannot fail")
}

/// [`run_chain`] with optional checkpoint wiring: periodic `.fckpt` writes,
/// resume-from-file, and a per-session iteration bound (`stop_after`) for
/// preemptible jobs. See [`crate::engine::checkpoint`].
pub fn run_chain_segments(
    target: ChainTarget,
    sampler: Box<dyn Sampler>,
    theta0: Vec<f64>,
    cfg: &ChainConfig,
    spec: Option<&ChainCheckpointSpec>,
) -> anyhow::Result<ChainResult> {
    let dim = theta0.len();
    let mut state = ChainState::new(target, sampler, theta0, cfg);
    let mut rec = RecordingObserver::new(cfg, dim);
    let mut stats = StreamingObserver::new(cfg, dim);
    match spec {
        None => {
            let mut observers: [&mut dyn ChainObserver; 2] = [&mut rec, &mut stats];
            state.run_to_end(&mut observers)?;
        }
        Some(spec) => {
            let mut writer = CheckpointObserver::new(&spec.path, spec.every, spec.fingerprint);
            let mut observers: [&mut dyn ChainObserver; 3] =
                [&mut rec, &mut stats, &mut writer];
            if spec.resume && std::path::Path::new(&spec.path).exists() {
                let image = read_checkpoint(&spec.path)?;
                if image.fingerprint != spec.fingerprint {
                    anyhow::bail!(
                        "{}: checkpoint was written under a different configuration \
                         (fingerprint {:#018x}, expected {:#018x})",
                        spec.path,
                        image.fingerprint,
                        spec.fingerprint
                    );
                }
                state
                    .restore(&image, &mut observers)
                    .map_err(|e| anyhow::anyhow!("{}: {e}", spec.path))?;
            }
            match spec.stop_after {
                Some(k) => {
                    state.run_for(k, &mut observers)?;
                    // a bounded session checkpoints at its stop point even
                    // off-cadence (and even with every = 0), so the work
                    // it did is never lost
                    if !state.is_finished() {
                        state.force_checkpoint(&mut observers)?;
                    }
                }
                None => state.run_to_end(&mut observers)?,
            }
        }
    }
    Ok(state.into_result(rec, stats))
}

/// Replica-spawn path: run `replicas` seeded chains, each constructed inside
/// its worker thread by `build` (targets own non-`Send` backends, so they
/// must be born where they run), with at most `threads` chains in flight
/// (0 = all at once). Workers pull replica ids from a shared queue, so a
/// slow chain never idles the other workers; results come back in replica
/// order and each replica's output depends only on (base, replica id),
/// never on scheduling.
pub fn run_chain_replicas<F>(
    replicas: usize,
    threads: usize,
    base: &ChainConfig,
    build: F,
) -> anyhow::Result<Vec<ChainResult>>
where
    F: Fn(&ChainConfig) -> anyhow::Result<(ChainTarget, Box<dyn Sampler>, Vec<f64>)> + Sync,
{
    run_chain_replicas_ckpt(replicas, threads, base, None, build)
}

/// [`run_chain_replicas`] with optional experiment-level checkpoint wiring:
/// each replica writes/resumes its own `chain_NNNN.fckpt` inside the spec's
/// directory (a replica with no checkpoint file starts fresh, so one
/// `resume` invocation heals a partially-checkpointed experiment).
pub fn run_chain_replicas_ckpt<F>(
    replicas: usize,
    threads: usize,
    base: &ChainConfig,
    ckpt: Option<&ExperimentCheckpointSpec>,
    build: F,
) -> anyhow::Result<Vec<ChainResult>>
where
    F: Fn(&ChainConfig) -> anyhow::Result<(ChainTarget, Box<dyn Sampler>, Vec<f64>)> + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    let replicas = replicas.max(1);
    let workers = if threads == 0 { replicas } else { threads.max(1).min(replicas) };
    let build = &build;
    let next = AtomicUsize::new(0);
    let next = &next;
    let mut collected: Vec<(usize, anyhow::Result<ChainResult>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(move || {
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= replicas {
                                break;
                            }
                            let ccfg = base.for_replica(i);
                            let spec = ckpt.map(|s| s.chain_spec(i));
                            let res = build(&ccfg).and_then(|(target, sampler, theta0)| {
                                run_chain_segments(target, sampler, theta0, &ccfg, spec.as_ref())
                            });
                            done.push((i, res));
                        }
                        done
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
    collected.sort_by_key(|(i, _)| *i);
    collected.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::engine::checkpoint::replica_checkpoint_path;
    use crate::metrics::Counters;
    use crate::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
    use crate::runtime::cpu_backend::CpuBackend;
    use crate::samplers::RandomWalkMh;
    use std::sync::Arc;

    fn flymc_target(n: usize, seed: u64) -> (ChainTarget, Vec<f64>) {
        let data = Arc::new(synth::synth_mnist(n, 6, seed));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(seed + 100);
        let theta0 = prior.sample(model.dim(), &mut rng);
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
        pp.init_z(&mut rng);
        (ChainTarget::FlyMc(pp), theta0)
    }

    fn tmp_dir(name: &str) -> String {
        let p = std::env::temp_dir()
            .join(format!("firefly_chain_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p.to_string_lossy().into_owned()
    }

    #[test]
    fn chain_runs_and_records_everything() {
        let (target, theta0) = flymc_target(400, 1);
        let cfg = ChainConfig {
            iters: 100,
            burnin: 20,
            record_full_every: 10,
            q_dark_to_bright: 0.05,
            ..Default::default()
        };
        let res = run_chain(target, Box::new(RandomWalkMh::adaptive(0.05)), theta0, &cfg);
        assert_eq!(res.logpost_joint.len(), 100);
        assert_eq!(res.bright.len(), 100);
        assert_eq!(res.queries_per_iter.len(), 100);
        assert_eq!(res.theta_trace.n_rows(), 80);
        assert_eq!(res.full_logpost.len(), 10);
        assert!(res.logpost_joint.iter().all(|l| l.is_finite()));
        // FlyMC must query far fewer than N per iteration once burned in
        let avg = res.avg_queries_post_burnin(20);
        assert!(avg < 400.0, "avg queries {avg}");
        assert!(res.wallclock_secs > 0.0);
        // the streaming observer rides every run: its moments cover the
        // trace rows and its bright stats the post-burnin window
        assert_eq!(res.stats.rows, 80);
        assert_eq!(res.stats.bright.count, 80);
        assert!(res.stats.bright.min <= res.stats.bright.max);
        assert_eq!(res.stats.bright.last, *res.bright.last().unwrap());
        assert!(res.stats.mean.iter().all(|m| m.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let (t1, th1) = flymc_target(200, 3);
        let (t2, th2) = flymc_target(200, 3);
        let cfg = ChainConfig { iters: 50, burnin: 10, ..Default::default() };
        let r1 = run_chain(t1, Box::new(RandomWalkMh::new(0.05)), th1, &cfg);
        let r2 = run_chain(t2, Box::new(RandomWalkMh::new(0.05)), th2, &cfg);
        assert_eq!(r1.logpost_joint, r2.logpost_joint);
        assert_eq!(r1.bright, r2.bright);
        assert_eq!(r1.queries_per_iter, r2.queries_per_iter);
        assert_eq!(r1.stats.mean, r2.stats.mean);
        assert_eq!(r1.stats.var, r2.stats.var);
    }

    #[test]
    fn replica_seeds_are_distinct_and_stable() {
        let a: Vec<u64> = (0..16).map(|i| derive_replica_seed(7, i)).collect();
        let b: Vec<u64> = (0..16).map(|i| derive_replica_seed(7, i)).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 16);
        assert_ne!(derive_replica_seed(7, 0), derive_replica_seed(8, 0));
        let cfg = ChainConfig { seed: 7, ..Default::default() };
        assert_eq!(cfg.for_replica(3).seed, derive_replica_seed(7, 3));
        assert_eq!(cfg.for_replica(3).iters, cfg.iters);
    }

    #[test]
    fn replica_spawn_path_is_ordered_and_reproducible() {
        let run_all = |threads: usize| {
            let base = ChainConfig { iters: 30, burnin: 10, seed: 5, ..Default::default() };
            run_chain_replicas(4, threads, &base, |ccfg: &ChainConfig| {
                let (target, theta0) = flymc_target(150, 9);
                let sampler: Box<dyn crate::samplers::Sampler> =
                    Box::new(RandomWalkMh::new(0.05));
                let _ = ccfg;
                Ok((target, sampler, theta0))
            })
            .unwrap()
        };
        let serial = run_all(1);
        let parallel = run_all(4);
        assert_eq!(serial.len(), 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.logpost_joint, b.logpost_joint);
            assert_eq!(a.queries_per_iter, b.queries_per_iter);
        }
        // distinct replica seeds drive distinct chains
        assert_ne!(serial[0].logpost_joint, serial[1].logpost_joint);
    }

    #[test]
    fn segmented_run_equals_one_shot() {
        // driving the chain in arbitrary segments must not change anything:
        // run_for is just a window over the same loop
        let (t1, th1) = flymc_target(200, 13);
        let cfg = ChainConfig { iters: 60, burnin: 15, record_full_every: 7, ..Default::default() };
        let reference = run_chain(t1, Box::new(RandomWalkMh::adaptive(0.05)), th1, &cfg);

        let (t2, th2) = flymc_target(200, 13);
        let dim = th2.len();
        let mut state =
            ChainState::new(t2, Box::new(RandomWalkMh::adaptive(0.05)), th2, &cfg);
        let mut rec = RecordingObserver::new(&cfg, dim);
        let mut stats = StreamingObserver::new(&cfg, dim);
        let mut observers: [&mut dyn ChainObserver; 2] = [&mut rec, &mut stats];
        for k in [1, 7, 20, 11, 100] {
            state.run_for(k, &mut observers).unwrap();
        }
        assert!(state.is_finished());
        assert_eq!(state.completed(), 60);
        let segmented = state.into_result(rec, stats);
        assert_eq!(reference.logpost_joint, segmented.logpost_joint);
        assert_eq!(reference.theta_trace, segmented.theta_trace);
        assert_eq!(reference.full_logpost, segmented.full_logpost);
        assert_eq!(reference.bright, segmented.bright);
        assert_eq!(reference.queries_per_iter, segmented.queries_per_iter);
        assert_eq!(reference.accepted, segmented.accepted);
        assert_eq!(reference.final_counters, segmented.final_counters);
        assert_eq!(reference.stats.mean, segmented.stats.mean);
        assert_eq!(reference.stats.var, segmented.stats.var);
    }

    #[test]
    fn killed_and_resumed_chain_is_byte_identical() {
        let dir = tmp_dir("resume_unit");
        let cfg = ChainConfig { iters: 80, burnin: 20, record_full_every: 9, ..Default::default() };
        let fingerprint = 0xABCD;
        let path = replica_checkpoint_path(&dir, 0);

        // uninterrupted reference (no checkpointing at all)
        let (t, th) = flymc_target(250, 31);
        let reference = run_chain(t, Box::new(RandomWalkMh::adaptive(0.05)), th, &cfg);

        // session 1: checkpoint every 25, HARD-killed after 37 iterations —
        // drive the state directly and drop it mid-interval, so the only
        // durable state is the cadence checkpoint at 25 (resume must then
        // re-run 25..37 and still match bit for bit)
        {
            let (t, th) = flymc_target(250, 31);
            let dim = th.len();
            let mut state =
                ChainState::new(t, Box::new(RandomWalkMh::adaptive(0.05)), th, &cfg);
            let mut rec = RecordingObserver::new(&cfg, dim);
            let mut stats = StreamingObserver::new(&cfg, dim);
            let mut writer = CheckpointObserver::new(&path, 25, fingerprint);
            let mut observers: [&mut dyn ChainObserver; 3] =
                [&mut rec, &mut stats, &mut writer];
            state.run_for(37, &mut observers).unwrap();
            // ...process dies here: everything in memory is lost
        }
        assert_eq!(read_checkpoint(&path).unwrap().completed, 25);

        // session 2: fresh build (same deterministic construction), resume
        let (t, th) = flymc_target(250, 31);
        let spec = ChainCheckpointSpec {
            path: path.clone(),
            every: 25,
            fingerprint,
            resume: true,
            stop_after: None,
        };
        let resumed =
            run_chain_segments(t, Box::new(RandomWalkMh::adaptive(0.05)), th, &cfg, Some(&spec))
                .unwrap();

        assert_eq!(reference.theta_trace, resumed.theta_trace);
        assert_eq!(reference.logpost_joint, resumed.logpost_joint);
        assert_eq!(reference.full_logpost, resumed.full_logpost);
        assert_eq!(reference.bright, resumed.bright);
        assert_eq!(reference.queries_per_iter, resumed.queries_per_iter);
        assert_eq!(reference.accepted, resumed.accepted);
        assert_eq!(reference.z_brightened, resumed.z_brightened);
        assert_eq!(reference.z_darkened, resumed.z_darkened);
        assert_eq!(reference.final_counters, resumed.final_counters);
        assert_eq!(reference.stats.mean, resumed.stats.mean);
        assert_eq!(reference.stats.var, resumed.stats.var);
        assert_eq!(
            reference.stats.ess_bm_min.to_bits(),
            resumed.stats.ess_bm_min.to_bits()
        );
        assert_eq!(reference.stats.bright, resumed.stats.bright);
        // the final checkpoint sits at completion (finished-forces-write)
        assert_eq!(read_checkpoint(&path).unwrap().completed, 80);

        // wrong fingerprint refuses to resume
        let (t, th) = flymc_target(250, 31);
        let bad = ChainCheckpointSpec { fingerprint: 0x9999, ..spec };
        let err =
            run_chain_segments(t, Box::new(RandomWalkMh::adaptive(0.05)), th, &cfg, Some(&bad))
                .unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");

        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bounded_session_checkpoints_at_its_stop_point() {
        // a voluntary stop_after session must persist ALL its work, even
        // off-cadence and even with every = 0 (final-only cadence) —
        // otherwise the session's iterations past the last boundary would
        // be silently re-run (or, with every = 0, entirely lost) on resume
        let dir = tmp_dir("stop_point");
        let cfg = ChainConfig { iters: 80, burnin: 20, record_full_every: 0, ..Default::default() };
        for every in [0usize, 25] {
            let path = replica_checkpoint_path(&dir, every);
            let (t, th) = flymc_target(150, 8);
            let spec = ChainCheckpointSpec {
                path: path.clone(),
                every,
                fingerprint: 1,
                resume: false,
                stop_after: Some(37),
            };
            let partial = run_chain_segments(
                t,
                Box::new(RandomWalkMh::adaptive(0.05)),
                th,
                &cfg,
                Some(&spec),
            )
            .unwrap();
            assert_eq!(partial.logpost_joint.len(), 37);
            assert_eq!(
                read_checkpoint(&path).unwrap().completed,
                37,
                "every={every}: session stop must checkpoint at the stop point"
            );
            // resume runs exactly the remaining 43 iterations
            let (t, th) = flymc_target(150, 8);
            let resumed = run_chain_segments(
                t,
                Box::new(RandomWalkMh::adaptive(0.05)),
                th,
                &cfg,
                Some(&ChainCheckpointSpec { resume: true, stop_after: None, ..spec }),
            )
            .unwrap();
            assert_eq!(resumed.logpost_joint.len(), 80);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn thinned_burned_trace_matches_full_trace_slice() {
        // Property: for random (iters, burnin, thin), the recorded trace
        // equals the corresponding slice of the full (burnin 0, thin 1)
        // trace — burn-in and thinning only select rows, they never alter
        // the chain's evolution.
        crate::testing::check_msg(
            "thin+burnin trace selection",
            6,
            |r| {
                let iters = 20 + r.below(60);
                let burnin = r.below(iters);
                let thin = 1 + r.below(5);
                (iters, burnin, thin)
            },
            |&(iters, burnin, thin)| {
                let mk = |burnin: usize, thin: usize| ChainConfig {
                    iters,
                    burnin,
                    thin,
                    record_full_every: 0,
                    ..Default::default()
                };
                let (t, th) = flymc_target(120, 77);
                let full = run_chain(t, Box::new(RandomWalkMh::adaptive(0.05)), th, &mk(0, 1));
                let (t, th) = flymc_target(120, 77);
                let thinned =
                    run_chain(t, Box::new(RandomWalkMh::adaptive(0.05)), th, &mk(burnin, thin));
                if full.theta_trace.n_rows() != iters {
                    return Err(format!("full trace has {} rows", full.theta_trace.n_rows()));
                }
                let expect_rows = (iters - burnin).div_ceil(thin);
                if thinned.theta_trace.n_rows() != expect_rows {
                    return Err(format!(
                        "({iters},{burnin},{thin}): {} rows, expected {expect_rows}",
                        thinned.theta_trace.n_rows()
                    ));
                }
                for (row, it) in (burnin..iters).step_by(thin).enumerate() {
                    let got = thinned.theta_trace.row(row);
                    let want = full.theta_trace.row(it);
                    if got
                        .iter()
                        .zip(want)
                        .any(|(a, b)| a.to_bits() != b.to_bits())
                    {
                        return Err(format!(
                            "({iters},{burnin},{thin}): row {row} (iter {it}) differs"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
