//! The FlyMC / regular-MCMC chain loop (paper Alg 1 at the top level):
//! alternate a θ-update (any sampler) with a z-update (FlyMC only), recording
//! the traces the paper's figures and tables need.

use crate::diagnostics::TraceMatrix;
use crate::flymc::{FullPosterior, PseudoPosterior, ZStats};
use crate::metrics::CounterSnapshot;
use crate::samplers::{Sampler, Target};
use crate::util::rng::splitmix64;
use crate::util::{Rng, Timer};

/// Either posterior, so the chain driver is shared between the baseline and
/// FlyMC (z-updates are a no-op for the regular posterior).
pub enum ChainTarget {
    /// the augmented FlyMC pseudo-posterior (z-updates active)
    FlyMc(PseudoPosterior),
    /// the regular full-data posterior (z-updates are a no-op)
    Regular(FullPosterior),
}

impl ChainTarget {
    /// The θ-density the sampler drives.
    pub fn as_target(&mut self) -> &mut dyn Target {
        match self {
            ChainTarget::FlyMc(p) => p,
            ChainTarget::Regular(p) => p,
        }
    }

    /// Current bright count (None for the regular posterior).
    pub fn n_bright(&self) -> Option<usize> {
        match self {
            ChainTarget::FlyMc(p) => Some(p.n_bright()),
            ChainTarget::Regular(_) => None,
        }
    }

    /// The query counters of the underlying backend (shared handle).
    pub fn counters(&self) -> crate::metrics::Counters {
        match self {
            ChainTarget::FlyMc(p) => p.eval.counters().clone(),
            ChainTarget::Regular(p) => p.eval.counters().clone(),
        }
    }

    /// Full-data log posterior (uncounted Fig-4 instrumentation).
    pub fn true_log_posterior(&self, theta: &[f64]) -> f64 {
        match self {
            ChainTarget::FlyMc(p) => p.true_log_posterior(theta),
            ChainTarget::Regular(p) => p.true_log_posterior(theta),
        }
    }

    fn z_step(&mut self, cfg: &ChainConfig, rng: &mut Rng) -> Option<ZStats> {
        match self {
            ChainTarget::FlyMc(p) => Some(if cfg.explicit_resample {
                p.explicit_resample(cfg.resample_fraction, rng)
            } else {
                p.implicit_resample(cfg.q_dark_to_bright, rng)
            }),
            ChainTarget::Regular(_) => None,
        }
    }
}

/// Per-chain driver configuration.
#[derive(Clone, Debug)]
pub struct ChainConfig {
    /// total iterations
    pub iters: usize,
    /// burn-in iterations (excluded from the θ trace)
    pub burnin: usize,
    /// record the (expensive, uncounted) full-data log posterior every k
    /// iterations; 0 disables
    pub record_full_every: usize,
    /// thinning for the θ trace used by ESS
    pub thin: usize,
    /// q_{d->b} for implicit (Alg 2) z-resampling
    pub q_dark_to_bright: f64,
    /// use explicit (Alg 1) instead of implicit z-resampling
    pub explicit_resample: bool,
    /// fraction of N redrawn per explicit sweep
    pub resample_fraction: f64,
    /// RNG seed for this chain
    pub seed: u64,
}

impl Default for ChainConfig {
    fn default() -> Self {
        ChainConfig {
            iters: 2000,
            burnin: 500,
            record_full_every: 10,
            thin: 1,
            q_dark_to_bright: 0.01,
            explicit_resample: false,
            resample_fraction: 0.1,
            seed: 0,
        }
    }
}

impl ChainConfig {
    /// The replica-`i` configuration: identical settings, statistically
    /// independent seed stream derived from (base seed, replica id).
    pub fn for_replica(&self, replica: usize) -> ChainConfig {
        let mut c = self.clone();
        c.seed = derive_replica_seed(self.seed, replica);
        c
    }
}

/// Derive a per-replica seed. Injective in `replica` for a fixed base —
/// `base ^ replica·odd` is injective and each splitmix64 output is a
/// bijection of its input state — and scrambled so nearby bases and replica
/// ids give uncorrelated xoshiro streams.
///
/// Deterministic: a replica's seed is a pure function of (base, replica),
/// so multi-chain runs are reproducible at any thread cap.
///
/// ```
/// use firefly::engine::derive_replica_seed;
///
/// // stable across calls ...
/// assert_eq!(derive_replica_seed(7, 3), derive_replica_seed(7, 3));
/// // ... distinct across replicas and bases
/// assert_ne!(derive_replica_seed(7, 0), derive_replica_seed(7, 1));
/// assert_ne!(derive_replica_seed(7, 0), derive_replica_seed(8, 0));
/// ```
pub fn derive_replica_seed(base: u64, replica: usize) -> u64 {
    let mut s = base ^ (replica as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let _ = splitmix64(&mut s); // extra scramble round; state advance is bijective
    splitmix64(&mut s)
}

/// Everything one chain records (see [`run_chain`]).
#[derive(Clone, Debug, Default)]
pub struct ChainResult {
    /// post-burnin θ samples (thinned), flat row-major
    pub theta_trace: TraceMatrix,
    /// joint (pseudo-)posterior log density at every iteration
    pub logpost_joint: Vec<f64>,
    /// (iter, full-data log posterior) instrumentation points
    pub full_logpost: Vec<(usize, f64)>,
    /// bright count per iteration (FlyMC only)
    pub bright: Vec<usize>,
    /// likelihood queries per iteration
    pub queries_per_iter: Vec<u64>,
    /// accepted θ-proposals
    pub accepted: usize,
    /// total dark→bright z-flips
    pub z_brightened: usize,
    /// total bright→dark z-flips
    pub z_darkened: usize,
    /// wall-clock duration of the chain loop
    pub wallclock_secs: f64,
    /// counter totals at chain end
    pub final_counters: CounterSnapshot,
    /// the seed this chain ran with
    pub seed: u64,
}

impl ChainResult {
    /// Mean likelihood queries per iteration after burn-in (Table 1 col 1).
    pub fn avg_queries_post_burnin(&self, burnin: usize) -> f64 {
        let tail = &self.queries_per_iter[burnin.min(self.queries_per_iter.len())..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().sum::<u64>() as f64 / tail.len() as f64
    }

    /// Mean bright count after burn-in (the paper's M).
    pub fn avg_bright_post_burnin(&self, burnin: usize) -> f64 {
        let tail = &self.bright[burnin.min(self.bright.len())..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().sum::<usize>() as f64 / tail.len() as f64
    }
}

/// Run one chain: θ-step then z-step per iteration, with per-iteration query
/// accounting and Fig-4-style instrumentation.
pub fn run_chain(
    mut target: ChainTarget,
    mut sampler: Box<dyn Sampler>,
    mut theta: Vec<f64>,
    cfg: &ChainConfig,
) -> ChainResult {
    let mut rng = Rng::new(cfg.seed);
    let counters = target.counters();
    let timer = Timer::start();
    let mut out = ChainResult { seed: cfg.seed, ..Default::default() };
    // Reserve every per-iteration series up front: recording must not
    // allocate inside the sampling loop (the zero-alloc hot-path invariant,
    // see DESIGN.md §Perf).
    out.logpost_joint.reserve(cfg.iters);
    out.queries_per_iter.reserve(cfg.iters);
    out.bright.reserve(cfg.iters);
    if cfg.record_full_every > 0 {
        out.full_logpost.reserve(cfg.iters / cfg.record_full_every + 1);
    }
    let trace_rows = cfg.iters.saturating_sub(cfg.burnin) / cfg.thin.max(1) + 1;
    out.theta_trace = TraceMatrix::with_capacity(theta.len(), trace_rows);

    // Make sure the target state is committed at theta.
    target.as_target().commit(&theta);
    let mut snap = counters.snapshot();

    for it in 0..cfg.iters {
        let info = sampler.step(target.as_target(), &mut theta, &mut rng);
        if info.accepted {
            out.accepted += 1;
        }
        if let Some(z) = target.z_step(cfg, &mut rng) {
            out.z_brightened += z.brightened;
            out.z_darkened += z.darkened;
        }
        let now = counters.snapshot();
        out.queries_per_iter.push(snap.delta(&now).lik_queries);
        snap = now;

        out.logpost_joint.push(target.as_target().current_log_density());
        if let Some(b) = target.n_bright() {
            out.bright.push(b);
        }
        if cfg.record_full_every > 0 && it % cfg.record_full_every == 0 {
            out.full_logpost.push((it, target.true_log_posterior(&theta)));
        }
        if it >= cfg.burnin && (it - cfg.burnin) % cfg.thin.max(1) == 0 {
            out.theta_trace.push_row(&theta);
        }
    }
    out.wallclock_secs = timer.elapsed_secs();
    out.final_counters = counters.snapshot();
    out
}

/// Replica-spawn path: run `replicas` seeded chains, each constructed inside
/// its worker thread by `build` (targets own non-`Send` backends, so they
/// must be born where they run), with at most `threads` chains in flight
/// (0 = all at once). Workers pull replica ids from a shared queue, so a
/// slow chain never idles the other workers; results come back in replica
/// order and each replica's output depends only on (base, replica id),
/// never on scheduling.
pub fn run_chain_replicas<F>(
    replicas: usize,
    threads: usize,
    base: &ChainConfig,
    build: F,
) -> anyhow::Result<Vec<ChainResult>>
where
    F: Fn(&ChainConfig) -> anyhow::Result<(ChainTarget, Box<dyn Sampler>, Vec<f64>)> + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    let replicas = replicas.max(1);
    let workers = if threads == 0 { replicas } else { threads.max(1).min(replicas) };
    let build = &build;
    let next = AtomicUsize::new(0);
    let next = &next;
    let mut collected: Vec<(usize, anyhow::Result<ChainResult>)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(move || {
                        let mut done = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= replicas {
                                break;
                            }
                            let ccfg = base.for_replica(i);
                            let res = build(&ccfg)
                                .map(|(target, sampler, theta0)| {
                                    run_chain(target, sampler, theta0, &ccfg)
                                });
                            done.push((i, res));
                        }
                        done
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
    collected.sort_by_key(|(i, _)| *i);
    collected.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::metrics::Counters;
    use crate::models::{IsoGaussian, LogisticJJ, ModelBound, Prior};
    use crate::runtime::cpu_backend::CpuBackend;
    use crate::samplers::RandomWalkMh;
    use std::sync::Arc;

    fn flymc_target(n: usize, seed: u64) -> (ChainTarget, Vec<f64>) {
        let data = Arc::new(synth::synth_mnist(n, 6, seed));
        let model: Arc<dyn ModelBound> = Arc::new(LogisticJJ::new(data, 1.5));
        let prior: Arc<dyn Prior> = Arc::new(IsoGaussian { scale: 1.0 });
        let counters = Counters::new();
        let eval = Box::new(CpuBackend::new(model.clone(), counters.clone()));
        let mut rng = Rng::new(seed + 100);
        let theta0 = prior.sample(model.dim(), &mut rng);
        let mut pp = PseudoPosterior::new(model, prior, eval, theta0.clone());
        pp.init_z(&mut rng);
        (ChainTarget::FlyMc(pp), theta0)
    }

    #[test]
    fn chain_runs_and_records_everything() {
        let (target, theta0) = flymc_target(400, 1);
        let cfg = ChainConfig {
            iters: 100,
            burnin: 20,
            record_full_every: 10,
            q_dark_to_bright: 0.05,
            ..Default::default()
        };
        let res = run_chain(target, Box::new(RandomWalkMh::adaptive(0.05)), theta0, &cfg);
        assert_eq!(res.logpost_joint.len(), 100);
        assert_eq!(res.bright.len(), 100);
        assert_eq!(res.queries_per_iter.len(), 100);
        assert_eq!(res.theta_trace.n_rows(), 80);
        assert_eq!(res.full_logpost.len(), 10);
        assert!(res.logpost_joint.iter().all(|l| l.is_finite()));
        // FlyMC must query far fewer than N per iteration once burned in
        let avg = res.avg_queries_post_burnin(20);
        assert!(avg < 400.0, "avg queries {avg}");
        assert!(res.wallclock_secs > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (t1, th1) = flymc_target(200, 3);
        let (t2, th2) = flymc_target(200, 3);
        let cfg = ChainConfig { iters: 50, burnin: 10, ..Default::default() };
        let r1 = run_chain(t1, Box::new(RandomWalkMh::new(0.05)), th1, &cfg);
        let r2 = run_chain(t2, Box::new(RandomWalkMh::new(0.05)), th2, &cfg);
        assert_eq!(r1.logpost_joint, r2.logpost_joint);
        assert_eq!(r1.bright, r2.bright);
        assert_eq!(r1.queries_per_iter, r2.queries_per_iter);
    }

    #[test]
    fn replica_seeds_are_distinct_and_stable() {
        let a: Vec<u64> = (0..16).map(|i| derive_replica_seed(7, i)).collect();
        let b: Vec<u64> = (0..16).map(|i| derive_replica_seed(7, i)).collect();
        assert_eq!(a, b);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 16);
        assert_ne!(derive_replica_seed(7, 0), derive_replica_seed(8, 0));
        let cfg = ChainConfig { seed: 7, ..Default::default() };
        assert_eq!(cfg.for_replica(3).seed, derive_replica_seed(7, 3));
        assert_eq!(cfg.for_replica(3).iters, cfg.iters);
    }

    #[test]
    fn replica_spawn_path_is_ordered_and_reproducible() {
        let run_all = |threads: usize| {
            let base = ChainConfig { iters: 30, burnin: 10, seed: 5, ..Default::default() };
            run_chain_replicas(4, threads, &base, |ccfg: &ChainConfig| {
                let (target, theta0) = flymc_target(150, 9);
                let sampler: Box<dyn crate::samplers::Sampler> =
                    Box::new(RandomWalkMh::new(0.05));
                let _ = ccfg;
                Ok((target, sampler, theta0))
            })
            .unwrap()
        };
        let serial = run_all(1);
        let parallel = run_all(4);
        assert_eq!(serial.len(), 4);
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.logpost_joint, b.logpost_joint);
            assert_eq!(a.queries_per_iter, b.queries_per_iter);
        }
        // distinct replica seeds drive distinct chains
        assert_ne!(serial[0].logpost_joint, serial[1].logpost_joint);
    }
}
