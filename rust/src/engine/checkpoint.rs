//! The versioned `.fckpt` chain-checkpoint format and its writer observer.
//!
//! A checkpoint is a complete snapshot of a running chain — θ, the
//! [`crate::flymc::BrightSet`] permutation, the pseudo-posterior caches and
//! memo, sampler adaptation (step size, decay count, MALA's current-point
//! gradient cache), the full [`crate::util::Rng`] state, counter totals,
//! and every attached observer's accumulators — such that a chain restored
//! from it and run to completion produces **byte-identical** traces,
//! diagnostics inputs, and query counters to the never-interrupted run
//! (the resume identity guarantee, DESIGN.md §Checkpointing; enforced by
//! `rust/tests/integration_checkpoint.rs`).
//!
//! ## File layout (version 2, all integers little-endian)
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"FFLYCKPT"
//! 8       4     format version (u32, currently 2)
//! 12      4     section count (u32)
//! 16      8     config fingerprint (u64, FNV-1a of the canonical config —
//!               resume refuses a checkpoint written under a different one)
//! 24      8     completed iterations (u64)
//! 32      8     FNV-1a checksum of the section region
//! 40      —     sections: [tag: 4 bytes][len: u64][payload], in order
//! ```
//!
//! The header discipline mirrors `data::fbin` (magic / version / explicit
//! lengths / reject-on-mismatch); the checksum catches torn or corrupted
//! files before any state is deserialized, and writes go through a
//! temp-file + rename so a crash mid-write never clobbers the previous
//! good checkpoint.
//!
//! Section tags: `CORE` (chain driver state), `TGT0` (posterior), `SMPL`
//! (sampler), `RANC` (working z-resampling knobs plus the optional
//! re-anchoring accumulator and q-controller — version 2), then one per
//! attached observer (`RECD` trace recorder, `STAT` streaming statistics,
//! `CKPT` the writer itself, empty). What is
//! deliberately **not** captured: wall-clock (time is not resumable),
//! block-cache contents (re-warmed on use; its hit/miss counters are
//! restored as totals but drift is possible and they are excluded from the
//! counter-equality contract), and the model/prior/dataset themselves —
//! those are rebuilt deterministically from the experiment config, which
//! is why the fingerprint is part of the header.

use std::io::Write;

use crate::engine::observer::ChainObserver;
use crate::util::codec::{fnv1a, ByteReader, ByteWriter};

/// The 8-byte magic prefix of every `.fckpt` file.
pub const FCKPT_MAGIC: [u8; 8] = *b"FFLYCKPT";
/// Current checkpoint format version. v2 added the `RANC` chain section
/// (working q/resampling-mode knobs, re-anchor accumulator, q-controller)
/// and the pre-re-anchor bright summary inside `STAT` — readers require an
/// exact version match, so v1 files are rejected rather than misread.
pub const FCKPT_VERSION: u32 = 2;
/// Header length in bytes (the section region starts here).
pub const FCKPT_HEADER_LEN: usize = 40;

/// An in-memory checkpoint: completed-iteration count plus tagged state
/// sections (see the module docs for the on-disk layout).
#[derive(Clone, Debug)]
pub struct CheckpointImage {
    /// config fingerprint the file was written under (0 until stamped by
    /// the writer; filled from the header on read)
    pub fingerprint: u64,
    /// iterations completed at snapshot time
    pub completed: u64,
    sections: Vec<([u8; 4], Vec<u8>)>,
}

impl CheckpointImage {
    /// Empty image at `completed` iterations.
    pub fn new(completed: u64) -> Self {
        CheckpointImage { fingerprint: 0, completed, sections: Vec::new() }
    }

    /// Append a tagged section (tags must be unique within an image).
    ///
    /// # Panics
    ///
    /// Panics on a duplicate tag — observer tags identify state sections,
    /// so two same-tag observers in one chain's pipeline is a wiring bug
    /// (e.g. two `CheckpointObserver`s both tagged `CKPT`), not a runtime
    /// condition. Write to two paths from one observer instead.
    pub fn push_section(&mut self, tag: [u8; 4], bytes: Vec<u8>) {
        assert!(
            self.section(tag).is_none(),
            "duplicate checkpoint section {:?}",
            String::from_utf8_lossy(&tag)
        );
        self.sections.push((tag, bytes));
    }

    /// Look up a section's payload by tag.
    pub fn section(&self, tag: [u8; 4]) -> Option<&[u8]> {
        self.sections.iter().find(|(t, _)| *t == tag).map(|(_, b)| b.as_slice())
    }

    /// Number of sections.
    pub fn n_sections(&self) -> usize {
        self.sections.len()
    }

    /// Serialize to the on-disk byte layout, stamping `fingerprint` into
    /// the header.
    pub fn to_bytes(&self, fingerprint: u64) -> Vec<u8> {
        let mut body = ByteWriter::new();
        for (tag, bytes) in &self.sections {
            body.u8(tag[0]);
            body.u8(tag[1]);
            body.u8(tag[2]);
            body.u8(tag[3]);
            body.bytes(bytes);
        }
        let body = body.into_bytes();
        let mut out = Vec::with_capacity(FCKPT_HEADER_LEN + body.len());
        out.extend_from_slice(&FCKPT_MAGIC);
        out.extend_from_slice(&FCKPT_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        out.extend_from_slice(&fingerprint.to_le_bytes());
        out.extend_from_slice(&self.completed.to_le_bytes());
        out.extend_from_slice(&fnv1a(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse and validate the on-disk byte layout (magic, version,
    /// checksum, section structure).
    pub fn from_bytes(buf: &[u8]) -> Result<CheckpointImage, String> {
        if buf.len() < FCKPT_HEADER_LEN {
            return Err(format!(
                "truncated header: {} bytes, need {FCKPT_HEADER_LEN}",
                buf.len()
            ));
        }
        if buf[..8] != FCKPT_MAGIC {
            return Err("not an .fckpt file (bad magic)".to_string());
        }
        let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
        if version != FCKPT_VERSION {
            return Err(format!(
                "unsupported .fckpt version {version} (this build reads version {FCKPT_VERSION})"
            ));
        }
        let n_sections = u32::from_le_bytes(buf[12..16].try_into().unwrap()) as usize;
        let fingerprint = u64::from_le_bytes(buf[16..24].try_into().unwrap());
        let completed = u64::from_le_bytes(buf[24..32].try_into().unwrap());
        let checksum = u64::from_le_bytes(buf[32..40].try_into().unwrap());
        let body = &buf[FCKPT_HEADER_LEN..];
        if fnv1a(body) != checksum {
            return Err("checksum mismatch (torn or corrupted checkpoint)".to_string());
        }
        let mut r = ByteReader::new(body);
        let mut image = CheckpointImage { fingerprint, completed, sections: Vec::new() };
        for _ in 0..n_sections {
            let tag = [r.u8()?, r.u8()?, r.u8()?, r.u8()?];
            let payload = r.bytes()?.to_vec();
            if image.section(tag).is_some() {
                return Err(format!(
                    "duplicate section {:?}",
                    String::from_utf8_lossy(&tag)
                ));
            }
            image.sections.push((tag, payload));
        }
        r.finish()
            .map_err(|e| format!("trailing bytes after sections: {e}"))?;
        Ok(image)
    }
}

/// Atomically write `image` to `path` (temp file + rename, so a crash
/// mid-write leaves the previous checkpoint intact). Returns the
/// serialized size in bytes.
pub fn write_checkpoint(
    path: &str,
    image: &CheckpointImage,
    fingerprint: u64,
) -> anyhow::Result<usize> {
    let bytes = image.to_bytes(fingerprint);
    let tmp = format!("{path}.tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .map_err(|e| anyhow::anyhow!("{tmp}: {e}"))?;
        f.write_all(&bytes).map_err(|e| anyhow::anyhow!("{tmp}: {e}"))?;
        f.sync_all().map_err(|e| anyhow::anyhow!("{tmp}: {e}"))?;
    }
    std::fs::rename(&tmp, path).map_err(|e| anyhow::anyhow!("{tmp} -> {path}: {e}"))?;
    Ok(bytes.len())
}

/// Read and validate a checkpoint file.
pub fn read_checkpoint(path: &str) -> anyhow::Result<CheckpointImage> {
    let bytes = std::fs::read(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    CheckpointImage::from_bytes(&bytes).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

/// The checkpoint path of replica `replica` inside a checkpoint directory.
pub fn replica_checkpoint_path(dir: &str, replica: usize) -> String {
    format!("{dir}/chain_{replica:04}.fckpt")
}

/// Checkpoint wiring for one chain (see [`ExperimentCheckpointSpec`] for
/// the multi-replica form).
#[derive(Clone, Debug)]
pub struct ChainCheckpointSpec {
    /// `.fckpt` file this chain writes / resumes from
    pub path: String,
    /// write a checkpoint every this many iterations (0 = only at the end)
    pub every: usize,
    /// config fingerprint stamped into the file and required on resume
    pub fingerprint: u64,
    /// load `path` (if it exists) before running
    pub resume: bool,
    /// bound this session to at most this many iterations (the chain stops
    /// mid-run, to be resumed later); None = run to completion
    pub stop_after: Option<usize>,
}

/// Checkpoint wiring for a whole multi-replica experiment: each replica
/// gets its own `.fckpt` file inside `dir`.
#[derive(Clone, Debug)]
pub struct ExperimentCheckpointSpec {
    /// directory holding one `chain_NNNN.fckpt` per replica
    pub dir: String,
    /// write a checkpoint every this many iterations (0 = only at the end)
    pub every: usize,
    /// config fingerprint (see [`crate::configx::ExperimentConfig::fingerprint`])
    pub fingerprint: u64,
    /// resume replicas whose checkpoint file exists (fresh start otherwise)
    pub resume: bool,
    /// per-replica session iteration bound (see [`ChainCheckpointSpec::stop_after`])
    pub stop_after: Option<usize>,
}

impl ExperimentCheckpointSpec {
    /// The per-chain spec of replica `replica`.
    pub fn chain_spec(&self, replica: usize) -> ChainCheckpointSpec {
        ChainCheckpointSpec {
            path: replica_checkpoint_path(&self.dir, replica),
            every: self.every,
            fingerprint: self.fingerprint,
            resume: self.resume,
            stop_after: self.stop_after,
        }
    }
}

/// The checkpoint-writer observer: rides the chain's observer pipeline,
/// requests a snapshot every `every` iterations (and at completion) and
/// writes it atomically to its `.fckpt` path. Carries no chain state of
/// its own — its section in the image is empty.
#[derive(Clone, Debug)]
pub struct CheckpointObserver {
    path: String,
    every: usize,
    fingerprint: u64,
    writes: u64,
    last_write_secs: f64,
    last_bytes: usize,
}

impl CheckpointObserver {
    /// Writer targeting `path` with the given cadence and fingerprint.
    pub fn new(path: &str, every: usize, fingerprint: u64) -> Self {
        CheckpointObserver {
            path: path.to_string(),
            every,
            fingerprint,
            writes: 0,
            last_write_secs: 0.0,
            last_bytes: 0,
        }
    }

    /// Checkpoints written so far this session.
    pub fn writes(&self) -> u64 {
        self.writes
    }

    /// Wall-clock seconds of the most recent write (bench instrumentation).
    pub fn last_write_secs(&self) -> f64 {
        self.last_write_secs
    }

    /// Serialized size in bytes of the most recent write.
    pub fn last_bytes(&self) -> usize {
        self.last_bytes
    }
}

impl ChainObserver for CheckpointObserver {
    fn tag(&self) -> [u8; 4] {
        *b"CKPT"
    }

    fn on_iter(&mut self, _rec: &crate::engine::observer::IterRecord<'_>) {}

    fn save_state(&self, _w: &mut ByteWriter) {}

    fn load_state(&mut self, _r: &mut ByteReader) -> Result<(), String> {
        Ok(())
    }

    fn wants_checkpoint(&self, completed: usize, finished: bool) -> bool {
        finished || (self.every > 0 && completed % self.every == 0)
    }

    fn on_checkpoint(&mut self, image: &CheckpointImage) -> anyhow::Result<()> {
        let timer = crate::util::Timer::start();
        self.last_bytes = write_checkpoint(&self.path, image, self.fingerprint)?;
        self.writes += 1;
        self.last_write_secs = timer.elapsed_secs();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("firefly_fckpt_{}_{name}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn sample_image() -> CheckpointImage {
        let mut image = CheckpointImage::new(123);
        let mut w = ByteWriter::new();
        w.f64_slice(&[1.0, -2.0, 3.5]);
        image.push_section(*b"CORE", w.into_bytes());
        image.push_section(*b"STAT", vec![9, 8, 7]);
        image.push_section(*b"CKPT", Vec::new());
        image
    }

    #[test]
    fn image_roundtrips_through_bytes_and_disk() {
        let image = sample_image();
        let bytes = image.to_bytes(0xFEED);
        let got = CheckpointImage::from_bytes(&bytes).unwrap();
        assert_eq!(got.fingerprint, 0xFEED);
        assert_eq!(got.completed, 123);
        assert_eq!(got.n_sections(), 3);
        assert_eq!(got.section(*b"STAT"), Some(&[9u8, 8, 7][..]));
        assert_eq!(got.section(*b"CKPT"), Some(&[][..]));
        assert!(got.section(*b"NOPE").is_none());

        let path = tmp("roundtrip.fckpt");
        write_checkpoint(&path, &image, 42).unwrap();
        let got = read_checkpoint(&path).unwrap();
        assert_eq!(got.fingerprint, 42);
        assert_eq!(got.section(*b"CORE"), image.section(*b"CORE"));
        // atomic write: no temp file left behind
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupt_and_truncated_checkpoints_are_rejected() {
        let good = sample_image().to_bytes(7);

        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(CheckpointImage::from_bytes(&bad).unwrap_err().contains("magic"));

        let mut bad = good.clone();
        bad[8] = 99;
        assert!(CheckpointImage::from_bytes(&bad).unwrap_err().contains("version"));

        // flip one payload byte: checksum must catch it
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        assert!(CheckpointImage::from_bytes(&bad).unwrap_err().contains("checksum"));

        // truncation inside the section region
        let bad = &good[..good.len() - 2];
        assert!(CheckpointImage::from_bytes(bad).is_err());
        // truncation inside the header
        assert!(CheckpointImage::from_bytes(&good[..20]).unwrap_err().contains("header"));

        // trailing garbage after the declared sections
        let mut bad = good.clone();
        bad.push(0);
        let err = CheckpointImage::from_bytes(&bad).unwrap_err();
        // (appending also breaks the checksum; either rejection is fine)
        assert!(err.contains("checksum") || err.contains("trailing"), "{err}");
    }

    #[test]
    fn writer_observer_cadence_and_final_write() {
        let obs = CheckpointObserver::new("/dev/null", 50, 1);
        assert!(!obs.wants_checkpoint(49, false));
        assert!(obs.wants_checkpoint(50, false));
        assert!(obs.wants_checkpoint(100, false));
        assert!(obs.wants_checkpoint(123, true)); // completion forces a write
        let end_only = CheckpointObserver::new("/dev/null", 0, 1);
        assert!(!end_only.wants_checkpoint(1000, false));
        assert!(end_only.wants_checkpoint(1000, true));
    }
}
