//! Config substrate: a minimal TOML-subset parser + typed experiment config.
//!
//! Supports what the experiment configs need: `[section]` headers, `key =
//! value` with string / float / int / bool / homogeneous array values, `#`
//! comments. The typed layer (`ExperimentConfig`) is what `firefly run
//! --config exp.toml` consumes; every field has a paper-faithful default so
//! an empty file is a valid MNIST-experiment config.

use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// double-quoted string
    Str(String),
    /// float literal (or int in a float context via [`Value::as_f64`])
    Float(f64),
    /// integer literal
    Int(i64),
    /// `true` / `false`
    Bool(bool),
    /// homogeneous-or-not bracketed array
    Array(Vec<Value>),
}

impl Value {
    /// Numeric value as f64 (ints widen; None otherwise).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Integer value (None otherwise).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// String value (None otherwise).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean value (None otherwise).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: section -> key -> value ("" = top-level section).
#[derive(Clone, Debug, Default)]
pub struct Doc {
    /// section name -> key -> value ("" = top-level)
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Parse a TOML-subset document (see the module docs for the grammar).
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            doc.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(doc)
    }

    /// Look up `key` in `section` ("" = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Numeric lookup with a default.
    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    /// Unsigned-integer lookup with a default.
    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key)
            .and_then(|v| v.as_i64())
            .map(|i| i as usize)
            .unwrap_or(default)
    }
    /// String lookup with a default.
    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
    /// Boolean lookup with a default.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

/// Split a comma-separated `host:port` list (the `--connect` flag and the
/// `[dist] connect` key), trimming whitespace and dropping empty items.
pub fn parse_connect_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(str::to_string)
        .collect()
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    s.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| format!("cannot parse value: {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if depth == 0 && !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

// ---------------------------------------------------------------------------
// Typed experiment config
// ---------------------------------------------------------------------------

/// Which of the three experiment stacks to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// MNIST-like logistic regression, random-walk MH (Table 1 rows 1-3)
    LogisticMnist,
    /// CIFAR-3-like softmax, MALA (Table 1 rows 4-6)
    SoftmaxCifar,
    /// OPV-like robust regression, slice sampling (Table 1 rows 7-9)
    RobustOpv,
    /// 2-d toy logistic (Fig 2)
    Toy,
}

impl Task {
    /// Parse a CLI/TOML task name (accepts the aliases shown in `--help`).
    pub fn parse(s: &str) -> Result<Task, String> {
        match s {
            "logistic" | "mnist" | "logistic_mnist" => Ok(Task::LogisticMnist),
            "softmax" | "cifar" | "softmax_cifar" => Ok(Task::SoftmaxCifar),
            "robust" | "opv" | "robust_opv" => Ok(Task::RobustOpv),
            "toy" => Ok(Task::Toy),
            _ => Err(format!("unknown task {s:?}")),
        }
    }
}

/// The algorithms the experiments compare: the paper's three exact stacks
/// (Table 1 / Fig 4) plus the approximate tall-data competitor baselines
/// (DESIGN.md §Baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// full-data MCMC baseline (N likelihood queries per evaluation)
    RegularMcmc,
    /// FlyMC with fixed bound anchors (paper: xi = 1.5, q = 0.1)
    UntunedFlyMc,
    /// FlyMC with bounds tightened at an approximate MAP (paper: q = 0.01)
    MapTunedFlyMc,
    /// stochastic-gradient Langevin dynamics (approximate; minibatch
    /// gradients, no accept/reject — `samplers::Sgld`)
    Sgld,
    /// austerity MH (approximate; sequential-test early stopping —
    /// `samplers::AusterityMh`)
    Austerity,
}

impl Algorithm {
    /// Parse a CLI/TOML algorithm name.
    pub fn parse(s: &str) -> Result<Algorithm, String> {
        match s {
            "regular" | "mcmc" | "full" => Ok(Algorithm::RegularMcmc),
            "untuned" | "flymc" => Ok(Algorithm::UntunedFlyMc),
            "maptuned" | "map" | "map_tuned" => Ok(Algorithm::MapTunedFlyMc),
            "sgld" => Ok(Algorithm::Sgld),
            "austerity" | "austere" => Ok(Algorithm::Austerity),
            _ => Err(format!("unknown algorithm {s:?}")),
        }
    }
    /// Human-readable label used in Table-1 rows and reports.
    pub fn label(&self) -> &'static str {
        match self {
            Algorithm::RegularMcmc => "Regular MCMC",
            Algorithm::UntunedFlyMc => "Untuned FlyMC",
            Algorithm::MapTunedFlyMc => "MAP-tuned FlyMC",
            Algorithm::Sgld => "SGLD",
            Algorithm::Austerity => "Austerity MH",
        }
    }
    /// Whether this algorithm's invariant law is only approximately the
    /// posterior (subsampling bias — the head-to-head bench measures it).
    pub fn is_approximate(&self) -> bool {
        matches!(self, Algorithm::Sgld | Algorithm::Austerity)
    }
}

/// Likelihood evaluation backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Serial pure-Rust reference backend.
    Cpu,
    /// Sharded data-parallel CPU backend (bit-identical to `Cpu`).
    ParCpu,
    /// Multi-process distributed backend over TCP shard workers
    /// (bit-identical to `Cpu` at any worker count, DESIGN.md
    /// §Distribution).
    Dist,
    /// PJRT/XLA execution of the AOT artifacts (needs the `xla` feature).
    Xla,
}

impl Backend {
    /// Parse a CLI/TOML backend name.
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s {
            "cpu" => Ok(Backend::Cpu),
            "parcpu" | "par_cpu" | "par" => Ok(Backend::ParCpu),
            "dist" | "distributed" => Ok(Backend::Dist),
            "xla" => Ok(Backend::Xla),
            other => Err(format!("unknown backend {other:?}")),
        }
    }

    /// [`Backend::parse`] for CLI front-ends (benches/examples): print the
    /// error and exit(2) instead of returning it.
    pub fn parse_or_exit(s: &str) -> Backend {
        Backend::parse(s).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        })
    }
}

/// Full experiment description with paper-faithful defaults.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// which experiment stack to run
    pub task: Task,
    /// which of the three compared algorithms
    pub algorithm: Algorithm,
    /// likelihood evaluation backend
    pub backend: Backend,
    /// base seed (replicas derive their own)
    pub seed: u64,
    /// total MCMC iterations per chain
    pub iters: usize,
    /// burn-in iterations (excluded from traces/averages)
    pub burnin: usize,
    /// dataset size; None = paper-scale default for the task
    pub n_data: Option<usize>,
    /// replica chains (run concurrently on the CPU backends)
    pub chains: usize,
    /// worker-thread cap: bounds how many replica chains run concurrently,
    /// and sizes the sharded backend's dedicated pool for single-chain runs
    /// (multi-chain runs share rayon's global pool so total workers stay
    /// bounded by the machine, not chains × threads). 0 = one thread per
    /// replica / rayon's default pool.
    pub threads: usize,
    /// q_{d->b} for implicit z-resampling (paper: 0.1 untuned, 0.01 tuned)
    pub q_dark_to_bright: Option<f64>,
    /// fixed JJ xi for untuned bounds (paper: 1.5)
    pub untuned_xi: f64,
    /// use explicit (Alg 1) instead of implicit (Alg 2) z-resampling
    pub explicit_resample: bool,
    /// explicit-resample fraction of N per iteration
    pub resample_fraction: f64,
    /// re-anchor the bounds once, at the chain's running posterior mean
    /// (DESIGN.md §Bound-management; FlyMC algorithms on the CPU backends
    /// only — XLA artifacts bake the anchors in)
    pub reanchor: bool,
    /// iteration the re-anchor fires at (None = end of burn-in); must lie
    /// in [1, burnin] so every recorded sample is post-restart
    pub reanchor_at: Option<usize>,
    /// adapt `q_dark_to_bright` toward the target bright-set turnover with
    /// a Robbins–Monro controller, frozen before any recorded sample
    pub adapt_q: bool,
    /// q-adaptation window in iterations (None = burnin / 2); must end
    /// strictly inside burn-in
    pub adapt_window: Option<usize>,
    /// None = per-task default (MNIST 1.0, CIFAR 0.15, OPV 0.5 — the paper
    /// chooses the scale by out-of-sample performance per experiment)
    pub prior_scale: Option<f64>,
    /// Adam steps for the MAP-tuning pre-pass
    pub map_steps: usize,
    /// record the full-data log posterior every k iterations (0 = never)
    pub record_every: usize,
    /// directory holding the XLA artifact manifest
    pub artifacts_dir: String,
    /// `.fbin` dataset to sample out of core (None = synthesize the task's
    /// workload in RAM); the file's label kind must match the task, and
    /// `n_data` is ignored (the file defines N)
    pub data_path: Option<String>,
    /// per-reader block-cache budget in rows for `.fbin` data (0 = default;
    /// see DESIGN.md §Storage for sizing)
    pub cache_rows: usize,
    /// write a `.fckpt` chain checkpoint every this many iterations
    /// (0 = disabled unless `checkpoint_dir` is set, in which case only a
    /// final checkpoint is written; see DESIGN.md §Checkpointing)
    pub checkpoint_every: usize,
    /// directory holding one `chain_NNNN.fckpt` per replica (required when
    /// `checkpoint_every` > 0 and for the `resume` subcommand)
    pub checkpoint_dir: Option<String>,
    /// bound this session to at most this many iterations per chain — the
    /// run stops mid-chain (checkpointed at the stop point, resumable)
    /// instead of completing; None = run to completion
    pub stop_after: Option<usize>,
    /// keep the O(iters × dim) in-memory series; false (CLI
    /// `--streaming-only`, TOML `[experiment] streaming_only = true`) keeps
    /// only the O(dim) streaming summary — bounded memory and small
    /// checkpoints for very long chains
    pub record_trace: bool,
    /// minibatch size m for the approximate samplers: SGLD's gradient
    /// estimator, and austerity MH's initial sequential-test batch
    pub minibatch: usize,
    /// SGLD step-schedule scale a in ε_t = a (b + t)^{-γ}
    pub sgld_step_a: f64,
    /// SGLD step-schedule offset b
    pub sgld_step_b: f64,
    /// SGLD step-schedule decay γ (0 = fixed step — deliberately biased,
    /// used by the validation harness to prove it can detect bias)
    pub sgld_step_gamma: f64,
    /// use the control-variate SGLD gradient anchored at the MAP point
    pub sgld_cv: bool,
    /// per-decision error tolerance ε of austerity MH's sequential test
    pub austerity_eps: f64,
    /// `dist` backend: spawn this many in-process localhost shard workers
    /// (0 = connect to standalone `firefly worker` processes instead)
    pub dist_workers: usize,
    /// `dist` backend: worker addresses (`host:port`), one per shard in
    /// ascending shard order; exclusive with `dist_workers`
    pub dist_connect: Vec<String>,
    /// `dist` backend: per-request I/O timeout in milliseconds (0 = block
    /// forever). Execution-only — never fingerprinted.
    pub dist_timeout_ms: u64,
    /// `dist` backend: bounded retry attempts per request after a
    /// transport failure (reconnect + re-handshake + resend)
    pub dist_retries: u32,
    /// `dist` backend: back-off between retry attempts, milliseconds
    pub dist_retry_backoff_ms: u64,
    /// `dist` backend: optional `.fshard` manifest to cross-check worker
    /// placement and model shape against at startup
    pub dist_manifest: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            task: Task::LogisticMnist,
            algorithm: Algorithm::MapTunedFlyMc,
            backend: Backend::Cpu,
            seed: 0,
            iters: 2000,
            burnin: 500,
            n_data: None,
            chains: 1,
            threads: 0,
            q_dark_to_bright: None,
            untuned_xi: 1.5,
            explicit_resample: false,
            resample_fraction: 0.1,
            reanchor: false,
            reanchor_at: None,
            adapt_q: false,
            adapt_window: None,
            prior_scale: None,
            map_steps: 400,
            record_every: 1,
            artifacts_dir: "artifacts".to_string(),
            data_path: None,
            cache_rows: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            stop_after: None,
            record_trace: true,
            minibatch: 100,
            sgld_step_a: 1e-5,
            sgld_step_b: 1.0,
            sgld_step_gamma: 0.55,
            sgld_cv: false,
            austerity_eps: 0.05,
            dist_workers: 0,
            dist_connect: Vec::new(),
            dist_timeout_ms: 5000,
            dist_retries: 3,
            dist_retry_backoff_ms: 200,
            dist_manifest: None,
        }
    }
}

impl ExperimentConfig {
    /// Typed config from a parsed document (missing keys keep defaults).
    pub fn from_doc(doc: &Doc) -> Result<Self, String> {
        let mut c = ExperimentConfig::default();
        c.task = Task::parse(&doc.str_or("experiment", "task", "logistic"))?;
        c.algorithm = Algorithm::parse(&doc.str_or("experiment", "algorithm", "map_tuned"))?;
        c.backend = Backend::parse(&doc.str_or("experiment", "backend", "cpu"))?;
        c.seed = doc.usize_or("experiment", "seed", 0) as u64;
        c.iters = doc.usize_or("experiment", "iters", c.iters);
        c.burnin = doc.usize_or("experiment", "burnin", c.burnin);
        if let Some(v) = doc.get("experiment", "n_data").and_then(|v| v.as_i64()) {
            c.n_data = Some(v as usize);
        }
        c.chains = doc.usize_or("experiment", "chains", c.chains);
        c.threads = doc.usize_or("experiment", "threads", c.threads);
        if let Some(v) = doc.get("flymc", "q_dark_to_bright").and_then(|v| v.as_f64()) {
            c.q_dark_to_bright = Some(v);
        }
        c.untuned_xi = doc.f64_or("flymc", "untuned_xi", c.untuned_xi);
        c.explicit_resample = doc.bool_or("flymc", "explicit_resample", c.explicit_resample);
        c.resample_fraction = doc.f64_or("flymc", "resample_fraction", c.resample_fraction);
        c.reanchor = doc.bool_or("flymc", "reanchor", c.reanchor);
        if let Some(v) = doc.get("flymc", "reanchor_at").and_then(|v| v.as_i64()) {
            if v <= 0 {
                return Err(format!("flymc.reanchor_at must be positive, got {v}"));
            }
            c.reanchor_at = Some(v as usize);
        }
        c.adapt_q = doc.bool_or("flymc", "adapt_q", c.adapt_q);
        if let Some(v) = doc.get("flymc", "adapt_window").and_then(|v| v.as_i64()) {
            if v <= 0 {
                return Err(format!("flymc.adapt_window must be positive, got {v}"));
            }
            c.adapt_window = Some(v as usize);
        }
        if let Some(v) = doc.get("model", "prior_scale").and_then(|v| v.as_f64()) {
            c.prior_scale = Some(v);
        }
        c.map_steps = doc.usize_or("flymc", "map_steps", c.map_steps);
        c.record_every = doc.usize_or("experiment", "record_every", c.record_every);
        c.artifacts_dir = doc.str_or("experiment", "artifacts_dir", &c.artifacts_dir);
        if let Some(p) = doc.get("data", "path").and_then(|v| v.as_str()) {
            c.data_path = Some(p.to_string());
        }
        c.cache_rows = doc.usize_or("data", "cache_rows", c.cache_rows);
        if let Some(v) = doc.get("checkpoint", "every").and_then(|v| v.as_i64()) {
            if v < 0 {
                return Err(format!("checkpoint.every must be non-negative, got {v}"));
            }
            c.checkpoint_every = v as usize;
        }
        if let Some(d) = doc.get("checkpoint", "dir").and_then(|v| v.as_str()) {
            c.checkpoint_dir = Some(d.to_string());
        }
        if let Some(v) = doc.get("checkpoint", "stop_after").and_then(|v| v.as_i64()) {
            if v <= 0 {
                return Err(format!("checkpoint.stop_after must be positive, got {v}"));
            }
            c.stop_after = Some(v as usize);
        }
        if doc.bool_or("experiment", "streaming_only", false) {
            c.record_trace = false;
        }
        c.minibatch = doc.usize_or("approx", "minibatch", c.minibatch);
        c.sgld_step_a = doc.f64_or("approx", "sgld_step_a", c.sgld_step_a);
        c.sgld_step_b = doc.f64_or("approx", "sgld_step_b", c.sgld_step_b);
        c.sgld_step_gamma = doc.f64_or("approx", "sgld_step_gamma", c.sgld_step_gamma);
        c.sgld_cv = doc.bool_or("approx", "sgld_cv", c.sgld_cv);
        c.austerity_eps = doc.f64_or("approx", "austerity_eps", c.austerity_eps);
        c.dist_workers = doc.usize_or("dist", "workers", c.dist_workers);
        if let Some(s) = doc.get("dist", "connect").and_then(|v| v.as_str()) {
            c.dist_connect = parse_connect_list(s);
        }
        c.dist_timeout_ms = doc.usize_or("dist", "timeout_ms", c.dist_timeout_ms as usize) as u64;
        c.dist_retries = doc.usize_or("dist", "retries", c.dist_retries as usize) as u32;
        c.dist_retry_backoff_ms =
            doc.usize_or("dist", "retry_backoff_ms", c.dist_retry_backoff_ms as usize) as u64;
        if let Some(m) = doc.get("dist", "manifest").and_then(|v| v.as_str()) {
            c.dist_manifest = Some(m.to_string());
        }
        c.validate()?;
        Ok(c)
    }

    /// Typed config straight from TOML-subset text.
    pub fn from_str_toml(text: &str) -> Result<Self, String> {
        Self::from_doc(&Doc::parse(text)?)
    }

    /// Paper's q_{d->b} default for the algorithm (0.1 untuned, 0.01 tuned).
    pub fn effective_q_db(&self) -> f64 {
        self.q_dark_to_bright.unwrap_or(match self.algorithm {
            Algorithm::UntunedFlyMc => 0.1,
            Algorithm::MapTunedFlyMc => 0.01,
            // non-FlyMC algorithms have no z-augmentation
            Algorithm::RegularMcmc | Algorithm::Sgld | Algorithm::Austerity => 0.0,
        })
    }

    /// Whether the configured algorithm runs the FlyMC auxiliary chain.
    pub fn is_flymc(&self) -> bool {
        matches!(
            self.algorithm,
            Algorithm::UntunedFlyMc | Algorithm::MapTunedFlyMc
        )
    }

    /// The chain-level re-anchor trigger iteration: the configured value,
    /// defaulting to the end of burn-in; `None` when the feature is off.
    pub fn effective_reanchor_at(&self) -> Option<usize> {
        if self.reanchor {
            Some(self.reanchor_at.unwrap_or(self.burnin))
        } else {
            None
        }
    }

    /// The q-adaptation window length, defaulting to half the burn-in;
    /// 0 when adaptation is off.
    pub fn effective_adapt_window(&self) -> usize {
        if self.adapt_q {
            self.adapt_window.unwrap_or(self.burnin / 2)
        } else {
            0
        }
    }

    /// Reject configurations whose FlyMC parameters silently degenerate the
    /// sampler instead of erroring at run time:
    ///
    /// * `q_dark_to_bright` must lie strictly inside (0, 1) — the implicit
    ///   resampler takes `ln q`, so q = 0 makes every bright→dark test
    ///   `-inf` and q ≥ 1 makes the geometric skip propose every dark point
    ///   (or, at exactly 1, `ln q = 0` degenerates both acceptance tests);
    /// * `resample_fraction` must lie in (0, 1] — 0 proposes nothing and
    ///   > 1 would redraw more than N points per sweep;
    /// * checkpointing needs a directory to write into, and a session
    ///   iteration bound of 0 would run nothing.
    ///
    /// Called by every parse path (TOML and CLI) so invalid values are
    /// rejected before any chain is built.
    pub fn validate(&self) -> Result<(), String> {
        if let Some(q) = self.q_dark_to_bright {
            if !(q > 0.0 && q < 1.0) {
                return Err(format!(
                    "q_dark_to_bright must lie strictly inside (0, 1), got {q}"
                ));
            }
        }
        if !(self.resample_fraction > 0.0 && self.resample_fraction <= 1.0) {
            return Err(format!(
                "resample_fraction must lie in (0, 1], got {}",
                self.resample_fraction
            ));
        }
        if self.checkpoint_every > 0 && self.checkpoint_dir.is_none() {
            return Err(
                "checkpoint_every is set but no checkpoint_dir to write into".to_string()
            );
        }
        if self.stop_after == Some(0) {
            return Err("stop_after = 0 would run no iterations".to_string());
        }
        if self.stop_after.is_some() && self.checkpoint_dir.is_none() {
            return Err(
                "stop_after bounds a session but without checkpoint_dir the partial \
                 run could never be resumed"
                    .to_string(),
            );
        }
        if self.reanchor {
            if !self.is_flymc() {
                return Err(format!(
                    "reanchor requires a FlyMC algorithm (bounds to re-anchor), got {:?}",
                    self.algorithm
                ));
            }
            if self.backend == Backend::Xla {
                return Err(
                    "reanchor cannot run on the XLA backend (the AOT artifacts bake the \
                     bound anchors in); use cpu or parcpu"
                        .to_string(),
                );
            }
            let at = self.reanchor_at.unwrap_or(self.burnin);
            if at == 0 {
                return Err(
                    "reanchor_at = 0 would re-anchor before any trajectory exists to \
                     anchor at"
                        .to_string(),
                );
            }
            if at > self.burnin {
                return Err(format!(
                    "reanchor_at ({at}) must lie inside burn-in ({}) so every recorded \
                     sample comes from the post-restart bound regime",
                    self.burnin
                ));
            }
        } else if self.reanchor_at.is_some() {
            return Err("reanchor_at is set but reanchor is off".to_string());
        }
        if self.adapt_q {
            if !self.is_flymc() {
                return Err(format!(
                    "adapt_q requires a FlyMC algorithm (a z-chain to control), got {:?}",
                    self.algorithm
                ));
            }
            let w = self.adapt_window.unwrap_or(self.burnin / 2);
            if w == 0 {
                return Err("adapt_window = 0 would adapt nothing".to_string());
            }
            if w >= self.burnin {
                return Err(format!(
                    "adapt_window ({w}) must end strictly inside burn-in ({}) so \
                     adaptation is frozen before any recorded sample",
                    self.burnin
                ));
            }
        } else if self.adapt_window.is_some() {
            return Err("adapt_window is set but adapt_q is off".to_string());
        }
        if self.backend == Backend::Dist {
            let spawn = self.dist_workers > 0;
            let connect = !self.dist_connect.is_empty();
            if spawn == connect {
                return Err(
                    "the dist backend needs either dist.workers > 0 (spawn localhost \
                     shard workers) or a non-empty dist.connect list (standalone \
                     `firefly worker` processes), not both and not neither"
                        .to_string(),
                );
            }
            if self.dist_retries == 0 {
                return Err(
                    "dist.retries = 0 would abort the chain on the first dropped \
                     packet; use at least 1"
                        .to_string(),
                );
            }
        } else if self.dist_manifest.is_some() {
            return Err("dist.manifest is set but the backend is not dist".to_string());
        }
        if self.algorithm.is_approximate() {
            if self.minibatch < 2 {
                return Err(format!(
                    "minibatch must be at least 2 for the approximate samplers, got {}",
                    self.minibatch
                ));
            }
            if self.algorithm == Algorithm::Sgld
                && !(self.sgld_step_a > 0.0
                    && self.sgld_step_b > 0.0
                    && self.sgld_step_gamma >= 0.0)
            {
                return Err(format!(
                    "SGLD schedule needs a > 0, b > 0, gamma >= 0; got a={} b={} gamma={}",
                    self.sgld_step_a, self.sgld_step_b, self.sgld_step_gamma
                ));
            }
            if self.algorithm == Algorithm::Austerity
                && !(self.austerity_eps > 0.0 && self.austerity_eps < 1.0)
            {
                return Err(format!(
                    "austerity_eps must lie strictly inside (0, 1), got {}",
                    self.austerity_eps
                ));
            }
        }
        Ok(())
    }

    /// FNV-1a fingerprint of every field that determines the chain's
    /// per-iteration evolution and recorded output — stamped into `.fckpt`
    /// headers so `resume` refuses a checkpoint written under a different
    /// configuration. Execution-only knobs (backend, threads, cache budget,
    /// artifacts dir, checkpoint wiring, session bounds) are deliberately
    /// excluded: the CPU backends are bit-identical at any thread count, so
    /// resuming a `cpu` run on `parcpu` (or with a different cache size) is
    /// legitimate. The backend's *equivalence class* is fingerprinted,
    /// though: `cpu` and `parcpu` share one class (byte-identical outputs,
    /// §Parallelism), while `xla` is its own — device-side reductions have
    /// no cross-family bit-identity guarantee, so a cpu-family checkpoint
    /// refuses to resume under XLA and vice versa. For out-of-core runs the
    /// fingerprint covers the `data_path` *string*, not the file's bytes —
    /// the `.fbin` dataset is assumed immutable between sessions (shape
    /// drift is caught at restore; content drift at the same path is not
    /// detectable without hashing the whole file, see DESIGN.md
    /// §Checkpointing).
    pub fn fingerprint(&self) -> u64 {
        let backend_family = match self.backend {
            // dist joins the cpu family: shard-order reduction replays the
            // serial fold bit-for-bit (DESIGN.md §Distribution), so a cpu
            // checkpoint legitimately resumes under dist and vice versa
            Backend::Cpu | Backend::ParCpu | Backend::Dist => "cpu",
            Backend::Xla => "xla",
        };
        let mut canon = format!(
            "task={:?};alg={:?};seed={};iters={};burnin={};n_data={:?};chains={};\
             q={:?};xi={};explicit={};fraction={};prior_scale={:?};map_steps={};\
             record_every={};data_path={:?};record_trace={};backend_family={}",
            self.task,
            self.algorithm,
            self.seed,
            self.iters,
            self.burnin,
            self.n_data,
            self.chains,
            self.q_dark_to_bright,
            self.untuned_xi,
            self.explicit_resample,
            self.resample_fraction,
            self.prior_scale,
            self.map_steps,
            self.record_every,
            self.data_path,
            self.record_trace,
            backend_family,
        );
        // Approximate-sampler knobs join the canon ONLY when an approximate
        // algorithm is active: every fingerprint minted before these knobs
        // existed (exact algorithms) must stay byte-for-byte reproducible or
        // committed `.fckpt` checkpoints would refuse to resume.
        match self.algorithm {
            Algorithm::Sgld => {
                use std::fmt::Write as _;
                let _ = write!(
                    canon,
                    ";minibatch={};sgld_a={};sgld_b={};sgld_gamma={};sgld_cv={}",
                    self.minibatch,
                    self.sgld_step_a,
                    self.sgld_step_b,
                    self.sgld_step_gamma,
                    self.sgld_cv,
                );
            }
            Algorithm::Austerity => {
                use std::fmt::Write as _;
                let _ = write!(
                    canon,
                    ";minibatch={};austerity_eps={}",
                    self.minibatch, self.austerity_eps,
                );
            }
            _ => {}
        }
        // The re-anchor/adaptive-q knobs join the canon ONLY when active,
        // for the same reason as the approx knobs: fingerprints minted
        // before these fields existed must stay byte-for-byte reproducible.
        if self.reanchor {
            use std::fmt::Write as _;
            let _ = write!(
                canon,
                ";reanchor_at={}",
                self.effective_reanchor_at().unwrap_or(0)
            );
        }
        if self.adapt_q {
            use std::fmt::Write as _;
            let _ = write!(canon, ";adapt_q_window={}", self.effective_adapt_window());
        }
        crate::util::codec::fnv1a(canon.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
            top = 1
            [experiment]
            task = "softmax"      # a comment
            iters = 5000
            step = 0.25
            flag = true
            arr = [1, 2.5, "x"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_i64(), Some(1));
        assert_eq!(doc.str_or("experiment", "task", "?"), "softmax");
        assert_eq!(doc.usize_or("experiment", "iters", 0), 5000);
        assert_eq!(doc.f64_or("experiment", "step", 0.0), 0.25);
        assert!(doc.bool_or("experiment", "flag", false));
        match doc.get("experiment", "arr").unwrap() {
            Value::Array(items) => {
                assert_eq!(items.len(), 3);
                assert_eq!(items[0].as_i64(), Some(1));
                assert_eq!(items[1].as_f64(), Some(2.5));
                assert_eq!(items[2].as_str(), Some("x"));
            }
            _ => panic!("not array"),
        }
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("", "s", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
        let err = Doc::parse("[unclosed").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn experiment_config_defaults_and_overrides() {
        let c = ExperimentConfig::from_str_toml("").unwrap();
        assert_eq!(c.task, Task::LogisticMnist);
        assert_eq!(c.untuned_xi, 1.5);
        assert!((c.effective_q_db() - 0.01).abs() < 1e-12); // map-tuned default

        let c = ExperimentConfig::from_str_toml(
            "[experiment]\ntask = \"opv\"\nalgorithm = \"untuned\"\n[flymc]\nuntuned_xi = 2.0",
        )
        .unwrap();
        assert_eq!(c.task, Task::RobustOpv);
        assert_eq!(c.untuned_xi, 2.0);
        assert!((c.effective_q_db() - 0.1).abs() < 1e-12); // untuned default
    }

    #[test]
    fn algorithm_and_task_parse_aliases() {
        assert_eq!(Task::parse("mnist").unwrap(), Task::LogisticMnist);
        assert_eq!(Task::parse("cifar").unwrap(), Task::SoftmaxCifar);
        assert!(Task::parse("nope").is_err());
        assert_eq!(Algorithm::parse("map").unwrap(), Algorithm::MapTunedFlyMc);
        assert!(Algorithm::parse("zzz").is_err());
    }

    #[test]
    fn backend_parse_and_parallel_plumbing() {
        assert_eq!(Backend::parse("cpu").unwrap(), Backend::Cpu);
        assert_eq!(Backend::parse("parcpu").unwrap(), Backend::ParCpu);
        assert_eq!(Backend::parse("par").unwrap(), Backend::ParCpu);
        assert_eq!(Backend::parse("xla").unwrap(), Backend::Xla);
        assert!(Backend::parse("gpu").is_err());

        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nbackend = \"parcpu\"\nchains = 4\nthreads = 2",
        )
        .unwrap();
        assert_eq!(c.backend, Backend::ParCpu);
        assert_eq!(c.chains, 4);
        assert_eq!(c.threads, 2);
        // defaults
        let c = ExperimentConfig::from_str_toml("").unwrap();
        assert_eq!(c.backend, Backend::Cpu);
        assert_eq!(c.threads, 0);
    }

    #[test]
    fn flymc_knobs_are_validated_at_parse_time() {
        // q_dark_to_bright outside (0, 1) is rejected
        for bad in ["0.0", "1.0", "-0.2", "1.5", "nan"] {
            let toml = format!("[flymc]\nq_dark_to_bright = {bad}");
            let err = ExperimentConfig::from_str_toml(&toml)
                .expect_err(&format!("q = {bad} must be rejected"));
            assert!(err.contains("q_dark_to_bright") || err.contains("parse"), "{err}");
        }
        // boundaries just inside are accepted
        for good in ["1e-6", "0.999"] {
            let toml = format!("[flymc]\nq_dark_to_bright = {good}");
            ExperimentConfig::from_str_toml(&toml).unwrap();
        }
        // resample_fraction outside (0, 1] is rejected; 1.0 is allowed
        for bad in ["0.0", "-0.1", "1.01"] {
            let toml = format!("[flymc]\nresample_fraction = {bad}");
            let err = ExperimentConfig::from_str_toml(&toml)
                .expect_err(&format!("fraction = {bad} must be rejected"));
            assert!(err.contains("resample_fraction"), "{err}");
        }
        ExperimentConfig::from_str_toml("[flymc]\nresample_fraction = 1.0").unwrap();
        // validate() rejects a programmatically-set bad value too
        let c = ExperimentConfig {
            q_dark_to_bright: Some(0.0),
            ..ExperimentConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn checkpoint_section_parses_and_is_validated() {
        let c = ExperimentConfig::from_str_toml(
            "[checkpoint]\nevery = 500\ndir = \"ckpt\"\nstop_after = 2000",
        )
        .unwrap();
        assert_eq!(c.checkpoint_every, 500);
        assert_eq!(c.checkpoint_dir.as_deref(), Some("ckpt"));
        assert_eq!(c.stop_after, Some(2000));
        // cadence without a directory is rejected
        let err = ExperimentConfig::from_str_toml("[checkpoint]\nevery = 500").unwrap_err();
        assert!(err.contains("checkpoint_dir"), "{err}");
        // a session bound without checkpointing could never resume
        let err = ExperimentConfig::from_str_toml("[checkpoint]\nstop_after = 10").unwrap_err();
        assert!(err.contains("stop_after") || err.contains("checkpoint_dir"), "{err}");
        let err =
            ExperimentConfig::from_str_toml("[checkpoint]\ndir = \"d\"\nstop_after = 0")
                .unwrap_err();
        assert!(err.contains("stop_after"), "{err}");
        // negative values must be rejected, not wrapped through `as usize`
        let err =
            ExperimentConfig::from_str_toml("[checkpoint]\ndir = \"d\"\nstop_after = -5")
                .unwrap_err();
        assert!(err.contains("positive"), "{err}");
        let err = ExperimentConfig::from_str_toml("[checkpoint]\ndir = \"d\"\nevery = -1")
            .unwrap_err();
        assert!(err.contains("non-negative"), "{err}");
        // defaults: checkpointing off
        let c = ExperimentConfig::from_str_toml("").unwrap();
        assert_eq!(c.checkpoint_every, 0);
        assert!(c.checkpoint_dir.is_none());
        assert!(c.stop_after.is_none());
    }

    #[test]
    fn streaming_only_parses_and_marks_the_fingerprint() {
        let c = ExperimentConfig::from_str_toml("[experiment]\nstreaming_only = true").unwrap();
        assert!(!c.record_trace);
        let base = ExperimentConfig::from_str_toml("").unwrap();
        assert!(base.record_trace);
        // recording mode changes recorded output, so it IS fingerprinted
        assert_ne!(c.fingerprint(), base.fingerprint());
    }

    #[test]
    fn fingerprint_tracks_evolution_fields_only() {
        let base = ExperimentConfig::default();
        assert_eq!(base.fingerprint(), ExperimentConfig::default().fingerprint());
        // evolution-relevant fields change the fingerprint
        let c = ExperimentConfig { seed: 99, ..base.clone() };
        assert_ne!(c.fingerprint(), base.fingerprint());
        let c = ExperimentConfig { iters: 12345, ..base.clone() };
        assert_ne!(c.fingerprint(), base.fingerprint());
        let c = ExperimentConfig { q_dark_to_bright: Some(0.05), ..base.clone() };
        assert_ne!(c.fingerprint(), base.fingerprint());
        // execution-only knobs do not (cpu/parcpu are bit-identical)
        let c = ExperimentConfig {
            backend: Backend::ParCpu,
            threads: 8,
            cache_rows: 4096,
            checkpoint_every: 100,
            checkpoint_dir: Some("x".into()),
            stop_after: Some(10),
            ..base.clone()
        };
        assert_eq!(c.fingerprint(), base.fingerprint());
        // ...but crossing the backend FAMILY boundary does: XLA outputs
        // have no bit-identity guarantee against the CPU family
        let c = ExperimentConfig { backend: Backend::Xla, ..base.clone() };
        assert_ne!(c.fingerprint(), base.fingerprint());
    }

    #[test]
    fn approx_algorithms_parse_and_validate() {
        assert_eq!(Algorithm::parse("full").unwrap(), Algorithm::RegularMcmc);
        assert_eq!(Algorithm::parse("sgld").unwrap(), Algorithm::Sgld);
        assert_eq!(Algorithm::parse("austerity").unwrap(), Algorithm::Austerity);
        assert_eq!(Algorithm::parse("austere").unwrap(), Algorithm::Austerity);
        assert!(Algorithm::Sgld.is_approximate());
        assert!(Algorithm::Austerity.is_approximate());
        assert!(!Algorithm::RegularMcmc.is_approximate());
        assert!(!Algorithm::MapTunedFlyMc.is_approximate());

        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nalgorithm = \"sgld\"\n[approx]\nminibatch = 64\n\
             sgld_step_a = 1e-4\nsgld_step_b = 2.0\nsgld_step_gamma = 0.33\nsgld_cv = true",
        )
        .unwrap();
        assert_eq!(c.algorithm, Algorithm::Sgld);
        assert_eq!(c.minibatch, 64);
        assert!((c.sgld_step_a - 1e-4).abs() < 1e-18);
        assert!((c.sgld_step_b - 2.0).abs() < 1e-12);
        assert!((c.sgld_step_gamma - 0.33).abs() < 1e-12);
        assert!(c.sgld_cv);
        // approximate samplers never run the FlyMC z-sweep
        assert_eq!(c.effective_q_db(), 0.0);

        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nalgorithm = \"austerity\"\n[approx]\nausterity_eps = 0.02",
        )
        .unwrap();
        assert_eq!(c.algorithm, Algorithm::Austerity);
        assert!((c.austerity_eps - 0.02).abs() < 1e-12);

        // knob validation fires only for the approximate algorithms
        let err = ExperimentConfig::from_str_toml(
            "[experiment]\nalgorithm = \"sgld\"\n[approx]\nminibatch = 1",
        )
        .unwrap_err();
        assert!(err.contains("minibatch"), "{err}");
        let err = ExperimentConfig::from_str_toml(
            "[experiment]\nalgorithm = \"sgld\"\n[approx]\nsgld_step_a = 0.0",
        )
        .unwrap_err();
        assert!(err.contains("SGLD schedule"), "{err}");
        let err = ExperimentConfig::from_str_toml(
            "[experiment]\nalgorithm = \"austerity\"\n[approx]\nausterity_eps = 1.0",
        )
        .unwrap_err();
        assert!(err.contains("austerity_eps"), "{err}");
        // exact algorithms ignore bad approx knobs entirely
        ExperimentConfig::from_str_toml("[approx]\nminibatch = 1").unwrap();
    }

    #[test]
    fn fingerprint_includes_approx_knobs_only_for_approx_algorithms() {
        // exact algorithms: approx knobs are inert and must NOT perturb the
        // fingerprint — committed .fckpt checkpoints predate these fields
        let base = ExperimentConfig::default();
        let c = ExperimentConfig { minibatch: 7, sgld_step_a: 0.5, ..base.clone() };
        assert_eq!(c.fingerprint(), base.fingerprint());

        // SGLD: every schedule knob evolves the chain
        let sgld = ExperimentConfig { algorithm: Algorithm::Sgld, ..base.clone() };
        for f in [
            ExperimentConfig { minibatch: 7, ..sgld.clone() },
            ExperimentConfig { sgld_step_a: 3e-4, ..sgld.clone() },
            ExperimentConfig { sgld_step_b: 9.0, ..sgld.clone() },
            ExperimentConfig { sgld_step_gamma: 0.0, ..sgld.clone() },
            ExperimentConfig { sgld_cv: true, ..sgld.clone() },
        ] {
            assert_ne!(f.fingerprint(), sgld.fingerprint());
        }
        // austerity: minibatch + eps evolve the chain, SGLD knobs do not
        let aus = ExperimentConfig { algorithm: Algorithm::Austerity, ..base.clone() };
        let c = ExperimentConfig { minibatch: 7, ..aus.clone() };
        assert_ne!(c.fingerprint(), aus.fingerprint());
        let c = ExperimentConfig { austerity_eps: 0.2, ..aus.clone() };
        assert_ne!(c.fingerprint(), aus.fingerprint());
        let c = ExperimentConfig { sgld_step_a: 3e-4, ..aus.clone() };
        assert_eq!(c.fingerprint(), aus.fingerprint());
    }

    #[test]
    fn reanchor_and_adapt_knobs_parse_and_validate() {
        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nburnin = 100\n[flymc]\nreanchor = true\nadapt_q = true",
        )
        .unwrap();
        assert!(c.reanchor && c.adapt_q);
        assert_eq!(c.effective_reanchor_at(), Some(100)); // default: end of burn-in
        assert_eq!(c.effective_adapt_window(), 50); // default: burnin / 2
        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nburnin = 100\n[flymc]\nreanchor = true\nreanchor_at = 60\n\
             adapt_q = true\nadapt_window = 40",
        )
        .unwrap();
        assert_eq!(c.effective_reanchor_at(), Some(60));
        assert_eq!(c.effective_adapt_window(), 40);
        // disabled: both helpers are inert
        let c = ExperimentConfig::from_str_toml("").unwrap();
        assert!(!c.reanchor && !c.adapt_q);
        assert_eq!(c.effective_reanchor_at(), None);
        assert_eq!(c.effective_adapt_window(), 0);

        for (toml, needle) in [
            // trigger at 0 (burnin 0 with the default trigger)
            ("[experiment]\nburnin = 0\n[flymc]\nreanchor = true", "reanchor_at"),
            // trigger past burn-in
            (
                "[experiment]\nburnin = 50\n[flymc]\nreanchor = true\nreanchor_at = 51",
                "burn-in",
            ),
            // knob set without enabling the feature
            ("[flymc]\nreanchor_at = 10", "reanchor is off"),
            ("[flymc]\nadapt_window = 10", "adapt_q is off"),
            // wrong algorithm / backend
            (
                "[experiment]\nalgorithm = \"regular\"\n[flymc]\nreanchor = true",
                "FlyMC",
            ),
            ("[experiment]\nalgorithm = \"sgld\"\n[flymc]\nadapt_q = true", "FlyMC"),
            ("[experiment]\nbackend = \"xla\"\n[flymc]\nreanchor = true", "XLA"),
            // window degenerate or overrunning burn-in
            (
                "[experiment]\nburnin = 50\n[flymc]\nadapt_q = true\nadapt_window = 50",
                "adapt_window",
            ),
            // negatives rejected at parse, never wrapped through `as usize`
            ("[flymc]\nreanchor = true\nreanchor_at = -3", "positive"),
            ("[flymc]\nadapt_q = true\nadapt_window = -1", "positive"),
        ] {
            let err = ExperimentConfig::from_str_toml(toml).expect_err(toml);
            assert!(err.contains(needle), "{toml}: {err}");
        }

        // validate() rejects programmatically-built configs the same way
        // (the CLI parse path funnels through it)
        let c = ExperimentConfig {
            reanchor: true,
            reanchor_at: Some(0),
            ..ExperimentConfig::default()
        };
        assert!(c.validate().unwrap_err().contains("reanchor_at"));
        let c = ExperimentConfig {
            adapt_q: true,
            adapt_window: Some(600),
            ..ExperimentConfig::default()
        };
        assert!(c.validate().unwrap_err().contains("adapt_window"));
    }

    #[test]
    fn fingerprint_includes_reanchor_knobs_only_when_enabled() {
        // inert knobs must not perturb historical fingerprints
        let base = ExperimentConfig::default();
        let re = ExperimentConfig { reanchor: true, ..base.clone() };
        assert_ne!(re.fingerprint(), base.fingerprint());
        let re2 = ExperimentConfig {
            reanchor: true,
            reanchor_at: Some(100),
            ..base.clone()
        };
        assert_ne!(re2.fingerprint(), re.fingerprint());
        let aq = ExperimentConfig { adapt_q: true, ..base.clone() };
        assert_ne!(aq.fingerprint(), base.fingerprint());
        let aq2 = ExperimentConfig {
            adapt_q: true,
            adapt_window: Some(33),
            ..base.clone()
        };
        assert_ne!(aq2.fingerprint(), aq.fingerprint());
    }

    #[test]
    fn dist_section_parses_and_is_validated() {
        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nbackend = \"dist\"\n[dist]\nworkers = 4\ntimeout_ms = 900\n\
             retries = 5\nretry_backoff_ms = 50",
        )
        .unwrap();
        assert_eq!(c.backend, Backend::Dist);
        assert_eq!(c.dist_workers, 4);
        assert_eq!(c.dist_timeout_ms, 900);
        assert_eq!(c.dist_retries, 5);
        assert_eq!(c.dist_retry_backoff_ms, 50);

        let c = ExperimentConfig::from_str_toml(
            "[experiment]\nbackend = \"dist\"\n[dist]\n\
             connect = \"h1:7001, h2:7002,h3:7003\"\nmanifest = \"data.fshard\"",
        )
        .unwrap();
        assert_eq!(c.dist_connect, vec!["h1:7001", "h2:7002", "h3:7003"]);
        assert_eq!(c.dist_manifest.as_deref(), Some("data.fshard"));

        // spawn/connect are exclusive, and one of them is required
        for toml in [
            "[experiment]\nbackend = \"dist\"",
            "[experiment]\nbackend = \"dist\"\n[dist]\nworkers = 2\nconnect = \"h:1\"",
        ] {
            let err = ExperimentConfig::from_str_toml(toml).expect_err(toml);
            assert!(err.contains("dist"), "{err}");
        }
        // zero retries would abort on the first dropped packet
        let err = ExperimentConfig::from_str_toml(
            "[experiment]\nbackend = \"dist\"\n[dist]\nworkers = 2\nretries = 0",
        )
        .unwrap_err();
        assert!(err.contains("retries"), "{err}");
        // a manifest on a non-dist backend is a config mistake
        let err =
            ExperimentConfig::from_str_toml("[dist]\nmanifest = \"x.fshard\"").unwrap_err();
        assert!(err.contains("manifest"), "{err}");
        // dist knobs on a non-dist backend are otherwise inert
        ExperimentConfig::from_str_toml("[dist]\nworkers = 4").unwrap();
    }

    #[test]
    fn dist_shares_the_cpu_fingerprint_family() {
        let base = ExperimentConfig::default();
        let dist = ExperimentConfig {
            backend: Backend::Dist,
            dist_workers: 4,
            dist_timeout_ms: 123,
            dist_retries: 9,
            dist_retry_backoff_ms: 7,
            ..base.clone()
        };
        // execution topology never perturbs the fingerprint: a cpu chain's
        // checkpoint resumes under dist (and at any worker count)
        assert_eq!(dist.fingerprint(), base.fingerprint());
        assert_eq!(Backend::parse("dist").unwrap(), Backend::Dist);
        assert_eq!(Backend::parse("distributed").unwrap(), Backend::Dist);
    }

    #[test]
    fn connect_list_splitting() {
        assert_eq!(parse_connect_list("a:1,b:2"), vec!["a:1", "b:2"]);
        assert_eq!(parse_connect_list(" a:1 , ,b:2, "), vec!["a:1", "b:2"]);
        assert!(parse_connect_list("").is_empty());
    }

    #[test]
    fn data_section_parses_path_and_cache_budget() {
        let c = ExperimentConfig::from_str_toml(
            "[data]\npath = \"mnist.fbin\"\ncache_rows = 4096",
        )
        .unwrap();
        assert_eq!(c.data_path.as_deref(), Some("mnist.fbin"));
        assert_eq!(c.cache_rows, 4096);
        let c = ExperimentConfig::from_str_toml("").unwrap();
        assert!(c.data_path.is_none());
        assert_eq!(c.cache_rows, 0);
    }
}
