//! Pure-Rust likelihood backend.
//!
//! Reference implementation of [`BatchEval`] over any [`ModelBound`]; used
//! for baselines, tests (numerics cross-check against the XLA artifacts),
//! and as the default when no artifact matches the model's shape. Each
//! evaluation is one call into the model's batch API, which tiles the
//! index list through the SoA kernels (DESIGN.md §Kernels); likelihood
//! and bound values are bit-identical to the historical per-datum loop.

use std::sync::Arc;

use super::evaluator::BatchEval;
use crate::metrics::Counters;
use crate::models::{EvalScratch, ModelBound};

/// Serial pure-Rust [`BatchEval`] backend — the reference implementation
/// every other backend is checked against.
pub struct CpuBackend {
    /// the model whose likelihoods/bounds this backend evaluates
    pub model: Arc<dyn ModelBound>,
    counters: Counters,
    /// reusable evaluation scratch — tile/lane buffers included (allocated
    /// once here, so the batch model calls never allocate — DESIGN.md §Perf)
    scratch: EvalScratch,
}

impl CpuBackend {
    /// Build a backend over `model`, reporting queries into `counters`.
    pub fn new(model: Arc<dyn ModelBound>, counters: Counters) -> Self {
        let scratch = model.new_scratch();
        CpuBackend { model, counters, scratch }
    }

    /// Drain the scratch's row-cache tallies into the shared counters
    /// (no-op with zero atomics touched for dense stores).
    fn flush_cache_stats(&mut self) {
        let (hits, misses) = self.scratch.take_cache_stats();
        if hits != 0 || misses != 0 {
            self.counters.add_data_cache(hits, misses);
        }
    }
}

impl BatchEval for CpuBackend {
    fn n(&self) -> usize {
        self.model.n()
    }
    fn dim(&self) -> usize {
        self.model.dim()
    }
    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn eval(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>, lb: &mut Vec<f64>) {
        self.counters.add_lik(idx.len() as u64);
        self.counters.add_bound(idx.len() as u64);
        ll.clear();
        lb.clear();
        ll.resize(idx.len(), 0.0);
        lb.resize(idx.len(), 0.0);
        self.model.log_both_batch(theta, idx, ll, lb, &mut self.scratch);
        self.flush_cache_stats();
    }

    fn eval_pseudo_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        lb: &mut Vec<f64>,
        grad: &mut [f64],
    ) {
        self.counters.add_lik(idx.len() as u64);
        self.counters.add_bound(idx.len() as u64);
        ll.clear();
        lb.clear();
        ll.resize(idx.len(), 0.0);
        lb.resize(idx.len(), 0.0);
        self.model.pseudo_grad_batch(theta, idx, ll, lb, grad, &mut self.scratch);
        self.flush_cache_stats();
    }

    fn eval_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>) {
        self.counters.add_lik(idx.len() as u64);
        ll.clear();
        ll.resize(idx.len(), 0.0);
        self.model.log_lik_batch(theta, idx, ll, &mut self.scratch);
        self.flush_cache_stats();
    }

    fn eval_lik_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
    ) {
        self.counters.add_lik(idx.len() as u64);
        ll.clear();
        ll.resize(idx.len(), 0.0);
        self.model.log_lik_grad_batch(theta, idx, ll, grad, &mut self.scratch);
        self.flush_cache_stats();
    }

    fn set_model(&mut self, model: Arc<dyn ModelBound>) -> bool {
        self.scratch = model.new_scratch();
        self.model = model;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::models::LogisticJJ;

    #[test]
    fn counts_queries_per_point() {
        let data = Arc::new(synth::synth_mnist(100, 5, 1));
        let model = Arc::new(LogisticJJ::new(data, 1.5));
        let counters = Counters::new();
        let mut be = CpuBackend::new(model, counters.clone());
        let theta = vec![0.1; be.dim()];
        let (mut ll, mut lb) = (Vec::new(), Vec::new());
        be.eval(&theta, &[0, 5, 9], &mut ll, &mut lb);
        assert_eq!(counters.lik_queries(), 3);
        assert_eq!(ll.len(), 3);
        be.eval_lik(&theta, &[1, 2], &mut ll);
        assert_eq!(counters.lik_queries(), 5);
        assert!(ll.iter().all(|l| l.is_finite() && *l < 0.0));
        assert!(lb.iter().zip(&ll) .all(|(b, _)| b.is_finite()));
    }
}
