//! Likelihood evaluation runtime: the [`evaluator::BatchEval`] interface and
//! its two implementations — pure-Rust [`cpu_backend::CpuBackend`] and the
//! PJRT-based [`xla_backend::XlaBackend`] that executes the AOT artifacts
//! from `make artifacts`. Python never runs on the sampling path.

pub mod cpu_backend;
pub mod evaluator;
pub mod manifest;
pub mod xla_backend;
pub mod xla_source;

pub use cpu_backend::CpuBackend;
pub use evaluator::BatchEval;
pub use manifest::Manifest;
pub use xla_backend::XlaBackend;
pub use xla_source::XlaSource;

use crate::configx::Backend;
use crate::metrics::Counters;
use std::sync::Arc;

/// Build the configured backend for a model that can feed the XLA artifacts.
pub fn make_backend(
    source: Arc<dyn XlaSource>,
    backend: Backend,
    counters: Counters,
    artifacts_dir: &str,
) -> anyhow::Result<Box<dyn BatchEval>> {
    Ok(match backend {
        Backend::Cpu => Box::new(CpuBackend::new(source, counters)),
        Backend::Xla => Box::new(XlaBackend::new(source, counters, artifacts_dir)?),
    })
}
