//! Likelihood evaluation runtime: the [`evaluator::BatchEval`] interface and
//! its implementations — serial pure-Rust [`cpu_backend::CpuBackend`], the
//! sharded data-parallel [`par_backend::ParBackend`] (bit-identical outputs
//! and identical query counts, fanned across a rayon pool), the multi-process
//! [`dist_backend::DistBackend`] (same bit-identity contract over TCP shard
//! workers, see DESIGN.md §Distribution), and the PJRT-based
//! [`xla_backend::XlaBackend`] that executes the AOT artifacts from
//! `make artifacts` (requires the `xla` cargo feature; the default offline
//! build ships a stub). Python never runs on the sampling path.

pub mod cpu_backend;
pub mod dist_backend;
pub mod evaluator;
pub mod manifest;
pub mod par_backend;
pub mod xla_backend;
pub mod xla_source;

pub use cpu_backend::CpuBackend;
pub use dist_backend::{DistBackend, DistOptions};
pub use evaluator::BatchEval;
pub use manifest::Manifest;
pub use par_backend::ParBackend;
pub use xla_backend::XlaBackend;
pub use xla_source::XlaSource;

use crate::configx::Backend;
use crate::metrics::Counters;
use std::sync::Arc;

/// Build the configured backend for a model that can feed the XLA artifacts.
/// `threads` caps the sharded backend's worker pool (0 = rayon's default);
/// `dist` carries the distributed backend's topology knobs; the serial and
/// XLA backends ignore both.
pub fn make_backend(
    source: Arc<dyn XlaSource>,
    backend: Backend,
    counters: Counters,
    artifacts_dir: &str,
    threads: usize,
    dist: &DistOptions,
) -> anyhow::Result<Box<dyn BatchEval>> {
    Ok(match backend {
        Backend::Cpu => Box::new(CpuBackend::new(source.as_model_bound(), counters)),
        Backend::ParCpu => {
            Box::new(ParBackend::with_threads(source.as_model_bound(), counters, threads))
        }
        Backend::Dist => Box::new(DistBackend::new(source.as_model_bound(), counters, dist)?),
        Backend::Xla => Box::new(XlaBackend::new(source, counters, artifacts_dir)?),
    })
}
