//! XLA/PJRT likelihood backend: loads the AOT HLO-text artifacts produced by
//! `python -m compile.aot`, compiles them on the PJRT CPU client once per
//! batch bucket, and serves [`BatchEval`](super::evaluator::BatchEval) by
//! padding each index chunk to the smallest bucket that fits (largest bucket
//! used for full-data chunking).
//!
//! Python never runs here — the artifacts are self-contained HLO.
//!
//! The PJRT bindings (`xla` crate) are not part of the offline build, so the
//! real implementation is gated behind the `xla` cargo feature. The default
//! build compiles a stub whose constructor performs the same manifest/shape
//! validation and then fails with a clear error, keeping every caller (CLI,
//! benches, integration tests) compiling and their artifact-skip logic
//! working unchanged.

#[cfg(feature = "xla")]
pub use enabled::XlaBackend;

#[cfg(not(feature = "xla"))]
pub use disabled::XlaBackend;

#[cfg(feature = "xla")]
mod enabled {
    use std::collections::HashMap;
    use std::sync::Arc;

    use anyhow::{anyhow, Context, Result};

    use crate::data::store::RowCache;
    use crate::metrics::Counters;
    use crate::runtime::evaluator::BatchEval;
    use crate::runtime::manifest::Manifest;
    use crate::runtime::xla_source::{BatchBufs, XlaSource};

    /// PJRT-backed [`BatchEval`] executing the AOT HLO artifacts.
    pub struct XlaBackend {
        source: Arc<dyn XlaSource>,
        counters: Counters,
        client: xla::PjRtClient,
        /// bucket size -> compiled executable (lazy)
        executables: HashMap<usize, xla::PjRtLoadedExecutable>,
        /// bucket size -> artifact path (from the manifest)
        bucket_paths: Vec<(usize, String)>,
        bufs: BatchBufs,
        /// feature-row cache for `fill_inputs` (zero-sized for dense data)
        row_cache: RowCache,
        theta_dims: Vec<i64>,
    }

    impl XlaBackend {
        /// Load the manifest for this model's shape and connect a PJRT CPU
        /// client; executables compile lazily per bucket.
        pub fn new(
            source: Arc<dyn XlaSource>,
            counters: Counters,
            artifacts_dir: &str,
        ) -> Result<Self> {
            let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
            let (kind, d, k) = source.artifact_key();
            let entries = manifest.buckets_for(kind, d, k);
            if entries.is_empty() {
                return Err(anyhow!(
                    "no artifact for kind={} d={d} k={k} in {artifacts_dir} — \
                     add the shape to python/compile/aot.py and re-run `make artifacts`",
                    kind.as_str()
                ));
            }
            let bucket_paths: Vec<(usize, String)> = entries
                .iter()
                .map(|e| (e.bucket, manifest.full_path(e)))
                .collect();
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let theta_dims = if k > 1 {
                vec![k as i64, d as i64]
            } else {
                vec![d as i64]
            };
            let row_cache = source.new_row_cache();
            Ok(XlaBackend {
                source,
                counters,
                client,
                executables: HashMap::new(),
                bucket_paths,
                bufs: BatchBufs::default(),
                row_cache,
                theta_dims,
            })
        }

        /// The padded batch sizes the manifest provides for this shape.
        pub fn available_buckets(&self) -> Vec<usize> {
            self.bucket_paths.iter().map(|(b, _)| *b).collect()
        }

        fn max_bucket(&self) -> usize {
            self.bucket_paths.last().map(|(b, _)| *b).unwrap()
        }

        /// Smallest bucket >= len (or the largest available).
        fn pick_bucket(&self, len: usize) -> usize {
            for (b, _) in &self.bucket_paths {
                if *b >= len {
                    return *b;
                }
            }
            self.max_bucket()
        }

        fn executable(&mut self, bucket: usize) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.executables.contains_key(&bucket) {
                let path = &self
                    .bucket_paths
                    .iter()
                    .find(|(b, _)| *b == bucket)
                    .ok_or_else(|| anyhow!("no artifact for bucket {bucket}"))?
                    .1;
                let proto = xla::HloModuleProto::from_text_file(path)
                    .with_context(|| format!("parse {path}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compile {path}"))?;
                self.executables.insert(bucket, exe);
            }
            Ok(self.executables.get(&bucket).unwrap())
        }

        /// Execute one padded chunk; returns (ll[bucket], lb[bucket],
        /// grad_pseudo[dim], grad_lik[dim]).
        fn run_chunk(
            &mut self,
            theta: &[f64],
            idx: &[u32],
        ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)> {
            let bucket = self.pick_bucket(idx.len());
            let (_, d, _) = self.source.artifact_key();
            let aux_w = self.source.aux_width();
            let mut bufs = std::mem::take(&mut self.bufs);
            self.source
                .fill_inputs(idx, bucket, &mut bufs, &mut self.row_cache);
            bufs.check_shape(bucket, d, aux_w);
            self.counters.add_padded((bucket - idx.len()) as u64);

            let theta_lit = xla::Literal::vec1(theta).reshape(&self.theta_dims)?;
            let x_lit = xla::Literal::vec1(&bufs.x).reshape(&[bucket as i64, d as i64])?;
            let (aux1_lit, aux2_lit) = if aux_w > 1 {
                (
                    xla::Literal::vec1(&bufs.aux1).reshape(&[bucket as i64, aux_w as i64])?,
                    xla::Literal::vec1(&bufs.aux2).reshape(&[bucket as i64, aux_w as i64])?,
                )
            } else {
                (
                    xla::Literal::vec1(&bufs.aux1),
                    xla::Literal::vec1(&bufs.aux2),
                )
            };
            let mask_lit = xla::Literal::vec1(&bufs.mask);
            self.bufs = bufs;

            let exe = self.executable(bucket)?;
            let result = exe
                .execute::<xla::Literal>(&[theta_lit, x_lit, aux1_lit, aux2_lit, mask_lit])?[0][0]
                .to_literal_sync()?;
            self.counters.add_xla_exec(1);
            let (ll, lb, gp, gl) = result.to_tuple4()?;
            Ok((
                ll.to_vec::<f64>()?,
                lb.to_vec::<f64>()?,
                gp.to_vec::<f64>()?,
                gl.to_vec::<f64>()?,
            ))
        }

        fn eval_impl(
            &mut self,
            theta: &[f64],
            idx: &[u32],
            ll: &mut Vec<f64>,
            lb: Option<&mut Vec<f64>>,
            grad_pseudo: Option<&mut [f64]>,
            grad_lik: Option<&mut [f64]>,
        ) {
            self.counters.add_lik(idx.len() as u64);
            let shift = self.source.output_shift();
            ll.clear();
            ll.reserve(idx.len());
            let mut lb = lb;
            if let Some(lb) = lb.as_deref_mut() {
                self.counters.add_bound(idx.len() as u64);
                lb.clear();
                lb.reserve(idx.len());
            }
            let mut grad_pseudo = grad_pseudo;
            let mut grad_lik = grad_lik;
            let max_bucket = self.max_bucket();
            for chunk in idx.chunks(max_bucket.max(1)) {
                let (cll, clb, cgp, cgl) = self
                    .run_chunk(theta, chunk)
                    .expect("XLA execution failed");
                ll.extend(cll[..chunk.len()].iter().map(|v| v - shift));
                if let Some(lb) = lb.as_deref_mut() {
                    lb.extend(clb[..chunk.len()].iter().map(|v| v - shift));
                }
                if let Some(g) = grad_pseudo.as_deref_mut() {
                    for (gi, &c) in g.iter_mut().zip(&cgp) {
                        *gi += c;
                    }
                }
                if let Some(g) = grad_lik.as_deref_mut() {
                    for (gi, &c) in g.iter_mut().zip(&cgl) {
                        *gi += c;
                    }
                }
            }
        }
    }

    impl BatchEval for XlaBackend {
        fn n(&self) -> usize {
            self.source.n()
        }
        fn dim(&self) -> usize {
            self.source.dim()
        }
        fn counters(&self) -> &Counters {
            &self.counters
        }

        fn eval(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>, lb: &mut Vec<f64>) {
            self.eval_impl(theta, idx, ll, Some(lb), None, None);
        }

        fn eval_pseudo_grad(
            &mut self,
            theta: &[f64],
            idx: &[u32],
            ll: &mut Vec<f64>,
            lb: &mut Vec<f64>,
            grad: &mut [f64],
        ) {
            self.eval_impl(theta, idx, ll, Some(lb), Some(grad), None);
        }

        fn eval_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>) {
            self.eval_impl(theta, idx, ll, None, None, None);
        }

        fn eval_lik_grad(
            &mut self,
            theta: &[f64],
            idx: &[u32],
            ll: &mut Vec<f64>,
            grad: &mut [f64],
        ) {
            self.eval_impl(theta, idx, ll, None, None, Some(grad));
        }

        fn set_model(&mut self, _model: std::sync::Arc<dyn crate::models::ModelBound>) -> bool {
            // The AOT artifacts bake the bound anchors into their aux
            // inputs; swapping the model cannot retune them.
            false
        }
    }
}

#[cfg(not(feature = "xla"))]
mod disabled {
    use std::sync::Arc;

    use anyhow::{anyhow, Result};

    use crate::metrics::Counters;
    use crate::runtime::evaluator::BatchEval;
    use crate::runtime::xla_source::XlaSource;

    /// Stub compiled when the `xla` feature is off (the default offline
    /// build). `new` refuses to construct with the decisive error up front
    /// (no point validating artifacts a build without PJRT bindings could
    /// never execute); the type itself is uninhabited, so the `BatchEval`
    /// methods are unreachable.
    pub struct XlaBackend {
        _unconstructable: std::convert::Infallible,
    }

    impl XlaBackend {
        /// Always fails: this build has no PJRT bindings (`xla` feature off).
        pub fn new(
            _source: Arc<dyn XlaSource>,
            _counters: Counters,
            _artifacts_dir: &str,
        ) -> Result<Self> {
            Err(anyhow!(
                "XLA backend disabled: this build has no PJRT bindings (rebuild with \
                 `--features xla` after vendoring the `xla` bindings crate — see \
                 Cargo.toml [features]); use `--backend cpu` or `--backend parcpu` instead"
            ))
        }

        /// Unreachable (the stub cannot be constructed).
        pub fn available_buckets(&self) -> Vec<usize> {
            unreachable!("stub XlaBackend cannot be constructed")
        }
    }

    impl BatchEval for XlaBackend {
        fn n(&self) -> usize {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn dim(&self) -> usize {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn counters(&self) -> &Counters {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn eval(
            &mut self,
            _theta: &[f64],
            _idx: &[u32],
            _ll: &mut Vec<f64>,
            _lb: &mut Vec<f64>,
        ) {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn eval_pseudo_grad(
            &mut self,
            _theta: &[f64],
            _idx: &[u32],
            _ll: &mut Vec<f64>,
            _lb: &mut Vec<f64>,
            _grad: &mut [f64],
        ) {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn eval_lik(&mut self, _theta: &[f64], _idx: &[u32], _ll: &mut Vec<f64>) {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn eval_lik_grad(
            &mut self,
            _theta: &[f64],
            _idx: &[u32],
            _ll: &mut Vec<f64>,
            _grad: &mut [f64],
        ) {
            unreachable!("stub XlaBackend cannot be constructed")
        }
        fn set_model(&mut self, _model: Arc<dyn crate::models::ModelBound>) -> bool {
            unreachable!("stub XlaBackend cannot be constructed")
        }
    }
}
