//! Sharded data-parallel CPU likelihood backend.
//!
//! [`ParBackend`] serves the same [`BatchEval`] contract as the serial
//! [`crate::runtime::CpuBackend`], but splits each index batch into
//! fixed-size shards and fans contiguous *groups* of shards out across a
//! rayon thread pool — one [`EvalScratch`] (including its feature-row
//! cache, see DESIGN.md §Storage) per worker group, one gradient partial
//! per shard.
//!
//! Determinism contract (verified by the property tests below and by
//! `rust/tests/integration_parallel.rs`):
//!
//! * `ll` / `lb` outputs are **bit-identical** to `CpuBackend` for any batch
//!   and any thread count: the SoA kernels compute each datum's value from
//!   its own lane alone (the per-lane dot reproduces `linalg::dot`'s
//!   association; see DESIGN.md §Kernels), so re-chunking the batch across
//!   groups never changes a value, and each task writes a disjoint slice
//!   of the output buffers.
//! * Gradient accumulations still produce one partial sum **per shard**
//!   (never per group or per thread) — each shard is tiled from its own
//!   start, so a shard's partial depends only on its contents — and reduce
//!   them **in shard order**, so they are deterministic for a fixed shard
//!   size regardless of thread count or scheduling: grouping only decides
//!   which worker computes a shard's partial, never its bits or its place
//!   in the reduction.
//! * Query accounting is identical to `CpuBackend` — `idx.len()` likelihood
//!   (+ bound) queries per call — so the paper's cost unit does not drift
//!   when the backend goes parallel.
//!
//! Scratch memory is bounded by the worker count, not the batch size: the
//! old one-scratch-per-shard layout was fine when a scratch was a few
//! dim-sized buffers, but a scratch now carries a block cache for
//! out-of-core stores, and a full-N `init_z` pass over a tall dataset would
//! have materialized thousands of caches.

use std::sync::Arc;

use rayon::prelude::*;

use super::evaluator::BatchEval;
use crate::linalg::axpy;
use crate::metrics::Counters;
use crate::models::{EvalScratch, ModelBound};

/// Default shard size: large enough to amortize task dispatch, small enough
/// to load-balance bright sets of a few hundred points.
pub const DEFAULT_SHARD: usize = 64;

/// Sharded data-parallel CPU [`BatchEval`] backend (see the module docs for
/// the determinism contract).
pub struct ParBackend {
    /// the model whose likelihoods/bounds this backend evaluates
    pub model: Arc<dyn ModelBound>,
    counters: Counters,
    /// `None` = the global rayon pool.
    pool: Option<rayon::ThreadPool>,
    shard: usize,
    /// per-worker-group model-evaluation scratch (row cache included), at
    /// most one per pool thread; grown lazily in `ensure_arenas` — FlyMC
    /// hits its maximum during the full-pass `init_z` setup, so
    /// steady-state sampling calls never grow it
    group_scratch: Vec<EvalScratch>,
    /// flat per-shard gradient partials, `nshards × dim` row-major — the
    /// shard-order reduction reads rows in order, so the sum is
    /// deterministic for a fixed shard size (and allocation-free)
    shard_grads: Vec<f64>,
}

impl ParBackend {
    /// Shard across the global rayon pool.
    pub fn new(model: Arc<dyn ModelBound>, counters: Counters) -> Self {
        Self::with_threads(model, counters, 0)
    }

    /// Shard across a dedicated pool of `threads` workers (0 = global pool).
    pub fn with_threads(model: Arc<dyn ModelBound>, counters: Counters, threads: usize) -> Self {
        let pool = if threads == 0 {
            None
        } else {
            Some(
                rayon::ThreadPoolBuilder::new()
                    .num_threads(threads)
                    .build()
                    .expect("build rayon thread pool"),
            )
        };
        ParBackend {
            model,
            counters,
            pool,
            shard: DEFAULT_SHARD,
            group_scratch: Vec::new(),
            shard_grads: Vec::new(),
        }
    }

    /// Override the shard size (gradient reduction order is a function of
    /// the shard size, so fixing it fixes the output bits).
    pub fn with_shard(mut self, shard: usize) -> Self {
        self.shard = shard.max(1);
        self
    }

    /// The configured shard size.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Worker count of the serving pool.
    fn workers(&self) -> usize {
        match &self.pool {
            Some(p) => p.current_num_threads().max(1),
            None => rayon::current_num_threads().max(1),
        }
    }

    /// Partition `nshards` into contiguous worker groups: (ngroups, shards
    /// per group). Outputs never depend on this split — only which worker
    /// computes what.
    fn grouping(&self, nshards: usize) -> (usize, usize) {
        let ngroups = nshards.min(self.workers()).max(1);
        (ngroups, nshards.div_ceil(ngroups))
    }

    /// Grow the per-group scratch pool and the per-shard gradient arena.
    /// Growth happens only when a batch larger than anything seen before
    /// arrives — for FlyMC that is the one-time full-N `init_z` pass, so
    /// steady-state sampling never allocates here. Scratch count is capped
    /// by the pool's worker count regardless of N.
    fn ensure_arenas(&mut self, ngroups: usize, nshards: usize) {
        while self.group_scratch.len() < ngroups {
            self.group_scratch.push(self.model.new_scratch());
        }
        let need = nshards * self.model.dim();
        if self.shard_grads.len() < need {
            self.shard_grads.resize(need, 0.0);
        }
    }

    /// Drain every group scratch's row-cache tallies into the counters.
    fn flush_cache_stats(&mut self) {
        let (mut hits, mut misses) = (0u64, 0u64);
        for sc in &mut self.group_scratch {
            let (h, m) = sc.take_cache_stats();
            hits += h;
            misses += m;
        }
        if hits != 0 || misses != 0 {
            self.counters.add_data_cache(hits, misses);
        }
    }
}

/// Dispatch `f` on the dedicated pool when one exists, inline otherwise —
/// a free function so callers can keep disjoint `&mut` borrows of the
/// backend's arenas while handing the pool reference over.
fn run_in<R: Send>(pool: &Option<rayon::ThreadPool>, f: impl FnOnce() -> R + Send) -> R {
    match pool {
        Some(p) => p.install(f),
        None => f(),
    }
}

impl BatchEval for ParBackend {
    fn n(&self) -> usize {
        self.model.n()
    }
    fn dim(&self) -> usize {
        self.model.dim()
    }
    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn eval(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>, lb: &mut Vec<f64>) {
        self.counters.add_lik(idx.len() as u64);
        self.counters.add_bound(idx.len() as u64);
        ll.clear();
        lb.clear();
        ll.resize(idx.len(), 0.0);
        lb.resize(idx.len(), 0.0);
        let nshards = idx.len().div_ceil(self.shard);
        let (ngroups, group_shards) = self.grouping(nshards);
        self.ensure_arenas(ngroups, 0);
        let sup = (self.shard * group_shards).max(1);
        let model = &*self.model;
        let pool = &self.pool;
        let scratch = &mut self.group_scratch[..ngroups];
        let (ll_s, lb_s) = (ll.as_mut_slice(), lb.as_mut_slice());
        let run = || {
            idx.par_chunks(sup)
                .zip(ll_s.par_chunks_mut(sup).zip(lb_s.par_chunks_mut(sup)))
                .zip(scratch.par_iter_mut())
                .for_each(|((ids, (lls, lbs)), sc)| {
                    model.log_both_batch(theta, ids, lls, lbs, sc);
                });
        };
        run_in(pool, run);
        self.flush_cache_stats();
    }

    fn eval_pseudo_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        lb: &mut Vec<f64>,
        grad: &mut [f64],
    ) {
        self.counters.add_lik(idx.len() as u64);
        self.counters.add_bound(idx.len() as u64);
        ll.clear();
        lb.clear();
        ll.resize(idx.len(), 0.0);
        lb.resize(idx.len(), 0.0);
        let dim = self.model.dim();
        let shard = self.shard;
        let nshards = idx.len().div_ceil(shard);
        let (ngroups, group_shards) = self.grouping(nshards);
        self.ensure_arenas(ngroups, nshards);
        let sup = (shard * group_shards).max(1);
        let model = &*self.model;
        let pool = &self.pool;
        let scratch = &mut self.group_scratch[..ngroups];
        let grads = &mut self.shard_grads[..nshards * dim];
        grads.fill(0.0);
        let (ll_s, lb_s) = (ll.as_mut_slice(), lb.as_mut_slice());
        {
            let grads_par = &mut *grads;
            let run = || {
                idx.par_chunks(sup)
                    .zip(ll_s.par_chunks_mut(sup).zip(lb_s.par_chunks_mut(sup)))
                    .zip(grads_par.par_chunks_mut((dim * group_shards).max(1)))
                    .zip(scratch.par_iter_mut())
                    .for_each(|(((ids, (lls, lbs)), gslab), sc)| {
                        // one gradient partial per shard WITHIN the group:
                        // the reduction below walks shards globally in order
                        for (((sids, slls), slbs), g) in ids
                            .chunks(shard)
                            .zip(lls.chunks_mut(shard))
                            .zip(lbs.chunks_mut(shard))
                            .zip(gslab.chunks_mut(dim))
                        {
                            model.pseudo_grad_batch(theta, sids, slls, slbs, g, sc);
                        }
                    });
            };
            run_in(pool, run);
        }
        // shard-order reduction: deterministic for a fixed shard size
        for g in grads.chunks_exact(dim) {
            axpy(1.0, g, grad);
        }
        self.flush_cache_stats();
    }

    fn eval_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>) {
        self.counters.add_lik(idx.len() as u64);
        ll.clear();
        ll.resize(idx.len(), 0.0);
        let nshards = idx.len().div_ceil(self.shard);
        let (ngroups, group_shards) = self.grouping(nshards);
        self.ensure_arenas(ngroups, 0);
        let sup = (self.shard * group_shards).max(1);
        let model = &*self.model;
        let pool = &self.pool;
        let scratch = &mut self.group_scratch[..ngroups];
        let ll_s = ll.as_mut_slice();
        let run = || {
            idx.par_chunks(sup)
                .zip(ll_s.par_chunks_mut(sup))
                .zip(scratch.par_iter_mut())
                .for_each(|((ids, lls), sc)| {
                    model.log_lik_batch(theta, ids, lls, sc);
                });
        };
        run_in(pool, run);
        self.flush_cache_stats();
    }

    fn eval_lik_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
    ) {
        self.counters.add_lik(idx.len() as u64);
        ll.clear();
        ll.resize(idx.len(), 0.0);
        let dim = self.model.dim();
        let shard = self.shard;
        let nshards = idx.len().div_ceil(shard);
        let (ngroups, group_shards) = self.grouping(nshards);
        self.ensure_arenas(ngroups, nshards);
        let sup = (shard * group_shards).max(1);
        let model = &*self.model;
        let pool = &self.pool;
        let scratch = &mut self.group_scratch[..ngroups];
        let grads = &mut self.shard_grads[..nshards * dim];
        grads.fill(0.0);
        let ll_s = ll.as_mut_slice();
        {
            let grads_par = &mut *grads;
            let run = || {
                idx.par_chunks(sup)
                    .zip(ll_s.par_chunks_mut(sup))
                    .zip(grads_par.par_chunks_mut((dim * group_shards).max(1)))
                    .zip(scratch.par_iter_mut())
                    .for_each(|(((ids, lls), gslab), sc)| {
                        for ((sids, slls), g) in ids
                            .chunks(shard)
                            .zip(lls.chunks_mut(shard))
                            .zip(gslab.chunks_mut(dim))
                        {
                            model.log_lik_grad_batch(theta, sids, slls, g, sc);
                        }
                    });
            };
            run_in(pool, run);
        }
        for g in grads.chunks_exact(dim) {
            axpy(1.0, g, grad);
        }
        self.flush_cache_stats();
    }

    fn set_model(&mut self, model: Arc<dyn ModelBound>) -> bool {
        // fresh scratches lazily rebuilt from the new model on first use;
        // shard_grads is model-independent (dim is unchanged)
        self.group_scratch.clear();
        self.model = model;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::models::{LogisticJJ, RobustT, SoftmaxBohning};
    use crate::runtime::cpu_backend::CpuBackend;
    use crate::testing;
    use crate::util::Rng;

    fn models(seed: u64) -> Vec<Arc<dyn ModelBound>> {
        vec![
            Arc::new(LogisticJJ::new(Arc::new(synth::synth_mnist(300, 7, seed)), 1.5)),
            Arc::new(SoftmaxBohning::new(Arc::new(synth::synth_cifar3(210, 10, seed)))),
            Arc::new(RobustT::new(Arc::new(synth::synth_opv(260, 9, seed)), 4.0, 0.7)),
        ]
    }

    #[test]
    fn bitwise_identical_to_cpu_backend_on_random_batches() {
        for model in models(11) {
            let cpu_counters = Counters::new();
            let par_counters = Counters::new();
            let mut cpu = CpuBackend::new(model.clone(), cpu_counters.clone());
            let mut par =
                ParBackend::with_threads(model.clone(), par_counters.clone(), 4).with_shard(16);
            let dim = model.dim();
            let n = model.n();
            testing::check_msg(
                "par backend == cpu backend (bitwise ll/lb, equal counters)",
                12,
                |r| {
                    let theta = testing::gen::vec_normal(r, dim, 0.4);
                    let len = r.below(200) + 1; // duplicates allowed
                    let idx: Vec<u32> = (0..len).map(|_| r.below(n) as u32).collect();
                    (theta, idx)
                },
                |(theta, idx)| {
                    let cpu_before = cpu_counters.snapshot();
                    let par_before = par_counters.snapshot();
                    let (mut cll, mut clb) = (Vec::new(), Vec::new());
                    let (mut pll, mut plb) = (Vec::new(), Vec::new());
                    cpu.eval(theta, idx, &mut cll, &mut clb);
                    par.eval(theta, idx, &mut pll, &mut plb);
                    for i in 0..idx.len() {
                        if cll[i].to_bits() != pll[i].to_bits() {
                            return Err(format!("ll bits differ at {i}"));
                        }
                        if clb[i].to_bits() != plb[i].to_bits() {
                            return Err(format!("lb bits differ at {i}"));
                        }
                    }
                    let mut cg = vec![0.0; dim];
                    let mut pg = vec![0.0; dim];
                    cpu.eval_pseudo_grad(theta, idx, &mut cll, &mut clb, &mut cg);
                    par.eval_pseudo_grad(theta, idx, &mut pll, &mut plb, &mut pg);
                    if cll.iter().zip(&pll).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return Err("pseudo-grad ll bits differ".into());
                    }
                    for j in 0..dim {
                        if (cg[j] - pg[j]).abs() > 1e-9 * (1.0 + cg[j].abs()) {
                            return Err(format!("grad {j}: {} vs {}", cg[j], pg[j]));
                        }
                    }
                    cpu.eval_lik(theta, idx, &mut cll);
                    par.eval_lik(theta, idx, &mut pll);
                    if cll.iter().zip(&pll).any(|(a, b)| a.to_bits() != b.to_bits()) {
                        return Err("eval_lik bits differ".into());
                    }
                    let cpu_delta = cpu_before.delta(&cpu_counters.snapshot());
                    let par_delta = par_before.delta(&par_counters.snapshot());
                    if cpu_delta != par_delta {
                        return Err(format!("counters {cpu_delta:?} vs {par_delta:?}"));
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn gradients_deterministic_across_thread_counts() {
        let model: Arc<dyn ModelBound> =
            Arc::new(LogisticJJ::new(Arc::new(synth::synth_mnist(400, 9, 3)), 1.5));
        let mut one = ParBackend::with_threads(model.clone(), Counters::new(), 1).with_shard(32);
        let mut four = ParBackend::with_threads(model.clone(), Counters::new(), 4).with_shard(32);
        let mut rng = Rng::new(5);
        let dim = model.dim();
        let theta: Vec<f64> = (0..dim).map(|_| rng.normal() * 0.3).collect();
        let idx: Vec<u32> = (0..333).map(|_| rng.below(model.n()) as u32).collect();
        let (mut ll1, mut lb1) = (Vec::new(), Vec::new());
        let (mut ll4, mut lb4) = (Vec::new(), Vec::new());
        let mut g1 = vec![0.0; dim];
        let mut g4 = vec![0.0; dim];
        one.eval_pseudo_grad(&theta, &idx, &mut ll1, &mut lb1, &mut g1);
        four.eval_pseudo_grad(&theta, &idx, &mut ll4, &mut lb4, &mut g4);
        // identical shard size => identical reduction order => identical bits
        for (a, b) in g1.iter().zip(&g4) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut gl1 = vec![0.0; dim];
        let mut gl4 = vec![0.0; dim];
        one.eval_lik_grad(&theta, &idx, &mut ll1, &mut gl1);
        four.eval_lik_grad(&theta, &idx, &mut ll4, &mut gl4);
        for (a, b) in gl1.iter().zip(&gl4) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn scratch_pool_is_bounded_by_workers_not_batch_size() {
        // A batch of many shards must not materialize one scratch (and one
        // row cache) per shard — that made full-N init_z passes explode on
        // out-of-core stores.
        let model: Arc<dyn ModelBound> =
            Arc::new(LogisticJJ::new(Arc::new(synth::synth_mnist(2000, 5, 8)), 1.5));
        let counters = Counters::new();
        let mut par = ParBackend::with_threads(model.clone(), counters, 3).with_shard(8);
        let idx: Vec<u32> = (0..2000).collect(); // 250 shards
        let theta = vec![0.1; model.dim()];
        let (mut ll, mut lb) = (Vec::new(), Vec::new());
        par.eval(&theta, &idx, &mut ll, &mut lb);
        assert!(par.group_scratch.len() <= 3, "{} scratches", par.group_scratch.len());
        // ...while gradient partials stay per-shard (determinism anchor)
        let mut g = vec![0.0; model.dim()];
        par.eval_pseudo_grad(&theta, &idx, &mut ll, &mut lb, &mut g);
        assert_eq!(par.shard_grads.len(), 250 * model.dim());
    }

    #[test]
    fn empty_and_tiny_batches() {
        let model: Arc<dyn ModelBound> =
            Arc::new(LogisticJJ::new(Arc::new(synth::synth_mnist(50, 4, 7)), 1.5));
        let counters = Counters::new();
        let mut par = ParBackend::new(model.clone(), counters.clone());
        let theta = vec![0.1; model.dim()];
        let (mut ll, mut lb) = (Vec::new(), Vec::new());
        par.eval(&theta, &[], &mut ll, &mut lb);
        assert!(ll.is_empty() && lb.is_empty());
        assert_eq!(counters.lik_queries(), 0);
        par.eval(&theta, &[3], &mut ll, &mut lb);
        assert_eq!(ll.len(), 1);
        assert_eq!(counters.lik_queries(), 1);
        assert!(ll[0].is_finite() && lb[0] <= ll[0]);
    }
}
