//! Distributed likelihood backend: `BatchEval` over shard workers.
//!
//! [`DistBackend`] implements the exact same [`BatchEval`] contract as
//! [`CpuBackend`](super::CpuBackend), but evaluates each batch across
//! multi-process shard workers over TCP ([`crate::net`]) — either
//! spawned in-process over localhost (`--workers K`, each worker owning an
//! exact [`ModelBound::shard_model`] slice) or connected to standalone
//! `firefly worker` processes (`--connect host:port,...`), each serving
//! one `.fbin` shard from a `convert shard` manifest.
//!
//! ## Determinism (DESIGN.md §Distribution)
//!
//! The coordinator partitions the request's index set by shard ownership,
//! pipelines one request per shard (write all, then read all), and puts
//! every per-datum result back in the position the caller asked for:
//!
//! * per-point `log L_n` / `log B_n` values are composition-invariant —
//!   each worker computes the same tile bits the serial backend would,
//!   and scattering them back is pure placement;
//! * summed gradients are **not** reduced on the workers. Workers return
//!   per-datum gradient product rows (raw multiplies, never folded) and
//!   the coordinator replays the serial kernels' exact fold over the rows
//!   in original request order ([`crate::kernels::fold_grad_rows`]), so
//!   worker count and shard boundaries cannot touch a single bit of the
//!   gradient.
//!
//! Likelihood queries are metered here, once per datum per request —
//! identically to the serial backend, and never again on retry.
//!
//! ## Failure model
//!
//! Transport failures (timeout, reset, checksum mismatch) trigger a
//! bounded retry loop: back off, reconnect, re-handshake (the Hello
//! replays the full model spec including the current bound anchor, so a
//! restarted worker rebuilds bit-identical state), resend the same
//! idempotent request. Only after `retries` consecutive failures does the
//! chain abort — at which point the run's `.fckpt` checkpoint resumes it
//! byte-identically. Worker-reported *semantic* errors (bad index, shape
//! mismatch) abort immediately: retrying cannot fix a wrong request.

use std::io;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::Duration;

use super::evaluator::BatchEval;
use crate::data::fbin::LabelKind;
use crate::data::shard::ShardManifest;
use crate::kernels::fold_grad_rows;
use crate::metrics::{Counters, WireStats};
use crate::models::{ModelBound, ModelKind};
use crate::net::frame::{read_frame, write_frame};
use crate::net::protocol::{
    check_response, encode_eval, encode_hello, encode_set_anchor, HelloAck, ModelSpec,
    OP_EVAL_BOTH, OP_EVAL_LIK, OP_EVAL_LIK_GRAD_ROWS, OP_EVAL_PSEUDO_GRAD_ROWS,
};
use crate::net::worker::{spawn_local_workers, WorkerHandle};

/// Execution-topology knobs for [`DistBackend`] — deliberately **not**
/// part of the config fingerprint: they choose where the arithmetic runs,
/// never what it computes (the dist backend shares the `cpu` fingerprint
/// family).
#[derive(Clone, Debug)]
pub struct DistOptions {
    /// spawn this many in-process localhost workers (0 = use `connect`)
    pub workers: usize,
    /// addresses of standalone `firefly worker` processes
    pub connect: Vec<String>,
    /// per-request I/O timeout in milliseconds (0 = block forever)
    pub timeout_ms: u64,
    /// bounded retry attempts per request after a transport failure
    pub retries: u32,
    /// sleep between retry attempts, milliseconds
    pub retry_backoff_ms: u64,
    /// optional shard-manifest path for startup cross-validation
    pub manifest: Option<String>,
    /// untuned logistic JJ anchor ξ (must match the workers' model build)
    pub untuned_xi: f64,
    /// robust-t degrees of freedom ν
    pub nu: f64,
    /// robust-t scale σ
    pub sigma: f64,
    /// shared transport tallies (wire bytes, retries, reconnects)
    pub wire: WireStats,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            workers: 0,
            connect: Vec::new(),
            timeout_ms: 5000,
            retries: 3,
            retry_backoff_ms: 200,
            manifest: None,
            untuned_xi: 1.5,
            nu: 4.0,
            sigma: 0.5,
            wire: WireStats::new(),
        }
    }
}

/// One worker connection plus its per-batch staging buffers.
struct ShardConn {
    addr: String,
    start: usize,
    end: usize,
    stream: Option<TcpStream>,
    /// shard-local indices of this batch's data owned by this worker
    local_idx: Vec<u32>,
    /// output positions (into the caller's buffers) of those indices
    pos: Vec<u32>,
    /// outstanding request (kept for idempotent resend on retry)
    req_id: u64,
    payload: Vec<u8>,
    /// response payload buffer
    resp: Vec<u8>,
    /// whether the outstanding request was written successfully
    sent_ok: bool,
}

/// Distributed [`BatchEval`] backend (see the module docs). Outputs,
/// query counters, and therefore whole chains are byte-identical to
/// [`CpuBackend`](super::CpuBackend) at any worker count.
pub struct DistBackend {
    model: Arc<dyn ModelBound>,
    counters: Counters,
    wire: WireStats,
    spec: ModelSpec,
    shards: Vec<ShardConn>,
    timeout: Option<Duration>,
    retries: u32,
    backoff: Duration,
    next_req_id: u64,
    /// keeps in-process workers alive for the backend's lifetime
    _local: Vec<WorkerHandle>,
    // reusable decode/staging buffers
    tmp_ll: Vec<f64>,
    tmp_lb: Vec<f64>,
    tmp_rows: Vec<f64>,
    rows_stage: Vec<f64>,
}

fn kind_matches(kind: ModelKind, label: LabelKind) -> bool {
    matches!(
        (kind, label),
        (ModelKind::Logistic, LabelKind::Binary)
            | (ModelKind::Softmax, LabelKind::Class)
            | (ModelKind::Robust, LabelKind::Target)
    )
}

impl DistBackend {
    /// Build the distributed backend: spawn or connect the workers,
    /// handshake each, and validate that together they own exactly
    /// `0..model.n()` (cross-checked against the manifest when given).
    pub fn new(
        model: Arc<dyn ModelBound>,
        counters: Counters,
        opts: &DistOptions,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            (opts.workers > 0) != (!opts.connect.is_empty()),
            "dist backend needs either workers > 0 or a connect list, not both"
        );
        let k = model.n_classes();
        let spec = ModelSpec {
            kind: model.kind(),
            n: model.n(),
            d: model.dim() / k,
            k,
            xi_const: opts.untuned_xi,
            nu: opts.nu,
            sigma: opts.sigma,
            anchor: model.anchor_theta().map(<[f64]>::to_vec),
        };

        let (placements, local): (Vec<(String, usize, usize)>, Vec<WorkerHandle>) =
            if opts.workers > 0 {
                let handles = spawn_local_workers(&model, opts.workers)
                    .map_err(|e| anyhow::anyhow!(e))?;
                let p = handles
                    .iter()
                    .map(|h| (h.addr.to_string(), h.start, h.end))
                    .collect();
                (p, handles)
            } else {
                // placement discovered from each worker's Hello ack
                (opts.connect.iter().map(|a| (a.clone(), usize::MAX, 0)).collect(), Vec::new())
            };

        let mut be = DistBackend {
            model,
            counters,
            wire: opts.wire.clone(),
            spec,
            shards: placements
                .into_iter()
                .map(|(addr, start, end)| ShardConn {
                    addr,
                    start,
                    end,
                    stream: None,
                    local_idx: Vec::new(),
                    pos: Vec::new(),
                    req_id: 0,
                    payload: Vec::new(),
                    resp: Vec::new(),
                    sent_ok: false,
                })
                .collect(),
            timeout: (opts.timeout_ms > 0).then(|| Duration::from_millis(opts.timeout_ms)),
            retries: opts.retries.max(1),
            backoff: Duration::from_millis(opts.retry_backoff_ms),
            next_req_id: 0,
            _local: local,
            tmp_ll: Vec::new(),
            tmp_lb: Vec::new(),
            tmp_rows: Vec::new(),
            rows_stage: Vec::new(),
        };

        for si in 0..be.shards.len() {
            be.connect_shard(si)
                .map_err(|e| anyhow::anyhow!("worker {}: {e}", be.shards[si].addr))?;
        }
        be.shards.sort_by_key(|s| s.start);
        be.validate_coverage().map_err(|e| anyhow::anyhow!(e))?;
        if let Some(path) = &opts.manifest {
            let manifest = ShardManifest::load(path).map_err(|e| anyhow::anyhow!(e))?;
            be.validate_manifest(&manifest).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        }
        Ok(be)
    }

    /// Every row of `0..n` owned by exactly one worker.
    fn validate_coverage(&self) -> Result<(), String> {
        if self.shards.is_empty() {
            return Err("no workers".to_string());
        }
        if self.shards[0].start != 0 {
            return Err(format!("first shard starts at {}, not 0", self.shards[0].start));
        }
        for w in self.shards.windows(2) {
            if w[0].end != w[1].start {
                return Err(format!(
                    "worker {} ends at {} but worker {} starts at {} — shard ranges must tile",
                    w[0].addr, w[0].end, w[1].addr, w[1].start
                ));
            }
        }
        let last = self.shards.last().unwrap();
        if last.end != self.model.n() {
            return Err(format!(
                "workers cover 0..{} but the model holds {} rows",
                last.end,
                self.model.n()
            ));
        }
        Ok(())
    }

    /// Cross-check worker placement and model shape against a manifest.
    fn validate_manifest(&self, m: &ShardManifest) -> Result<(), String> {
        if !kind_matches(self.spec.kind, m.kind) {
            return Err(format!(
                "manifest is for {} data, model is {}",
                m.kind.name(),
                self.spec.kind.as_str()
            ));
        }
        if m.n != self.spec.n || m.d != self.spec.d || m.k != self.spec.k {
            return Err(format!(
                "manifest shape (n={}, d={}, k={}) does not match the model \
                 (n={}, d={}, k={})",
                m.n, m.d, m.k, self.spec.n, self.spec.d, self.spec.k
            ));
        }
        if m.shards.len() != self.shards.len() {
            return Err(format!(
                "manifest lists {} shards but {} workers are connected",
                m.shards.len(),
                self.shards.len()
            ));
        }
        for (s, e) in self.shards.iter().zip(&m.shards) {
            if s.start != e.start || s.end != e.end {
                return Err(format!(
                    "worker {} claims rows {}..{} but the manifest assigns {}..{}",
                    s.addr, s.start, s.end, e.start, e.end
                ));
            }
        }
        Ok(())
    }

    fn next_id(&mut self) -> u64 {
        self.next_req_id += 1;
        self.next_req_id
    }

    /// Open a fresh connection to shard `si` and run the Hello handshake
    /// (replays the current spec, so a restarted worker re-anchors).
    fn connect_shard(&mut self, si: usize) -> io::Result<()> {
        self.shards[si].stream = None;
        let addr_str = self.shards[si].addr.clone();
        let stream = match self.timeout {
            Some(t) => {
                let addr = addr_str
                    .to_socket_addrs()?
                    .next()
                    .ok_or_else(|| io::Error::new(io::ErrorKind::NotFound, addr_str.clone()))?;
                TcpStream::connect_timeout(&addr, t)?
            }
            None => TcpStream::connect(&*addr_str)?,
        };
        stream.set_nodelay(true)?;
        stream.set_read_timeout(self.timeout)?;
        stream.set_write_timeout(self.timeout)?;
        self.shards[si].stream = Some(stream);

        let req_id = self.next_id();
        let hello = encode_hello(req_id, &self.spec);
        self.write_to(si, &hello)?;
        self.read_from(si)?;
        let ack = {
            let s = &self.shards[si];
            let mut r = check_response(&s.resp, req_id)
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?;
            HelloAck::decode(&mut r)
                .and_then(|a| r.finish().map(|()| a))
                .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))?
        };
        let s = &mut self.shards[si];
        if s.start == usize::MAX {
            // discovery (connect mode): adopt the worker's claimed range;
            // validate_coverage then proves the claims tile 0..n
            s.start = ack.start;
            s.end = ack.end;
        } else if ack.start != s.start || ack.end != s.end {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "worker claims rows {}..{}, expected {}..{}",
                    ack.start, ack.end, s.start, s.end
                ),
            ));
        }
        if ack.n != self.spec.n || ack.dim != self.spec.d * self.spec.k {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "worker model shape (n={}, dim={}) does not match (n={}, dim={})",
                    ack.n,
                    ack.dim,
                    self.spec.n,
                    self.spec.d * self.spec.k
                ),
            ));
        }
        Ok(())
    }

    fn write_to(&mut self, si: usize, payload: &[u8]) -> io::Result<()> {
        let wire = self.wire.clone();
        let s = &mut self.shards[si];
        let stream =
            s.stream.as_mut().ok_or_else(|| io::Error::from(io::ErrorKind::NotConnected))?;
        match write_frame(stream, payload) {
            Ok(sent) => {
                wire.add_request();
                wire.add_sent(sent as u64);
                Ok(())
            }
            Err(e) => {
                s.stream = None;
                Err(e)
            }
        }
    }

    fn read_from(&mut self, si: usize) -> io::Result<()> {
        let wire = self.wire.clone();
        let s = &mut self.shards[si];
        let stream =
            s.stream.as_mut().ok_or_else(|| io::Error::from(io::ErrorKind::NotConnected))?;
        let mut resp = std::mem::take(&mut s.resp);
        let result = read_frame(stream, &mut resp);
        s.resp = resp;
        match result {
            Ok(got) => {
                wire.add_received(got as u64);
                Ok(())
            }
            Err(e) => {
                s.stream = None;
                Err(e)
            }
        }
    }

    /// Split the caller's index set by shard ownership, remembering each
    /// datum's output position. Ranges are sorted and tiling, so ownership
    /// is a binary search.
    fn partition(&mut self, idx: &[u32]) {
        for s in &mut self.shards {
            s.local_idx.clear();
            s.pos.clear();
        }
        let n = self.model.n();
        for (i, &g) in idx.iter().enumerate() {
            let gi = g as usize;
            assert!(gi < n, "datum index {gi} out of range (N = {n})");
            let si = self.shards.partition_point(|s| s.end <= gi);
            let s = &mut self.shards[si];
            s.local_idx.push(g - s.start as u32);
            s.pos.push(i as u32);
        }
    }

    /// Phase 1 of the pipeline: encode and write one request per active
    /// shard. Write failures are deferred to the read phase's retry loop.
    fn send_all(&mut self, op: u8, theta: &[f64]) {
        for si in 0..self.shards.len() {
            if self.shards[si].local_idx.is_empty() {
                self.shards[si].sent_ok = false;
                continue;
            }
            let req_id = self.next_id();
            let s = &mut self.shards[si];
            s.req_id = req_id;
            s.payload = encode_eval(req_id, op, theta, &s.local_idx);
            let payload = std::mem::take(&mut self.shards[si].payload);
            self.shards[si].sent_ok = self.write_to(si, &payload).is_ok();
            self.shards[si].payload = payload;
        }
    }

    /// Phase 2, per shard: collect the response, falling back to the
    /// bounded reconnect/resend/re-read loop on any transport failure.
    /// Panics (aborting the chain) when a worker stays unreachable.
    fn recv(&mut self, si: usize) {
        let mut last_err: io::Error;
        if self.shards[si].sent_ok {
            match self.read_from(si) {
                Ok(()) => return,
                Err(e) => last_err = e,
            }
        } else {
            last_err = io::Error::from(io::ErrorKind::NotConnected);
        }
        for _ in 0..self.retries {
            self.wire.add_retry();
            std::thread::sleep(self.backoff);
            self.wire.add_reconnect();
            if let Err(e) = self.connect_shard(si) {
                last_err = e;
                continue;
            }
            let payload = std::mem::take(&mut self.shards[si].payload);
            let sent = self.write_to(si, &payload);
            self.shards[si].payload = payload;
            if let Err(e) = sent {
                last_err = e;
                continue;
            }
            match self.read_from(si) {
                Ok(()) => return,
                Err(e) => last_err = e,
            }
        }
        panic!(
            "dist backend: worker {} unreachable after {} retries: {last_err} \
             (the chain can be resumed from its last checkpoint)",
            self.shards[si].addr, self.retries
        );
    }

    /// Unwrap shard `si`'s response status/req-id, leaving the payload
    /// available, and hand back an owned copy-free reader position via a
    /// callback. Semantic errors abort the chain.
    fn take_resp(&mut self, si: usize) -> (Vec<u8>, u64) {
        let s = &mut self.shards[si];
        (std::mem::take(&mut s.resp), s.req_id)
    }

    fn put_resp(&mut self, si: usize, resp: Vec<u8>) {
        self.shards[si].resp = resp;
    }

    /// Run one eval op end to end over the already-partitioned batch:
    /// send to all shards, then per shard (in ascending-range order)
    /// receive, decode `n_vals` f64 slices into the tmp buffers, and
    /// scatter/stage through `scatter(self, si)`.
    fn exchange(
        &mut self,
        op: u8,
        theta: &[f64],
        n_vals: usize,
        mut scatter: impl FnMut(&mut Self, usize),
    ) {
        self.send_all(op, theta);
        for si in 0..self.shards.len() {
            if self.shards[si].local_idx.is_empty() {
                continue;
            }
            self.recv(si);
            let (resp, req_id) = self.take_resp(si);
            {
                let mut r = check_response(&resp, req_id).unwrap_or_else(|e| {
                    panic!("dist backend: worker {}: {e}", self.shards[si].addr)
                });
                let body: Result<(), String> = (|| {
                    if n_vals >= 1 {
                        r.f64_slice_into(&mut self.tmp_ll)?;
                    }
                    if n_vals >= 2 {
                        r.f64_slice_into(&mut self.tmp_lb)?;
                    }
                    if n_vals >= 3 {
                        r.f64_slice_into(&mut self.tmp_rows)?;
                    }
                    r.finish()
                })();
                body.unwrap_or_else(|e| {
                    panic!("dist backend: worker {}: bad response body: {e}", self.shards[si].addr)
                });
            }
            self.put_resp(si, resp);
            scatter(self, si);
        }
    }
}

impl BatchEval for DistBackend {
    fn n(&self) -> usize {
        self.model.n()
    }
    fn dim(&self) -> usize {
        self.model.dim()
    }
    fn counters(&self) -> &Counters {
        &self.counters
    }

    fn eval(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>, lb: &mut Vec<f64>) {
        self.counters.add_lik(idx.len() as u64);
        self.counters.add_bound(idx.len() as u64);
        ll.clear();
        lb.clear();
        ll.resize(idx.len(), 0.0);
        lb.resize(idx.len(), 0.0);
        self.partition(idx);
        self.exchange(OP_EVAL_BOTH, theta, 2, |be, si| {
            let s = &be.shards[si];
            for (j, &p) in s.pos.iter().enumerate() {
                ll[p as usize] = be.tmp_ll[j];
                lb[p as usize] = be.tmp_lb[j];
            }
        });
    }

    fn eval_pseudo_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        lb: &mut Vec<f64>,
        grad: &mut [f64],
    ) {
        self.counters.add_lik(idx.len() as u64);
        self.counters.add_bound(idx.len() as u64);
        ll.clear();
        lb.clear();
        ll.resize(idx.len(), 0.0);
        lb.resize(idx.len(), 0.0);
        let dim = self.model.dim();
        self.rows_stage.clear();
        self.rows_stage.resize(idx.len() * dim, 0.0);
        self.partition(idx);
        self.exchange(OP_EVAL_PSEUDO_GRAD_ROWS, theta, 3, |be, si| {
            let s = &be.shards[si];
            for (j, &p) in s.pos.iter().enumerate() {
                let p = p as usize;
                ll[p] = be.tmp_ll[j];
                lb[p] = be.tmp_lb[j];
            }
            stage_rows(&mut be.rows_stage, &be.tmp_rows, &s.pos, dim);
        });
        fold_grad_rows(&self.rows_stage, dim, grad);
    }

    fn eval_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>) {
        self.counters.add_lik(idx.len() as u64);
        ll.clear();
        ll.resize(idx.len(), 0.0);
        self.partition(idx);
        self.exchange(OP_EVAL_LIK, theta, 1, |be, si| {
            let s = &be.shards[si];
            for (j, &p) in s.pos.iter().enumerate() {
                ll[p as usize] = be.tmp_ll[j];
            }
        });
    }

    fn eval_lik_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
    ) {
        self.counters.add_lik(idx.len() as u64);
        ll.clear();
        ll.resize(idx.len(), 0.0);
        let dim = self.model.dim();
        self.rows_stage.clear();
        self.rows_stage.resize(idx.len() * dim, 0.0);
        self.partition(idx);
        self.exchange(OP_EVAL_LIK_GRAD_ROWS, theta, 3, |be, si| {
            let s = &be.shards[si];
            for (j, &p) in s.pos.iter().enumerate() {
                ll[p as usize] = be.tmp_ll[j];
            }
            stage_rows(&mut be.rows_stage, &be.tmp_rows, &s.pos, dim);
        });
        fold_grad_rows(&self.rows_stage, dim, grad);
    }

    fn set_model(&mut self, model: Arc<dyn ModelBound>) -> bool {
        if model.n() != self.spec.n
            || model.dim() != self.spec.d * self.spec.k
            || model.kind() != self.spec.kind
        {
            return false;
        }
        let Some(anchor) = model.anchor_theta().map(<[f64]>::to_vec) else {
            // re-anchoring always installs a tuned model; a spec without an
            // anchor cannot be broadcast retroactively
            return false;
        };
        self.model = model;
        // updating the spec FIRST makes transport failures below harmless:
        // any failed write/read drops that worker's stream, and the next
        // eval's reconnect Hello replays this anchor before serving
        self.spec.anchor = Some(anchor.clone());
        for si in 0..self.shards.len() {
            let req_id = self.next_id();
            let payload = encode_set_anchor(req_id, &anchor);
            if self.write_to(si, &payload).is_err() {
                continue; // stream dropped; reconnect will re-anchor
            }
            if self.read_from(si).is_err() {
                continue; // ditto
            }
            let (resp, _) = self.take_resp(si);
            let ok = match check_response(&resp, req_id) {
                Ok(mut r) => r.finish().is_ok(),
                // a worker that *refuses* the anchor (semantic error, not
                // transport) means the swap cannot be honored
                Err(_) => false,
            };
            self.put_resp(si, resp);
            if !ok {
                return false;
            }
        }
        true
    }
}

/// Copy each response row `j` (worker order) into the staging buffer at
/// its original request position `pos[j]` — placement only, no arithmetic.
fn stage_rows(stage: &mut [f64], rows: &[f64], pos: &[u32], dim: usize) {
    debug_assert_eq!(rows.len(), pos.len() * dim);
    for (j, &p) in pos.iter().enumerate() {
        let src = &rows[j * dim..(j + 1) * dim];
        let dst = &mut stage[p as usize * dim..(p as usize + 1) * dim];
        dst.copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::models::LogisticJJ;
    use crate::runtime::CpuBackend;
    use crate::util::Rng;

    fn logistic_model(n: usize, d: usize, seed: u64) -> Arc<dyn ModelBound> {
        Arc::new(LogisticJJ::new(Arc::new(synth::synth_mnist(n, d, seed)), 1.5))
    }

    fn opts(workers: usize) -> DistOptions {
        DistOptions { workers, ..DistOptions::default() }
    }

    #[test]
    fn matches_cpu_backend_bitwise_on_random_batches() {
        let model = logistic_model(200, 6, 11);
        let mut cpu = CpuBackend::new(model.clone(), Counters::new());
        for workers in [1usize, 2, 4] {
            let mut dist =
                DistBackend::new(model.clone(), Counters::new(), &opts(workers)).unwrap();
            let mut rng = Rng::new(42 + workers as u64);
            let dim = model.dim();
            let (mut ll_a, mut lb_a) = (Vec::new(), Vec::new());
            let (mut ll_b, mut lb_b) = (Vec::new(), Vec::new());
            for round in 0..8 {
                let theta: Vec<f64> =
                    (0..dim).map(|_| rng.normal() * 0.2).collect();
                let batch = 1 + (rng.next_u64() as usize) % 150;
                let idx: Vec<u32> =
                    (0..batch).map(|_| (rng.next_u64() % 200) as u32).collect();
                let mut grad_a = vec![0.0; dim];
                let mut grad_b = vec![0.0; dim];
                cpu.eval_pseudo_grad(&theta, &idx, &mut ll_a, &mut lb_a, &mut grad_a);
                dist.eval_pseudo_grad(&theta, &idx, &mut ll_b, &mut lb_b, &mut grad_b);
                for (a, b) in ll_a.iter().zip(&ll_b) {
                    assert_eq!(a.to_bits(), b.to_bits(), "ll, {workers} workers");
                }
                for (a, b) in lb_a.iter().zip(&lb_b) {
                    assert_eq!(a.to_bits(), b.to_bits(), "lb, {workers} workers");
                }
                for (a, b) in grad_a.iter().zip(&grad_b) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "grad, {workers} workers, round {round}"
                    );
                }
                cpu.eval(&theta, &idx, &mut ll_a, &mut lb_a);
                dist.eval(&theta, &idx, &mut ll_b, &mut lb_b);
                assert_eq!(ll_a, ll_b);
                assert_eq!(lb_a, lb_b);
            }
            // identical batches ⇒ identical query metering, at any worker count
            assert_eq!(cpu.counters().totals(), dist.counters().totals());
            cpu.counters().reset();
        }
    }

    #[test]
    fn wire_stats_accumulate() {
        let model = logistic_model(64, 4, 3);
        let o = opts(2);
        let mut dist = DistBackend::new(model.clone(), Counters::new(), &o).unwrap();
        let theta = vec![0.1; model.dim()];
        let mut ll = Vec::new();
        dist.eval_lik(&theta, &[0, 13, 40, 63], &mut ll);
        assert!(o.wire.bytes_sent() > 0);
        assert!(o.wire.bytes_received() > 0);
        assert!(o.wire.requests() >= 4, "2 hellos + 2 evals");
        assert_eq!(o.wire.retries(), 0);
    }

    #[test]
    fn rejects_workers_and_connect_together() {
        let model = logistic_model(10, 2, 1);
        let mut o = opts(2);
        o.connect = vec!["127.0.0.1:1".to_string()];
        assert!(DistBackend::new(model.clone(), Counters::new(), &o).is_err());
        let o = opts(0);
        assert!(DistBackend::new(model, Counters::new(), &o).is_err());
    }
}
