//! How each model feeds the fixed-shape XLA artifacts.
//!
//! The AOT graphs (python/compile/aot.py) take `(theta, x, aux1, aux2, mask)`
//! with model-specific aux buffers; [`XlaSource`] produces those buffers for
//! a padded index chunk. The robust model uses the sigma-rescaling identity
//! (feed x/σ, y/σ, u0/σ² into the σ=1 artifact; shift log-densities by
//! -log σ — gradients come out exact, see python/tests/test_kernels.py
//! `test_t_sigma_rescale_identity`).

use std::sync::Arc;

use crate::data::store::{DataStore, RowCache};
use crate::kernels::W;
use crate::models::{LogisticJJ, ModelBound, ModelKind, RobustT, SoftmaxBohning};

/// Input buffers for one padded chunk, in artifact argument order after
/// theta: `x` then aux1, aux2, mask (flattened row-major).
#[derive(Debug, Default)]
pub struct BatchBufs {
    /// `[bucket, D]` features, flattened
    pub x: Vec<f64>,
    /// first aux buffer (`[bucket]` or `[bucket, K]`)
    pub aux1: Vec<f64>,
    /// second aux buffer (`[bucket]` or `[bucket, K]`)
    pub aux2: Vec<f64>,
    /// 1.0 for live lanes, 0.0 for padding
    pub mask: Vec<f64>,
    /// W-lane gather scratch (`D × W` column-major, see `DataStore::gather_tile`)
    tile: Vec<f64>,
}

impl BatchBufs {
    /// Append `idx`'s feature rows to `x`, each element scaled by `scale`:
    /// rows come in through the same [`DataStore::gather_tile`] path the
    /// CPU kernels use (W lanes at a time, identical reads in identical
    /// order), then transpose back to the artifact's row-major layout.
    /// `scale = 1.0` reproduces the raw row bits exactly.
    fn gather_rows(&mut self, store: &DataStore, idx: &[u32], scale: f64, rows: &mut RowCache) {
        let d = store.d();
        self.tile.resize(d * W, 0.0);
        for chunk in idx.chunks(W) {
            store.gather_tile(chunk, rows, &mut self.tile);
            for l in 0..chunk.len() {
                for j in 0..d {
                    self.x.push(self.tile[j * W + l] * scale);
                }
            }
        }
    }

    /// Assert the filled buffers match the artifact's `(bucket, d, aux_w)`
    /// shape — backends call this before handing pointers to PJRT (or, in
    /// the stub, before faking an execution).
    pub fn check_shape(&self, bucket: usize, d: usize, aux_w: usize) {
        assert_eq!(self.x.len(), bucket * d, "x buffer shape");
        assert_eq!(self.aux1.len(), bucket * aux_w, "aux1 buffer shape");
        assert_eq!(self.aux2.len(), bucket * aux_w, "aux2 buffer shape");
        assert_eq!(self.mask.len(), bucket, "mask buffer shape");
    }
}

/// A model that can feed the fixed-shape XLA artifacts (see module docs).
pub trait XlaSource: ModelBound {
    /// (kind, d, k) used to look up artifacts in the manifest.
    fn artifact_key(&self) -> (ModelKind, usize, usize);

    /// Upcast to the plain model interface. Implemented as `self` by every
    /// concrete model (where the unsize coercion is always available);
    /// callers holding an `Arc<dyn XlaSource>` go through this instead of a
    /// dyn-to-dyn upcast so the crate does not depend on trait-upcasting
    /// toolchain support.
    fn as_model_bound(self: Arc<Self>) -> Arc<dyn ModelBound>;

    /// A feature-row cache sized for this model's [`crate::data::store::DataStore`]
    /// (zero-sized for resident data); the XLA backend owns one and threads
    /// it through [`Self::fill_inputs`].
    fn new_row_cache(&self) -> RowCache;

    /// Fill `bufs` for `idx` (u32, as handed through [`crate::runtime::evaluator::BatchEval`]),
    /// padded to `bucket` rows (mask 0 on padding). Feature rows are read
    /// through the caller-owned `rows` cache.
    fn fill_inputs(&self, idx: &[u32], bucket: usize, bufs: &mut BatchBufs, rows: &mut RowCache);

    /// Dims of aux1/aux2 per row (1 for vectors, K for [B,K] buffers).
    fn aux_width(&self) -> usize {
        1
    }

    /// Constant subtracted from each live lane of the returned log L / log B
    /// (sigma rescaling for the robust model; 0 otherwise).
    fn output_shift(&self) -> f64 {
        0.0
    }
}

fn pad_common(bufs: &mut BatchBufs, d: usize, aux_w: usize, bucket: usize) {
    bufs.x.clear();
    bufs.x.reserve(bucket * d);
    bufs.aux1.clear();
    bufs.aux1.reserve(bucket * aux_w);
    bufs.aux2.clear();
    bufs.aux2.reserve(bucket * aux_w);
    bufs.mask.clear();
    bufs.mask.reserve(bucket);
}

impl XlaSource for LogisticJJ {
    fn artifact_key(&self) -> (ModelKind, usize, usize) {
        (ModelKind::Logistic, self.data.d(), 1)
    }

    fn as_model_bound(self: Arc<Self>) -> Arc<dyn ModelBound> {
        self
    }

    fn new_row_cache(&self) -> RowCache {
        self.data.x.new_cache()
    }

    fn fill_inputs(&self, idx: &[u32], bucket: usize, bufs: &mut BatchBufs, rows: &mut RowCache) {
        let d = self.data.d();
        pad_common(bufs, d, 1, bucket);
        bufs.gather_rows(&self.data.x, idx, 1.0, rows);
        for &n in idx {
            let n = n as usize;
            bufs.aux1.push(self.data.t[n]);
            bufs.aux2.push(self.xi[n]);
            bufs.mask.push(1.0);
        }
        for _ in idx.len()..bucket {
            bufs.x.extend(std::iter::repeat(0.0).take(d));
            bufs.aux1.push(1.0);
            bufs.aux2.push(1.0);
            bufs.mask.push(0.0);
        }
        bufs.check_shape(bucket, d, 1);
    }
}

impl XlaSource for SoftmaxBohning {
    fn artifact_key(&self) -> (ModelKind, usize, usize) {
        (ModelKind::Softmax, self.data.d(), self.data.k)
    }

    fn as_model_bound(self: Arc<Self>) -> Arc<dyn ModelBound> {
        self
    }

    fn aux_width(&self) -> usize {
        self.data.k
    }

    fn new_row_cache(&self) -> RowCache {
        self.data.x.new_cache()
    }

    fn fill_inputs(&self, idx: &[u32], bucket: usize, bufs: &mut BatchBufs, rows: &mut RowCache) {
        let d = self.data.d();
        let k = self.data.k;
        pad_common(bufs, d, k, bucket);
        bufs.gather_rows(&self.data.x, idx, 1.0, rows);
        for &n in idx {
            let n = n as usize;
            for kk in 0..k {
                bufs.aux1
                    .push(if kk == self.data.labels[n] { 1.0 } else { 0.0 });
            }
            bufs.aux2.extend_from_slice(&self.psi[n * k..(n + 1) * k]);
            bufs.mask.push(1.0);
        }
        for _ in idx.len()..bucket {
            bufs.x.extend(std::iter::repeat(0.0).take(d));
            bufs.aux1.push(1.0);
            bufs.aux1.extend(std::iter::repeat(0.0).take(k - 1));
            bufs.aux2.extend(std::iter::repeat(0.0).take(k));
            bufs.mask.push(0.0);
        }
        bufs.check_shape(bucket, d, k);
    }
}

impl XlaSource for RobustT {
    fn artifact_key(&self) -> (ModelKind, usize, usize) {
        (ModelKind::Robust, self.data.d(), 1)
    }

    fn as_model_bound(self: Arc<Self>) -> Arc<dyn ModelBound> {
        self
    }

    fn output_shift(&self) -> f64 {
        self.sigma.ln()
    }

    fn new_row_cache(&self) -> RowCache {
        self.data.x.new_cache()
    }

    fn fill_inputs(&self, idx: &[u32], bucket: usize, bufs: &mut BatchBufs, rows: &mut RowCache) {
        let d = self.data.d();
        let inv_s = 1.0 / self.sigma;
        pad_common(bufs, d, 1, bucket);
        bufs.gather_rows(&self.data.x, idx, inv_s, rows);
        for &n in idx {
            let n = n as usize;
            bufs.aux1.push(self.data.y[n] * inv_s);
            bufs.aux2.push(self.u0[n] * inv_s * inv_s);
            bufs.mask.push(1.0);
        }
        for _ in idx.len()..bucket {
            bufs.x.extend(std::iter::repeat(0.0).take(d));
            bufs.aux1.push(0.0);
            bufs.aux2.push(1.0);
            bufs.mask.push(0.0);
        }
        bufs.check_shape(bucket, d, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use std::sync::Arc;

    #[test]
    fn logistic_fill_pads_correctly() {
        let data = Arc::new(synth::synth_mnist(20, 4, 1));
        let m = LogisticJJ::new(data, 1.5);
        let mut bufs = BatchBufs::default();
        let mut rows = m.new_row_cache();
        m.fill_inputs(&[3, 7], 8, &mut bufs, &mut rows);
        assert_eq!(bufs.x.len(), 8 * 5);
        assert_eq!(bufs.mask, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(bufs.aux1[0], m.data.t[3]);
        assert_eq!(&bufs.x[..5], m.data.x.as_dense().unwrap().row(3));
    }

    #[test]
    fn softmax_onehot_rows() {
        let data = Arc::new(synth::synth_cifar3(30, 6, 2));
        let m = SoftmaxBohning::new(data.clone());
        let mut bufs = BatchBufs::default();
        let mut rows = m.new_row_cache();
        m.fill_inputs(&[0, 1, 2], 4, &mut bufs, &mut rows);
        assert_eq!(bufs.aux1.len(), 4 * 3);
        for (i, &n) in [0usize, 1, 2].iter().enumerate() {
            let row = &bufs.aux1[i * 3..(i + 1) * 3];
            assert_eq!(row.iter().sum::<f64>(), 1.0);
            assert_eq!(row[data.labels[n]], 1.0);
        }
    }

    #[test]
    fn fill_crosses_tile_boundaries_bit_exactly() {
        // 11 live rows = one full W-lane tile plus a 3-lane remainder; the
        // transposed gather must reproduce every row's bits in row-major x.
        let data = Arc::new(synth::synth_mnist(40, 6, 9));
        let m = LogisticJJ::new(data, 1.5);
        let mut bufs = BatchBufs::default();
        let mut rows = m.new_row_cache();
        let idx: Vec<u32> = (0..11).map(|i| (i * 3) as u32).collect();
        let d = m.data.d();
        m.fill_inputs(&idx, 16, &mut bufs, &mut rows);
        let dense = m.data.x.as_dense().unwrap();
        for (i, &n) in idx.iter().enumerate() {
            for j in 0..d {
                assert_eq!(
                    bufs.x[i * d + j].to_bits(),
                    dense.row(n as usize)[j].to_bits(),
                    "row {i} feature {j}"
                );
            }
        }
        bufs.check_shape(16, d, 1);
    }

    #[test]
    #[should_panic(expected = "x buffer shape")]
    fn check_shape_rejects_wrong_bucket() {
        let data = Arc::new(synth::synth_mnist(10, 4, 5));
        let m = LogisticJJ::new(data, 1.5);
        let mut bufs = BatchBufs::default();
        let mut rows = m.new_row_cache();
        m.fill_inputs(&[1, 2], 4, &mut bufs, &mut rows);
        bufs.check_shape(8, m.data.d(), 1); // wrong bucket
    }

    #[test]
    fn robust_rescales_by_sigma() {
        let data = Arc::new(synth::synth_opv(25, 5, 3));
        let m = RobustT::new(data.clone(), 4.0, 2.0);
        let mut bufs = BatchBufs::default();
        let mut rows = m.new_row_cache();
        m.fill_inputs(&[4], 2, &mut bufs, &mut rows);
        assert!((bufs.aux1[0] - data.y[4] / 2.0).abs() < 1e-15);
        assert!((bufs.x[0] - data.x.get(4, 0) / 2.0).abs() < 1e-15);
        assert!((m.output_shift() - 2.0f64.ln()).abs() < 1e-15);
    }
}
