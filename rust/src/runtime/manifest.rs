//! `artifacts/manifest.txt` parsing — the contract between `python -m
//! compile.aot` and the Rust runtime. One line per artifact:
//!
//! ```text
//! name=logistic.d51.b2048 kind=logistic d=51 k=1 bucket=2048 path=logistic.d51.b2048.hlo.txt
//! ```

use crate::models::ModelKind;

/// One line of `manifest.txt`: a compiled artifact for a model shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// artifact name (e.g. `logistic.d51.b2048`)
    pub name: String,
    /// model family
    pub kind: ModelKind,
    /// feature dimension
    pub d: usize,
    /// softmax classes (1 for non-softmax)
    pub k: usize,
    /// padded batch size
    pub bucket: usize,
    /// artifact file path relative to the manifest directory
    pub path: String,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// all artifacts, in file order
    pub entries: Vec<ArtifactEntry>,
    /// the directory the manifest was loaded from
    pub dir: String,
}

impl Manifest {
    /// Parse manifest text; `dir` is recorded for [`Manifest::full_path`].
    pub fn parse(text: &str, dir: &str) -> Result<Manifest, String> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut name = None;
            let mut kind = None;
            let mut d = None;
            let mut k = None;
            let mut bucket = None;
            let mut path = None;
            for field in line.split_whitespace() {
                let (key, val) = field
                    .split_once('=')
                    .ok_or_else(|| format!("line {}: bad field {field:?}", lineno + 1))?;
                match key {
                    "name" => name = Some(val.to_string()),
                    "kind" => {
                        kind = Some(match val {
                            "logistic" => ModelKind::Logistic,
                            "softmax" => ModelKind::Softmax,
                            "robust" => ModelKind::Robust,
                            other => {
                                return Err(format!("line {}: unknown kind {other}", lineno + 1))
                            }
                        })
                    }
                    "d" => d = val.parse().ok(),
                    "k" => k = val.parse().ok(),
                    "bucket" => bucket = val.parse().ok(),
                    "path" => path = Some(val.to_string()),
                    _ => {} // forward-compatible: ignore unknown keys
                }
            }
            entries.push(ArtifactEntry {
                name: name.ok_or_else(|| format!("line {}: missing name", lineno + 1))?,
                kind: kind.ok_or_else(|| format!("line {}: missing kind", lineno + 1))?,
                d: d.ok_or_else(|| format!("line {}: missing d", lineno + 1))?,
                k: k.unwrap_or(1),
                bucket: bucket.ok_or_else(|| format!("line {}: missing bucket", lineno + 1))?,
                path: path.ok_or_else(|| format!("line {}: missing path", lineno + 1))?,
            });
        }
        Ok(Manifest { entries, dir: dir.to_string() })
    }

    /// Load and parse `<dir>/manifest.txt`.
    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path}: {e} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Ascending bucket sizes available for a (kind, d, k) triple.
    pub fn buckets_for(&self, kind: ModelKind, d: usize, k: usize) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self
            .entries
            .iter()
            .filter(|e| e.kind == kind && e.d == d && e.k == k)
            .collect();
        v.sort_by_key(|e| e.bucket);
        v
    }

    /// Absolute-ish path of an entry (manifest dir + relative path).
    pub fn full_path(&self, entry: &ArtifactEntry) -> String {
        format!("{}/{}", self.dir, entry.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_filters() {
        let text = "\
name=logistic.d51.b256 kind=logistic d=51 k=1 bucket=256 path=a.hlo.txt
name=logistic.d51.b2048 kind=logistic d=51 k=1 bucket=2048 path=b.hlo.txt
name=softmax.k3.d256.b256 kind=softmax d=256 k=3 bucket=256 path=c.hlo.txt
";
        let m = Manifest::parse(text, "artifacts").unwrap();
        assert_eq!(m.entries.len(), 3);
        let logi = m.buckets_for(ModelKind::Logistic, 51, 1);
        assert_eq!(logi.len(), 2);
        assert_eq!(logi[0].bucket, 256);
        assert_eq!(logi[1].bucket, 2048);
        assert!(m.buckets_for(ModelKind::Robust, 57, 1).is_empty());
        assert_eq!(m.full_path(logi[0]), "artifacts/a.hlo.txt");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("name=x kind=banana d=1 bucket=2 path=p", "d").is_err());
        assert!(Manifest::parse("kind=logistic d=1 bucket=2 path=p", "d").is_err());
        assert!(Manifest::parse("name=x kind=logistic bucket=2 path=p", "d").is_err());
    }

    #[test]
    fn ignores_comments_and_unknown_keys() {
        let m = Manifest::parse(
            "# comment\nname=x kind=robust d=57 k=1 bucket=256 path=p extra=42\n",
            "d",
        )
        .unwrap();
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].kind, ModelKind::Robust);
    }
}
