//! The batch-evaluation interface both backends implement.
//!
//! Everything the samplers need from the likelihood layer goes through
//! [`BatchEval`]: per-point (log L, log B) over an index set, plus summed
//! gradients. The CPU backends hand the whole index set to the model's
//! batch API, which tiles it through the `W = 8`-lane SoA kernels
//! ([`crate::kernels`], DESIGN.md §Kernels); the XLA backend pads the
//! index set to a bucket and executes the AOT-compiled artifact. Query
//! counting happens here so all backends account identically.
//!
//! Index sets are `&[u32]` — the same element type `BrightSet` stores — so
//! the FlyMC hot path hands `BrightSet::bright_slice()` straight to the
//! backend without materializing a widened copy (datasets are bounded to
//! `u32::MAX` points at `BrightSet` construction). Steady-state sampling
//! performs no heap allocation anywhere on this interface: callers own
//! reusable output buffers and backends only `clear`/`reserve` them.

use std::sync::Arc;

use crate::metrics::Counters;
use crate::models::ModelBound;

/// Batched per-datum likelihood/bound evaluation over a `&[u32]` index set.
///
/// This is the whole contract between the MCMC layer and the likelihood
/// layer; see the module docs for the index convention and the
/// cost-accounting rules (DESIGN.md §Cost-accounting). Backends own any
/// scratch their evaluation needs ([`crate::models::EvalScratch`]) and only
/// `clear`/`reserve` the caller-owned output buffers, so steady-state
/// sampling performs no heap allocation on this interface.
// Note: deliberately NOT `Send` — each chain thread constructs its own
// backend inside `run_chain_replicas` (the XLA client must stay on its
// thread; the sharded ParBackend parallelizes internally instead).
pub trait BatchEval {
    /// Number of data points the backing model holds.
    fn n(&self) -> usize;
    /// Flattened parameter dimension.
    fn dim(&self) -> usize;
    /// The query counters this backend reports into.
    fn counters(&self) -> &Counters;

    /// Per-point (log L_n, log B_n) for `idx` at `theta`. Outputs are
    /// cleared and resized to `idx.len()`. Counts `idx.len()` likelihood +
    /// bound queries.
    fn eval(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>, lb: &mut Vec<f64>);

    /// [`BatchEval::eval`] plus `grad += sum_n d[log(L_n - B_n) - log B_n]`.
    fn eval_pseudo_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        lb: &mut Vec<f64>,
        grad: &mut [f64],
    );

    /// Per-point log L_n only (regular MCMC; still counts queries).
    fn eval_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>);

    /// [`BatchEval::eval_lik`] plus `grad += sum_n d log L_n`.
    fn eval_lik_grad(
        &mut self,
        theta: &[f64],
        idx: &[u32],
        ll: &mut Vec<f64>,
        grad: &mut [f64],
    );

    /// Swap the backing model (bound re-anchoring swaps in a freshly tuned
    /// clone mid-run; see `PseudoPosterior::reanchor`). Backends rebuild
    /// whatever scratch depends on the model. Returns `false` when the
    /// backend cannot swap — the XLA backend bakes the bound anchors into
    /// its AOT artifacts — and the caller must refuse the re-anchor
    /// (configx validation rejects `reanchor` + the XLA backend up front).
    fn set_model(&mut self, model: Arc<dyn ModelBound>) -> bool;
}
