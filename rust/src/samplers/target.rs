//! The target-distribution interface the θ-samplers drive.
//!
//! A `Target` is a (possibly augmented) log-density with *state*: FlyMC's
//! pseudo-posterior caches per-bright-point likelihoods at the committed
//! point, so the protocol is evaluate-then-commit:
//!
//! 1. the sampler calls `log_density` / `grad_log_density` at proposals
//!    (the target memoizes the last evaluation);
//! 2. the sampler calls `commit(theta)` on the point it accepted — a memo
//!    hit promotes the cached evaluation to state with no new likelihood
//!    queries (both MH outcomes, the MALA outcomes, and slice sampling's
//!    final point are always the last evaluation or the unchanged state).
//!
//! ## Buffer-based gradient contract
//!
//! Gradient evaluation never returns a fresh vector: the sampler owns a
//! reusable dim-sized `grad` buffer and [`Target::grad_log_density`]
//! overwrites it. Implementations must not allocate on this path — the
//! FlyMC pseudo-posterior routes the per-datum sum through the backend's
//! scratch arena and its own accumulators, so steady-state gradient steps
//! (MALA) are as allocation-free as the gradient-free ones (the zero-alloc
//! invariant of DESIGN.md §Perf, enforced by the `integration_hotpath*`
//! test binaries).

/// The (possibly augmented) log-density a θ-sampler drives — see the module
/// docs for the evaluate-then-commit protocol.
pub trait Target {
    /// Dimension of the flattened parameter vector.
    fn dim(&self) -> usize;

    /// Log density at `theta` (up to a constant). May memoize.
    fn log_density(&mut self, theta: &[f64]) -> f64;

    /// Fills the caller-owned `grad` (overwriting, `dim` elements) with
    /// d log p / d theta; returns log p. Must not allocate.
    fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64;

    /// Declare `theta` the chain's new current state.
    fn commit(&mut self, theta: &[f64]);

    /// Log density at the committed state (cached; no queries).
    fn current_log_density(&self) -> f64;

    /// Monotone counter bumped whenever the target distribution itself
    /// changes under the sampler's feet (FlyMC bumps it on every z-update).
    /// Lets gradient samplers (MALA) reuse the current-point gradient across
    /// iterations when the target is unchanged — regular MCMC then costs one
    /// evaluation per iteration, matching the paper's Table-1 accounting.
    fn version(&self) -> u64 {
        0
    }

    /// Downcast hook for the approximate tall-data samplers: a target that
    /// can serve minibatch likelihood estimates returns `Some(self)` here.
    /// Default `None` keeps exact targets (the FlyMC pseudo-posterior)
    /// opaque, so SGLD/austerity refuse them at startup instead of silently
    /// subsampling an augmented density.
    fn as_subsample(&mut self) -> Option<&mut dyn SubsampleTarget> {
        None
    }
}

/// Minibatch view of a full-data posterior, the contract the approximate
/// samplers (`samplers::sgld`, `samplers::austerity`) are written against.
///
/// The posterior factorizes as `p(θ|x) ∝ p(θ) Π_n L_n(θ)`; implementations
/// serve per-datum log-likelihood terms and their gradients for
/// caller-chosen index subsets through the same `BatchEval` kernel path the
/// exact samplers use, so every datum touched is metered as one likelihood
/// query in `metrics::Counters` — queries/iteration stays comparable across
/// exact and approximate algorithms.
///
/// All buffer parameters follow the crate's zero-alloc contract: outputs are
/// caller-owned, cleared/overwritten by the callee, never reallocated in
/// steady state.
pub trait SubsampleTarget {
    /// Number of likelihood factors N.
    fn n_data(&self) -> usize;

    /// Per-datum log-likelihoods `log L_i(θ)` for each `i` in `idx`, written
    /// to `ll` (cleared and resized to `idx.len()`). Counts `idx.len()`
    /// likelihood queries.
    fn minibatch_log_lik(&mut self, theta: &[f64], idx: &[u32], ll: &mut Vec<f64>);

    /// Accumulates `Σ_{i∈idx} ∇ log L_i(θ)` into `grad` (NOT zeroed first —
    /// callers compose prior/anchor terms by accumulation) and returns
    /// `Σ_{i∈idx} log L_i(θ)`. Counts `idx.len()` likelihood queries.
    fn minibatch_grad_acc(&mut self, theta: &[f64], idx: &[u32], grad: &mut [f64]) -> f64;

    /// Prior log density at `theta` (no likelihood queries).
    fn prior_log_density(&self, theta: &[f64]) -> f64;

    /// Accumulates the prior's gradient into `grad` (no likelihood queries).
    fn prior_grad_acc(&self, theta: &[f64], grad: &mut [f64]);

    /// Adopt `theta` as the committed state with `log_density_estimate` as
    /// its (estimated) log density, WITHOUT re-evaluating the full dataset.
    /// This is how approximate samplers advance the chain: a full
    /// [`Target::commit`] on a fresh point would cost N queries and destroy
    /// the queries/iteration accounting the head-to-head bench reports.
    fn set_state(&mut self, theta: &[f64], log_density_estimate: f64);
}
