//! Univariate slice sampling with stepping-out and shrinkage (Neal 2003) —
//! the paper's θ-update for the OPV robust-regression experiment.
//!
//! Each `step` updates `coords_per_iter` randomly-chosen coordinates; every
//! slice update costs a variable number of target evaluations (which is why
//! the paper's regular-MCMC row for OPV reports ~10·N likelihood queries per
//! iteration). The final accepted point is always the last evaluated one, so
//! `Target::commit` hits the memo and costs nothing extra.

use super::{Sampler, StepInfo, Target};
use crate::util::Rng;

/// Univariate slice sampler with stepping-out and shrinkage.
///
/// Allocation-free at steady state: every slice update mutates `theta` in
/// place and reads the target through `log_density`/`commit` memo hits.
pub struct SliceSampler {
    /// initial bracket width w (Neal 2003)
    pub w: f64,
    /// maximum number of stepping-out expansions each side
    pub max_stepout: usize,
    /// how many random coordinates to update per iteration
    pub coords_per_iter: usize,
    evals_total: u64,
    steps: u64,
}

impl SliceSampler {
    /// Sampler with bracket width `w`, 8 step-out expansions, 1 coord/iter.
    pub fn new(w: f64) -> Self {
        SliceSampler { w, max_stepout: 8, coords_per_iter: 1, evals_total: 0, steps: 0 }
    }

    /// Update `c` randomly-chosen coordinates per iteration (min 1).
    pub fn with_coords_per_iter(mut self, c: usize) -> Self {
        self.coords_per_iter = c.max(1);
        self
    }

    /// Mean target evaluations per step so far (NaN before the first step).
    pub fn mean_evals_per_step(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.evals_total as f64 / self.steps as f64
    }

    /// One univariate slice update of coordinate `i`. Returns evals used.
    // lint: zero-alloc
    fn slice_coord(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        i: usize,
        rng: &mut Rng,
    ) -> usize {
        let mut evals = 0;
        let x0 = theta[i];
        let logp0 = target.current_log_density();
        // slice level: log y = log p(x0) - Exp(1)
        let log_y = logp0 - rng.exponential();

        // stepping out
        let mut lo = x0 - self.w * rng.f64();
        let mut hi = lo + self.w;
        let mut lo_steps = self.max_stepout;
        let mut hi_steps = self.max_stepout;
        loop {
            theta[i] = lo;
            let lp = target.log_density(theta);
            evals += 1;
            if lp <= log_y || lo_steps == 0 {
                break;
            }
            lo -= self.w;
            lo_steps -= 1;
        }
        loop {
            theta[i] = hi;
            let lp = target.log_density(theta);
            evals += 1;
            if lp <= log_y || hi_steps == 0 {
                break;
            }
            hi += self.w;
            hi_steps -= 1;
        }

        // shrinkage
        loop {
            let x1 = rng.range(lo, hi);
            theta[i] = x1;
            let lp = target.log_density(theta);
            evals += 1;
            if lp > log_y {
                target.commit(theta); // memo hit: last evaluation
                return evals;
            }
            if x1 < x0 {
                lo = x1;
            } else {
                hi = x1;
            }
            if (hi - lo) < 1e-14 * (1.0 + x0.abs()) {
                // numerically-empty slice: stay put
                theta[i] = x0;
                target.commit(theta);
                return evals;
            }
        }
    }
}

impl Sampler for SliceSampler {
    // lint: zero-alloc
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut Rng,
    ) -> StepInfo {
        let d = target.dim();
        let mut evals = 0;
        for _ in 0..self.coords_per_iter {
            let i = rng.below(d);
            evals += self.slice_coord(target, theta, i, rng);
        }
        self.steps += 1;
        self.evals_total += evals as u64;
        StepInfo { accepted: true, evals, log_density: target.current_log_density() }
    }

    fn name(&self) -> &'static str {
        "slice sampling"
    }

    fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        // w / max_stepout / coords_per_iter are construction-time config;
        // only the reported statistics are chain state
        w.u64(self.evals_total);
        w.u64(self.steps);
    }

    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.evals_total = r.u64()?;
        self.steps = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_targets::GaussTarget;
    use super::*;
    use crate::util::math::{mean, variance};

    #[test]
    fn samples_gaussian() {
        let mut target = GaussTarget::new(2, 1.5);
        let mut slice = SliceSampler::new(1.0).with_coords_per_iter(2);
        let mut theta = vec![0.0; 2];
        target.commit(&theta);
        let mut rng = Rng::new(5);
        let mut draws = Vec::new();
        for i in 0..15_000 {
            slice.step(&mut target, &mut theta, &mut rng);
            if i > 1000 {
                draws.push(theta[0]);
            }
        }
        assert!(mean(&draws).abs() < 0.1);
        let v = variance(&draws);
        assert!((v - 2.25).abs() < 0.3, "var {v}");
    }

    #[test]
    fn skewed_target_sampled_correctly() {
        // Exp(1) restricted via log density -x (x>0): slice handles
        // asymmetric targets; check the mean ~ 1.
        struct ExpTarget {
            theta: Vec<f64>,
            cur: f64,
        }
        impl Target for ExpTarget {
            fn dim(&self) -> usize {
                1
            }
            fn log_density(&mut self, t: &[f64]) -> f64 {
                if t[0] < 0.0 {
                    f64::NEG_INFINITY
                } else {
                    -t[0]
                }
            }
            fn grad_log_density(&mut self, _t: &[f64], _g: &mut [f64]) -> f64 {
                unimplemented!()
            }
            fn commit(&mut self, t: &[f64]) {
                self.theta = t.to_vec();
                self.cur = if t[0] < 0.0 { f64::NEG_INFINITY } else { -t[0] };
            }
            fn current_log_density(&self) -> f64 {
                self.cur
            }
        }
        let mut target = ExpTarget { theta: vec![1.0], cur: -1.0 };
        let mut slice = SliceSampler::new(2.0);
        let mut theta = vec![1.0];
        let mut rng = Rng::new(6);
        let mut draws = Vec::new();
        for i in 0..20_000 {
            slice.step(&mut target, &mut theta, &mut rng);
            if i > 1000 {
                draws.push(theta[0]);
            }
        }
        let m = mean(&draws);
        assert!((m - 1.0).abs() < 0.08, "mean {m}");
        assert!(draws.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn evals_counted() {
        let mut target = GaussTarget::new(3, 1.0);
        let mut slice = SliceSampler::new(1.0);
        let mut theta = vec![0.0; 3];
        target.commit(&theta);
        let mut rng = Rng::new(7);
        let info = slice.step(&mut target, &mut theta, &mut rng);
        assert!(info.evals >= 3); // at least 2 stepping-out + 1 shrink
        assert!(slice.mean_evals_per_step() >= 3.0);
    }
}
