//! Robbins–Monro step-size adaptation toward a target acceptance rate
//! (0.234 for random-walk MH, 0.574 for MALA — Roberts et al. 1997 / Roberts
//! & Rosenthal 1998, as the paper tunes). Adaptation decays and is frozen
//! after burn-in so the chain is asymptotically exact.

/// Robbins–Monro step-size adapter toward a target acceptance rate.
#[derive(Clone, Debug)]
pub struct StepSizeAdapter {
    /// acceptance rate the adaptation drives toward
    pub target_accept: f64,
    /// base adaptation gain (decays as count^-0.6)
    pub gamma0: f64,
    count: usize,
    frozen: bool,
}

impl StepSizeAdapter {
    /// Adapter driving toward `target_accept`.
    pub fn new(target_accept: f64) -> Self {
        StepSizeAdapter { target_accept, gamma0: 1.0, count: 0, frozen: false }
    }

    /// Stop adapting (call at the end of burn-in).
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    /// Whether adaptation has been frozen.
    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// Serialize the adaptation state (target, gain, decay count, frozen
    /// flag) — the decay count determines every future gain, so it must
    /// survive a checkpoint for the resumed step-size trajectory to be
    /// bit-identical.
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.f64(self.target_accept);
        w.f64(self.gamma0);
        w.usize(self.count);
        w.bool(self.frozen);
    }

    /// Restore [`Self::save_state`] bytes.
    pub fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.target_accept = r.f64()?;
        self.gamma0 = r.f64()?;
        self.count = r.usize()?;
        self.frozen = r.bool()?;
        Ok(())
    }

    /// Update `log step` after observing an accept/reject; returns the new
    /// step size.
    pub fn update(&mut self, step: f64, accepted: bool) -> f64 {
        if self.frozen {
            return step;
        }
        self.count += 1;
        let gamma = self.gamma0 / (self.count as f64).powf(0.6);
        let a = if accepted { 1.0 } else { 0.0 };
        (step.ln() + gamma * (a - self.target_accept)).exp()
    }
}

/// Robbins–Monro controller for the FlyMC dark→bright resampling rate
/// `q_dark_to_bright`, driving the observed bright-set *turnover* toward a
/// target (DESIGN.md §Bound-management). Turnover per z-update is
/// `(brightened + darkened) / (2 max(1, |bright|))` — ~0 means the bright
/// set is frozen (sticky z chain, high autocorrelation), ~1 means it churns
/// completely. Mirrors [`StepSizeAdapter`]: log-scale updates with gain
/// `gamma0 / count^0.6`, adapt during burn-in, [`QController::freeze`]
/// after — frozen, the controller is exactly inert, so a chain that never
/// adapts is byte-identical with or without it.
#[derive(Clone, Debug)]
pub struct QController {
    /// bright-set turnover the adaptation drives toward
    pub target_turnover: f64,
    /// base adaptation gain (decays as count^-0.6)
    pub gamma0: f64,
    /// EWMA of observed turnover (decay 0.9) — the explicit-vs-implicit
    /// resampling decision at freeze time reads this
    pub ewma_turnover: f64,
    count: usize,
    frozen: bool,
}

/// Clamp bounds for the controlled `q_dark_to_bright`.
pub const Q_DB_MIN: f64 = 1e-6;
/// Upper clamp: q beyond 0.5 churns the dark set faster than it mixes.
pub const Q_DB_MAX: f64 = 0.5;

impl QController {
    /// Controller driving toward `target_turnover` (the tentpole default is
    /// 0.05: 5% of the bright set replaced per z-update).
    pub fn new(target_turnover: f64) -> Self {
        QController {
            target_turnover,
            gamma0: 0.5,
            ewma_turnover: target_turnover,
            count: 0,
            frozen: false,
        }
    }

    /// Stop adapting (end of the adaptation window; before any recorded
    /// sample so the chain stays asymptotically exact).
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    /// Whether adaptation has been frozen.
    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// Observed bright-set turnover for one z-update.
    pub fn turnover(brightened: usize, darkened: usize, n_bright: usize) -> f64 {
        (brightened + darkened) as f64 / (2.0 * n_bright.max(1) as f64)
    }

    /// Update `q_dark_to_bright` after observing one z-update's flip tallies;
    /// returns the new (clamped) q. Frozen: identity, zero state touched.
    pub fn update(&mut self, q: f64, brightened: usize, darkened: usize, n_bright: usize) -> f64 {
        if self.frozen {
            return q;
        }
        let tau = Self::turnover(brightened, darkened, n_bright);
        self.ewma_turnover = 0.9 * self.ewma_turnover + 0.1 * tau;
        self.count += 1;
        let gamma = self.gamma0 / (self.count as f64).powf(0.6);
        (q.ln() + gamma * (self.target_turnover - tau))
            .exp()
            .clamp(Q_DB_MIN, Q_DB_MAX)
    }

    /// Resampling-mode recommendation at freeze time: if turnover is still
    /// below half the target with q pinned at its upper clamp, the geometric
    /// dark→bright trickle can't keep up (sticky bounds) — switch to the
    /// explicit full-conditional z sweep.
    pub fn recommend_explicit(&self, q: f64) -> bool {
        q >= Q_DB_MAX * (1.0 - 1e-12) && self.ewma_turnover < 0.5 * self.target_turnover
    }

    /// Serialize the controller (target, gain, EWMA, decay count, frozen
    /// flag) — the count determines every future gain, so it must survive a
    /// checkpoint for the resumed q trajectory to be bit-identical.
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.f64(self.target_turnover);
        w.f64(self.gamma0);
        w.f64(self.ewma_turnover);
        w.usize(self.count);
        w.bool(self.frozen);
    }

    /// Restore [`Self::save_state`] bytes.
    pub fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.target_turnover = r.f64()?;
        self.gamma0 = r.f64()?;
        self.ewma_turnover = r.f64()?;
        self.count = r.usize()?;
        self.frozen = r.bool()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_toward_target_acceptance() {
        // Accept iff step < 1 with prob ~ sigmoid-like: simulate a toy
        // environment where acceptance probability = exp(-step).
        let mut adapter = StepSizeAdapter::new(0.234);
        let mut step: f64 = 10.0;
        let mut rng = crate::util::Rng::new(0);
        for _ in 0..20_000 {
            let p_acc = (-step).exp();
            let acc = rng.bernoulli(p_acc);
            step = adapter.update(step, acc);
        }
        let p_final = (-step).exp();
        assert!((p_final - 0.234).abs() < 0.05, "p_final {p_final}");
    }

    #[test]
    fn frozen_adapter_is_identity() {
        let mut a = StepSizeAdapter::new(0.5);
        a.freeze();
        assert_eq!(a.update(0.7, true), 0.7);
        assert_eq!(a.update(0.7, false), 0.7);
    }

    #[test]
    fn q_controller_raises_q_when_turnover_low() {
        let mut c = QController::new(0.05);
        let mut q = 0.01;
        // bright set of 100, nothing flipping: turnover 0 < target
        for _ in 0..50 {
            q = c.update(q, 0, 0, 100);
        }
        assert!(q > 0.01, "q should grow, got {q}");
        assert!(q <= Q_DB_MAX);
        // heavy churn drives it back down
        for _ in 0..200 {
            q = c.update(q, 40, 40, 100);
        }
        assert!(q < Q_DB_MAX, "q should shrink under churn, got {q}");
        assert!(q >= Q_DB_MIN);
    }

    #[test]
    fn q_controller_frozen_is_inert() {
        let mut c = QController::new(0.05);
        c.freeze();
        let before = c.ewma_turnover;
        assert_eq!(c.update(0.03, 10, 10, 50), 0.03);
        assert_eq!(c.ewma_turnover, before);
    }

    #[test]
    fn q_controller_recommends_explicit_only_when_pinned_and_sticky() {
        let mut c = QController::new(0.05);
        // sticky: drive the EWMA toward zero
        for _ in 0..100 {
            c.update(Q_DB_MAX, 0, 0, 100);
        }
        assert!(c.recommend_explicit(Q_DB_MAX));
        assert!(!c.recommend_explicit(0.01), "not pinned at clamp");
        let healthy = QController::new(0.05);
        assert!(!healthy.recommend_explicit(Q_DB_MAX), "EWMA at target");
    }

    #[test]
    fn q_controller_codec_roundtrip() {
        let mut c = QController::new(0.07);
        for i in 0..9 {
            c.update(0.02, i, i / 2, 40);
        }
        c.freeze();
        let mut w = crate::util::codec::ByteWriter::new();
        c.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut d = QController::new(0.0);
        let mut r = crate::util::codec::ByteReader::new(&bytes);
        d.load_state(&mut r).unwrap();
        assert_eq!(c.target_turnover, d.target_turnover);
        assert_eq!(c.ewma_turnover, d.ewma_turnover);
        assert_eq!(c.count, d.count);
        assert!(d.frozen);
    }
}
