//! Robbins–Monro step-size adaptation toward a target acceptance rate
//! (0.234 for random-walk MH, 0.574 for MALA — Roberts et al. 1997 / Roberts
//! & Rosenthal 1998, as the paper tunes). Adaptation decays and is frozen
//! after burn-in so the chain is asymptotically exact.

/// Robbins–Monro step-size adapter toward a target acceptance rate.
#[derive(Clone, Debug)]
pub struct StepSizeAdapter {
    /// acceptance rate the adaptation drives toward
    pub target_accept: f64,
    /// base adaptation gain (decays as count^-0.6)
    pub gamma0: f64,
    count: usize,
    frozen: bool,
}

impl StepSizeAdapter {
    /// Adapter driving toward `target_accept`.
    pub fn new(target_accept: f64) -> Self {
        StepSizeAdapter { target_accept, gamma0: 1.0, count: 0, frozen: false }
    }

    /// Stop adapting (call at the end of burn-in).
    pub fn freeze(&mut self) {
        self.frozen = true;
    }

    /// Whether adaptation has been frozen.
    pub fn is_frozen(&self) -> bool {
        self.frozen
    }

    /// Serialize the adaptation state (target, gain, decay count, frozen
    /// flag) — the decay count determines every future gain, so it must
    /// survive a checkpoint for the resumed step-size trajectory to be
    /// bit-identical.
    pub fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.f64(self.target_accept);
        w.f64(self.gamma0);
        w.usize(self.count);
        w.bool(self.frozen);
    }

    /// Restore [`Self::save_state`] bytes.
    pub fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.target_accept = r.f64()?;
        self.gamma0 = r.f64()?;
        self.count = r.usize()?;
        self.frozen = r.bool()?;
        Ok(())
    }

    /// Update `log step` after observing an accept/reject; returns the new
    /// step size.
    pub fn update(&mut self, step: f64, accepted: bool) -> f64 {
        if self.frozen {
            return step;
        }
        self.count += 1;
        let gamma = self.gamma0 / (self.count as f64).powf(0.6);
        let a = if accepted { 1.0 } else { 0.0 };
        (step.ln() + gamma * (a - self.target_accept)).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_toward_target_acceptance() {
        // Accept iff step < 1 with prob ~ sigmoid-like: simulate a toy
        // environment where acceptance probability = exp(-step).
        let mut adapter = StepSizeAdapter::new(0.234);
        let mut step: f64 = 10.0;
        let mut rng = crate::util::Rng::new(0);
        for _ in 0..20_000 {
            let p_acc = (-step).exp();
            let acc = rng.bernoulli(p_acc);
            step = adapter.update(step, acc);
        }
        let p_final = (-step).exp();
        assert!((p_final - 0.234).abs() < 0.05, "p_final {p_final}");
    }

    #[test]
    fn frozen_adapter_is_identity() {
        let mut a = StepSizeAdapter::new(0.5);
        a.freeze();
        assert_eq!(a.update(0.7, true), 0.7);
        assert_eq!(a.update(0.7, false), 0.7);
    }
}
