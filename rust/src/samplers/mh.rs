//! Symmetric random-walk Metropolis–Hastings (paper Alg 1's θ-update; used
//! for the MNIST experiment, tuned to acceptance 0.234).

use super::{Sampler, StepInfo, StepSizeAdapter, Target};
use crate::util::Rng;

/// Symmetric random-walk Metropolis–Hastings sampler.
pub struct RandomWalkMh {
    /// isotropic Gaussian proposal step size
    pub step: f64,
    /// Robbins–Monro acceptance-rate adaptation (None = fixed step)
    pub adapter: Option<StepSizeAdapter>,
    proposal: Vec<f64>,
    accepts: u64,
    steps: u64,
}

impl RandomWalkMh {
    /// Fixed-step sampler with the given proposal scale.
    pub fn new(step: f64) -> Self {
        RandomWalkMh { step, adapter: None, proposal: Vec::new(), accepts: 0, steps: 0 }
    }

    /// Enable Robbins–Monro adaptation toward 0.234 (freeze after burn-in).
    pub fn adaptive(step: f64) -> Self {
        let mut s = Self::new(step);
        s.adapter = Some(StepSizeAdapter::new(0.234));
        s
    }

    /// Stop step-size adaptation (call at the end of burn-in).
    pub fn freeze_adaptation(&mut self) {
        if let Some(a) = &mut self.adapter {
            a.freeze();
        }
    }

    /// Lifetime acceptance rate (NaN before the first step).
    pub fn acceptance_rate(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.accepts as f64 / self.steps as f64
    }
}

impl Sampler for RandomWalkMh {
    // lint: zero-alloc
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut Rng,
    ) -> StepInfo {
        debug_assert_eq!(theta.len(), target.dim());
        let logp_cur = target.current_log_density();
        self.proposal.clear();
        self.proposal
            .extend(theta.iter().map(|&t| t + self.step * rng.normal()));
        let logp_new = target.log_density(&self.proposal);
        let accepted = rng.f64_open().ln() < logp_new - logp_cur;
        self.steps += 1;
        let logp = if accepted {
            self.accepts += 1;
            theta.clear();
            theta.extend_from_slice(&self.proposal);
            target.commit(theta);
            logp_new
        } else {
            logp_cur
        };
        if let Some(a) = &mut self.adapter {
            self.step = a.update(self.step, accepted);
        }
        StepInfo { accepted, evals: 1, log_density: logp }
    }

    fn name(&self) -> &'static str {
        "random-walk MH"
    }

    fn freeze_adaptation(&mut self) {
        RandomWalkMh::freeze_adaptation(self);
    }

    fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.f64(self.step);
        w.u64(self.accepts);
        w.u64(self.steps);
        w.bool(self.adapter.is_some());
        if let Some(a) = &self.adapter {
            a.save_state(w);
        }
    }

    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.step = r.f64()?;
        self.accepts = r.u64()?;
        self.steps = r.u64()?;
        let adaptive = r.bool()?;
        match (&mut self.adapter, adaptive) {
            (Some(a), true) => a.load_state(r)?,
            (None, false) => {}
            _ => return Err("checkpoint adaptive-ness does not match this sampler".to_string()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_targets::GaussTarget;
    use super::*;
    use crate::util::math::variance;

    #[test]
    fn samples_standard_gaussian() {
        let mut target = GaussTarget::new(2, 1.0);
        let mut mh = RandomWalkMh::new(1.2);
        let mut theta = vec![0.0; 2];
        target.commit(&theta);
        let mut rng = Rng::new(1);
        let mut draws = Vec::new();
        for i in 0..30_000 {
            mh.step(&mut target, &mut theta, &mut rng);
            if i > 2000 {
                draws.push(theta[0]);
            }
        }
        let m = draws.iter().sum::<f64>() / draws.len() as f64;
        let v = variance(&draws);
        assert!(m.abs() < 0.08, "mean {m}");
        assert!((v - 1.0).abs() < 0.15, "var {v}");
    }

    #[test]
    fn adaptation_reaches_0234() {
        let mut target = GaussTarget::new(5, 1.0);
        let mut mh = RandomWalkMh::adaptive(10.0); // far-off initial step
        let mut theta = vec![0.0; 5];
        target.commit(&theta);
        let mut rng = Rng::new(2);
        for _ in 0..5000 {
            mh.step(&mut target, &mut theta, &mut rng);
        }
        mh.freeze_adaptation();
        let (a0, s0) = (mh.accepts, mh.steps);
        for _ in 0..10_000 {
            mh.step(&mut target, &mut theta, &mut rng);
        }
        let rate = (mh.accepts - a0) as f64 / (mh.steps - s0) as f64;
        assert!((rate - 0.234).abs() < 0.08, "acceptance {rate}");
    }
}
