//! θ-update operators. All are generic over [`target::Target`] so regular
//! MCMC (`FullPosterior`) and FlyMC (`PseudoPosterior`) share the exact same
//! sampler code — the paper's comparison is then apples-to-apples.

pub mod adapt;
pub mod mala;
pub mod mh;
pub mod slice;
pub mod target;

pub use adapt::StepSizeAdapter;
pub use mala::Mala;
pub use mh::RandomWalkMh;
pub use slice::SliceSampler;
pub use target::Target;

/// Outcome of one θ-update.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepInfo {
    /// whether the proposal was accepted (always true for slice sampling)
    pub accepted: bool,
    /// number of target evaluations performed
    pub evals: usize,
    /// target log density at the post-step state
    pub log_density: f64,
}

/// Standalone analytic targets for sampler unit tests.
#[cfg(test)]
pub(crate) mod test_targets {
    use super::Target;

    pub struct GaussTarget {
        pub dim: usize,
        pub sigma: f64,
        theta: Vec<f64>,
        cur: f64,
    }

    impl GaussTarget {
        pub fn new(dim: usize, sigma: f64) -> Self {
            GaussTarget { dim, sigma, theta: vec![0.0; dim], cur: 0.0 }
        }
        fn logp(&self, t: &[f64]) -> f64 {
            -0.5 * t.iter().map(|x| x * x).sum::<f64>() / (self.sigma * self.sigma)
        }
    }

    impl Target for GaussTarget {
        fn dim(&self) -> usize {
            self.dim
        }
        fn log_density(&mut self, theta: &[f64]) -> f64 {
            self.logp(theta)
        }
        fn grad_log_density(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            for (g, t) in grad.iter_mut().zip(theta) {
                *g = -t / (self.sigma * self.sigma);
            }
            self.logp(theta)
        }
        fn commit(&mut self, theta: &[f64]) {
            self.theta.clear();
            self.theta.extend_from_slice(theta);
            self.cur = self.logp(theta);
        }
        fn current_log_density(&self) -> f64 {
            self.cur
        }
    }
}

/// A Markov θ-update operator.
pub trait Sampler {
    /// Advance `theta` in place by one transition that leaves `target`
    /// invariant (conditioned on its state).
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut crate::util::Rng,
    ) -> StepInfo;

    /// Human-readable sampler name for reports.
    fn name(&self) -> &'static str;

    /// Stop any step-size adaptation (default no-op for non-adaptive
    /// samplers). Call at the end of burn-in so the chain is asymptotically
    /// exact, and before any timed measurement window.
    fn freeze_adaptation(&mut self) {}

    /// Serialize every piece of sampler state that influences future steps
    /// or reported statistics — step size, adaptation decay, acceptance
    /// tallies, and any cross-iteration caches (MALA's current-point
    /// gradient). Part of the chain checkpoint's bit-identical-resume
    /// contract (`engine::checkpoint`).
    fn save_state(&self, w: &mut crate::util::codec::ByteWriter);

    /// Restore [`Sampler::save_state`] bytes into a sampler constructed
    /// with the same configuration (adaptive-ness must match).
    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String>;
}
