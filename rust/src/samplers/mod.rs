//! θ-update operators. All are generic over [`target::Target`] so regular
//! MCMC (`FullPosterior`) and FlyMC (`PseudoPosterior`) share the exact same
//! sampler code — the paper's comparison is then apples-to-apples.

pub mod adapt;
pub mod austerity;
pub mod mala;
pub mod mh;
pub mod sgld;
pub mod slice;
pub mod target;

pub use adapt::{QController, StepSizeAdapter};
pub use austerity::AusterityMh;
pub use mala::Mala;
pub use mh::RandomWalkMh;
pub use sgld::Sgld;
pub use slice::SliceSampler;
pub use target::{SubsampleTarget, Target};

/// Outcome of one θ-update.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepInfo {
    /// whether the proposal was accepted (always true for slice sampling)
    pub accepted: bool,
    /// number of target evaluations performed
    pub evals: usize,
    /// target log density at the post-step state
    pub log_density: f64,
}

/// Standalone analytic targets for sampler unit tests — the implementations
/// live in [`crate::testing::targets`] so the statistical harness and
/// integration suites can use them too; this alias keeps the historical
/// unit-test import path.
#[cfg(test)]
pub(crate) mod test_targets {
    pub use crate::testing::targets::{GaussDataTarget, GaussTarget};
}

/// A Markov θ-update operator.
pub trait Sampler {
    /// Advance `theta` in place by one transition that leaves `target`
    /// invariant (conditioned on its state).
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut crate::util::Rng,
    ) -> StepInfo;

    /// Human-readable sampler name for reports.
    fn name(&self) -> &'static str;

    /// Stop any step-size adaptation (default no-op for non-adaptive
    /// samplers). Call at the end of burn-in so the chain is asymptotically
    /// exact, and before any timed measurement window.
    fn freeze_adaptation(&mut self) {}

    /// Serialize every piece of sampler state that influences future steps
    /// or reported statistics — step size, adaptation decay, acceptance
    /// tallies, and any cross-iteration caches (MALA's current-point
    /// gradient). Part of the chain checkpoint's bit-identical-resume
    /// contract (`engine::checkpoint`).
    fn save_state(&self, w: &mut crate::util::codec::ByteWriter);

    /// Restore [`Sampler::save_state`] bytes into a sampler constructed
    /// with the same configuration (adaptive-ness must match).
    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String>;
}
