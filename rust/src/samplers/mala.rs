//! Metropolis-adjusted Langevin algorithm (paper §4.2's θ-update for the
//! CIFAR softmax experiment, tuned to acceptance ≈ 0.574).
//!
//! Proposal: θ' = θ + (ε²/2) ∇log p(θ) + ε ξ, ξ ~ N(0, I), with the exact
//! MH correction using the asymmetric Gaussian proposal densities.

use super::{Sampler, StepInfo, StepSizeAdapter, Target};
use crate::util::Rng;

/// Metropolis-adjusted Langevin sampler.
///
/// Owns every buffer a step touches (current/proposal gradients, the
/// proposal point, the current-point cache), all sized to the target's
/// dimension on first use — steady-state steps perform zero heap
/// allocations, completing the gradient half of the zero-alloc hot-path
/// invariant (DESIGN.md §Perf).
pub struct Mala {
    /// proposal step size ε
    pub step: f64,
    /// Robbins–Monro acceptance-rate adaptation (None = fixed step)
    pub adapter: Option<StepSizeAdapter>,
    grad_cur: Vec<f64>,
    grad_new: Vec<f64>,
    proposal: Vec<f64>,
    accepts: u64,
    steps: u64,
    // cache of (target version, theta, grad, logp) at the committed point —
    // valid while the target distribution is unchanged (regular MCMC always;
    // FlyMC only until the next z-update). Saves one evaluation per step.
    cache_version: u64,
    cache_theta: Vec<f64>,
    cache_logp: f64,
    cache_valid: bool,
}

impl Mala {
    /// Fixed-step sampler with the given ε.
    pub fn new(step: f64) -> Self {
        Mala {
            step,
            adapter: None,
            grad_cur: Vec::new(),
            grad_new: Vec::new(),
            proposal: Vec::new(),
            accepts: 0,
            steps: 0,
            cache_version: 0,
            cache_theta: Vec::new(),
            cache_logp: 0.0,
            cache_valid: false,
        }
    }

    /// Robbins–Monro adaptation toward the optimal 0.574.
    pub fn adaptive(step: f64) -> Self {
        let mut s = Self::new(step);
        s.adapter = Some(StepSizeAdapter::new(0.574));
        s
    }

    /// Stop step-size adaptation (call at the end of burn-in).
    pub fn freeze_adaptation(&mut self) {
        if let Some(a) = &mut self.adapter {
            a.freeze();
        }
    }

    /// Lifetime acceptance rate (NaN before the first step).
    pub fn acceptance_rate(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.accepts as f64 / self.steps as f64
    }

    /// log q(to | from) for the drift-mean Gaussian proposal, fused into one
    /// allocation-free pass (same accumulation order as summing
    /// `(to - mean)^2` over a materialized mean vector, so the values are
    /// bit-identical to the pre-fusion form).
    fn log_q(step: f64, from: &[f64], grad_from: &[f64], to: &[f64]) -> f64 {
        let e2 = step * step;
        let mut d2 = 0.0;
        for ((&f, &g), &t) in from.iter().zip(grad_from).zip(to) {
            let mean_i = f + 0.5 * e2 * g;
            let d = t - mean_i;
            d2 += d * d;
        }
        -d2 / (2.0 * e2)
    }
}

impl Sampler for Mala {
    // lint: zero-alloc
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut Rng,
    ) -> StepInfo {
        let d = target.dim();
        self.grad_cur.resize(d, 0.0);
        self.grad_new.resize(d, 0.0);
        // gradient at the current point: reuse the cached one from the last
        // step when the target is unchanged (version match) and theta is the
        // same point; otherwise (first step, or FlyMC resampled z) recompute.
        let mut evals = 1; // the proposal evaluation below is unconditional
        let logp_cur = if self.cache_valid
            && self.cache_version == target.version()
            && self.cache_theta == *theta
        {
            self.cache_logp
        } else {
            evals += 1;
            let lp = target.grad_log_density(theta, &mut self.grad_cur);
            self.cache_theta.clear();
            self.cache_theta.extend_from_slice(theta);
            self.cache_logp = lp;
            self.cache_version = target.version();
            self.cache_valid = true;
            lp
        };
        let e2 = self.step * self.step;
        self.proposal.clear();
        for i in 0..d {
            self.proposal
                .push(theta[i] + 0.5 * e2 * self.grad_cur[i] + self.step * rng.normal());
        }
        let logp_new = target.grad_log_density(&self.proposal, &mut self.grad_new);
        let log_fwd = Self::log_q(self.step, theta, &self.grad_cur, &self.proposal);
        let log_rev = Self::log_q(self.step, &self.proposal, &self.grad_new, theta);
        let log_alpha = logp_new - logp_cur + log_rev - log_fwd;
        let accepted = rng.f64_open().ln() < log_alpha;
        self.steps += 1;
        let logp = if accepted {
            self.accepts += 1;
            theta.clear();
            theta.extend_from_slice(&self.proposal);
            target.commit(theta);
            // the proposal's gradient becomes the current-point cache
            std::mem::swap(&mut self.grad_cur, &mut self.grad_new);
            self.cache_theta.clear();
            self.cache_theta.extend_from_slice(theta);
            self.cache_logp = logp_new;
            self.cache_version = target.version();
            self.cache_valid = true;
            logp_new
        } else {
            logp_cur
        };
        if let Some(a) = &mut self.adapter {
            self.step = a.update(self.step, accepted);
        }
        StepInfo { accepted, evals, log_density: logp }
    }

    fn name(&self) -> &'static str {
        "MALA"
    }

    fn freeze_adaptation(&mut self) {
        Mala::freeze_adaptation(self);
    }

    fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.f64(self.step);
        w.u64(self.accepts);
        w.u64(self.steps);
        w.bool(self.adapter.is_some());
        if let Some(a) = &self.adapter {
            a.save_state(w);
        }
        // the current-point cache decides whether the next step spends a
        // gradient evaluation — it must survive a checkpoint for the
        // resumed query accounting to match the uninterrupted run
        w.bool(self.cache_valid);
        if self.cache_valid {
            w.u64(self.cache_version);
            w.f64(self.cache_logp);
            w.f64_slice(&self.cache_theta);
            w.f64_slice(&self.grad_cur);
        }
    }

    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.step = r.f64()?;
        self.accepts = r.u64()?;
        self.steps = r.u64()?;
        let adaptive = r.bool()?;
        match (&mut self.adapter, adaptive) {
            (Some(a), true) => a.load_state(r)?,
            (None, false) => {}
            _ => return Err("checkpoint adaptive-ness does not match this sampler".to_string()),
        }
        self.cache_valid = r.bool()?;
        if self.cache_valid {
            self.cache_version = r.u64()?;
            self.cache_logp = r.f64()?;
            r.f64_slice_into(&mut self.cache_theta)?;
            r.f64_slice_into(&mut self.grad_cur)?;
            if self.cache_theta.len() != self.grad_cur.len() {
                return Err("MALA cache shape mismatch".to_string());
            }
        } else {
            self.cache_theta.clear();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_targets::GaussTarget;
    use super::*;
    use crate::util::math::variance;

    #[test]
    fn samples_gaussian_with_correct_variance() {
        let mut target = GaussTarget::new(3, 2.0);
        let mut mala = Mala::new(1.0);
        let mut theta = vec![0.5; 3];
        target.commit(&theta);
        let mut rng = Rng::new(3);
        let mut draws = Vec::new();
        for i in 0..30_000 {
            mala.step(&mut target, &mut theta, &mut rng);
            if i > 2000 {
                draws.push(theta[1]);
            }
        }
        let v = variance(&draws);
        assert!((v - 4.0).abs() < 0.5, "var {v}");
        assert!(mala.acceptance_rate() > 0.3);
    }

    #[test]
    fn adaptation_reaches_0574() {
        let mut target = GaussTarget::new(4, 1.0);
        let mut mala = Mala::adaptive(5.0);
        let mut theta = vec![0.0; 4];
        target.commit(&theta);
        let mut rng = Rng::new(4);
        for _ in 0..6000 {
            mala.step(&mut target, &mut theta, &mut rng);
        }
        mala.freeze_adaptation();
        let (a0, s0) = (mala.accepts, mala.steps);
        for _ in 0..10_000 {
            mala.step(&mut target, &mut theta, &mut rng);
        }
        let rate = (mala.accepts - a0) as f64 / (mala.steps - s0) as f64;
        assert!((rate - 0.574).abs() < 0.1, "acceptance {rate}");
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let mut target = GaussTarget::new(3, 1.0);
        let mut mala = Mala::adaptive(0.8);
        let mut theta = vec![0.2; 3];
        target.commit(&theta);
        let mut rng = Rng::new(12);
        for _ in 0..200 {
            mala.step(&mut target, &mut theta, &mut rng);
        }
        let mut w = ByteWriter::new();
        mala.save_state(&mut w);
        rng.save_state(&mut w);
        let bytes = w.into_bytes();

        let mut twin = Mala::adaptive(0.8); // same construction config
        let mut r = ByteReader::new(&bytes);
        twin.load_state(&mut r).unwrap();
        let mut twin_rng = Rng::load_state(&mut r).unwrap();
        r.finish().unwrap();
        let mut twin_target = GaussTarget::new(3, 1.0);
        let mut twin_theta = theta.clone();
        twin_target.commit(&twin_theta);

        for it in 0..100 {
            let a = mala.step(&mut target, &mut theta, &mut rng);
            let b = twin.step(&mut twin_target, &mut twin_theta, &mut twin_rng);
            assert_eq!(a.accepted, b.accepted, "iter {it}");
            assert_eq!(a.evals, b.evals, "iter {it}: cache state diverged");
            assert_eq!(a.log_density.to_bits(), b.log_density.to_bits(), "iter {it}");
            for (x, y) in theta.iter().zip(&twin_theta) {
                assert_eq!(x.to_bits(), y.to_bits(), "iter {it}");
            }
            assert_eq!(mala.step.to_bits(), twin.step.to_bits(), "iter {it}");
        }
        assert_eq!(mala.acceptance_rate(), twin.acceptance_rate());

        // adaptive-ness mismatch is rejected
        let mut fixed = Mala::new(0.8);
        assert!(fixed.load_state(&mut ByteReader::new(&bytes)).is_err());
    }

    #[test]
    fn reversibility_sanity_log_q_symmetric_when_no_drift() {
        // with zero gradient, q is symmetric
        let from = [0.0, 0.0];
        let to = [0.3, -0.2];
        let g = [0.0, 0.0];
        assert!(
            (Mala::log_q(0.5, &from, &g, &to) - Mala::log_q(0.5, &to, &g, &from)).abs() < 1e-12
        );
    }
}
