//! Austerity (subsampled) Metropolis–Hastings with sequential-test early
//! stopping (Korattikara, Chen & Welling's "austerity" framework, surveyed
//! critically in Bardenet, Doucet & Holmes, "On Markov chain Monte Carlo
//! methods for tall data") — the second approximate tall-data baseline.
//!
//! The exact MH accept test for a symmetric proposal is
//!
//! ```text
//! accept  ⟺  (1/N) Σ_n [log L_n(θ') − log L_n(θ)]  >  μ₀
//! μ₀ = [ln u − (log p(θ') − log p(θ))] / N ,   u ~ U(0,1)
//! ```
//!
//! i.e. a comparison between a *population mean* of per-datum log-likelihood
//! differences and a known threshold. Austerity MH estimates that mean from
//! a growing without-replacement subsample and stops as soon as a sequential
//! t-test (normal-approximation form, with the finite-population correction
//! `√(1 − (c−1)/(N−1))` on the standard error) is confident at level `1−ε`
//! about which side of μ₀ the population mean falls on. If the test never
//! concludes, the batch doubles until the whole dataset is consumed and the
//! decision is exact.
//!
//! The accept decision is therefore *approximately* correct per step — each
//! decision is wrong with probability ≤ ε under the test's normality
//! assumption, and the assumption itself fails on heavy-tailed difference
//! distributions (Bardenet et al.'s critique). The chain's invariant law is
//! biased accordingly; `testing::posterior_check` is the instrument that
//! measures whether that bias is visible.
//!
//! Query metering: each batch evaluates the new indices at both θ and θ',
//! through [`SubsampleTarget::minibatch_log_lik`] (2·batch queries), so a
//! step that stops after `c` data costs `2c` queries vs full MH's `N` — the
//! head-to-head bench reports the realized ratio.

use super::target::SubsampleTarget;
use super::{Sampler, StepInfo, StepSizeAdapter, Target};
use crate::util::math::normal_cdf;
use crate::util::Rng;

/// Subsampled Metropolis–Hastings with sequential-t-test early stopping.
pub struct AusterityMh {
    /// isotropic Gaussian proposal step size
    pub step: f64,
    /// Robbins–Monro acceptance-rate adaptation (None = fixed step)
    pub adapter: Option<StepSizeAdapter>,
    /// per-decision error tolerance ε of the sequential test
    pub eps: f64,
    /// initial minibatch size m₀ (doubles until confident; ≥ 2)
    pub batch0: usize,
    proposal: Vec<f64>,
    /// persistent 0..N index permutation; each step re-prefixes suffixes of
    /// it to extend the consumed sample without replacement
    pool: Vec<u32>,
    ll_cur: Vec<f64>,
    ll_prop: Vec<f64>,
    accepts: u64,
    steps: u64,
    /// total data consumed by sequential tests (diagnostic)
    consumed_total: u64,
}

impl AusterityMh {
    /// Fixed-step austerity MH with tolerance `eps` and initial batch `m0`.
    pub fn new(step: f64, eps: f64, batch0: usize) -> Self {
        assert!(eps > 0.0 && eps < 1.0, "AusterityMh: eps must be in (0,1)");
        assert!(batch0 >= 2, "AusterityMh: batch0 must be at least 2");
        AusterityMh {
            step,
            adapter: None,
            eps,
            batch0,
            proposal: Vec::new(),
            pool: Vec::new(),
            ll_cur: Vec::new(),
            ll_prop: Vec::new(),
            accepts: 0,
            steps: 0,
            consumed_total: 0,
        }
    }

    /// Enable Robbins–Monro adaptation toward 0.234 (freeze after burn-in).
    pub fn adaptive(step: f64, eps: f64, batch0: usize) -> Self {
        let mut s = Self::new(step, eps, batch0);
        s.adapter = Some(StepSizeAdapter::new(0.234));
        s
    }

    /// Stop step-size adaptation (call at the end of burn-in).
    pub fn freeze_adaptation(&mut self) {
        if let Some(a) = &mut self.adapter {
            a.freeze();
        }
    }

    /// Lifetime acceptance rate (NaN before the first step).
    pub fn acceptance_rate(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.accepts as f64 / self.steps as f64
    }

    /// Mean number of data consumed per accept/reject decision (NaN before
    /// the first step) — the early-stopping win the bench reports.
    pub fn avg_consumed(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.consumed_total as f64 / self.steps as f64
    }
}

impl Sampler for AusterityMh {
    // lint: zero-alloc
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut Rng,
    ) -> StepInfo {
        debug_assert_eq!(theta.len(), target.dim());
        let sub = target
            .as_subsample()
            .expect("austerity MH requires a subsample-capable target (full-data posterior)");
        let n = sub.n_data();
        if self.pool.len() != n {
            self.pool.clear();
            self.pool.extend(0..n as u32);
        }

        self.proposal.clear();
        self.proposal
            .extend(theta.iter().map(|&t| t + self.step * rng.normal()));

        // Threshold μ₀ of the exact test, per datum.
        let dprior = sub.prior_log_density(&self.proposal) - sub.prior_log_density(theta);
        let mu0 = (rng.f64_open().ln() - dprior) / n as f64;

        // Sequential test over growing without-replacement batches: running
        // Welford moments of d_i = log L_i(θ') − log L_i(θ).
        let mut consumed = 0usize;
        let mut mean_d = 0.0f64;
        let mut m2_d = 0.0f64;
        let mut sum_prop = 0.0f64;
        let mut take = self.batch0.min(n);
        let accepted = loop {
            // Extend the uniform sample: prefix-shuffle the unconsumed tail,
            // then consume `take` fresh indices from it.
            let tail = self.pool.len() - consumed;
            let take_now = take.min(tail);
            rng.shuffle_prefix(&mut self.pool[consumed..], take_now);
            let batch = &self.pool[consumed..consumed + take_now];
            sub.minibatch_log_lik(theta, batch, &mut self.ll_cur);
            sub.minibatch_log_lik(&self.proposal, batch, &mut self.ll_prop);
            for (&lp, &lc) in self.ll_prop.iter().zip(&self.ll_cur) {
                sum_prop += lp;
                consumed += 1;
                let d = lp - lc;
                let delta = d - mean_d;
                mean_d += delta / consumed as f64;
                m2_d += delta * (d - mean_d);
            }
            if consumed >= n {
                // Whole dataset consumed: the decision is the exact MH test.
                break mean_d > mu0;
            }
            // Std error of the mean with finite-population correction.
            let var = m2_d / (consumed as f64 - 1.0);
            let fpc = 1.0 - (consumed as f64 - 1.0) / (n as f64 - 1.0);
            let se = (var / consumed as f64 * fpc).sqrt();
            if se == 0.0 {
                // Degenerate differences: the mean is known exactly.
                break mean_d > mu0;
            }
            let t_stat = (mean_d - mu0) / se;
            // P(population mean on the other side of μ₀) under the normal
            // approximation; decide once it drops below ε.
            if 1.0 - normal_cdf(t_stat.abs()) < self.eps {
                break mean_d > mu0;
            }
            take = consumed; // double the consumed sample
        };
        self.consumed_total += consumed as u64;
        self.steps += 1;

        let logp = if accepted {
            self.accepts += 1;
            theta.clear();
            theta.extend_from_slice(&self.proposal);
            // Estimated log density at the accepted point from the data the
            // test already touched (no extra queries).
            let est = sub.prior_log_density(theta) + n as f64 / consumed as f64 * sum_prop;
            sub.set_state(theta, est);
            est
        } else {
            target.current_log_density()
        };
        if let Some(a) = &mut self.adapter {
            self.step = a.update(self.step, accepted);
        }
        StepInfo { accepted, evals: 1, log_density: logp }
    }

    fn name(&self) -> &'static str {
        "austerity MH"
    }

    fn freeze_adaptation(&mut self) {
        AusterityMh::freeze_adaptation(self);
    }

    fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.f64(self.step);
        w.u64(self.accepts);
        w.u64(self.steps);
        w.u64(self.consumed_total);
        w.u32_slice(&self.pool);
        w.bool(self.adapter.is_some());
        if let Some(a) = &self.adapter {
            a.save_state(w);
        }
    }

    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.step = r.f64()?;
        self.accepts = r.u64()?;
        self.steps = r.u64()?;
        self.consumed_total = r.u64()?;
        r.u32_slice_into(&mut self.pool)?;
        let adaptive = r.bool()?;
        match (&mut self.adapter, adaptive) {
            (Some(a), true) => a.load_state(r)?,
            (None, false) => {}
            _ => return Err("checkpoint adaptive-ness does not match this sampler".to_string()),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_targets::{GaussDataTarget, GaussTarget};
    use super::*;
    use crate::util::math::{mean, variance};

    fn run(
        s: &mut AusterityMh,
        target: &mut GaussDataTarget,
        iters: usize,
        burnin: usize,
        seed: u64,
    ) -> Vec<f64> {
        let mut theta = vec![target.posterior_mean()];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(seed);
        let mut draws = Vec::new();
        for i in 0..iters {
            s.step(target, &mut theta, &mut rng);
            if i >= burnin {
                draws.push(theta[0]);
            }
        }
        draws
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn approximates_conjugate_posterior() {
        let mut rng = crate::util::Rng::new(41);
        let mut target = GaussDataTarget::synth(500, 0.9, 1.0, 25.0, &mut rng);
        let sd = target.posterior_var().sqrt();
        // Tight tolerance: decisions rarely differ from exact MH.
        let mut s = AusterityMh::new(2.5 * sd, 0.01, 50);
        let draws = run(&mut s, &mut target, 30_000, 2_000, 42);
        let m = mean(&draws);
        assert!((m - target.posterior_mean()).abs() < 0.5 * sd, "mean {m}");
        let ratio = variance(&draws) / target.posterior_var();
        assert!((0.5..2.0).contains(&ratio), "var ratio {ratio}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn early_stopping_consumes_a_strict_subset_on_average() {
        let mut rng = crate::util::Rng::new(43);
        let mut target = GaussDataTarget::synth(1000, 0.4, 1.0, 25.0, &mut rng);
        let sd = target.posterior_var().sqrt();
        // Large proposals make decisions clear-cut, so the first batch
        // usually settles them.
        let mut s = AusterityMh::new(4.0 * sd, 0.05, 50);
        let _ = run(&mut s, &mut target, 2_000, 0, 44);
        let avg = s.avg_consumed();
        assert!(avg < 1000.0, "avg consumed {avg} not below N");
        assert!(avg >= 50.0, "cannot consume less than the first batch");
    }

    #[test]
    fn decisions_deterministic_under_pinned_seed() {
        let mut mk = |seed_data: u64, seed_chain: u64| {
            let mut rng = crate::util::Rng::new(seed_data);
            let mut target = GaussDataTarget::synth(300, 0.2, 1.0, 16.0, &mut rng);
            let mut s = AusterityMh::new(0.2, 0.05, 20);
            let mut theta = vec![0.0];
            target.commit(&theta);
            let mut chain_rng = crate::util::Rng::new(seed_chain);
            let mut bits = Vec::new();
            let mut accept_pattern = Vec::new();
            for _ in 0..200 {
                let info = s.step(&mut target, &mut theta, &mut chain_rng);
                bits.push(theta[0].to_bits());
                accept_pattern.push(info.accepted);
            }
            (bits, accept_pattern, s.consumed_total)
        };
        let (b1, a1, c1) = mk(7, 8);
        let (b2, a2, c2) = mk(7, 8);
        assert_eq!(b1, b2, "trace bits differ under identical seeds");
        assert_eq!(a1, a2, "accept decisions differ under identical seeds");
        assert_eq!(c1, c2, "consumed counts differ under identical seeds");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn adaptation_reaches_0234() {
        let mut rng = crate::util::Rng::new(45);
        let mut target = GaussDataTarget::synth(300, 0.0, 1.0, 16.0, &mut rng);
        let mut s = AusterityMh::adaptive(10.0, 0.05, 20);
        let mut theta = vec![0.0];
        target.commit(&theta);
        let mut chain_rng = crate::util::Rng::new(46);
        for _ in 0..4000 {
            s.step(&mut target, &mut theta, &mut chain_rng);
        }
        s.freeze_adaptation();
        let (a0, s0) = (s.accepts, s.steps);
        for _ in 0..8000 {
            s.step(&mut target, &mut theta, &mut chain_rng);
        }
        let rate = (s.accepts - a0) as f64 / (s.steps - s0) as f64;
        assert!((rate - 0.234).abs() < 0.1, "acceptance {rate}");
    }

    #[test]
    #[should_panic(expected = "subsample-capable")]
    fn refuses_opaque_targets() {
        let mut target = GaussTarget::new(2, 1.0);
        let mut theta = vec![0.0; 2];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(1);
        AusterityMh::new(0.5, 0.05, 10).step(&mut target, &mut theta, &mut rng);
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let mut rng_data = crate::util::Rng::new(51);
        let mut target = GaussDataTarget::synth(120, 0.1, 1.0, 9.0, &mut rng_data);
        let mut twin_rng = crate::util::Rng::new(51);
        let mut twin_target = GaussDataTarget::synth(120, 0.1, 1.0, 9.0, &mut twin_rng);
        let mut s = AusterityMh::adaptive(0.3, 0.05, 10);
        let mut theta = vec![0.0];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(52);
        for _ in 0..60 {
            s.step(&mut target, &mut theta, &mut rng);
        }
        let mut w = ByteWriter::new();
        s.save_state(&mut w);
        rng.save_state(&mut w);
        w.f64_slice(&theta);
        w.f64(target.current_log_density());
        let bytes = w.into_bytes();

        let mut resumed = AusterityMh::adaptive(0.3, 0.05, 10);
        let mut r = ByteReader::new(&bytes);
        resumed.load_state(&mut r).unwrap();
        let mut rng2 = crate::util::Rng::load_state(&mut r).unwrap();
        let mut theta2 = r.f64_vec().unwrap();
        let logp = r.f64().unwrap();
        r.finish().unwrap();
        twin_target.set_state(&theta2, logp);

        for i in 0..60 {
            let i1 = s.step(&mut target, &mut theta, &mut rng);
            let i2 = resumed.step(&mut twin_target, &mut theta2, &mut rng2);
            assert_eq!(theta[0].to_bits(), theta2[0].to_bits(), "diverged at {i}");
            assert_eq!(i1.accepted, i2.accepted, "decision diverged at {i}");
        }
    }

    #[test]
    fn mismatched_adaptiveness_rejected_on_load() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let s = AusterityMh::adaptive(0.3, 0.05, 10);
        let mut w = ByteWriter::new();
        s.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut fixed = AusterityMh::new(0.3, 0.05, 10);
        assert!(fixed.load_state(&mut ByteReader::new(&bytes)).is_err());
    }
}
