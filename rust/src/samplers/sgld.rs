//! Stochastic-gradient Langevin dynamics (Welling & Teh; survey treatment in
//! Nemeth & Fearnhead, "Stochastic gradient Markov chain Monte Carlo") — the
//! first of the repo's *approximate* tall-data competitor baselines.
//!
//! One iteration draws a uniform without-replacement minibatch `S` of size
//! `m`, forms the unbiased gradient estimate
//!
//! ```text
//! ĝ(θ) = ∇ log p(θ) + (N/m) Σ_{i∈S} ∇ log L_i(θ)                  (plain)
//! ĝ(θ) = ∇ log p(θ) + G(θ̂) + (N/m) Σ_{i∈S} [∇ log L_i(θ) − ∇ log L_i(θ̂)]
//!                                                                  (CV)
//! ```
//!
//! and moves `θ ← θ + (ε_t/2) ĝ + √ε_t ξ`, `ξ ~ N(0, I)`, with the decaying
//! step schedule `ε_t = a (b + t)^{-γ}`. The control-variate (CV) form
//! anchors at the MAP point `θ̂` the FlyMC pipeline already computes:
//! `G(θ̂) = Σ_n ∇ log L_n(θ̂)` is evaluated once over the full dataset at the
//! first step, after which each iteration touches `2m` likelihood terms (the
//! minibatch gradient at θ and at θ̂) instead of `m` — variance falls ∝ to
//! the squared distance from the anchor, a good trade near the mode.
//!
//! There is no accept/reject: every step "accepts", and the invariant
//! distribution is only approximate (O(ε) bias at fixed step — which is
//! exactly what `testing::posterior_check` is built to detect, and what the
//! paper's exactness claim is measured against). With `γ = 0` the step never
//! decays; the integration suite uses that deliberately-biased mode to prove
//! the statistical harness has power.
//!
//! Query metering: minibatch gradients route through
//! [`SubsampleTarget::minibatch_grad_acc`] and are counted by the backend at
//! `idx.len()` likelihood queries per call, so queries/iteration (m, or 2m
//! for CV, plus the one-time N for the anchor) is directly comparable to
//! FlyMC's bright-set accounting in the head-to-head bench.
//!
//! The recorded `StepInfo::log_density` is the minibatch estimate
//! `log p(θ) + (N/m) Σ_{i∈S} log L_i(θ)` formed at the *pre-step* point (a
//! free by-product of the gradient pass) — a diagnostic trace signal, not an
//! exact density.

use super::target::SubsampleTarget;
use super::{Sampler, StepInfo, Target};
use crate::util::Rng;

/// Stochastic-gradient Langevin dynamics over a [`SubsampleTarget`].
pub struct Sgld {
    /// minibatch size m (clamped to N at step time)
    pub minibatch: usize,
    /// step-schedule scale a in ε_t = a (b + t)^{-γ}
    pub a: f64,
    /// step-schedule offset b
    pub b: f64,
    /// step-schedule decay exponent γ (0 = fixed step, deliberately biased)
    pub gamma: f64,
    /// control-variate anchor θ̂ (None = plain SGLD)
    anchor: Option<Vec<f64>>,
    /// Σ_n ∇ log L_n(θ̂), filled on the first step when anchored
    anchor_grad: Vec<f64>,
    anchor_ready: bool,
    /// iteration counter t driving the schedule
    t: u64,
    /// persistent 0..N index permutation the minibatches are prefixed from
    pool: Vec<u32>,
    /// current minibatch indices
    idx: Vec<u32>,
    /// gradient-estimate accumulator
    ghat: Vec<f64>,
    /// anchor-minibatch gradient accumulator (CV only)
    gaux: Vec<f64>,
}

impl Sgld {
    /// Plain SGLD with minibatch size `m` and schedule `ε_t = a (b + t)^{-γ}`.
    pub fn new(minibatch: usize, a: f64, b: f64, gamma: f64) -> Self {
        assert!(minibatch > 0, "Sgld: minibatch must be positive");
        assert!(a > 0.0 && b > 0.0 && gamma >= 0.0, "Sgld: invalid schedule");
        Sgld {
            minibatch,
            a,
            b,
            gamma,
            anchor: None,
            anchor_grad: Vec::new(),
            anchor_ready: false,
            t: 0,
            pool: Vec::new(),
            idx: Vec::new(),
            ghat: Vec::new(),
            gaux: Vec::new(),
        }
    }

    /// Enable the control-variate gradient anchored at `anchor` (the MAP
    /// point the FlyMC pipeline tunes bounds at).
    pub fn with_anchor(mut self, anchor: Vec<f64>) -> Self {
        self.anchor = Some(anchor);
        self
    }

    /// Step size the schedule yields at iteration `t`.
    pub fn step_size_at(&self, t: u64) -> f64 {
        self.a * (self.b + t as f64).powf(-self.gamma)
    }

    /// Iterations taken so far.
    pub fn iterations(&self) -> u64 {
        self.t
    }

    fn ensure_buffers(&mut self, n: usize, d: usize) {
        if self.pool.len() != n {
            self.pool.clear();
            self.pool.extend(0..n as u32);
        }
        let m = self.minibatch.min(n);
        self.idx.resize(m, 0);
        self.ghat.resize(d, 0.0);
        self.gaux.resize(d, 0.0);
        self.anchor_grad.resize(d, 0.0);
    }
}

impl Sampler for Sgld {
    // lint: zero-alloc
    fn step(
        &mut self,
        target: &mut dyn Target,
        theta: &mut Vec<f64>,
        rng: &mut Rng,
    ) -> StepInfo {
        debug_assert_eq!(theta.len(), target.dim());
        let d = theta.len();
        let sub = target
            .as_subsample()
            .expect("SGLD requires a subsample-capable target (full-data posterior)");
        let n = sub.n_data();
        self.ensure_buffers(n, d);
        let m = self.idx.len();
        let scale = n as f64 / m as f64;

        // One-time full-data anchor gradient for the CV estimator, computed
        // over the pool in its pristine 0..N order (before any shuffling) so
        // the float reduction order is canonical and deterministic.
        if self.anchor.is_some() && !self.anchor_ready {
            self.anchor_grad.fill(0.0);
            let anchor = self.anchor.as_ref().expect("checked above");
            sub.minibatch_grad_acc(anchor, &self.pool, &mut self.anchor_grad);
            self.anchor_ready = true;
        }

        rng.sample_without_replacement_into(&mut self.pool, &mut self.idx);
        let eps = self.step_size_at(self.t);
        self.t += 1;

        // Likelihood part of the gradient estimate.
        self.ghat.fill(0.0);
        let ll_sum = sub.minibatch_grad_acc(theta, &self.idx, &mut self.ghat);
        if let Some(anchor) = &self.anchor {
            self.gaux.fill(0.0);
            sub.minibatch_grad_acc(anchor, &self.idx, &mut self.gaux);
            for ((g, &ga), &gfull) in
                self.ghat.iter_mut().zip(&self.gaux).zip(&self.anchor_grad)
            {
                *g = gfull + scale * (*g - ga);
            }
        } else {
            for g in &mut self.ghat {
                *g *= scale;
            }
        }
        sub.prior_grad_acc(theta, &mut self.ghat);

        // Minibatch density estimate at the pre-step point (diagnostic).
        let logp_est = sub.prior_log_density(theta) + scale * ll_sum;

        // Langevin move: θ += (ε/2) ĝ + √ε ξ.
        let noise = eps.sqrt();
        for (th, &g) in theta.iter_mut().zip(&self.ghat) {
            *th += 0.5 * eps * g + noise * rng.normal();
        }
        sub.set_state(theta, logp_est);
        StepInfo { accepted: true, evals: 1, log_density: logp_est }
    }

    fn name(&self) -> &'static str {
        if self.anchor.is_some() {
            "SGLD-CV"
        } else {
            "SGLD"
        }
    }

    fn save_state(&self, w: &mut crate::util::codec::ByteWriter) {
        w.u64(self.t);
        w.bool(self.anchor_ready);
        if self.anchor_ready {
            w.f64_slice(&self.anchor_grad);
        }
        w.u32_slice(&self.pool);
    }

    fn load_state(&mut self, r: &mut crate::util::codec::ByteReader) -> Result<(), String> {
        self.t = r.u64()?;
        self.anchor_ready = r.bool()?;
        if self.anchor_ready {
            if self.anchor.is_none() {
                return Err("checkpoint has a CV anchor gradient, sampler has no anchor".into());
            }
            r.f64_slice_into(&mut self.anchor_grad)?;
        }
        r.u32_slice_into(&mut self.pool)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_targets::{GaussDataTarget, GaussTarget};
    use super::*;
    use crate::util::math::{mean, variance};

    fn run_sgld(
        sgld: &mut Sgld,
        target: &mut GaussDataTarget,
        iters: usize,
        burnin: usize,
        seed: u64,
    ) -> Vec<f64> {
        let mut theta = vec![target.posterior_mean()];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(seed);
        let mut draws = Vec::new();
        for i in 0..iters {
            sgld.step(target, &mut theta, &mut rng);
            if i >= burnin {
                draws.push(theta[0]);
            }
        }
        draws
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn tracks_conjugate_posterior_mean() {
        let mut rng = crate::util::Rng::new(11);
        let mut target = GaussDataTarget::synth(400, 1.2, 1.0, 25.0, &mut rng);
        // Small near-constant step: bias O(ε) stays below the check tolerance.
        let mut sgld = Sgld::new(32, 2e-5, 1.0, 0.05);
        let draws = run_sgld(&mut sgld, &mut target, 30_000, 2_000, 12);
        let m = mean(&draws);
        let sd = target.posterior_var().sqrt();
        assert!(
            (m - target.posterior_mean()).abs() < 0.5 * sd,
            "mean {m} vs {}",
            target.posterior_mean()
        );
        let ratio = variance(&draws) / target.posterior_var();
        assert!((0.3..3.0).contains(&ratio), "var ratio {ratio}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn cv_variant_tracks_posterior_too() {
        let mut rng = crate::util::Rng::new(13);
        let mut target = GaussDataTarget::synth(400, -0.7, 1.0, 25.0, &mut rng);
        let anchor = vec![target.posterior_mean()]; // MAP ≈ posterior mean here
        let mut sgld = Sgld::new(32, 2e-5, 1.0, 0.05).with_anchor(anchor);
        assert_eq!(sgld.name(), "SGLD-CV");
        let draws = run_sgld(&mut sgld, &mut target, 30_000, 2_000, 14);
        let m = mean(&draws);
        let sd = target.posterior_var().sqrt();
        assert!((m - target.posterior_mean()).abs() < 0.5 * sd, "mean {m}");
    }

    #[test]
    #[cfg_attr(miri, ignore = "statistical loop is too slow under Miri")]
    fn large_fixed_step_overdisperses() {
        // γ=0 with a step ~40× posterior variance: the invariant law is
        // visibly wrong — the mode integration_baselines relies on.
        let mut rng = crate::util::Rng::new(15);
        let mut target = GaussDataTarget::synth(400, 0.5, 1.0, 25.0, &mut rng);
        let mut sgld = Sgld::new(32, 1e-1, 1.0, 0.0);
        let draws = run_sgld(&mut sgld, &mut target, 8_000, 500, 16);
        let v = variance(&draws);
        assert!(v > 3.0 * target.posterior_var(), "var {v} not inflated");
    }

    #[test]
    fn schedule_decays_and_gamma0_is_fixed() {
        let s = Sgld::new(8, 1e-3, 10.0, 0.55);
        assert!(s.step_size_at(0) > s.step_size_at(100));
        let fixed = Sgld::new(8, 1e-3, 10.0, 0.0);
        assert_eq!(fixed.step_size_at(0), fixed.step_size_at(10_000));
    }

    #[test]
    #[should_panic(expected = "subsample-capable")]
    fn refuses_opaque_targets() {
        let mut target = GaussTarget::new(2, 1.0);
        let mut theta = vec![0.0; 2];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(1);
        Sgld::new(4, 1e-4, 1.0, 0.0).step(&mut target, &mut theta, &mut rng);
    }

    #[test]
    fn state_roundtrip_resumes_bit_identically() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let mut rng_data = crate::util::Rng::new(21);
        let mut target = GaussDataTarget::synth(100, 0.3, 1.0, 9.0, &mut rng_data);
        let mut twin_rng = crate::util::Rng::new(21);
        let mut twin_target = GaussDataTarget::synth(100, 0.3, 1.0, 9.0, &mut twin_rng);
        let mut sgld = Sgld::new(16, 1e-4, 1.0, 0.3);
        let mut theta = vec![0.0];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(22);
        for _ in 0..50 {
            sgld.step(&mut target, &mut theta, &mut rng);
        }
        // checkpoint sampler + rng + theta
        let mut w = ByteWriter::new();
        sgld.save_state(&mut w);
        rng.save_state(&mut w);
        w.f64_slice(&theta);
        let bytes = w.into_bytes();

        let mut resumed = Sgld::new(16, 1e-4, 1.0, 0.3);
        let mut r = ByteReader::new(&bytes);
        resumed.load_state(&mut r).unwrap();
        let mut rng2 = crate::util::Rng::load_state(&mut r).unwrap();
        let mut theta2 = r.f64_vec().unwrap();
        r.finish().unwrap();
        twin_target.commit(&theta2);
        target.commit(&theta); // align committed state representations

        for i in 0..50 {
            sgld.step(&mut target, &mut theta, &mut rng);
            resumed.step(&mut twin_target, &mut theta2, &mut rng2);
            assert_eq!(theta[0].to_bits(), theta2[0].to_bits(), "diverged at {i}");
        }
    }

    #[test]
    fn cv_anchor_mismatch_is_rejected_on_load() {
        use crate::util::codec::{ByteReader, ByteWriter};
        let mut rng_data = crate::util::Rng::new(31);
        let mut target = GaussDataTarget::synth(50, 0.0, 1.0, 4.0, &mut rng_data);
        let mut sgld = Sgld::new(8, 1e-4, 1.0, 0.0).with_anchor(vec![0.1]);
        let mut theta = vec![0.0];
        target.commit(&theta);
        let mut rng = crate::util::Rng::new(32);
        sgld.step(&mut target, &mut theta, &mut rng); // computes anchor grad
        let mut w = ByteWriter::new();
        sgld.save_state(&mut w);
        let bytes = w.into_bytes();
        let mut plain = Sgld::new(8, 1e-4, 1.0, 0.0);
        assert!(plain.load_state(&mut ByteReader::new(&bytes)).is_err());
    }
}
