//! `firefly` CLI — run the paper's experiments from the command line.
//!
//! Subcommands:
//!   run        — run one experiment (flags or --config TOML), print summary
//!   resume     — continue a checkpointed experiment from its --checkpoint-dir
//!                (bit-identical to the never-interrupted run)
//!   table1     — run all three algorithms for a task, print the Table-1 rows
//!   map        — run the MAP estimation alone, print the objective
//!   convert    — write a CSV file or a synthetic workload as a `.fbin`
//!                out-of-core dataset; `convert shard` splits a `.fbin`
//!                into K shard files + a `.fshard` manifest
//!   worker     — serve one dataset shard to a `--backend dist` coordinator
//!   artifacts  — list the XLA artifacts the runtime can see
//!
//! Examples:
//!   firefly run --task mnist --algorithm map --iters 2000
//!   firefly table1 --task mnist --n 12214 --iters 1500 --chains 2
//!   firefly convert --task opv --n 1800000 --out opv.fbin
//!   firefly convert --csv data.csv --kind logistic --out data.fbin
//!   firefly run --task opv --data opv.fbin --cache-rows 65536
//!   firefly run --task mnist --iters 1000000 --checkpoint-every 10000 \
//!       --checkpoint-dir ckpt
//!   firefly resume --task mnist --iters 1000000 --checkpoint-every 10000 \
//!       --checkpoint-dir ckpt
//!   firefly run --task mnist --backend dist --workers 4
//!   firefly convert shard --src mnist.fbin --shards 2 --out-dir shards
//!   firefly worker --manifest shards/mnist.fshard --index 0 --listen 0.0.0.0:7001
//!   firefly run --task mnist --backend dist --connect h1:7001,h2:7002 \
//!       --dist-manifest shards/mnist.fshard

use firefly::bench_harness::Report;
use firefly::cli::Args;
use firefly::configx::{Algorithm, Backend, ExperimentConfig, Task};
use firefly::data::fbin::LabelKind;
use firefly::engine::{run_experiment, synth_dataset, ExperimentResult};
use firefly::runtime::Manifest;

fn usage() -> ! {
    eprintln!(
        "usage: firefly <run|resume|table1|map|convert|worker|artifacts> [flags]
  common flags:
    --task mnist|cifar|opv|toy     workload (default mnist)
    --algo flymc|full|sgld|austerity  algorithm, incl. the approximate
                                   competitors (--algorithm regular|untuned|
                                   map spells the exact ones; default map)
    --backend cpu|parcpu|dist|xla  likelihood backend (default cpu; parcpu
                                   shards batches across threads; dist shards
                                   them across worker processes, bit-identical
                                   to cpu — see DESIGN.md §Distribution)
    --n <int>                      dataset size (default: paper scale)
    --iters / --burnin <int>
    --chains <int>                 replica chains, run concurrently on the
                                   cpu backends (split-R-hat reported for >= 2)
    --threads <int>                worker-thread cap for replicas and the
                                   parcpu shards (default 0 = automatic)
    --seed <int>
    --q <float>                    q_dark->bright override
    --explicit                     use explicit (Alg 1) z-resampling
    --reanchor                     re-anchor the bounds at the running
                                   posterior mean once burn-in ends (FlyMC
                                   only; exact — a legal Markov restart)
    --reanchor-at <int>            re-anchor trigger iteration (default:
                                   end of burn-in; must lie inside burn-in)
    --adapt-q                      Robbins-Monro adaptation of q_dark->bright
                                   toward a target z-turnover during early
                                   burn-in (frozen afterwards; FlyMC only)
    --adapt-window <int>           adaptation window in iterations (default
                                   burnin/2; must end strictly inside burn-in)
    --data <file.fbin>             sample this out-of-core dataset instead of
                                   synthesizing (label kind must match --task;
                                   --n is ignored)
    --cache-rows <int>             block-cache budget in rows per reader for
                                   --data (0 = default)
    --config <file.toml>           load config file first, flags override
    --artifacts <dir>              artifact directory (default artifacts)
    --checkpoint-every <int>       write a .fckpt chain checkpoint every k
                                   iterations (requires --checkpoint-dir)
    --checkpoint-dir <dir>         one chain_NNNN.fckpt per replica chain;
                                   `firefly resume` continues from here,
                                   bit-identical to an uninterrupted run
    --stop-after <int>             bound this session to k iterations per
                                   chain (checkpointed at the stop point;
                                   resume later)
    --streaming-only               keep only O(dim) streaming statistics
                                   (no θ trace / per-iteration series):
                                   bounded memory for very long chains
    --record-every <int>           full-data log-posterior instrumentation
                                   cadence (0 disables; default 1 — set 0
                                   for long runs, it costs N queries/tick)
  approximate-sampler flags (--algo sgld|austerity):
    --minibatch <int>              subsample size per step (default 100)
    --sgld-step-a/-b/-gamma <float>  SGLD step schedule a(b+t)^-gamma
                                   (gamma 0 = fixed step; default 1e-5/1/0.55)
    --sgld-cv                      control-variate gradient anchored at the
                                   MAP point (computed during setup)
    --austerity-eps <float>        sequential-test error tolerance per
                                   austerity MH decision (default 0.05)
  dist-backend flags (--backend dist):
    --workers <int>                spawn this many in-process localhost shard
                                   workers (exclusive with --connect)
    --connect <host:port,...>      standalone `firefly worker` addresses, one
                                   per shard in ascending shard order
    --dist-timeout-ms <int>        per-request I/O timeout (default 5000;
                                   0 = block forever)
    --dist-retries <int>           bounded reconnect/resend attempts per
                                   request (default 3)
    --dist-backoff-ms <int>        sleep between retry attempts (default 200)
    --dist-manifest <file.fshard>  cross-check worker placement against this
                                   shard manifest at startup
  convert flags:
    --out <file.fbin>              output path (required)
    --csv <file.csv>               convert a CSV file (streamed row by row)
    --kind logistic|softmax|regression  CSV label kind (default logistic)
    --no-bias                      do not append a bias column to CSV rows
    --task/--n/--seed              without --csv: write the task's synthetic
                                   workload (paper-scale N by default)
  convert shard flags (split a .fbin for `firefly worker` processes):
    --src <file.fbin>              dataset to split (required; streamed)
    --shards <int>                 shard count K (required)
    --out-dir <dir>                output directory (default: alongside --src)
    --cache-rows <int>             reader block-cache budget while splitting
  worker flags (serve one shard to a --backend dist coordinator):
    --manifest <file.fshard>       shard manifest (required)
    --index <int>                  which shard of the manifest to own (required)
    --listen <host:port>           bind address (default 127.0.0.1:0, prints
                                   the bound port); blocks until a coordinator
                                   sends shutdown
    --cache-rows <int>             block-cache budget in rows for the shard"
    );
    std::process::exit(2);
}

fn config_from_args(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        ExperimentConfig::from_str_toml(&text)?
    } else {
        ExperimentConfig::default()
    };
    if let Some(t) = args.get("task") {
        cfg.task = Task::parse(t)?;
    }
    // --algo is the head-to-head spelling (flymc|full|sgld|austerity);
    // --algorithm keeps the historical exact-stack names. Same parser.
    if let Some(a) = args.get("algorithm").or_else(|| args.get("algo")) {
        cfg.algorithm = Algorithm::parse(a)?;
    }
    if let Some(b) = args.get("backend") {
        cfg.backend = Backend::parse(b)?;
    }
    if let Some(n) = args.get("n") {
        cfg.n_data = Some(n.parse().map_err(|_| "bad --n")?);
    }
    cfg.iters = args.get_usize("iters", cfg.iters);
    cfg.burnin = args.get_usize("burnin", cfg.burnin);
    cfg.chains = args.get_usize("chains", cfg.chains);
    cfg.threads = args.get_usize("threads", cfg.threads);
    cfg.seed = args.get_u64("seed", cfg.seed);
    if let Some(q) = args.get("q") {
        cfg.q_dark_to_bright = Some(q.parse().map_err(|_| "bad --q")?);
    }
    if args.has("explicit") {
        cfg.explicit_resample = true;
    }
    if args.has("reanchor") {
        cfg.reanchor = true;
    }
    if let Some(v) = args.get("reanchor-at") {
        cfg.reanchor = true;
        cfg.reanchor_at = Some(v.parse().map_err(|_| "bad --reanchor-at")?);
    }
    if args.has("adapt-q") {
        cfg.adapt_q = true;
    }
    if let Some(v) = args.get("adapt-window") {
        cfg.adapt_q = true;
        cfg.adapt_window = Some(v.parse().map_err(|_| "bad --adapt-window")?);
    }
    cfg.map_steps = args.get_usize("map-steps", cfg.map_steps);
    cfg.artifacts_dir = args.get_str("artifacts", &cfg.artifacts_dir);
    if let Some(p) = args.get("data") {
        cfg.data_path = Some(p.to_string());
    }
    cfg.cache_rows = args.get_usize("cache-rows", cfg.cache_rows);
    cfg.checkpoint_every = args.get_usize("checkpoint-every", cfg.checkpoint_every);
    if let Some(d) = args.get("checkpoint-dir") {
        cfg.checkpoint_dir = Some(d.to_string());
    }
    if let Some(s) = args.get("stop-after") {
        cfg.stop_after = Some(s.parse().map_err(|_| "bad --stop-after")?);
    }
    if args.has("streaming-only") {
        cfg.record_trace = false;
    }
    cfg.record_every = args.get_usize("record-every", cfg.record_every);
    // approximate-sampler knobs ([approx] section equivalents)
    cfg.minibatch = args.get_usize("minibatch", cfg.minibatch);
    cfg.sgld_step_a = args.get_f64("sgld-step-a", cfg.sgld_step_a);
    cfg.sgld_step_b = args.get_f64("sgld-step-b", cfg.sgld_step_b);
    cfg.sgld_step_gamma = args.get_f64("sgld-step-gamma", cfg.sgld_step_gamma);
    if args.has("sgld-cv") {
        cfg.sgld_cv = true;
    }
    cfg.austerity_eps = args.get_f64("austerity-eps", cfg.austerity_eps);
    // dist-backend topology ([dist] section equivalents)
    cfg.dist_workers = args.get_usize("workers", cfg.dist_workers);
    if let Some(list) = args.get("connect") {
        cfg.dist_connect = firefly::configx::parse_connect_list(list);
    }
    cfg.dist_timeout_ms = args.get_u64("dist-timeout-ms", cfg.dist_timeout_ms);
    cfg.dist_retries = args.get_usize("dist-retries", cfg.dist_retries as usize) as u32;
    cfg.dist_retry_backoff_ms = args.get_u64("dist-backoff-ms", cfg.dist_retry_backoff_ms);
    if let Some(m) = args.get("dist-manifest") {
        cfg.dist_manifest = Some(m.to_string());
    }
    cfg.validate()?;
    Ok(cfg)
}

/// `firefly convert shard`: split a `.fbin` into K shard files plus a
/// `.fshard` manifest for `firefly worker` processes (streamed row by row,
/// so the source may be larger than RAM).
fn run_convert_shard(args: &Args) -> Result<(), String> {
    let src = args
        .get("src")
        .ok_or_else(|| "convert shard requires --src <file.fbin>".to_string())?;
    let k = args.get_usize("shards", 0);
    if k == 0 {
        return Err("convert shard requires --shards <K> (K > 0)".to_string());
    }
    let out_dir = match args.get("out-dir") {
        Some(d) => d.to_string(),
        None => std::path::Path::new(src)
            .parent()
            .unwrap_or_else(|| std::path::Path::new("."))
            .to_string_lossy()
            .into_owned(),
    };
    let cache = firefly::data::store::BlockCacheConfig::with_budget(
        args.get_usize("cache-rows", 0),
    );
    let (manifest, manifest_path) = firefly::data::shard::split_fbin(src, &out_dir, k, cache)?;
    println!(
        "wrote {manifest_path}: kind={} N={} D={}{} across {} shards",
        manifest.kind.name(),
        manifest.n,
        manifest.d,
        if manifest.kind == LabelKind::Class {
            format!(" K={}", manifest.k)
        } else {
            String::new()
        },
        manifest.shards.len()
    );
    for (i, s) in manifest.shards.iter().enumerate() {
        println!(
            "  shard {i}: {} rows {}..{} checksum {:#018x}",
            s.file, s.start, s.end, s.checksum
        );
    }
    Ok(())
}

/// `firefly worker`: validate and serve one manifest shard to a
/// `--backend dist` coordinator, blocking until a shutdown request.
fn run_worker(args: &Args) -> Result<(), String> {
    let manifest_path = args
        .get("manifest")
        .ok_or_else(|| "worker requires --manifest <file.fshard>".to_string())?;
    let index = args
        .get("index")
        .ok_or_else(|| "worker requires --index <shard number>".to_string())?
        .parse::<usize>()
        .map_err(|_| "bad --index".to_string())?;
    let listen = args.get_str("listen", "127.0.0.1:0");
    let manifest = firefly::data::shard::ShardManifest::load(manifest_path)?;
    let cache = firefly::data::store::BlockCacheConfig::with_budget(
        args.get_usize("cache-rows", 0),
    );
    // checksum + shape validation happens here, before any coordinator
    // connects — a corrupted or mis-assigned shard never serves a byte
    let data = firefly::data::shard::open_shard(&manifest, manifest_path, index, cache)?;
    let entry = &manifest.shards[index];
    let state = firefly::net::WorkerState::from_data(data, entry.start, entry.end, manifest.n);
    let listener = std::net::TcpListener::bind(&listen).map_err(|e| format!("{listen}: {e}"))?;
    let addr = listener.local_addr().map_err(|e| e.to_string())?;
    println!(
        "worker {index}: serving rows {}..{} of {} ({} kind) on {addr}",
        entry.start,
        entry.end,
        manifest.n,
        manifest.kind.name()
    );
    let ctl = firefly::net::ServeControl::new();
    firefly::net::serve(&listener, state, &ctl, None).map_err(|e| e.to_string())?;
    println!("worker {index}: shutdown requested, exiting");
    Ok(())
}

/// `firefly convert`: CSV or synthetic workload → `.fbin`.
fn run_convert(args: &Args) -> Result<(), String> {
    if args.positional.first().map(String::as_str) == Some("shard") {
        return run_convert_shard(args);
    }
    let out = args
        .get("out")
        .ok_or_else(|| "convert requires --out <file.fbin>".to_string())?
        .to_string();
    let header = if let Some(csv_path) = args.get("csv") {
        let kind = LabelKind::parse(&args.get_str("kind", "logistic"))?;
        let bias = !args.has("no-bias");
        // streamed line by line: the source CSV may be larger than RAM
        let file = std::fs::File::open(csv_path).map_err(|e| format!("{csv_path}: {e}"))?;
        let reader = std::io::BufReader::new(file);
        firefly::data::csv::stream_reader_to_fbin(reader, kind, bias, &out)?
    } else {
        let task = Task::parse(&args.get_str("task", "mnist"))?;
        let n = args.get_usize(
            "n",
            firefly::engine::experiment::default_n(task),
        );
        let seed = args.get_u64("seed", 0);
        let data = synth_dataset(task, n, seed);
        firefly::data::fbin::write_fbin(&out, &data).map_err(|e| format!("{out}: {e}"))?
    };
    println!(
        "wrote {out}: kind={} N={} D={}{}",
        header.label_kind.name(),
        header.n,
        header.d,
        if header.label_kind == LabelKind::Class {
            format!(" K={}", header.k)
        } else {
            String::new()
        }
    );
    Ok(())
}

fn print_summary(res: &ExperimentResult) {
    let row = res.table_row();
    println!("\n=== {} / {:?} ===", row.algorithm, res.config.task);
    println!("data points (N):             {}", res.n_data);
    println!("iterations x chains:         {} x {}", res.config.iters, res.chains.len());
    println!("avg lik queries / iter:      {:.1}", row.avg_lik_queries_per_iter);
    if let Some((min, mean, max, _)) = res.bright_pre_stats() {
        println!(
            "bright points M (pre-reanchor): min {min} / mean {mean:.1} / max {max}"
        );
    }
    if let Some((min, mean, max, last)) = res.bright_stats() {
        println!(
            "bright points M (post-burnin): min {min} / mean {mean:.1} / max {max} / last {last}"
        );
    }
    println!("ESS / 1000 iters (min dim):  {:.2}", row.ess_per_1000);
    if row.split_rhat.is_finite() {
        println!("split-R-hat (worst dim):     {:.3}", row.split_rhat);
    }
    println!("MAP tuning lik queries:      {}", res.map_lik_queries);
    println!("wallclock per chain:         {:.2}s", row.wallclock_secs);
}

fn main() {
    let args = Args::from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| usage());
    match sub.as_str() {
        "run" | "resume" => {
            let resume = sub == "resume";
            let cfg = config_from_args(&args).unwrap_or_else(|e| {
                eprintln!("config error: {e}");
                std::process::exit(2)
            });
            if resume && cfg.checkpoint_dir.is_none() {
                eprintln!("config error: resume requires --checkpoint-dir (or [checkpoint] dir)");
                std::process::exit(2)
            }
            match firefly::engine::run_experiment_resume(&cfg, resume) {
                Ok(res) => {
                    print_summary(&res);
                    if let (Some(stop), Some(dir)) = (cfg.stop_after, &cfg.checkpoint_dir) {
                        println!(
                            "session bounded to {stop} iterations/chain — continue with \
                             `firefly resume --checkpoint-dir {dir} ...` (same flags)"
                        );
                    }
                }
                Err(e) => {
                    eprintln!("experiment failed: {e:#}");
                    std::process::exit(1)
                }
            }
        }
        "table1" => {
            let base = config_from_args(&args).unwrap_or_else(|e| {
                eprintln!("config error: {e}");
                std::process::exit(2)
            });
            let mut report = Report::new(
                &format!("Table 1 — {:?}", base.task),
                &[
                    "Algorithm",
                    "Avg lik queries/iter",
                    "ESS per 1000 iters",
                    "split-R-hat",
                    "Speedup vs regular",
                ],
            );
            let mut regular_row = None;
            for alg in [Algorithm::RegularMcmc, Algorithm::UntunedFlyMc, Algorithm::MapTunedFlyMc]
            {
                let mut cfg = base.clone();
                cfg.algorithm = alg;
                let res = run_experiment(&cfg).unwrap_or_else(|e| {
                    eprintln!("{alg:?} failed: {e:#}");
                    std::process::exit(1)
                });
                let row = res.table_row();
                let speedup = match &regular_row {
                    None => {
                        regular_row = Some(row.clone());
                        "(1)".to_string()
                    }
                    Some(reg) => format!("{:.1}", row.speedup_vs(reg)),
                };
                let rhat = if row.split_rhat.is_finite() {
                    format!("{:.3}", row.split_rhat)
                } else {
                    "-".to_string()
                };
                report.row(&[
                    row.algorithm.clone(),
                    format!("{:.0}", row.avg_lik_queries_per_iter),
                    format!("{:.2}", row.ess_per_1000),
                    rhat,
                    speedup,
                ]);
                print_summary(&res);
            }
            report.print();
        }
        "map" => {
            let cfg = config_from_args(&args).unwrap_or_else(|e| {
                eprintln!("config error: {e}");
                std::process::exit(2)
            });
            let (model, prior, _, _) =
                firefly::engine::experiment::build_model(&cfg).unwrap_or_else(|e| {
                    eprintln!("model error: {e:#}");
                    std::process::exit(1)
                });
            let res = firefly::map_estimate::map_estimate(
                model.as_ref(),
                prior.as_ref(),
                &firefly::map_estimate::MapConfig {
                    steps: cfg.map_steps,
                    seed: cfg.seed,
                    ..Default::default()
                },
            );
            println!("MAP objective estimate: {:.3}", res.final_log_post_estimate);
            println!("lik queries: {}", res.lik_queries);
            println!("theta[0..5]: {:?}", &res.theta[..res.theta.len().min(5)]);
        }
        "convert" => {
            if let Err(e) = run_convert(&args) {
                eprintln!("convert error: {e}");
                std::process::exit(1)
            }
        }
        "worker" => {
            if let Err(e) = run_worker(&args) {
                eprintln!("worker error: {e}");
                std::process::exit(1)
            }
        }
        "artifacts" => {
            let dir = args.get_str("artifacts", "artifacts");
            match Manifest::load(&dir) {
                Ok(m) => {
                    println!("{} artifacts in {dir}:", m.entries.len());
                    for e in &m.entries {
                        println!(
                            "  {:<28} kind={:<8} d={:<4} k={} bucket={}",
                            e.name,
                            e.kind.as_str(),
                            e.d,
                            e.k,
                            e.bucket
                        );
                    }
                }
                Err(e) => {
                    eprintln!("{e}");
                    std::process::exit(1)
                }
            }
        }
        _ => usage(),
    }
}
