//! Shard manifests: splitting one `.fbin` dataset across worker processes.
//!
//! `convert shard` splits a dataset into K contiguous-row `.fbin` shard
//! files (ranges from [`crate::net::shard_ranges`] — the same function the
//! in-process worker spawner and the coordinator's coverage check use, so
//! the three can never disagree on row ownership) plus one `.fshard`
//! manifest recording, per shard: the file name, its global `[start, end)`
//! row range, and an FNV-1a checksum of the complete shard file bytes.
//!
//! The manifest is the integrity contract of a distributed run: a worker
//! validates its own shard file's checksum and row count before serving,
//! and the coordinator validates the manifest's source shape against its
//! model and each worker's claimed placement against the manifest
//! (DESIGN.md §Distribution). A stale or re-split shard therefore fails
//! loudly at startup, never as a silently-wrong likelihood.
//!
//! Layout (little-endian, [`crate::util::codec`]):
//!
//! ```text
//! magic   b"FFLYSHRD"
//! u32     format version (currently 1)
//! u32     label kind (same tag as .fbin)
//! u64     N, D, K of the source dataset
//! u64     shard count
//! per shard: bytes file-name (relative to the manifest), u64 start,
//!            u64 end, u64 fnv1a(shard file bytes)
//! ```

use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use super::fbin::{open_fbin, FbinWriter, LabelKind};
use super::store::BlockCacheConfig;
use super::AnyData;
use crate::util::codec::{fnv1a_continue, ByteReader, ByteWriter, FNV1A_BASIS};

/// The 8-byte magic prefix of every `.fshard` manifest.
pub const SHARD_MAGIC: [u8; 8] = *b"FFLYSHRD";
/// Current manifest format version.
pub const SHARD_VERSION: u32 = 1;

/// One shard's placement and integrity record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// shard file name, relative to the manifest's directory
    pub file: String,
    /// first global row owned (inclusive)
    pub start: usize,
    /// one past the last global row owned (exclusive)
    pub end: usize,
    /// FNV-1a hash of the complete shard file bytes
    pub checksum: u64,
}

/// The manifest for one sharded dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardManifest {
    /// label kind of the source dataset (selects the model family)
    pub kind: LabelKind,
    /// global row count N of the source dataset
    pub n: usize,
    /// feature columns D
    pub d: usize,
    /// class count K (1 unless `kind` is class)
    pub k: usize,
    /// per-shard records, in ascending `start` order
    pub shards: Vec<ShardEntry>,
}

impl ShardManifest {
    /// Structural validation: at least one shard, ranges sorted,
    /// contiguous, and covering exactly `0..n`.
    pub fn validate(&self) -> Result<(), String> {
        if self.shards.is_empty() {
            return Err("manifest lists no shards".to_string());
        }
        if self.shards[0].start != 0 {
            return Err(format!("first shard starts at {}, not 0", self.shards[0].start));
        }
        for (i, s) in self.shards.iter().enumerate() {
            if s.end < s.start {
                return Err(format!("shard {i} has inverted range {}..{}", s.start, s.end));
            }
            if i + 1 < self.shards.len() && self.shards[i + 1].start != s.end {
                return Err(format!(
                    "shard {i} ends at {} but shard {} starts at {} — ranges must tile",
                    s.end,
                    i + 1,
                    self.shards[i + 1].start
                ));
            }
        }
        let last = self.shards.last().unwrap();
        if last.end != self.n {
            return Err(format!("shards cover 0..{} but the source has {} rows", last.end, self.n));
        }
        Ok(())
    }

    /// Serialize to `path`.
    pub fn save(&self, path: &str) -> Result<(), String> {
        let mut w = ByteWriter::new();
        w.u32(SHARD_VERSION);
        w.u32(self.kind.as_u32());
        w.usize(self.n);
        w.usize(self.d);
        w.usize(self.k);
        w.usize(self.shards.len());
        for s in &self.shards {
            w.bytes(s.file.as_bytes());
            w.usize(s.start);
            w.usize(s.end);
            w.u64(s.checksum);
        }
        let mut out = Vec::with_capacity(8 + w.len());
        out.extend_from_slice(&SHARD_MAGIC);
        out.extend_from_slice(w.as_bytes());
        std::fs::write(path, out).map_err(|e| format!("{path}: {e}"))
    }

    /// Load and structurally validate a manifest.
    pub fn load(path: &str) -> Result<ShardManifest, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
        if bytes.len() < 8 || bytes[..8] != SHARD_MAGIC {
            return Err(format!("{path}: not a shard manifest (bad magic)"));
        }
        let mut r = ByteReader::new(&bytes[8..]);
        let inner = || -> Result<ShardManifest, String> {
            let version = r.u32()?;
            if version != SHARD_VERSION {
                return Err(format!(
                    "unsupported manifest version {version} (this build reads {SHARD_VERSION})"
                ));
            }
            let kind_raw = r.u32()?;
            let kind = LabelKind::from_u32(kind_raw)
                .ok_or_else(|| format!("bad label-kind tag {kind_raw}"))?;
            let n = r.usize()?;
            let d = r.usize()?;
            let k = r.usize()?;
            let count = r.usize()?;
            let mut shards = Vec::with_capacity(count.min(1 << 16));
            for _ in 0..count {
                let file = String::from_utf8(r.bytes()?.to_vec())
                    .map_err(|_| "shard file name is not UTF-8".to_string())?;
                let start = r.usize()?;
                let end = r.usize()?;
                let checksum = r.u64()?;
                shards.push(ShardEntry { file, start, end, checksum });
            }
            r.finish()?;
            Ok(ShardManifest { kind, n, d, k, shards })
        };
        let m = inner().map_err(|e| format!("{path}: {e}"))?;
        m.validate().map_err(|e| format!("{path}: {e}"))?;
        Ok(m)
    }

    /// Absolute-ish path of shard `i`'s file: entries are stored relative
    /// to the manifest, so resolve against the manifest's directory.
    pub fn shard_path(&self, manifest_path: &str, i: usize) -> String {
        let dir = Path::new(manifest_path).parent().unwrap_or_else(|| Path::new("."));
        dir.join(&self.shards[i].file).to_string_lossy().into_owned()
    }
}

/// FNV-1a of a whole file, streamed in 64 KiB chunks (shard files are
/// split precisely because they are large).
pub fn checksum_file(path: &str) -> Result<u64, String> {
    let file = File::open(path).map_err(|e| format!("{path}: {e}"))?;
    let mut r = BufReader::new(file);
    let mut buf = vec![0u8; 64 * 1024];
    let mut h = FNV1A_BASIS;
    loop {
        let got = r.read(&mut buf).map_err(|e| format!("{path}: {e}"))?;
        if got == 0 {
            return Ok(h);
        }
        h = fnv1a_continue(h, &buf[..got]);
    }
}

/// Open shard `i` of a manifest for serving: verifies the checksum and the
/// row count against the manifest before handing the dataset back. This is
/// the worker-side startup validation.
pub fn open_shard(
    manifest: &ShardManifest,
    manifest_path: &str,
    i: usize,
    cache: BlockCacheConfig,
) -> Result<AnyData, String> {
    if i >= manifest.shards.len() {
        return Err(format!(
            "shard index {i} out of range: manifest lists {} shards",
            manifest.shards.len()
        ));
    }
    let entry = &manifest.shards[i];
    let path = manifest.shard_path(manifest_path, i);
    let got = checksum_file(&path)?;
    if got != entry.checksum {
        return Err(format!(
            "{path}: checksum mismatch (file hashes to {got:#018x}, manifest says \
             {:#018x}) — re-run `convert shard` or fetch the right shard",
            entry.checksum
        ));
    }
    let data = open_fbin(&path, cache)?;
    if data.n() != entry.end - entry.start {
        return Err(format!(
            "{path}: holds {} rows, manifest range {}..{} implies {}",
            data.n(),
            entry.start,
            entry.end,
            entry.end - entry.start
        ));
    }
    if data.d() != manifest.d {
        return Err(format!("{path}: d = {} but the manifest says {}", data.d(), manifest.d));
    }
    Ok(data)
}

/// Split `src` (a `.fbin` dataset) into `k` contiguous shard files under
/// `out_dir`, writing `<stem>.fshard` there and returning the manifest.
/// Rows stream through the block cache one at a time — the source is never
/// materialized. Class datasets propagate the global K into every shard
/// header via [`FbinWriter::force_classes`].
pub fn split_fbin(
    src: &str,
    out_dir: &str,
    k: usize,
    cache: BlockCacheConfig,
) -> Result<(ShardManifest, String), String> {
    if k == 0 {
        return Err("shard count must be positive".to_string());
    }
    let data = open_fbin(src, cache)?;
    let n = data.n();
    if k > n {
        return Err(format!("cannot split {n} rows into {k} shards (more shards than rows)"));
    }
    let stem = Path::new(src)
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "data".to_string());
    std::fs::create_dir_all(out_dir).map_err(|e| format!("{out_dir}: {e}"))?;

    let (store, label_kind) = match &data {
        AnyData::Logistic(d) => (&d.x, LabelKind::Binary),
        AnyData::Softmax(d) => (&d.x, LabelKind::Class),
        AnyData::Regression(d) => (&d.x, LabelKind::Target),
    };
    let classes = match &data {
        AnyData::Softmax(d) => d.k,
        _ => 1,
    };
    let mut cache_reader = store.new_cache();
    let mut shards = Vec::with_capacity(k);
    for (si, (start, end)) in crate::net::shard_ranges(n, k).into_iter().enumerate() {
        let file = format!("{stem}.shard{si}.fbin");
        let path = Path::new(out_dir).join(&file).to_string_lossy().into_owned();
        let mut w = FbinWriter::create(&path, data.d(), label_kind)
            .map_err(|e| format!("{path}: {e}"))?;
        if label_kind == LabelKind::Class {
            w.force_classes(classes).map_err(|e| format!("{path}: {e}"))?;
        }
        for i in start..end {
            let label = match &data {
                AnyData::Logistic(d) => d.t[i],
                AnyData::Softmax(d) => d.labels[i] as f64,
                AnyData::Regression(d) => d.y[i],
            };
            let row = store.row(i, &mut cache_reader);
            w.push_row(row, label).map_err(|e| format!("{path}: row {i}: {e}"))?;
        }
        w.finish().map_err(|e| format!("{path}: {e}"))?;
        let checksum = checksum_file(&path)?;
        shards.push(ShardEntry { file, start, end, checksum });
    }
    let manifest = ShardManifest {
        kind: label_kind,
        n,
        d: data.d(),
        k: classes,
        shards,
    };
    manifest.validate()?;
    let manifest_path =
        Path::new(out_dir).join(format!("{stem}.fshard")).to_string_lossy().into_owned();
    manifest.save(&manifest_path)?;
    Ok((manifest, manifest_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fbin::write_fbin;
    use crate::data::synth;

    fn tmp_dir(name: &str) -> String {
        let dir = std::env::temp_dir()
            .join(format!("firefly_shard_{}_{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.to_string_lossy().into_owned()
    }

    #[test]
    fn split_and_reopen_all_shards_bitwise() {
        let dir = tmp_dir("roundtrip");
        let src = format!("{dir}/full.fbin");
        let d = synth::synth_mnist(101, 6, 3);
        write_fbin(&src, &AnyData::Logistic(d.clone())).unwrap();
        let (manifest, mpath) =
            split_fbin(&src, &dir, 4, BlockCacheConfig::default()).unwrap();
        assert_eq!(manifest.n, 101);
        assert_eq!(manifest.shards.len(), 4);
        assert_eq!(manifest, ShardManifest::load(&mpath).unwrap());

        let dense = d.x.as_dense().unwrap();
        for (si, entry) in manifest.shards.iter().enumerate() {
            let shard =
                open_shard(&manifest, &mpath, si, BlockCacheConfig::default()).unwrap();
            let AnyData::Logistic(got) = shard else { panic!("wrong kind") };
            assert_eq!(got.t, d.t[entry.start..entry.end]);
            let mut rc = got.x.new_cache();
            for (local, global) in (entry.start..entry.end).enumerate() {
                for (a, b) in got.x.row(local, &mut rc).iter().zip(dense.row(global)) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn class_shards_inherit_global_k() {
        let dir = tmp_dir("classes");
        let src = format!("{dir}/full.fbin");
        // synth_cifar3 is 3-way; with enough shards some slice will miss a
        // class, which must NOT deflate that shard's K
        let d = synth::synth_cifar3(12, 4, 5);
        write_fbin(&src, &AnyData::Softmax(d)).unwrap();
        let (manifest, mpath) =
            split_fbin(&src, &dir, 6, BlockCacheConfig::default()).unwrap();
        assert_eq!(manifest.k, 3);
        for si in 0..manifest.shards.len() {
            let AnyData::Softmax(got) =
                open_shard(&manifest, &mpath, si, BlockCacheConfig::default()).unwrap()
            else {
                panic!("wrong kind")
            };
            assert_eq!(got.k, 3, "shard {si} deflated K");
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn tampered_shard_is_rejected_by_checksum() {
        let dir = tmp_dir("tamper");
        let src = format!("{dir}/full.fbin");
        write_fbin(&src, &AnyData::Regression(synth::synth_opv(40, 3, 9))).unwrap();
        let (manifest, mpath) =
            split_fbin(&src, &dir, 2, BlockCacheConfig::default()).unwrap();
        let victim = manifest.shard_path(&mpath, 1);
        let mut bytes = std::fs::read(&victim).unwrap();
        let at = bytes.len() - 5;
        bytes[at] ^= 0x01; // flip one label bit
        std::fs::write(&victim, &bytes).unwrap();
        let err =
            open_shard(&manifest, &mpath, 1, BlockCacheConfig::default()).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
        // shard 0 is untouched and still opens
        open_shard(&manifest, &mpath, 0, BlockCacheConfig::default()).unwrap();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn manifest_validation_rejects_bad_coverage() {
        let entry = |start, end| ShardEntry {
            file: format!("s{start}.fbin"),
            start,
            end,
            checksum: 0,
        };
        let m = |shards| ShardManifest {
            kind: LabelKind::Binary,
            n: 10,
            d: 2,
            k: 1,
            shards,
        };
        assert!(m(vec![]).validate().is_err());
        assert!(m(vec![entry(1, 10)]).validate().is_err()); // hole at 0
        assert!(m(vec![entry(0, 4), entry(5, 10)]).validate().is_err()); // gap
        assert!(m(vec![entry(0, 6), entry(4, 10)]).validate().is_err()); // overlap
        assert!(m(vec![entry(0, 9)]).validate().is_err()); // short
        assert!(m(vec![entry(0, 5), entry(5, 10)]).validate().is_ok());
    }

    #[test]
    fn streamed_checksum_matches_one_shot() {
        let dir = tmp_dir("fnv");
        let path = format!("{dir}/blob");
        let bytes: Vec<u8> = (0..200_000u32).map(|i| (i * 31 % 251) as u8).collect();
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(checksum_file(&path).unwrap(), crate::util::codec::fnv1a(&bytes));
        let _ = std::fs::remove_dir_all(dir);
    }
}
