//! Unified feature-matrix storage: in-RAM [`DenseStore`] and out-of-core
//! [`BlockStore`] behind one statically-dispatched [`DataStore`] enum.
//!
//! Every model reads its N×D feature matrix through `DataStore`, so the
//! whole stack — models, backends, FlyMC, engine, CLI — is agnostic to
//! whether the dataset is resident (today's behaviour, bit-identical) or
//! served from a versioned `.fbin` file (see [`crate::data::fbin`]) through
//! a direct-mapped block cache of row blocks. Steady-state FlyMC touches
//! only the O(|bright|) rows the bright set names, so the cache working set
//! is a few blocks — not the O(N·D) matrix — and the paper's "larger
//! datasets than previously feasible" claim stops being gated on RAM.
//!
//! ## Ownership and the zero-alloc contract (DESIGN.md §Storage)
//!
//! The store itself is shared (inside the model's `Arc`) and immutable; the
//! mutable state a cached read needs — block slots, tags, the staging byte
//! buffer, hit/miss tallies — lives in a caller-owned [`RowCache`], carried
//! by [`crate::models::EvalScratch`] exactly like the per-datum evaluation
//! buffers. Backends allocate one cache per evaluator (serial) or per
//! worker group (sharded) at construction; [`DataStore::row`] then never
//! allocates: a miss is a positioned `read_exact_at` into the preallocated
//! staging buffer plus an in-place little-endian decode into the slot.
//! Dense reads ignore the cache entirely and return the resident row, so
//! the `DenseStore` path is byte-for-byte the pre-refactor behaviour.
//! [`DataStore::gather_tile`] layers the kernel layer's W-lane SoA gather
//! on top of `row` — same reads, same order, same accounting.

use std::fs::File;
use std::io;

use crate::linalg::Matrix;

/// Sizing for a [`BlockStore`]'s per-reader row caches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockCacheConfig {
    /// rows per cached block (the positioned-read granularity)
    pub rows_per_block: usize,
    /// total cache budget in rows per [`RowCache`] (rounded down to whole
    /// blocks, minimum one block)
    pub cached_rows: usize,
}

impl Default for BlockCacheConfig {
    fn default() -> Self {
        BlockCacheConfig { rows_per_block: 64, cached_rows: 8192 }
    }
}

impl BlockCacheConfig {
    /// Config with a `cached_rows` budget (0 = keep the default budget).
    pub fn with_budget(cached_rows: usize) -> Self {
        let mut c = BlockCacheConfig::default();
        if cached_rows > 0 {
            c.cached_rows = cached_rows;
        }
        c
    }

    fn slots(&self) -> usize {
        (self.cached_rows / self.rows_per_block.max(1)).max(1)
    }
}

/// Caller-owned direct-mapped cache of feature-row blocks.
///
/// All storage is allocated at construction ([`DataStore::new_cache`]);
/// lookups and fills never allocate. `hits`/`misses` are plain (non-atomic)
/// tallies the owning backend drains into [`crate::metrics::Counters`]
/// after each batch via [`RowCache::take_stats`].
#[derive(Clone, Debug, Default)]
pub struct RowCache {
    rows_per_block: usize,
    d: usize,
    /// slot -> cached block id (`u64::MAX` = empty)
    tags: Vec<u64>,
    /// slot-major decoded rows: `slots × rows_per_block × d`
    data: Vec<f64>,
    /// staging buffer for one block's raw bytes
    bytes: Vec<u8>,
    hits: u64,
    misses: u64,
}

impl RowCache {
    /// A zero-capacity cache: what dense stores hand out (their reads never
    /// consult it). Feeding it to a [`BlockStore`] read panics.
    pub fn empty() -> Self {
        RowCache::default()
    }

    fn sized(d: usize, cfg: BlockCacheConfig) -> Self {
        let rpb = cfg.rows_per_block.max(1);
        let slots = cfg.slots();
        RowCache {
            rows_per_block: rpb,
            d,
            tags: vec![u64::MAX; slots],
            data: vec![0.0; slots * rpb * d],
            bytes: vec![0; rpb * d * 8],
            hits: 0,
            misses: 0,
        }
    }

    /// Number of block slots (0 for the dense/empty cache).
    pub fn slots(&self) -> usize {
        self.tags.len()
    }

    /// Drain and zero the (hits, misses) tallies accumulated since the last
    /// call — the backend flushes these into its shared counters.
    pub fn take_stats(&mut self) -> (u64, u64) {
        let out = (self.hits, self.misses);
        self.hits = 0;
        self.misses = 0;
        out
    }
}

/// Today's storage: the resident row-major [`Matrix`]. Reads are direct
/// slice borrows — bit-identical to the pre-`DataStore` code.
#[derive(Clone, Debug)]
pub struct DenseStore {
    /// the resident N×D feature matrix
    pub x: Matrix,
}

/// Out-of-core reader over the feature block of a `.fbin` dataset file
/// (format: [`crate::data::fbin`]), serving rows through caller-owned
/// [`RowCache`]s with pure-`std` positioned reads.
#[derive(Debug)]
pub struct BlockStore {
    file: File,
    n: usize,
    d: usize,
    /// byte offset of the row-major f64 feature block within the file
    feat_off: u64,
    cache_cfg: BlockCacheConfig,
}

impl Clone for BlockStore {
    fn clone(&self) -> Self {
        BlockStore {
            file: self.file.try_clone().expect("duplicate BlockStore file handle"),
            n: self.n,
            d: self.d,
            feat_off: self.feat_off,
            cache_cfg: self.cache_cfg,
        }
    }
}

#[cfg(unix)]
fn read_exact_at(file: &File, buf: &mut [u8], off: u64) -> io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, off)
}

#[cfg(windows)]
fn read_exact_at(file: &File, buf: &mut [u8], mut off: u64) -> io::Result<()> {
    use std::os::windows::fs::FileExt;
    let mut buf = buf;
    while !buf.is_empty() {
        match file.seek_read(buf, off) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "unexpected end of file",
                ))
            }
            Ok(k) => {
                let tmp = buf;
                buf = &mut tmp[k..];
                off += k as u64;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(not(any(unix, windows)))]
fn read_exact_at(_file: &File, _buf: &mut [u8], _off: u64) -> io::Result<()> {
    Err(io::Error::new(
        io::ErrorKind::Unsupported,
        "positioned file reads are not supported on this platform",
    ))
}

impl BlockStore {
    /// Wrap an open dataset file whose feature block (`n × d` row-major f64,
    /// little-endian) starts at byte `feat_off`. The caller (the `.fbin`
    /// reader) has already validated the header and file length.
    pub fn new(
        file: File,
        n: usize,
        d: usize,
        feat_off: u64,
        cache_cfg: BlockCacheConfig,
    ) -> Self {
        BlockStore { file, n, d, feat_off, cache_cfg }
    }

    /// The per-reader cache sizing this store hands out.
    pub fn cache_config(&self) -> BlockCacheConfig {
        self.cache_cfg
    }

    /// Read row `i` through `cache`, filling the row's block on a miss.
    // lint: zero-alloc
    fn row<'a>(&self, i: usize, cache: &'a mut RowCache) -> &'a [f64] {
        assert!(i < self.n, "row {i} out of range (n={})", self.n);
        assert!(
            cache.slots() > 0 && cache.d == self.d,
            "BlockStore read through an unsized RowCache — build it with \
             DataStore::new_cache()"
        );
        let rpb = cache.rows_per_block;
        let block = i / rpb;
        let slot = block % cache.tags.len();
        let slot_base = slot * rpb * self.d;
        if cache.tags[slot] != block as u64 {
            cache.misses += 1;
            let rows = rpb.min(self.n - block * rpb);
            let nbytes = rows * self.d * 8;
            let off = self.feat_off + (block * rpb * self.d) as u64 * 8;
            read_exact_at(&self.file, &mut cache.bytes[..nbytes], off)
                .expect("BlockStore positioned read failed");
            for (v, chunk) in cache.data[slot_base..slot_base + rows * self.d]
                .iter_mut()
                .zip(cache.bytes[..nbytes].chunks_exact(8))
            {
                *v = f64::from_le_bytes(chunk.try_into().unwrap());
            }
            cache.tags[slot] = block as u64;
        } else {
            cache.hits += 1;
        }
        let base = slot_base + (i - block * rpb) * self.d;
        &cache.data[base..base + self.d]
    }

    /// Single-element positioned read (test/tool convenience; slow).
    fn get(&self, i: usize, j: usize) -> f64 {
        assert!(i < self.n && j < self.d);
        let mut buf = [0u8; 8];
        let off = self.feat_off + (i * self.d + j) as u64 * 8;
        read_exact_at(&self.file, &mut buf, off).expect("BlockStore positioned read failed");
        f64::from_le_bytes(buf)
    }

    /// Sequential full pass with early exit (setup-time; allocates one
    /// block buffer). Stops reading at the first `Err`.
    fn try_for_each_row<E>(
        &self,
        mut f: impl FnMut(usize, &[f64]) -> Result<(), E>,
    ) -> Result<(), E> {
        let rpb = self.cache_cfg.rows_per_block.max(1);
        let mut bytes = vec![0u8; rpb * self.d * 8];
        let mut rows_buf = vec![0.0f64; rpb * self.d];
        let nblocks = self.n.div_ceil(rpb);
        for block in 0..nblocks {
            let rows = rpb.min(self.n - block * rpb);
            let nbytes = rows * self.d * 8;
            let off = self.feat_off + (block * rpb * self.d) as u64 * 8;
            read_exact_at(&self.file, &mut bytes[..nbytes], off)
                .expect("BlockStore positioned read failed");
            for (v, chunk) in rows_buf[..rows * self.d]
                .iter_mut()
                .zip(bytes[..nbytes].chunks_exact(8))
            {
                *v = f64::from_le_bytes(chunk.try_into().unwrap());
            }
            for r in 0..rows {
                f(block * rpb + r, &rows_buf[r * self.d..(r + 1) * self.d])?;
            }
        }
        Ok(())
    }
}

/// The unified feature-matrix storage every model reads through.
///
/// An enum (static dispatch, no `dyn`) with the resident [`DenseStore`] and
/// the out-of-core [`BlockStore`]; see the module docs for the ownership
/// model and the zero-allocation contract.
#[derive(Clone, Debug)]
pub enum DataStore {
    /// resident row-major matrix (bit-identical to pre-refactor behaviour)
    Dense(DenseStore),
    /// block-cached out-of-core `.fbin` reader
    Block(BlockStore),
}

impl From<Matrix> for DataStore {
    fn from(x: Matrix) -> Self {
        DataStore::Dense(DenseStore { x })
    }
}

impl DataStore {
    /// Resident storage over `x` (the default everywhere data is synthesized
    /// or parsed in RAM).
    pub fn dense(x: Matrix) -> Self {
        x.into()
    }

    /// Number of data rows N.
    pub fn n_rows(&self) -> usize {
        match self {
            DataStore::Dense(s) => s.x.rows,
            DataStore::Block(s) => s.n,
        }
    }

    /// Feature dimension D (columns).
    pub fn d(&self) -> usize {
        match self {
            DataStore::Dense(s) => s.x.cols,
            DataStore::Block(s) => s.d,
        }
    }

    /// Whether rows are served from disk rather than resident memory.
    pub fn is_out_of_core(&self) -> bool {
        matches!(self, DataStore::Block(_))
    }

    /// A row cache sized for this store: zero-capacity for dense storage,
    /// the store's [`BlockCacheConfig`] budget for block storage. One-time
    /// setup (owned by [`crate::models::EvalScratch`]); reads through it
    /// never allocate.
    pub fn new_cache(&self) -> RowCache {
        match self {
            DataStore::Dense(_) => RowCache::empty(),
            DataStore::Block(s) => RowCache::sized(s.d, s.cache_cfg),
        }
    }

    /// Row `i` as a slice — the hot-path read. Dense: a direct borrow of the
    /// resident matrix (`cache` untouched). Block: served from `cache`,
    /// filling the row's block with one positioned read on a miss.
    /// Allocation-free in both arms.
    #[inline]
    // lint: zero-alloc
    pub fn row<'a>(&'a self, i: usize, cache: &'a mut RowCache) -> &'a [f64] {
        match self {
            DataStore::Dense(s) => s.x.row(i),
            DataStore::Block(s) => s.row(i, cache),
        }
    }

    /// Gather up to [`W`](crate::kernels::W) rows into a column-major lane
    /// tile: `tile[j * W + l] = x[idx[l]][j]`, with dead lanes
    /// (`l >= idx.len()`) zero-filled so downstream reduction trees see
    /// exact `+0.0` contributions. Rows are read through `cache` in lane
    /// order — the same reads, in the same order, as `idx.len()` calls to
    /// [`Self::row`], so block-cache hit/miss accounting is unchanged.
    // lint: zero-alloc
    pub fn gather_tile(&self, idx: &[u32], cache: &mut RowCache, tile: &mut [f64]) {
        use crate::kernels::W;
        let d = self.d();
        debug_assert!(idx.len() <= W);
        debug_assert_eq!(tile.len(), d * W);
        for (l, &n) in idx.iter().enumerate() {
            let row = self.row(n as usize, cache);
            for (j, &v) in row.iter().enumerate() {
                tile[j * W + l] = v;
            }
        }
        for l in idx.len()..W {
            for j in 0..d {
                tile[j * W + l] = 0.0;
            }
        }
    }

    /// Scalar element read (tests/tools; slow for block stores).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self {
            DataStore::Dense(s) => s.x[(i, j)],
            DataStore::Block(s) => s.get(i, j),
        }
    }

    /// Visit every row in order — the setup-time streaming pass
    /// (`rebuild_stats`, anchor tuning). May allocate a block buffer for
    /// block stores; not part of the sampling hot path.
    pub fn for_each_row(&self, mut f: impl FnMut(usize, &[f64])) {
        let done: Result<(), std::convert::Infallible> = self.try_for_each_row(|i, row| {
            f(i, row);
            Ok(())
        });
        done.unwrap();
    }

    /// [`Self::for_each_row`] with early exit: stops visiting (and, for
    /// block stores, stops reading blocks) at the first `Err`. Used by the
    /// `.fbin` writer so a row rejected up front does not cost a full
    /// streaming pass over a tall source.
    pub fn try_for_each_row<E>(
        &self,
        mut f: impl FnMut(usize, &[f64]) -> Result<(), E>,
    ) -> Result<(), E> {
        match self {
            DataStore::Dense(s) => {
                for i in 0..s.x.rows {
                    f(i, s.x.row(i))?;
                }
                Ok(())
            }
            DataStore::Block(s) => s.try_for_each_row(f),
        }
    }

    /// Copy rows `start..end` into a resident [`DenseStore`] — the
    /// shard-extraction primitive of `ModelBound::shard_model`. Feature
    /// bits are copied verbatim (reads go through [`Self::row`], which is
    /// bit-exact for both arms), so a shard model evaluates the same bits
    /// as the full model on the same data points. Setup-time; allocates.
    pub fn slice_rows(&self, start: usize, end: usize) -> DataStore {
        assert!(start <= end && end <= self.n_rows(), "bad shard range {start}..{end}");
        let d = self.d();
        let mut cache = self.new_cache();
        let mut data = Vec::with_capacity((end - start) * d);
        for i in start..end {
            data.extend_from_slice(self.row(i, &mut cache));
        }
        DataStore::dense(Matrix::from_vec(end - start, d, data))
    }

    /// The resident matrix, when this store is dense (tests/benches).
    pub fn as_dense(&self) -> Option<&Matrix> {
        match self {
            DataStore::Dense(s) => Some(&s.x),
            DataStore::Block(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_matrix(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let data: Vec<f64> = (0..n * d).map(|_| rng.normal()).collect();
        Matrix::from_vec(n, d, data)
    }

    fn block_store_over(m: &Matrix, cfg: BlockCacheConfig) -> (BlockStore, std::path::PathBuf) {
        // raw feature block only (offset 0) — header handling is fbin's job
        let path = std::env::temp_dir().join(format!(
            "firefly_store_test_{}_{}x{}.bin",
            std::process::id(),
            m.rows,
            m.cols
        ));
        let mut bytes = Vec::with_capacity(m.data.len() * 8);
        for v in &m.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let file = File::open(&path).unwrap();
        (BlockStore::new(file, m.rows, m.cols, 0, cfg), path)
    }

    #[test]
    fn dense_rows_are_direct_borrows() {
        let m = random_matrix(10, 4, 1);
        let store = DataStore::dense(m.clone());
        let mut cache = store.new_cache();
        assert_eq!(cache.slots(), 0);
        for i in 0..10 {
            assert_eq!(store.row(i, &mut cache), m.row(i));
        }
        assert_eq!(cache.take_stats(), (0, 0));
        assert!(!store.is_out_of_core());
        assert_eq!(store.as_dense().unwrap().data, m.data);
    }

    #[test]
    fn block_rows_bit_identical_to_dense_under_eviction() {
        let m = random_matrix(103, 7, 2); // deliberately not block-aligned
        // cache of 2 blocks × 8 rows — far smaller than N, forcing eviction
        let cfg = BlockCacheConfig { rows_per_block: 8, cached_rows: 16 };
        let (bs, path) = block_store_over(&m, cfg);
        let store = DataStore::Block(bs);
        assert_eq!(store.n_rows(), 103);
        assert_eq!(store.d(), 7);
        assert!(store.is_out_of_core());
        assert!(store.as_dense().is_none());
        let mut cache = store.new_cache();
        assert_eq!(cache.slots(), 2);
        // random access pattern with duplicates
        let mut rng = Rng::new(3);
        for _ in 0..500 {
            let i = rng.below(103);
            let got = store.row(i, &mut cache);
            for (a, b) in got.iter().zip(m.row(i)) {
                assert_eq!(a.to_bits(), b.to_bits(), "row {i}");
            }
        }
        let (hits, misses) = cache.take_stats();
        assert_eq!(hits + misses, 500);
        assert!(misses > 2, "eviction never happened: {misses} misses");
        // scalar reads and streaming agree too
        assert_eq!(store.get(50, 3).to_bits(), m[(50, 3)].to_bits());
        let mut seen = 0;
        store.for_each_row(|i, row| {
            assert_eq!(row, m.row(i));
            seen += 1;
        });
        assert_eq!(seen, 103);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn repeated_reads_within_a_block_hit() {
        let m = random_matrix(64, 3, 4);
        let cfg = BlockCacheConfig { rows_per_block: 32, cached_rows: 32 };
        let (bs, path) = block_store_over(&m, cfg);
        let store = DataStore::Block(bs);
        let mut cache = store.new_cache();
        for _ in 0..10 {
            store.row(5, &mut cache);
            store.row(6, &mut cache);
        }
        let (hits, misses) = cache.take_stats();
        assert_eq!(misses, 1);
        assert_eq!(hits, 19);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn gather_tile_matches_rows_and_zero_pads() {
        use crate::kernels::W;
        let m = random_matrix(23, 5, 6);
        let cfg = BlockCacheConfig { rows_per_block: 4, cached_rows: 8 };
        let (bs, path) = block_store_over(&m, cfg);
        for store in [DataStore::dense(m.clone()), DataStore::Block(bs)] {
            let mut cache = store.new_cache();
            let mut tile = vec![f64::NAN; 5 * W];
            let idx = [3u32, 11, 22]; // remainder tile: 3 live lanes
            store.gather_tile(&idx, &mut cache, &mut tile);
            for (l, &n) in idx.iter().enumerate() {
                for j in 0..5 {
                    assert_eq!(
                        tile[j * W + l].to_bits(),
                        m[(n as usize, j)].to_bits(),
                        "lane {l} feature {j}"
                    );
                }
            }
            for l in idx.len()..W {
                for j in 0..5 {
                    assert_eq!(tile[j * W + l].to_bits(), 0.0f64.to_bits());
                }
            }
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[should_panic(expected = "unsized RowCache")]
    fn block_read_through_empty_cache_panics() {
        let m = random_matrix(8, 2, 5);
        let (bs, _path) = block_store_over(&m, BlockCacheConfig::default());
        let store = DataStore::Block(bs);
        let mut cache = RowCache::empty();
        store.row(0, &mut cache);
    }

    #[test]
    fn cache_config_budget_rounding() {
        let c = BlockCacheConfig { rows_per_block: 64, cached_rows: 100 };
        assert_eq!(c.slots(), 1); // rounds down, min one block
        assert_eq!(BlockCacheConfig::with_budget(0).cached_rows, 8192);
        assert_eq!(BlockCacheConfig::with_budget(256).cached_rows, 256);
    }
}
