//! The versioned `.fbin` binary dataset format and its reader/writer.
//!
//! Layout (all integers little-endian; spec in DESIGN.md §Storage):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"FFLYFBIN"
//! 8       4     format version (u32, currently 1)
//! 12      4     label kind (u32: 0 binary ±1, 1 class, 2 regression target)
//! 16      8     N (u64, number of rows; bounded to u32::MAX on read)
//! 24      8     D (u64, feature columns, bias included if the writer added one)
//! 32      8     K (u64, class count; 1 for non-class label kinds)
//! 40      8·N·D feature block, row-major f64
//! 40+8ND  8·N   label block, f64 (class labels stored as exact integers)
//! ```
//!
//! The feature block — the O(N·D) part — is what [`super::store::BlockStore`]
//! serves out of core; labels are O(N) and stay resident (every model indexes
//! them per datum and the z-resamplers touch arbitrary subsets).
//!
//! [`FbinWriter`] streams: the header is written with placeholder N/K,
//! feature rows are appended as they arrive (so a CSV→fbin conversion never
//! materializes the matrix), labels are buffered (8 bytes/row) and written
//! at [`FbinWriter::finish`], which then patches the header.

use std::fs::File;
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};

use super::store::{BlockCacheConfig, BlockStore, DataStore};
use super::{AnyData, LogisticData, RegressionData, SoftmaxData};

/// The 8-byte magic prefix of every `.fbin` file.
pub const FBIN_MAGIC: [u8; 8] = *b"FFLYFBIN";
/// Current format version.
pub const FBIN_VERSION: u32 = 1;
/// Total header length in bytes (the feature block starts here).
pub const FBIN_HEADER_LEN: u64 = 40;

/// What the label block means — selects which model family the dataset
/// feeds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LabelKind {
    /// binary classification labels in {-1, +1} ([`LogisticData`])
    Binary,
    /// integer class labels in [0, K) ([`SoftmaxData`])
    Class,
    /// regression targets ([`RegressionData`])
    Target,
}

impl LabelKind {
    /// The on-disk u32 tag.
    pub fn as_u32(self) -> u32 {
        match self {
            LabelKind::Binary => 0,
            LabelKind::Class => 1,
            LabelKind::Target => 2,
        }
    }

    /// Decode the on-disk u32 tag (inverse of [`Self::as_u32`]).
    pub fn from_u32(v: u32) -> Option<LabelKind> {
        match v {
            0 => Some(LabelKind::Binary),
            1 => Some(LabelKind::Class),
            2 => Some(LabelKind::Target),
            _ => None,
        }
    }

    /// Parse a CLI spelling (`logistic`/`binary`, `softmax`/`class`,
    /// `regression`/`target`).
    pub fn parse(s: &str) -> Result<LabelKind, String> {
        match s {
            "logistic" | "binary" => Ok(LabelKind::Binary),
            "softmax" | "class" => Ok(LabelKind::Class),
            "regression" | "target" | "robust" => Ok(LabelKind::Target),
            _ => Err(format!("unknown label kind {s:?}")),
        }
    }

    /// Human-readable name (matches the model family).
    pub fn name(self) -> &'static str {
        match self {
            LabelKind::Binary => "logistic",
            LabelKind::Class => "softmax",
            LabelKind::Target => "regression",
        }
    }
}

/// Decoded `.fbin` header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FbinHeader {
    /// label block semantics
    pub label_kind: LabelKind,
    /// number of rows
    pub n: u64,
    /// feature columns
    pub d: u64,
    /// class count (1 unless `label_kind` is `Class`)
    pub k: u64,
}

fn encode_header(h: &FbinHeader) -> [u8; FBIN_HEADER_LEN as usize] {
    let mut buf = [0u8; FBIN_HEADER_LEN as usize];
    buf[..8].copy_from_slice(&FBIN_MAGIC);
    buf[8..12].copy_from_slice(&FBIN_VERSION.to_le_bytes());
    buf[12..16].copy_from_slice(&h.label_kind.as_u32().to_le_bytes());
    buf[16..24].copy_from_slice(&h.n.to_le_bytes());
    buf[24..32].copy_from_slice(&h.d.to_le_bytes());
    buf[32..40].copy_from_slice(&h.k.to_le_bytes());
    buf
}

fn decode_header(buf: &[u8; FBIN_HEADER_LEN as usize]) -> Result<FbinHeader, String> {
    if buf[..8] != FBIN_MAGIC {
        return Err("not an .fbin file (bad magic)".to_string());
    }
    let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    if version != FBIN_VERSION {
        return Err(format!(
            "unsupported .fbin version {version} (this build reads version {FBIN_VERSION})"
        ));
    }
    let kind_raw = u32::from_le_bytes(buf[12..16].try_into().unwrap());
    let label_kind = LabelKind::from_u32(kind_raw)
        .ok_or_else(|| format!("bad label-kind tag {kind_raw}"))?;
    Ok(FbinHeader {
        label_kind,
        n: u64::from_le_bytes(buf[16..24].try_into().unwrap()),
        d: u64::from_le_bytes(buf[24..32].try_into().unwrap()),
        k: u64::from_le_bytes(buf[32..40].try_into().unwrap()),
    })
}

/// Streaming `.fbin` writer: create, [`push_row`](Self::push_row) N times,
/// [`finish`](Self::finish). Feature rows go straight to disk; labels are
/// buffered (8 bytes/row) and the header N/K are patched at the end.
pub struct FbinWriter {
    out: BufWriter<File>,
    d: usize,
    kind: LabelKind,
    labels: Vec<f64>,
    max_class: u64,
    forced_k: Option<u64>,
}

impl FbinWriter {
    /// Start a new dataset file with `d` feature columns.
    pub fn create(path: &str, d: usize, kind: LabelKind) -> io::Result<FbinWriter> {
        if d == 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "d must be positive"));
        }
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        let placeholder =
            FbinHeader { label_kind: kind, n: 0, d: d as u64, k: 1 };
        out.write_all(&encode_header(&placeholder))?;
        Ok(FbinWriter { out, d, kind, labels: Vec::new(), max_class: 0, forced_k: None })
    }

    /// Pin the class count written to the header instead of inferring
    /// `max label + 1` from the rows. Required when writing a *subset* of
    /// a class dataset (e.g. one shard of a K-way problem whose slice
    /// happens not to contain every class): the softmax model's parameter
    /// dimension is `K·D`, so a shard file with a deflated K would build a
    /// model of the wrong shape. Rows pushed after this call must keep
    /// their labels below `k`.
    pub fn force_classes(&mut self, k: usize) -> io::Result<()> {
        if self.kind != LabelKind::Class {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                "force_classes only applies to class-labelled datasets",
            ));
        }
        if k == 0 {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "k must be positive"));
        }
        self.forced_k = Some(k as u64);
        Ok(())
    }

    /// Append one data row. Labels are validated per kind: binary must be
    /// ±1 (map {0,1} inputs before calling), class must be a non-negative
    /// integer, targets are any finite f64.
    pub fn push_row(&mut self, features: &[f64], label: f64) -> io::Result<()> {
        if features.len() != self.d {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("row has {} features, expected {}", features.len(), self.d),
            ));
        }
        match self.kind {
            LabelKind::Binary if label != 1.0 && label != -1.0 => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("bad binary label {label} (want -1 or 1)"),
                ));
            }
            LabelKind::Class if label < 0.0 || label.fract() != 0.0 => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("bad class label {label}"),
                ));
            }
            _ => {}
        }
        if self.kind == LabelKind::Class {
            if let Some(k) = self.forced_k {
                if label as u64 >= k {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidInput,
                        format!("class label {label} out of range for forced k = {k}"),
                    ));
                }
            }
            self.max_class = self.max_class.max(label as u64);
        }
        for v in features {
            self.out.write_all(&v.to_le_bytes())?;
        }
        self.labels.push(label);
        Ok(())
    }

    /// Write the label block, patch the header, and flush. Returns the
    /// final header. Zero-row datasets are rejected.
    pub fn finish(mut self) -> io::Result<FbinHeader> {
        if self.labels.is_empty() {
            return Err(io::Error::new(io::ErrorKind::InvalidInput, "no data rows"));
        }
        for v in &self.labels {
            self.out.write_all(&v.to_le_bytes())?;
        }
        let header = FbinHeader {
            label_kind: self.kind,
            n: self.labels.len() as u64,
            d: self.d as u64,
            k: if self.kind == LabelKind::Class {
                self.forced_k.unwrap_or(self.max_class + 1)
            } else {
                1
            },
        };
        self.out.flush()?;
        let mut file = self.out.into_inner().map_err(|e| e.into_error())?;
        file.seek(SeekFrom::Start(0))?;
        file.write_all(&encode_header(&header))?;
        file.flush()?;
        Ok(header)
    }
}

/// Write any loaded/synthesized dataset to `path` (streams the feature
/// store row by row, so an out-of-core source is never materialized).
pub fn write_fbin(path: &str, data: &AnyData) -> io::Result<FbinHeader> {
    let (store, kind): (&DataStore, LabelKind) = match data {
        AnyData::Logistic(d) => (&d.x, LabelKind::Binary),
        AnyData::Softmax(d) => (&d.x, LabelKind::Class),
        AnyData::Regression(d) => (&d.x, LabelKind::Target),
    };
    let mut w = FbinWriter::create(path, store.d(), kind)?;
    store.try_for_each_row(|i, row| {
        let label = match data {
            AnyData::Logistic(d) => d.t[i],
            AnyData::Softmax(d) => d.labels[i] as f64,
            AnyData::Regression(d) => d.y[i],
        };
        w.push_row(row, label)
    })?;
    w.finish()
}

/// Open a `.fbin` dataset for out-of-core sampling: validates the header
/// and file length, loads the label block (O(N) resident), and wraps the
/// feature block in a [`BlockStore`] whose per-reader caches use `cache`.
pub fn open_fbin(path: &str, cache: BlockCacheConfig) -> Result<AnyData, String> {
    let mut file = File::open(path).map_err(|e| format!("{path}: {e}"))?;
    let mut hbuf = [0u8; FBIN_HEADER_LEN as usize];
    file.read_exact(&mut hbuf)
        .map_err(|e| format!("{path}: truncated header: {e}"))?;
    let header = decode_header(&hbuf).map_err(|e| format!("{path}: {e}"))?;
    if header.n == 0 || header.d == 0 {
        return Err(format!("{path}: empty dataset (n={}, d={})", header.n, header.d));
    }
    if header.n > u64::from(u32::MAX) {
        return Err(format!(
            "{path}: n={} exceeds the u32 index limit of the sampling engine",
            header.n
        ));
    }
    let (n, d) = (header.n as usize, header.d as usize);
    let feat_bytes = header
        .n
        .checked_mul(header.d)
        .and_then(|nd| nd.checked_mul(8))
        .ok_or_else(|| format!("{path}: n*d overflows"))?;
    let expect_len = FBIN_HEADER_LEN + feat_bytes + header.n * 8;
    let actual_len = file
        .metadata()
        .map_err(|e| format!("{path}: {e}"))?
        .len();
    if actual_len != expect_len {
        return Err(format!(
            "{path}: file is {actual_len} bytes, header implies {expect_len} \
             (truncated or corrupt)"
        ));
    }

    // label block: resident, one pass
    file.seek(SeekFrom::Start(FBIN_HEADER_LEN + feat_bytes))
        .map_err(|e| format!("{path}: {e}"))?;
    let mut lbytes = vec![0u8; n * 8];
    file.read_exact(&mut lbytes)
        .map_err(|e| format!("{path}: label block: {e}"))?;
    let labels: Vec<f64> = lbytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    drop(lbytes);

    let store = DataStore::Block(BlockStore::new(file, n, d, FBIN_HEADER_LEN, cache));
    match header.label_kind {
        LabelKind::Binary => {
            for (i, &l) in labels.iter().enumerate() {
                if l != 1.0 && l != -1.0 {
                    return Err(format!("{path}: row {i}: bad binary label {l}"));
                }
            }
            Ok(AnyData::Logistic(LogisticData { x: store, t: labels }))
        }
        LabelKind::Class => {
            let k = header.k as usize;
            if k == 0 {
                return Err(format!("{path}: class dataset with k=0"));
            }
            let mut ints = Vec::with_capacity(n);
            for (i, &l) in labels.iter().enumerate() {
                if l < 0.0 || l.fract() != 0.0 || (l as usize) >= k {
                    return Err(format!(
                        "{path}: row {i}: bad class label {l} (header k={k})"
                    ));
                }
                ints.push(l as usize);
            }
            Ok(AnyData::Softmax(SoftmaxData { x: store, labels: ints, k }))
        }
        LabelKind::Target => Ok(AnyData::Regression(RegressionData { x: store, y: labels })),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmp(name: &str) -> String {
        std::env::temp_dir()
            .join(format!("firefly_fbin_{}_{name}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn writer_reader_roundtrip_logistic() {
        let path = tmp("rt_logistic.fbin");
        let d = synth::synth_mnist(200, 6, 1);
        let header = write_fbin(&path, &AnyData::Logistic(d.clone())).unwrap();
        assert_eq!(header.n, 200);
        assert_eq!(header.d, 7); // 6 features + bias
        assert_eq!(header.label_kind, LabelKind::Binary);
        let cache = BlockCacheConfig { rows_per_block: 16, cached_rows: 32 };
        match open_fbin(&path, cache).unwrap() {
            AnyData::Logistic(got) => {
                assert_eq!(got.t, d.t);
                assert!(got.x.is_out_of_core());
                let dense = d.x.as_dense().unwrap();
                let mut rc = got.x.new_cache();
                for i in (0..200).rev() {
                    // reverse order: defeats sequential prefetch luck
                    let row = got.x.row(i, &mut rc);
                    for (a, b) in row.iter().zip(dense.row(i)) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
            }
            other => panic!("wrong kind: {}", other.kind_name()),
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn writer_reader_roundtrip_softmax_and_regression() {
        let spath = tmp("rt_softmax.fbin");
        let sd = synth::synth_cifar3(90, 10, 2);
        let h = write_fbin(&spath, &AnyData::Softmax(sd.clone())).unwrap();
        assert_eq!(h.k, 3);
        match open_fbin(&spath, BlockCacheConfig::default()).unwrap() {
            AnyData::Softmax(got) => {
                assert_eq!(got.k, 3);
                assert_eq!(got.labels, sd.labels);
            }
            other => panic!("wrong kind: {}", other.kind_name()),
        }
        let rpath = tmp("rt_regression.fbin");
        let rd = synth::synth_opv(120, 5, 3);
        write_fbin(&rpath, &AnyData::Regression(rd.clone())).unwrap();
        match open_fbin(&rpath, BlockCacheConfig::default()).unwrap() {
            AnyData::Regression(got) => {
                assert_eq!(got.y.len(), 120);
                for (a, b) in got.y.iter().zip(&rd.y) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong kind: {}", other.kind_name()),
        }
        let _ = std::fs::remove_file(spath);
        let _ = std::fs::remove_file(rpath);
    }

    #[test]
    fn corrupt_and_truncated_files_are_rejected() {
        let path = tmp("corrupt.fbin");
        let d = synth::synth_mnist(50, 4, 7);
        write_fbin(&path, &AnyData::Logistic(d)).unwrap();
        let good = std::fs::read(&path).unwrap();

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = open_fbin(&path, BlockCacheConfig::default()).unwrap_err();
        assert!(err.contains("magic"), "{err}");

        // unsupported version
        let mut bad = good.clone();
        bad[8] = 99;
        std::fs::write(&path, &bad).unwrap();
        let err = open_fbin(&path, BlockCacheConfig::default()).unwrap_err();
        assert!(err.contains("version"), "{err}");

        // bad label-kind tag
        let mut bad = good.clone();
        bad[12] = 7;
        std::fs::write(&path, &bad).unwrap();
        let err = open_fbin(&path, BlockCacheConfig::default()).unwrap_err();
        assert!(err.contains("label-kind"), "{err}");

        // truncated feature block
        let mut bad = good.clone();
        bad.truncate(good.len() - 100);
        std::fs::write(&path, &bad).unwrap();
        let err = open_fbin(&path, BlockCacheConfig::default()).unwrap_err();
        assert!(err.contains("truncated"), "{err}");

        // header shorter than 40 bytes
        std::fs::write(&path, &good[..20]).unwrap();
        let err = open_fbin(&path, BlockCacheConfig::default()).unwrap_err();
        assert!(err.contains("header"), "{err}");

        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn writer_validates_labels_and_shapes() {
        let path = tmp("validate.fbin");
        let mut w = FbinWriter::create(&path, 2, LabelKind::Binary).unwrap();
        assert!(w.push_row(&[1.0, 2.0], 0.5).is_err()); // bad binary label
        assert!(w.push_row(&[1.0], 1.0).is_err()); // wrong width
        w.push_row(&[1.0, 2.0], -1.0).unwrap();
        w.finish().unwrap();

        let mut w = FbinWriter::create(&path, 2, LabelKind::Class).unwrap();
        assert!(w.push_row(&[0.0, 0.0], -1.0).is_err());
        assert!(w.push_row(&[0.0, 0.0], 1.5).is_err());
        w.push_row(&[0.0, 0.0], 2.0).unwrap();
        let h = w.finish().unwrap();
        assert_eq!(h.k, 3);

        // forced class count overrides the observed maximum (shard files)
        let mut w = FbinWriter::create(&path, 2, LabelKind::Class).unwrap();
        w.force_classes(5).unwrap();
        assert!(w.push_row(&[0.0, 0.0], 5.0).is_err()); // >= forced k
        w.push_row(&[0.0, 0.0], 1.0).unwrap();
        let h = w.finish().unwrap();
        assert_eq!(h.k, 5);
        match open_fbin(&path, BlockCacheConfig::default()).unwrap() {
            AnyData::Softmax(got) => assert_eq!(got.k, 5),
            other => panic!("wrong kind: {}", other.kind_name()),
        }

        // force_classes is class-only
        let mut w = FbinWriter::create(&path, 2, LabelKind::Target).unwrap();
        assert!(w.force_classes(3).is_err());

        // empty dataset rejected at finish
        let w = FbinWriter::create(&path, 2, LabelKind::Target).unwrap();
        assert!(w.finish().is_err());
        let _ = std::fs::remove_file(path);
    }
}
