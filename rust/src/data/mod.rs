//! Datasets: synthetic generators matching the paper's three workloads, a
//! CSV loader for user-supplied real data, and the storage layer that feeds
//! them to the models.
//!
//! The paper's datasets (MNIST 7v9 PCA features, CIFAR-10 3-class binary
//! autoencoder features, Harvard CEP OPV molecules) are not redistributable
//! here; per DESIGN.md §Data-substitutions each generator reproduces the
//! properties FlyMC's behaviour actually depends on — N, D, and the margin /
//! logit-spread / residual-tail distribution that controls bound tightness —
//! through the identical code path. All generators are seeded and
//! deterministic.
//!
//! Feature matrices are held behind [`store::DataStore`]: either resident
//! ([`store::DenseStore`], today's behaviour, bit-identical) or out-of-core
//! over a `.fbin` file ([`store::BlockStore`] + [`fbin`]), so datasets
//! larger than RAM sample through the same models and backends. Labels are
//! O(N) and stay resident in every case (DESIGN.md §Storage).

pub mod csv;
pub mod fbin;
pub mod shard;
pub mod store;
pub mod synth;

use self::store::DataStore;

/// Binary classification data; `t[n]` in {-1, +1}. Feature matrix includes
/// the bias column when the generator appends one.
#[derive(Clone, Debug)]
pub struct LogisticData {
    /// N x D feature store
    pub x: DataStore,
    /// labels in {-1, +1}
    pub t: Vec<f64>,
}

impl LogisticData {
    /// Number of data points.
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }
    /// Feature dimension (bias column included when present).
    pub fn d(&self) -> usize {
        self.x.d()
    }
}

/// Multi-class classification data; `labels[n]` in [0, k).
#[derive(Clone, Debug)]
pub struct SoftmaxData {
    /// N x D feature store
    pub x: DataStore,
    /// integer class labels in [0, k)
    pub labels: Vec<usize>,
    /// number of classes K
    pub k: usize,
}

impl SoftmaxData {
    /// Number of data points.
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }
    /// Feature dimension.
    pub fn d(&self) -> usize {
        self.x.d()
    }
}

/// Regression data.
#[derive(Clone, Debug)]
pub struct RegressionData {
    /// N x D feature store
    pub x: DataStore,
    /// regression targets
    pub y: Vec<f64>,
}

impl RegressionData {
    /// Number of data points.
    pub fn n(&self) -> usize {
        self.x.n_rows()
    }
    /// Feature dimension (bias column included when present).
    pub fn d(&self) -> usize {
        self.x.d()
    }
}

/// A dataset of any of the three workload families — what the `.fbin`
/// reader returns (the file's label kind selects the variant) and the
/// `convert` pipeline consumes.
#[derive(Clone, Debug)]
pub enum AnyData {
    /// binary classification ([`LogisticData`])
    Logistic(LogisticData),
    /// multi-class classification ([`SoftmaxData`])
    Softmax(SoftmaxData),
    /// regression ([`RegressionData`])
    Regression(RegressionData),
}

impl AnyData {
    /// Number of data points.
    pub fn n(&self) -> usize {
        match self {
            AnyData::Logistic(d) => d.n(),
            AnyData::Softmax(d) => d.n(),
            AnyData::Regression(d) => d.n(),
        }
    }

    /// Feature dimension.
    pub fn d(&self) -> usize {
        match self {
            AnyData::Logistic(d) => d.d(),
            AnyData::Softmax(d) => d.d(),
            AnyData::Regression(d) => d.d(),
        }
    }

    /// The model-family name of the variant (`logistic`/`softmax`/
    /// `regression`).
    pub fn kind_name(&self) -> &'static str {
        match self {
            AnyData::Logistic(_) => "logistic",
            AnyData::Softmax(_) => "softmax",
            AnyData::Regression(_) => "regression",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::synth;

    #[test]
    fn mnist_like_shape_and_balance() {
        let d = synth::synth_mnist(2000, 50, 7);
        assert_eq!(d.n(), 2000);
        assert_eq!(d.d(), 51); // 50 features + bias
        let pos = d.t.iter().filter(|&&t| t > 0.0).count();
        assert!((700..1300).contains(&pos), "class balance {pos}");
        // bias column is all ones
        for i in 0..d.n() {
            assert_eq!(d.x.get(i, 50), 1.0);
        }
        // deterministic
        let d2 = synth::synth_mnist(2000, 50, 7);
        assert_eq!(d.x.as_dense().unwrap().data, d2.x.as_dense().unwrap().data);
        assert_eq!(d.t, d2.t);
    }

    #[test]
    fn mnist_like_is_mostly_separable() {
        // A logistic fit should reach high accuracy: check the *generating*
        // weights classify >= 90% correctly (the paper's 7v9 task is ~97%).
        let (d, w) = synth::synth_mnist_with_truth(5000, 50, 3);
        let mut correct = 0;
        d.x.for_each_row(|i, row| {
            let s: f64 = row.iter().zip(&w).map(|(a, b)| a * b).sum();
            if s * d.t[i] > 0.0 {
                correct += 1;
            }
        });
        let acc = correct as f64 / d.n() as f64;
        assert!(acc > 0.90, "generator accuracy {acc}");
        // ... but not trivially separable (some hard points near the margin)
        assert!(acc < 0.999, "generator accuracy suspiciously perfect {acc}");
    }

    #[test]
    fn cifar_like_shape_binary_features() {
        let d = synth::synth_cifar3(1500, 256, 11);
        assert_eq!(d.n(), 1500);
        assert_eq!(d.d(), 256); // exactly the artifact's feature dim
        assert_eq!(d.k, 3);
        d.x.for_each_row(|_, row| {
            for &v in row {
                assert!(v == 0.0 || v == 1.0);
            }
        });
        let mut counts = [0usize; 3];
        for &l in &d.labels {
            counts[l] += 1;
        }
        for c in counts {
            assert!((300..700).contains(&c), "class count {c}");
        }
    }

    #[test]
    fn opv_like_heavy_tails_and_sparse_truth() {
        let (d, w) = synth::synth_opv_with_truth(20_000, 57, 5);
        assert_eq!(d.n(), 20_000);
        assert_eq!(d.d(), 57); // 56 features + bias = the artifact dim
        let nonzero = w.iter().filter(|&&v| v != 0.0).count();
        assert!(nonzero < 58 / 2, "truth should be sparse, got {nonzero} nonzero");
        // residuals under the truth have heavier-than-gaussian tails
        let mut resid = vec![0.0f64; d.n()];
        d.x.for_each_row(|i, row| {
            let pred: f64 = row.iter().zip(&w).map(|(a, b)| a * b).sum();
            resid[i] = d.y[i] - pred;
        });
        let n = resid.len() as f64;
        let mean = resid.iter().sum::<f64>() / n;
        for r in &mut resid {
            *r -= mean;
        }
        let var = resid.iter().map(|r| r * r).sum::<f64>() / n;
        let kurt = resid.iter().map(|r| r.powi(4)).sum::<f64>() / n / (var * var);
        assert!(kurt > 3.5, "excess kurtosis expected for t4 noise, got {kurt}");
    }
}
