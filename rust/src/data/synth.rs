//! Synthetic workload generators (paper-dataset stand-ins; see DESIGN.md).

use super::{LogisticData, RegressionData, SoftmaxData};
use crate::linalg::Matrix;
use crate::util::{math, Rng};

/// Paper-scale N for the MNIST 7v9 logistic experiment.
pub const MNIST_N: usize = 12_214;
/// Paper-scale N for the CIFAR-3 softmax experiment.
pub const CIFAR_N: usize = 18_000;
/// Full paper-scale N for the OPV robust-regression experiment.
pub const OPV_N_FULL: usize = 1_800_000;
/// Default OPV N (scaled down; see DESIGN.md §Scaling-defaults).
pub const OPV_N_DEFAULT: usize = 200_000;

/// MNIST-7v9-like task: `d` PCA-like features (decaying spectrum) + bias,
/// labels from a ground-truth logistic model so the margin distribution
/// matches a well-separated digit pair (~97% linearly separable).
pub fn synth_mnist(n: usize, d: usize, seed: u64) -> LogisticData {
    synth_mnist_with_truth(n, d, seed).0
}

/// Same, returning the generating weights (bias last) for tests.
pub fn synth_mnist_with_truth(n: usize, d: usize, seed: u64) -> (LogisticData, Vec<f64>) {
    let mut rng = Rng::new(seed ^ 0x6D6E_6973_74);
    // ground-truth direction, heavier on the leading "principal components"
    let mut w: Vec<f64> = (0..d)
        .map(|j| rng.normal() / (1.0 + j as f64 / 8.0))
        .collect();
    // normalize by the *induced logit std* (features have decaying variance
    // 1/(1+j/4)) so the margin distribution is scale-controlled: logit std 6
    // gives ~96-97% Bayes accuracy, like the paper's 7-vs-9 task.
    let logit_var: f64 = w
        .iter()
        .enumerate()
        .map(|(j, &wj)| wj * wj / (1.0 + j as f64 / 4.0))
        .sum();
    let scale = 6.0 / logit_var.sqrt();
    for v in w.iter_mut() {
        *v *= scale;
    }
    w.push(0.3); // bias

    let mut x = Matrix::zeros(n, d + 1);
    let mut t = vec![0.0; n];
    for i in 0..n {
        // PCA-like spectrum: sd of component j decays as 1/sqrt(1+j/4)
        for j in 0..d {
            x[(i, j)] = rng.normal() / (1.0 + j as f64 / 4.0).sqrt();
        }
        x[(i, d)] = 1.0;
        let logit: f64 = crate::linalg::dot(x.row(i), &w);
        t[i] = if rng.bernoulli(math::sigmoid(logit)) { 1.0 } else { -1.0 };
    }
    (LogisticData { x: x.into(), t }, w)
}

/// CIFAR-3-like task: exactly `d` binary features (matching the paper's 256
/// deep-autoencoder bits — no bias column, so the feature dim matches the
/// `softmax.k3.d256` XLA artifact) from per-class Bernoulli prototypes;
/// 3 balanced classes. The class-conditional rate separation controls logit
/// spread (Böhning-bound tightness).
pub fn synth_cifar3(n: usize, d: usize, seed: u64) -> SoftmaxData {
    let k = 3;
    let mut rng = Rng::new(seed ^ 0x6369_6661_72);
    // Per-class feature rates: baseline plus a MODERATE class-specific
    // boost. The boost size controls logit spread and hence posterior
    // concentration: large boosts saturate the softmax (tiny Fisher info →
    // wide posterior → per-datum logits wander far from any anchor → the
    // fixed-curvature Böhning bound goes loose and everything stays bright).
    // ~0.08 boosts over ~85 features/class give ~75-85% Bayes accuracy and a
    // posterior tight enough for the paper's few-%-bright regime.
    let mut rates = vec![vec![0.0f64; d]; k];
    for j in 0..d {
        let base = 0.10 + 0.25 * rng.f64();
        let hot = rng.below(k);
        for (c, row) in rates.iter_mut().enumerate() {
            row[j] = if c == hot { (base + 0.05 + 0.07 * rng.f64()).min(0.95) } else { base };
        }
    }
    let mut x = Matrix::zeros(n, d);
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = i % k; // balanced
        labels[i] = c;
        for j in 0..d {
            x[(i, j)] = if rng.bernoulli(rates[c][j]) { 1.0 } else { 0.0 };
        }
    }
    // shuffle rows so batches are class-mixed
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let mut xs = Matrix::zeros(n, d);
    let mut ls = vec![0usize; n];
    for (dst, &src) in perm.iter().enumerate() {
        xs.row_mut(dst).copy_from_slice(x.row(src));
        ls[dst] = labels[src];
    }
    SoftmaxData { x: xs.into(), labels: ls, k }
}

/// OPV-like robust-regression task: `d` total columns — `d-1` correlated
/// positive (log-normal-ish) cheminformatic-style features plus a trailing
/// bias column (total matches the paper's 57 and the `robust.d57` XLA
/// artifact) — sparse true weights, student-t(4) noise plus a fraction of
/// gross outliers.
pub fn synth_opv(n: usize, d: usize, seed: u64) -> RegressionData {
    synth_opv_with_truth(n, d, seed).0
}

/// Same as [`synth_opv`], returning the generating weights for tests.
pub fn synth_opv_with_truth(n: usize, d_total: usize, seed: u64) -> (RegressionData, Vec<f64>) {
    assert!(d_total >= 2);
    let d = d_total - 1; // raw features; the last column is the bias
    let mut rng = Rng::new(seed ^ 0x6F70_76);
    // sparse truth: ~20% of features active
    let mut w = vec![0.0f64; d + 1];
    let active = (d / 5).max(3);
    for _ in 0..active {
        let j = rng.below(d);
        w[j] = rng.normal() * 0.8;
    }
    w[d] = 1.2; // intercept

    // factor model for feature correlation: x = |loadings @ z + eps|^0.7
    let nfac = 6;
    let loadings: Vec<Vec<f64>> = (0..d)
        .map(|_| (0..nfac).map(|_| rng.normal() * 0.5).collect())
        .collect();
    let mut x = Matrix::zeros(n, d + 1);
    let mut y = vec![0.0; n];
    let mut z = vec![0.0; nfac];
    for i in 0..n {
        rng.fill_normal(&mut z);
        for j in 0..d {
            let f: f64 = crate::linalg::dot(&loadings[j], &z) + rng.normal() * 0.6;
            // positive, right-skewed like molecular descriptors; then center
            x[(i, j)] = f.abs().powf(0.7) - 0.8;
        }
        x[(i, d)] = 1.0;
        let mean: f64 = crate::linalg::dot(x.row(i), &w);
        let noise = if rng.bernoulli(0.01) {
            rng.normal() * 10.0 // gross outliers: DFT failures etc.
        } else {
            rng.student_t(4.0) * 0.3
        };
        y[i] = mean + noise;
    }
    (RegressionData { x: x.into(), y }, w)
}

/// Tiny 2-d (+bias) two-class problem for Fig 2 / quickstart.
pub fn synth_toy2d(n: usize, seed: u64) -> LogisticData {
    let mut rng = Rng::new(seed ^ 0x746F_79);
    let mut x = Matrix::zeros(n, 3);
    let mut t = vec![0.0; n];
    for i in 0..n {
        let c = if i % 2 == 0 { 1.0 } else { -1.0 };
        x[(i, 0)] = rng.normal() + 1.2 * c;
        x[(i, 1)] = rng.normal() + 0.8 * c;
        x[(i, 2)] = 1.0;
        t[i] = c;
    }
    LogisticData { x: x.into(), t }
}
