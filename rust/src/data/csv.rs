//! Numeric CSV loading for user-supplied real datasets.
//!
//! Formats (documented in README §Data):
//! - logistic:  each row `f_1,...,f_D,label` with label in {-1, 1} (or {0,1});
//! - softmax:   each row `f_1,...,f_D,label` with integer label in [0, K);
//! - regression: each row `f_1,...,f_D,y`.
//!
//! A bias column of ones is appended unless `bias=false`.
//!
//! Parsing goes directly into one flat row-major buffer (plus a reused
//! per-line cell buffer): the old `Vec<Vec<f64>>` intermediate boxed every
//! row and roughly doubled peak RSS before flattening. The same line-level
//! parser also backs [`stream_to_fbin`], which converts CSV to the `.fbin`
//! out-of-core format without ever materializing the feature matrix.

use std::io;

use super::fbin::{FbinHeader, FbinWriter, LabelKind};
use super::{LogisticData, RegressionData, SoftmaxData};
use crate::linalg::Matrix;

/// Parse the data lines yielded by `lines`, calling `f(row_values)` for
/// each — only one line's cells are ever resident, so the same machinery
/// backs the in-RAM loaders and the streaming `.fbin` converter.
///
/// Semantics shared by every loader: blank lines and `#` comments are
/// skipped anywhere; the first non-empty, non-comment line may be a header
/// of non-numeric tokens; later non-numeric lines are errors; all data rows
/// must have the same column count as the first. Returns the column count.
fn parse_lines_from<S, I, F>(lines: I, mut f: F) -> Result<usize, String>
where
    S: AsRef<str>,
    I: Iterator<Item = io::Result<S>>,
    F: FnMut(&[f64]) -> Result<(), String>,
{
    let mut cells: Vec<f64> = Vec::new();
    let mut cols = 0usize;
    let mut nrows = 0usize;
    // The header is the first *non-empty, non-comment* line, wherever it
    // sits — keying on the raw line number rejected files whose header
    // follows a `#` comment or blank line.
    let mut header_candidate = true;
    for (lineno, line) in lines.enumerate() {
        let line = line.map_err(|e| format!("line {}: read error: {e}", lineno + 1))?;
        let line = line.as_ref().trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let is_header_slot = header_candidate;
        header_candidate = false;
        cells.clear();
        let mut bad: Option<String> = None;
        for cell in line.split(',') {
            match cell.trim().parse::<f64>() {
                Ok(v) => cells.push(v),
                Err(e) => {
                    bad = Some(e.to_string());
                    break;
                }
            }
        }
        if let Some(e) = bad {
            if is_header_slot {
                continue; // header
            }
            return Err(format!("line {}: {}", lineno + 1, e));
        }
        if nrows > 0 && cells.len() != cols {
            return Err(format!(
                "line {}: ragged row ({} vs {} cols)",
                lineno + 1,
                cells.len(),
                cols
            ));
        }
        cols = cells.len();
        nrows += 1;
        f(&cells)?;
    }
    if nrows == 0 {
        return Err("no data rows".to_string());
    }
    Ok(cols)
}

/// [`parse_lines_from`] over in-memory text.
fn parse_lines<F>(text: &str, f: F) -> Result<usize, String>
where
    F: FnMut(&[f64]) -> Result<(), String>,
{
    parse_lines_from(text.lines().map(Ok::<&str, io::Error>), f)
}

/// Parse into one flat row-major buffer; returns (flat, rows, cols).
fn parse_flat(text: &str) -> Result<(Vec<f64>, usize, usize), String> {
    let mut flat: Vec<f64> = Vec::new();
    let cols = parse_lines(text, |row| {
        flat.extend_from_slice(row);
        Ok(())
    })?;
    let rows = flat.len() / cols;
    Ok((flat, rows, cols))
}

/// Split the trailing label column off `flat` **in place** (rows move
/// forward, never backward, so no second full-size buffer is needed) and
/// optionally overwrite the label slot with a bias 1.0 column.
fn split_features(mut flat: Vec<f64>, rows: usize, cols: usize, bias: bool) -> (Matrix, Vec<f64>) {
    let d = cols - 1;
    let out_cols = if bias { d + 1 } else { d };
    let mut labels = vec![0.0; rows];
    for i in 0..rows {
        let src = i * cols;
        labels[i] = flat[src + d];
        let dst = i * out_cols;
        debug_assert!(dst <= src);
        flat.copy_within(src..src + d, dst);
        if bias {
            flat[dst + d] = 1.0;
        }
    }
    flat.truncate(rows * out_cols);
    (Matrix::from_vec(rows, out_cols, flat), labels)
}

fn binary_label(l: f64) -> Result<f64, String> {
    if l == 1.0 || l == -1.0 {
        Ok(l)
    } else if l == 0.0 {
        Ok(-1.0)
    } else {
        Err(format!("bad binary label {l}"))
    }
}

/// Parse binary-classification CSV text (`f_1,...,f_D,label`, label in
/// {-1,1} or {0,1}); appends a bias column of ones when `bias`.
pub fn load_logistic(text: &str, bias: bool) -> Result<LogisticData, String> {
    let (flat, rows, cols) = parse_flat(text)?;
    let (x, labels) = split_features(flat, rows, cols, bias);
    let t = labels
        .into_iter()
        .map(binary_label)
        .collect::<Result<Vec<f64>, String>>()?;
    Ok(LogisticData { x: x.into(), t })
}

/// Parse multi-class CSV text (`f_1,...,f_D,label`, integer label ≥ 0;
/// K inferred as max label + 1); appends a bias column when `bias`.
pub fn load_softmax(text: &str, bias: bool) -> Result<SoftmaxData, String> {
    let (flat, rows, cols) = parse_flat(text)?;
    let (x, labels) = split_features(flat, rows, cols, bias);
    let mut ints = Vec::with_capacity(labels.len());
    let mut k = 0usize;
    for l in labels {
        if l < 0.0 || l.fract() != 0.0 {
            return Err(format!("bad class label {l}"));
        }
        let li = l as usize;
        k = k.max(li + 1);
        ints.push(li);
    }
    Ok(SoftmaxData { x: x.into(), labels: ints, k })
}

/// Parse regression CSV text (`f_1,...,f_D,y`); appends a bias column when
/// `bias`.
pub fn load_regression(text: &str, bias: bool) -> Result<RegressionData, String> {
    let (flat, rows, cols) = parse_flat(text)?;
    let (x, y) = split_features(flat, rows, cols, bias);
    Ok(RegressionData { x: x.into(), y })
}

/// Stream CSV from any buffered reader straight into a `.fbin` dataset at
/// `out_path` — lines parse one at a time and feature rows go to disk as
/// they arrive, so only one row plus the O(N) label buffer is ever
/// resident and the source CSV may be (much) larger than RAM. Same
/// header/comment/label semantics as the in-RAM loaders. Returns the
/// written header.
pub fn stream_reader_to_fbin<R: io::BufRead>(
    reader: R,
    kind: LabelKind,
    bias: bool,
    out_path: &str,
) -> Result<FbinHeader, String> {
    let mut writer: Option<FbinWriter> = None;
    let mut row_buf: Vec<f64> = Vec::new();
    parse_lines_from(reader.lines(), |cells| {
        if cells.len() < 2 {
            return Err(format!("need at least 1 feature + label, got {} cols", cells.len()));
        }
        let d = cells.len() - 1;
        if writer.is_none() {
            let out_d = if bias { d + 1 } else { d };
            writer = Some(
                FbinWriter::create(out_path, out_d, kind)
                    .map_err(|e| format!("{out_path}: {e}"))?,
            );
        }
        let label = match kind {
            LabelKind::Binary => binary_label(cells[d])?,
            _ => cells[d],
        };
        row_buf.clear();
        row_buf.extend_from_slice(&cells[..d]);
        if bias {
            row_buf.push(1.0);
        }
        writer
            .as_mut()
            .unwrap()
            .push_row(&row_buf, label)
            .map_err(|e| format!("{out_path}: {e}"))
    })?;
    writer
        .expect("parse_lines_from guarantees at least one data row")
        .finish()
        .map_err(|e| format!("{out_path}: {e}"))
}

/// [`stream_reader_to_fbin`] over in-memory CSV text.
pub fn stream_to_fbin(
    text: &str,
    kind: LabelKind,
    bias: bool,
    out_path: &str,
) -> Result<FbinHeader, String> {
    stream_reader_to_fbin(text.as_bytes(), kind, bias, out_path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::fbin::open_fbin;
    use crate::data::store::BlockCacheConfig;
    use crate::data::AnyData;

    #[test]
    fn logistic_roundtrip_with_header_and_zero_labels() {
        let text = "f1,f2,label\n0.5,1.0,1\n-0.5,2.0,0\n";
        let d = load_logistic(text, true).unwrap();
        assert_eq!(d.n(), 2);
        assert_eq!(d.d(), 3);
        assert_eq!(d.t, vec![1.0, -1.0]);
        assert_eq!(d.x.get(0, 2), 1.0);
    }

    #[test]
    fn header_after_comment_and_blank_lines() {
        // Regression: the header used to be tolerated only at raw line 0,
        // so a leading comment or blank line failed the whole load.
        let text = "# exported by tool\n\nf1,f2,label\n0.5,1.0,1\n-0.5,2.0,0\n";
        let d = load_logistic(text, true).unwrap();
        assert_eq!(d.n(), 2);
        assert_eq!(d.t, vec![1.0, -1.0]);
        // a second non-numeric line is NOT a header — it is an error
        let bad = "# c\nf1,f2,label\noops,1.0,1\n";
        assert!(load_logistic(bad, true).is_err());
        // comment-interleaved data still loads without a header
        let plain = "# c\n1.0,2.0,1\n# mid\n3.0,4.0,0\n";
        assert_eq!(load_logistic(plain, false).unwrap().n(), 2);
    }

    #[test]
    fn flat_parse_preserves_row_and_column_order() {
        // Regression for the Vec<Vec<f64>> → flat-buffer rewrite: values
        // land at exactly the same (row, col) positions, with the header and
        // interleaved comments ignored, both with and without a bias column.
        let text = "a,b,c,y\n# note\n1.0,2.0,3.0,10.0\n\n4.0,5.0,6.0,20.0\n7.0,8.0,9.0,30.0\n";
        for bias in [false, true] {
            let d = load_regression(text, bias).unwrap();
            assert_eq!(d.n(), 3);
            assert_eq!(d.d(), if bias { 4 } else { 3 });
            assert_eq!(d.y, vec![10.0, 20.0, 30.0]);
            let m = d.x.as_dense().unwrap();
            for i in 0..3 {
                for j in 0..3 {
                    assert_eq!(m[(i, j)], (3 * i + j) as f64 + 1.0, "({i},{j})");
                }
                if bias {
                    assert_eq!(m[(i, 3)], 1.0);
                }
            }
            // the flat storage is contiguous row-major with no slack
            assert_eq!(m.data.len(), 3 * d.d());
        }
    }

    #[test]
    fn softmax_infers_k() {
        let text = "1,0,2\n0,1,0\n1,1,1\n";
        let d = load_softmax(text, false).unwrap();
        assert_eq!(d.k, 3);
        assert_eq!(d.labels, vec![2, 0, 1]);
        assert_eq!(d.d(), 2);
    }

    #[test]
    fn regression_basic() {
        let d = load_regression("1.0,2.0,3.5\n2.0,1.0,-0.5\n", true).unwrap();
        assert_eq!(d.y, vec![3.5, -0.5]);
        assert_eq!(d.d(), 3);
    }

    #[test]
    fn rejects_ragged_and_bad_labels() {
        assert!(load_regression("1,2\n1,2,3\n", false).is_err());
        assert!(load_logistic("1,2,5\n", false).is_err());
        assert!(load_softmax("1,2,-1\n", false).is_err());
        assert!(load_regression("", false).is_err());
    }

    #[test]
    fn stream_to_fbin_matches_in_ram_loader() {
        let text = "f1,f2,label\n0.5,1.0,1\n-0.5,2.0,0\n0.25,-3.0,1\n";
        let path = std::env::temp_dir()
            .join(format!("firefly_csv_stream_{}.fbin", std::process::id()))
            .to_string_lossy()
            .into_owned();
        let header = stream_to_fbin(text, LabelKind::Binary, true, &path).unwrap();
        assert_eq!(header.n, 3);
        assert_eq!(header.d, 3);
        let in_ram = load_logistic(text, true).unwrap();
        match open_fbin(&path, BlockCacheConfig::default()).unwrap() {
            AnyData::Logistic(got) => {
                assert_eq!(got.t, in_ram.t);
                let dense = in_ram.x.as_dense().unwrap();
                for i in 0..3 {
                    for j in 0..3 {
                        assert_eq!(got.x.get(i, j).to_bits(), dense[(i, j)].to_bits());
                    }
                }
            }
            other => panic!("wrong kind {}", other.kind_name()),
        }
        // streaming applies the same label validation
        assert!(stream_to_fbin("1,2,7\n", LabelKind::Binary, false, &path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
