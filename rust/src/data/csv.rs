//! Numeric CSV loading for user-supplied real datasets.
//!
//! Formats (documented in README §Data):
//! - logistic:  each row `f_1,...,f_D,label` with label in {-1, 1} (or {0,1});
//! - softmax:   each row `f_1,...,f_D,label` with integer label in [0, K);
//! - regression: each row `f_1,...,f_D,y`.
//!
//! A bias column of ones is appended unless `bias=false`.

use super::{LogisticData, RegressionData, SoftmaxData};
use crate::linalg::Matrix;

fn parse_rows(text: &str) -> Result<Vec<Vec<f64>>, String> {
    let mut rows = Vec::new();
    // The header is the first *non-empty, non-comment* line, wherever it
    // sits — keying on the raw line number rejected files whose header
    // follows a `#` comment or blank line.
    let mut header_candidate = true;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // skip a header line of non-numeric tokens
        let cells: Result<Vec<f64>, _> =
            line.split(',').map(|c| c.trim().parse::<f64>()).collect();
        let is_header_slot = header_candidate;
        header_candidate = false;
        match cells {
            Ok(v) => {
                if let Some(first) = rows.first() {
                    if v.len() != first.len() {
                        return Err(format!(
                            "line {}: ragged row ({} vs {} cols)",
                            lineno + 1,
                            v.len(),
                            first.len()
                        ));
                    }
                }
                rows.push(v);
            }
            Err(_) if is_header_slot => continue, // header
            Err(e) => return Err(format!("line {}: {}", lineno + 1, e)),
        }
    }
    if rows.is_empty() {
        return Err("no data rows".to_string());
    }
    Ok(rows)
}

fn to_features(rows: &[Vec<f64>], bias: bool) -> (Matrix, Vec<f64>) {
    let n = rows.len();
    let d = rows[0].len() - 1;
    let cols = if bias { d + 1 } else { d };
    let mut x = Matrix::zeros(n, cols);
    let mut last = vec![0.0; n];
    for (i, row) in rows.iter().enumerate() {
        x.row_mut(i)[..d].copy_from_slice(&row[..d]);
        if bias {
            x[(i, d)] = 1.0;
        }
        last[i] = row[d];
    }
    (x, last)
}

/// Parse binary-classification CSV text (`f_1,...,f_D,label`, label in
/// {-1,1} or {0,1}); appends a bias column of ones when `bias`.
pub fn load_logistic(text: &str, bias: bool) -> Result<LogisticData, String> {
    let rows = parse_rows(text)?;
    let (x, labels) = to_features(&rows, bias);
    let t = labels
        .iter()
        .map(|&l| {
            if l == 1.0 || l == -1.0 {
                Ok(l)
            } else if l == 0.0 {
                Ok(-1.0)
            } else {
                Err(format!("bad binary label {l}"))
            }
        })
        .collect::<Result<Vec<f64>, String>>()?;
    Ok(LogisticData { x, t })
}

/// Parse multi-class CSV text (`f_1,...,f_D,label`, integer label ≥ 0;
/// K inferred as max label + 1); appends a bias column when `bias`.
pub fn load_softmax(text: &str, bias: bool) -> Result<SoftmaxData, String> {
    let rows = parse_rows(text)?;
    let (x, labels) = to_features(&rows, bias);
    let mut ints = Vec::with_capacity(labels.len());
    let mut k = 0usize;
    for &l in &labels {
        if l < 0.0 || l.fract() != 0.0 {
            return Err(format!("bad class label {l}"));
        }
        let li = l as usize;
        k = k.max(li + 1);
        ints.push(li);
    }
    Ok(SoftmaxData { x, labels: ints, k })
}

/// Parse regression CSV text (`f_1,...,f_D,y`); appends a bias column when
/// `bias`.
pub fn load_regression(text: &str, bias: bool) -> Result<RegressionData, String> {
    let rows = parse_rows(text)?;
    let (x, y) = to_features(&rows, bias);
    Ok(RegressionData { x, y })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logistic_roundtrip_with_header_and_zero_labels() {
        let text = "f1,f2,label\n0.5,1.0,1\n-0.5,2.0,0\n";
        let d = load_logistic(text, true).unwrap();
        assert_eq!(d.n(), 2);
        assert_eq!(d.d(), 3);
        assert_eq!(d.t, vec![1.0, -1.0]);
        assert_eq!(d.x[(0, 2)], 1.0);
    }

    #[test]
    fn header_after_comment_and_blank_lines() {
        // Regression: the header used to be tolerated only at raw line 0,
        // so a leading comment or blank line failed the whole load.
        let text = "# exported by tool\n\nf1,f2,label\n0.5,1.0,1\n-0.5,2.0,0\n";
        let d = load_logistic(text, true).unwrap();
        assert_eq!(d.n(), 2);
        assert_eq!(d.t, vec![1.0, -1.0]);
        // a second non-numeric line is NOT a header — it is an error
        let bad = "# c\nf1,f2,label\noops,1.0,1\n";
        assert!(load_logistic(bad, true).is_err());
        // comment-interleaved data still loads without a header
        let plain = "# c\n1.0,2.0,1\n# mid\n3.0,4.0,0\n";
        assert_eq!(load_logistic(plain, false).unwrap().n(), 2);
    }

    #[test]
    fn softmax_infers_k() {
        let text = "1,0,2\n0,1,0\n1,1,1\n";
        let d = load_softmax(text, false).unwrap();
        assert_eq!(d.k, 3);
        assert_eq!(d.labels, vec![2, 0, 1]);
        assert_eq!(d.d(), 2);
    }

    #[test]
    fn regression_basic() {
        let d = load_regression("1.0,2.0,3.5\n2.0,1.0,-0.5\n", true).unwrap();
        assert_eq!(d.y, vec![3.5, -0.5]);
        assert_eq!(d.d(), 3);
    }

    #[test]
    fn rejects_ragged_and_bad_labels() {
        assert!(load_regression("1,2\n1,2,3\n", false).is_err());
        assert!(load_logistic("1,2,5\n", false).is_err());
        assert!(load_softmax("1,2,-1\n", false).is_err());
        assert!(load_regression("", false).is_err());
    }
}
