//! # firefly — Firefly Monte Carlo
//!
//! A production-grade reproduction of *“Firefly Monte Carlo: Exact MCMC with
//! Subsets of Data”* (Maclaurin & Adams, 2015) as a three-layer Rust + JAX +
//! Pallas system: the MCMC coordinator, data structures, samplers and
//! diagnostics live in Rust; the likelihood/bound hot spot is a Pallas
//! kernel inside a JAX graph, AOT-lowered to HLO and executed through
//! PJRT (`runtime::XlaBackend`, behind the `xla` feature) with pure-Rust
//! fallbacks: the serial reference `runtime::CpuBackend` and the sharded
//! data-parallel `runtime::ParBackend` (bit-identical outputs, identical
//! query counts). Python never runs on the sampling path. R replica chains
//! run concurrently through `engine::multi_chain`, which reports split-R̂
//! and pooled ESS across replicas (`--chains`/`--threads` on the CLI).
//!
//! ## Quick start
//!
//! ```no_run
//! use firefly::configx::{Algorithm, ExperimentConfig, Task};
//! use firefly::engine::run_experiment;
//!
//! let cfg = ExperimentConfig {
//!     task: Task::LogisticMnist,
//!     algorithm: Algorithm::MapTunedFlyMc,
//!     iters: 2000,
//!     burnin: 500,
//!     ..Default::default()
//! };
//! let result = run_experiment(&cfg).unwrap();
//! let row = result.table_row();
//! println!("lik queries/iter: {:.0}", row.avg_lik_queries_per_iter);
//! ```
//!
//! See `examples/` for the three paper experiments and DESIGN.md for the
//! architecture and experiment index.

pub mod bench_harness;
pub mod cli;
pub mod configx;
pub mod data;
pub mod diagnostics;
pub mod engine;
pub mod flymc;
pub mod linalg;
pub mod map_estimate;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod samplers;
pub mod testing;
pub mod util;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::configx::{Algorithm, Backend, ExperimentConfig, Task};
    pub use crate::engine::{
        run_experiment, run_multi_chain, ExperimentResult, MultiChainSummary, TableRow,
    };
    pub use crate::flymc::{BrightSet, FullPosterior, PseudoPosterior};
    pub use crate::models::{
        IsoGaussian, Laplace, LogisticJJ, ModelBound, Prior, RobustT, SoftmaxBohning,
    };
    pub use crate::samplers::{Mala, RandomWalkMh, Sampler, SliceSampler, Target};
    pub use crate::util::Rng;
}
