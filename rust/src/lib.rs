//! # firefly — Firefly Monte Carlo
//!
//! A production-grade reproduction of *“Firefly Monte Carlo: Exact MCMC with
//! Subsets of Data”* (Maclaurin & Adams, 2015) as a three-layer Rust + JAX +
//! Pallas system: the MCMC coordinator, data structures, samplers and
//! diagnostics live in Rust; the likelihood/bound hot spot is a Pallas
//! kernel inside a JAX graph, AOT-lowered to HLO and executed through
//! PJRT ([`runtime::XlaBackend`], behind the `xla` feature) with pure-Rust
//! fallbacks: the serial reference [`runtime::CpuBackend`] and the sharded
//! data-parallel [`runtime::ParBackend`] (bit-identical outputs, identical
//! query counts). Python never runs on the sampling path. R replica chains
//! run concurrently through [`engine::multi_chain`], which reports split-R̂
//! and pooled ESS across replicas (`--chains`/`--threads` on the CLI).
//!
//! Steady-state FlyMC iterations — every paper sampler (random-walk MH,
//! MALA, slice) on every model (logistic, softmax, robust) — perform
//! **zero heap allocations** on the CPU backends: samplers, posterior and
//! backends own reusable buffers reserved up front, and the model
//! evaluation contract threads a caller-owned scratch arena
//! ([`models::EvalScratch`]) through every batch call (DESIGN.md §Perf;
//! enforced by counting-allocator tests and tracked by
//! `benches/hotpath.rs`). Evaluation itself is batched: models gather
//! `W = 8`-lane structure-of-arrays feature tiles and run the
//! [`kernels`] batch kernels — a scalar reference path and an
//! autovectorized fast path with **identical bits** (DESIGN.md §Kernels),
//! selected process-wide via [`kernels::set_kernel_path`].
//!
//! Datasets feed the models through the unified
//! [`data::store::DataStore`] layer: resident (`DenseStore`,
//! bit-identical to in-RAM behaviour) or out-of-core over the versioned
//! `.fbin` format (`BlockStore` + [`data::fbin`]) with preallocated
//! block-cached reads, so datasets larger than RAM sample through the
//! same engine — byte-identical chains, still allocation-free (DESIGN.md
//! §Storage; CLI `convert` / `--data`).
//!
//! Chains are **resumable**: the runtime ([`engine::ChainState`]) is
//! driven in segments, publishing each iteration to a pluggable observer
//! pipeline ([`engine::observer`]) — in-memory recording, O(dim)
//! streaming statistics ([`diagnostics::streaming`]: Welford moments,
//! batch-means ESS, split-R̂ inputs, so ten-million-iteration chains need
//! no trace), and a `.fckpt` checkpoint writer ([`engine::checkpoint`]).
//! A chain killed at any iteration and resumed from its last checkpoint
//! finishes with byte-identical traces, diagnostics, and query counters
//! to the never-interrupted run (DESIGN.md §Checkpointing; CLI
//! `--checkpoint-every` / `--checkpoint-dir` / `resume`).
//!
//! Chains also scale **out**: [`runtime::DistBackend`] implements the same
//! [`runtime::BatchEval`] contract over multi-process shard workers
//! ([`net`], pure-`std` TCP; `firefly worker` + `convert shard` on the
//! CLI, or in-process with `--backend dist --workers K`). Per-datum
//! results scatter back into request order and gradient rows re-fold
//! through the canonical kernel tree on the coordinator, so θ-traces,
//! acceptances, z-flips and query counters are **byte-identical to the
//! serial backend at any worker count** — including across worker crashes,
//! thanks to bounded retry/reconnect against stateless re-handshaking
//! workers (DESIGN.md §Distribution).
//!
//! Beyond the exact samplers, the crate ships the *approximate* tall-data
//! competitors the paper's exactness claim is measured against —
//! [`samplers::Sgld`] and [`samplers::AusterityMh`], driven through the
//! [`samplers::SubsampleTarget`] minibatch contract with per-minibatch
//! likelihood-query metering — plus a seeded statistical validation
//! harness ([`testing::posterior_check`]) and a head-to-head bench
//! (`benches/head2head.rs`) reporting ESS/sec, queries/iteration, and
//! posterior-moment bias per algorithm (DESIGN.md §Baselines; CLI
//! `--algo`).
//!
//! ## Quick start
//!
//! A complete (tiny) experiment runs in milliseconds:
//!
//! ```
//! use firefly::configx::{Algorithm, ExperimentConfig, Task};
//! use firefly::engine::run_experiment;
//!
//! let cfg = ExperimentConfig {
//!     task: Task::Toy,             // 2-d synthetic logistic task
//!     algorithm: Algorithm::UntunedFlyMc,
//!     n_data: Some(60),
//!     iters: 30,
//!     burnin: 10,
//!     record_every: 0,
//!     ..Default::default()
//! };
//! let result = run_experiment(&cfg).unwrap();
//! let row = result.table_row();
//! // FlyMC queries the bright subset (plus the z-sweep), never a fixed N
//! // per evaluation — the per-iteration cost is data-dependent but finite.
//! assert!(row.avg_lik_queries_per_iter.is_finite());
//! assert!(row.avg_bright.is_finite());
//! ```
//!
//! See `examples/` for the three paper experiments at real scale and
//! DESIGN.md for the architecture and experiment index.

#![warn(missing_docs)]

pub mod bench_harness;
pub mod cli;
pub mod configx;
pub mod data;
pub mod diagnostics;
pub mod engine;
pub mod flymc;
pub mod kernels;
pub mod linalg;
pub mod map_estimate;
pub mod metrics;
pub mod models;
pub mod net;
pub mod runtime;
pub mod samplers;
pub mod testing;
pub mod util;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::configx::{Algorithm, Backend, ExperimentConfig, Task};
    pub use crate::engine::{
        run_experiment, run_multi_chain, ExperimentResult, MultiChainSummary, TableRow,
    };
    pub use crate::flymc::{BrightSet, FullPosterior, PseudoPosterior};
    pub use crate::models::{
        EvalScratch, IsoGaussian, Laplace, LogisticJJ, ModelBound, Prior, RobustT,
        SoftmaxBohning,
    };
    pub use crate::samplers::{
        AusterityMh, Mala, RandomWalkMh, Sampler, Sgld, SliceSampler, SubsampleTarget, Target,
    };
    pub use crate::util::Rng;
}
