//! Shard worker: serves one contiguous slice of the dataset over TCP.
//!
//! A worker owns rows `start..end` of the global dataset and answers the
//! coordinator's eval requests through the same SoA kernel path the
//! in-process backends use, so every per-datum `f64` it returns is
//! bit-identical to what [`crate::runtime::CpuBackend`] would have
//! computed for the same global indices (DESIGN.md §Distribution).
//!
//! Workers are deliberately **stateless across connections**: each
//! connection must open with a [`Request::Hello`] carrying the full
//! [`ModelSpec`] (including the current bound anchor), and the worker
//! reconciles its cached model against it — building it on first contact,
//! re-anchoring when the anchor moved while it was away. A worker that
//! crashed and restarted therefore re-serves correctly from nothing but
//! its shard file plus the next handshake; the coordinator's bounded
//! retry/reconnect loop (`runtime::dist_backend`) relies on exactly this.
//!
//! The serve loop is sequential: one coordinator connection at a time,
//! requests answered in arrival order. That is not a scalability
//! compromise — the coordinator pipelines across *workers*, and each
//! worker's work per request is the batched kernel evaluation itself.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::frame::{read_frame, write_frame};
use super::protocol::{
    decode_request, err_response, ok_response, HelloAck, ModelSpec, Request, OP_EVAL_BOTH,
    OP_EVAL_LIK, OP_EVAL_LIK_GRAD_ROWS, OP_EVAL_PSEUDO_GRAD_ROWS,
};
use crate::data::AnyData;
use crate::models::{EvalScratch, LogisticJJ, ModelBound, ModelKind, RobustT, SoftmaxBohning};

/// Deterministic fault injection for the integration tests: the worker
/// closes the live connection after serving this many requests on it, then
/// keeps accepting. The coordinator sees a dead peer mid-chain and must
/// reconnect + re-handshake + resend — the full failure path — without any
/// wall-clock races in the test.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// drop the connection after this many served requests (0 = never)
    pub drop_conn_after: u64,
}

/// Bitwise slice equality — anchors are compared by bits, not by `==`,
/// so `-0.0` vs `0.0` (which tune to different per-datum anchor bits in
/// the softmax ψ formulas) forces a re-anchor instead of a silent skip.
fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One worker's mutable serving state: the shard placement, the cached
/// model (lazily built from shard data on first Hello in process mode, or
/// handed in pre-sliced for in-process workers), and reusable buffers.
pub struct WorkerState {
    start: usize,
    end: usize,
    n_global: usize,
    /// shard dataset, consumed by the first Hello (process-worker mode)
    data: Option<AnyData>,
    model: Option<Arc<dyn ModelBound>>,
    scratch: Option<EvalScratch>,
    ll: Vec<f64>,
    lb: Vec<f64>,
    rows: Vec<f64>,
}

impl WorkerState {
    /// State for an in-process worker already holding its slice of the
    /// coordinator's model (`ModelBound::shard_model`).
    pub fn in_process(model: Arc<dyn ModelBound>, start: usize, end: usize, n_global: usize) -> Self {
        let scratch = model.new_scratch();
        WorkerState {
            start,
            end,
            n_global,
            data: None,
            model: Some(model),
            scratch: Some(scratch),
            ll: Vec::new(),
            lb: Vec::new(),
            rows: Vec::new(),
        }
    }

    /// State for a standalone `firefly worker` process that loaded its
    /// shard rows from disk and builds the model on first Hello.
    pub fn from_data(data: AnyData, start: usize, end: usize, n_global: usize) -> Self {
        assert_eq!(data.n(), end - start, "shard dataset does not match its manifest range");
        WorkerState {
            start,
            end,
            n_global,
            data: Some(data),
            model: None,
            scratch: None,
            ll: Vec::new(),
            lb: Vec::new(),
            rows: Vec::new(),
        }
    }

    fn n_local(&self) -> usize {
        self.end - self.start
    }

    /// Reconcile the cached model with a Hello's spec: build it if absent,
    /// validate the static shape, and re-anchor if the anchor moved.
    fn hello(&mut self, spec: &ModelSpec) -> Result<HelloAck, String> {
        if spec.n != self.n_global {
            return Err(format!(
                "spec says N = {}, this worker was started for N = {}",
                spec.n, self.n_global
            ));
        }
        if self.model.is_none() {
            let data = self.data.take().ok_or("worker has neither a model nor shard data")?;
            let model = build_shard_model(spec, data)?;
            self.scratch = Some(model.new_scratch());
            self.model = Some(model);
        }
        let model = self.model.as_ref().unwrap();
        if model.kind() != spec.kind {
            return Err(format!(
                "spec wants a {} model, worker holds {}",
                spec.kind.as_str(),
                model.kind().as_str()
            ));
        }
        let want_dim = spec.d * spec.k;
        if model.dim() != want_dim || model.n_classes() != spec.k {
            return Err(format!(
                "spec shape (d={}, k={}) does not match worker model (dim={}, k={})",
                spec.d,
                spec.k,
                model.dim(),
                model.n_classes()
            ));
        }
        self.reanchor(spec.anchor.as_deref())?;
        let model = self.model.as_ref().unwrap();
        Ok(HelloAck { start: self.start, end: self.end, n: self.n_global, dim: model.dim() })
    }

    /// Move the bound anchor to `anchor` (bit-compared; a no-op when it
    /// already matches). Per-datum anchor tuning over only this shard's
    /// rows reproduces the coordinator's full-model tuning bits exactly.
    fn reanchor(&mut self, anchor: Option<&[f64]>) -> Result<(), String> {
        let model = self.model.as_ref().ok_or("handshake required before set-anchor")?;
        match (anchor, model.anchor_theta()) {
            (None, None) => Ok(()),
            (Some(a), Some(b)) if bits_eq(a, b) => Ok(()),
            (Some(a), _) => {
                if a.len() != model.dim() {
                    return Err(format!(
                        "anchor has {} components, model dim is {}",
                        a.len(),
                        model.dim()
                    ));
                }
                let m = model
                    .clone_reanchored(a)
                    .ok_or("model family does not support re-anchoring")?;
                self.scratch = Some(m.new_scratch());
                self.model = Some(m);
                Ok(())
            }
            (None, Some(_)) => Err("cannot clear a tuned anchor".to_string()),
        }
    }

    /// Serve one eval op over shard-local `idx`, returning the encoded
    /// ok-response payload.
    fn eval(&mut self, req_id: u64, op: u8, theta: &[f64], idx: &[u32]) -> Result<Vec<u8>, String> {
        let model = self.model.clone().ok_or("handshake required before eval")?;
        if theta.len() != model.dim() {
            return Err(format!("theta has {} components, model dim is {}", theta.len(), model.dim()));
        }
        let n_local = self.n_local();
        if let Some(&bad) = idx.iter().find(|&&i| i as usize >= n_local) {
            return Err(format!("shard-local index {bad} out of range (shard holds {n_local} rows)"));
        }
        let scratch = self.scratch.as_mut().ok_or("worker scratch missing")?;
        let dim = model.dim();
        self.ll.clear();
        self.ll.resize(idx.len(), 0.0);
        let mut w = ok_response(req_id);
        match op {
            OP_EVAL_LIK => {
                model.log_lik_batch(theta, idx, &mut self.ll, scratch);
                w.f64_slice(&self.ll);
            }
            OP_EVAL_BOTH => {
                self.lb.clear();
                self.lb.resize(idx.len(), 0.0);
                model.log_both_batch(theta, idx, &mut self.ll, &mut self.lb, scratch);
                w.f64_slice(&self.ll);
                w.f64_slice(&self.lb);
            }
            OP_EVAL_LIK_GRAD_ROWS => {
                self.rows.clear();
                self.rows.resize(idx.len() * dim, 0.0);
                model.log_lik_grad_rows_batch(theta, idx, &mut self.ll, &mut self.rows, scratch);
                w.f64_slice(&self.ll);
                w.f64_slice(&self.rows);
            }
            OP_EVAL_PSEUDO_GRAD_ROWS => {
                self.lb.clear();
                self.lb.resize(idx.len(), 0.0);
                self.rows.clear();
                self.rows.resize(idx.len() * dim, 0.0);
                model.pseudo_grad_rows_batch(
                    theta,
                    idx,
                    &mut self.ll,
                    &mut self.lb,
                    &mut self.rows,
                    scratch,
                );
                w.f64_slice(&self.ll);
                w.f64_slice(&self.lb);
                w.f64_slice(&self.rows);
            }
            _ => return Err(format!("op {op} is not an eval op")),
        }
        // drain the row-cache tallies so they do not grow without bound;
        // worker-side cache stats are topology-dependent and deliberately
        // not wired back (same exclusion as the ParBackend shards)
        let _ = scratch.take_cache_stats();
        Ok(w.into_bytes())
    }

    /// Dispatch one decoded request to the matching handler.
    fn handle(&mut self, req_id: u64, req: &Request, hello_done: bool) -> Result<Vec<u8>, String> {
        if !hello_done && !matches!(req, Request::Hello(_) | Request::Ping | Request::Shutdown) {
            return Err("handshake required: first request on a connection must be Hello".into());
        }
        match req {
            Request::Hello(spec) => {
                let ack = self.hello(spec)?;
                let mut w = ok_response(req_id);
                ack.encode(&mut w);
                Ok(w.into_bytes())
            }
            Request::SetAnchor(a) => {
                self.reanchor(Some(a))?;
                Ok(ok_response(req_id).into_bytes())
            }
            Request::EvalLik { theta, idx } => self.eval(req_id, OP_EVAL_LIK, theta, idx),
            Request::EvalBoth { theta, idx } => self.eval(req_id, OP_EVAL_BOTH, theta, idx),
            Request::EvalLikGradRows { theta, idx } => {
                self.eval(req_id, OP_EVAL_LIK_GRAD_ROWS, theta, idx)
            }
            Request::EvalPseudoGradRows { theta, idx } => {
                self.eval(req_id, OP_EVAL_PSEUDO_GRAD_ROWS, theta, idx)
            }
            Request::Ping | Request::Shutdown => Ok(ok_response(req_id).into_bytes()),
        }
    }
}

/// Build a worker's model over its shard dataset from a Hello spec —
/// the standalone-process path. The constructors' untuned per-datum
/// anchors are data-local constants and `tune_anchors_map` is a per-datum
/// formula, so this matches `ModelBound::shard_model` on the
/// coordinator's full model bit-for-bit.
pub fn build_shard_model(spec: &ModelSpec, data: AnyData) -> Result<Arc<dyn ModelBound>, String> {
    match (spec.kind, data) {
        (ModelKind::Logistic, AnyData::Logistic(d)) => {
            let mut m = LogisticJJ::new(Arc::new(d), spec.xi_const);
            if let Some(a) = &spec.anchor {
                m.tune_anchors_map(a);
            }
            Ok(Arc::new(m))
        }
        (ModelKind::Softmax, AnyData::Softmax(d)) => {
            if d.k != spec.k {
                return Err(format!(
                    "shard file declares K = {}, spec says K = {} — re-shard with a forced \
                     class count",
                    d.k, spec.k
                ));
            }
            let mut m = SoftmaxBohning::new(Arc::new(d));
            if let Some(a) = &spec.anchor {
                m.tune_anchors_map(a);
            }
            Ok(Arc::new(m))
        }
        (ModelKind::Robust, AnyData::Regression(d)) => {
            let mut m = RobustT::new(Arc::new(d), spec.nu, spec.sigma);
            if let Some(a) = &spec.anchor {
                m.tune_anchors_map(a);
            }
            Ok(Arc::new(m))
        }
        (kind, data) => Err(format!(
            "spec wants a {} model but the shard file holds {} data",
            kind.as_str(),
            data.kind_name()
        )),
    }
}

/// Serve one accepted connection until the peer goes away, the fault plan
/// drops it, or a Shutdown arrives. Returns `Ok(true)` on Shutdown.
fn serve_conn(
    state: &mut WorkerState,
    stream: &mut TcpStream,
    fault: Option<FaultPlan>,
) -> io::Result<bool> {
    let mut buf = Vec::new();
    let mut served = 0u64;
    let mut hello_done = false;
    loop {
        if read_frame(stream, &mut buf).is_err() {
            // EOF, reset, or a corrupt frame: this connection is done; the
            // coordinator reconnects and re-handshakes if it still cares
            return Ok(false);
        }
        let resp = match decode_request(&buf) {
            Ok((req_id, req)) => {
                let shutdown = matches!(req, Request::Shutdown);
                let resp = match state.handle(req_id, &req, hello_done) {
                    Ok(bytes) => {
                        if matches!(req, Request::Hello(_)) {
                            hello_done = true;
                        }
                        bytes
                    }
                    Err(msg) => err_response(req_id, &msg),
                };
                if shutdown {
                    let _ = write_frame(stream, &resp);
                    return Ok(true);
                }
                resp
            }
            // undecodable request: req_id unknown, answer with id 0 so the
            // coordinator's id check rejects it loudly, then drop the link
            Err(msg) => {
                let _ = write_frame(stream, &err_response(0, &msg));
                return Ok(false);
            }
        };
        write_frame(stream, &resp)?;
        served += 1;
        if let Some(f) = fault {
            if f.drop_conn_after != 0 && served >= f.drop_conn_after {
                return Ok(false);
            }
        }
    }
}

/// Shared shutdown control for a serve loop: a stop flag plus a handle to
/// the connection currently being served, so a stop request can sever a
/// live (possibly idle-blocked) connection instead of waiting for the
/// coordinator to go away on its own.
#[derive(Default)]
pub struct ServeControl {
    stop: AtomicBool,
    live: std::sync::Mutex<Option<TcpStream>>,
}

impl ServeControl {
    /// Fresh control block (not yet stopped, no live connection).
    pub fn new() -> Self {
        Self::default()
    }

    /// Whether a stop has been requested.
    pub fn stopped(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// Request a stop: set the flag, sever the live connection (unblocking
    /// a read), and poke the listener at `addr` to unblock its accept.
    pub fn stop_and_wake(&self, addr: SocketAddr) {
        self.stop.store(true, Ordering::SeqCst);
        if let Ok(mut live) = self.live.lock() {
            if let Some(s) = live.take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
        let _ = TcpStream::connect(addr);
    }

    fn set_live(&self, stream: &TcpStream) {
        if let Ok(mut live) = self.live.lock() {
            *live = stream.try_clone().ok();
        }
    }

    fn clear_live(&self) {
        if let Ok(mut live) = self.live.lock() {
            *live = None;
        }
    }
}

/// Blocking accept-and-serve loop. Exits when `ctl` is stopped (see
/// [`ServeControl::stop_and_wake`]) or a Shutdown request is served.
pub fn serve(
    listener: &TcpListener,
    mut state: WorkerState,
    ctl: &ServeControl,
    fault: Option<FaultPlan>,
) -> io::Result<()> {
    for conn in listener.incoming() {
        if ctl.stopped() {
            break;
        }
        let mut stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_nodelay(true);
        ctl.set_live(&stream);
        let shutdown = serve_conn(&mut state, &mut stream, fault);
        ctl.clear_live();
        if shutdown? || ctl.stopped() {
            break;
        }
    }
    Ok(())
}

/// A spawned worker thread plus the shard placement it serves. Dropping
/// the handle stops the thread (idempotent).
pub struct WorkerHandle {
    /// the address the worker accepts coordinator connections on
    pub addr: SocketAddr,
    /// first global index owned (inclusive)
    pub start: usize,
    /// one past the last global index owned (exclusive)
    pub end: usize,
    ctl: Arc<ServeControl>,
    join: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Stop the worker thread and wait for it to exit — even mid-request
    /// or with an idle coordinator connection open (the live connection is
    /// severed). Safe to call twice.
    pub fn stop(&mut self) {
        self.ctl.stop_and_wake(self.addr);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind `listen`, retrying briefly: a worker restarted on the port it just
/// vacated can race the kernel's release of the old listening socket.
fn bind_with_retry(listen: &str) -> io::Result<TcpListener> {
    let mut last = None;
    for _ in 0..8 {
        match TcpListener::bind(listen) {
            Ok(l) => return Ok(l),
            Err(e) if e.kind() == io::ErrorKind::AddrInUse => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(250));
            }
            Err(e) => return Err(e),
        }
    }
    Err(last.unwrap_or_else(|| io::Error::new(io::ErrorKind::AddrInUse, listen.to_string())))
}

/// Spawn one worker thread serving `state` on `listen` (use port 0 for an
/// ephemeral port; the bound address is on the returned handle).
pub fn spawn_worker(
    state: WorkerState,
    listen: &str,
    fault: Option<FaultPlan>,
) -> io::Result<WorkerHandle> {
    let listener = bind_with_retry(listen)?;
    let addr = listener.local_addr()?;
    let (start, end) = (state.start, state.end);
    let ctl = Arc::new(ServeControl::new());
    let ctl2 = Arc::clone(&ctl);
    let join = std::thread::Builder::new()
        .name(format!("ffly-worker-{start}-{end}"))
        .spawn(move || {
            let _ = serve(&listener, state, &ctl2, fault);
        })?;
    Ok(WorkerHandle { addr, start, end, ctl, join: Some(join) })
}

/// Spawn `workers` in-process shard workers over `model` on localhost
/// ephemeral ports, slicing the model with [`ModelBound::shard_model`]
/// (exact: per-datum anchor state is sliced, not retuned).
pub fn spawn_local_workers(
    model: &Arc<dyn ModelBound>,
    workers: usize,
) -> Result<Vec<WorkerHandle>, String> {
    assert!(workers > 0, "need at least one worker");
    let n = model.n();
    let mut handles = Vec::with_capacity(workers);
    for (start, end) in super::shard_ranges(n, workers) {
        let shard = model
            .shard_model(start, end)
            .ok_or_else(|| format!("{} models do not support sharding", model.kind().as_str()))?;
        let state = WorkerState::in_process(shard, start, end, n);
        handles.push(
            spawn_worker(state, "127.0.0.1:0", None).map_err(|e| format!("spawn worker: {e}"))?,
        );
    }
    Ok(handles)
}
