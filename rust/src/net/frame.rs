//! Length-prefixed, checksummed wire frames.
//!
//! Every message between the coordinator and a shard worker travels as one
//! frame: a `u32` little-endian payload length, the payload bytes, and a
//! trailing `u64` little-endian FNV-1a checksum of the payload (the same
//! [`crate::util::codec::fnv1a`] the checkpoint layer uses). The checksum
//! turns a corrupted or desynchronized stream into a clean
//! [`std::io::ErrorKind::InvalidData`] error instead of a silently-wrong
//! likelihood — the distributed backend treats it like any other transport
//! failure and retries on a fresh connection (DESIGN.md §Distribution).
//!
//! Framing is transport-agnostic (`Read`/`Write`), so the protocol tests
//! exercise it over in-memory buffers and the runtime over `TcpStream`s.

use std::io::{self, Read, Write};

use crate::util::codec::fnv1a;

/// Fixed per-frame overhead: 4-byte length prefix + 8-byte checksum.
pub const FRAME_OVERHEAD: usize = 12;

/// Hard cap on a single frame's payload (1 GiB). A length prefix beyond
/// this is treated as stream corruption, not an allocation request.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Write one frame; returns the total bytes put on the wire
/// (`payload.len() + FRAME_OVERHEAD`). Flushes so a pipelined request is
/// visible to the worker before the coordinator blocks on the response.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<usize> {
    let len = u32::try_from(payload.len())
        .ok()
        .filter(|&l| (l as usize) <= MAX_FRAME_LEN)
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("frame payload of {} bytes exceeds MAX_FRAME_LEN", payload.len()),
            )
        })?;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.write_all(&fnv1a(payload).to_le_bytes())?;
    w.flush()?;
    Ok(payload.len() + FRAME_OVERHEAD)
}

/// Read one frame into `buf` (cleared and resized to the payload length);
/// returns the total bytes taken off the wire. A checksum mismatch or an
/// oversized length prefix surfaces as [`io::ErrorKind::InvalidData`]; a
/// peer that closed mid-frame surfaces as the underlying
/// [`io::ErrorKind::UnexpectedEof`].
pub fn read_frame(r: &mut impl Read, buf: &mut Vec<u8>) -> io::Result<usize> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes)?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME_LEN {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame length prefix {len} exceeds MAX_FRAME_LEN — stream corrupt"),
        ));
    }
    buf.clear();
    buf.resize(len, 0);
    r.read_exact(buf)?;
    let mut sum_bytes = [0u8; 8];
    r.read_exact(&mut sum_bytes)?;
    let expect = u64::from_le_bytes(sum_bytes);
    let got = fnv1a(buf);
    if got != expect {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("frame checksum mismatch: payload hashes to {got:#018x}, trailer says {expect:#018x}"),
        ));
    }
    Ok(len + FRAME_OVERHEAD)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_and_counts_bytes() {
        let payload = b"firefly dist frame".to_vec();
        let mut wire = Vec::new();
        let sent = write_frame(&mut wire, &payload).unwrap();
        assert_eq!(sent, payload.len() + FRAME_OVERHEAD);
        assert_eq!(wire.len(), sent);
        let mut buf = vec![0xAA; 3]; // stale contents must be discarded
        let got = read_frame(&mut wire.as_slice(), &mut buf).unwrap();
        assert_eq!(got, sent);
        assert_eq!(buf, payload);
    }

    #[test]
    fn empty_payload_roundtrips() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[]).unwrap();
        let mut buf = Vec::new();
        read_frame(&mut wire.as_slice(), &mut buf).unwrap();
        assert!(buf.is_empty());
    }

    #[test]
    fn corrupted_payload_is_invalid_data() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"payload under test").unwrap();
        wire[7] ^= 0x40; // flip one payload bit
        let mut buf = Vec::new();
        let err = read_frame(&mut wire.as_slice(), &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn corrupted_trailer_is_invalid_data() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"payload under test").unwrap();
        let at = wire.len() - 1;
        wire[at] ^= 0x01;
        let mut buf = Vec::new();
        let err = read_frame(&mut wire.as_slice(), &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut buf = Vec::new();
        let err = read_frame(&mut wire.as_slice(), &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("length prefix"), "{err}");
    }

    #[test]
    fn truncated_stream_is_unexpected_eof() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"cut short").unwrap();
        wire.truncate(wire.len() - 3);
        let mut buf = Vec::new();
        let err = read_frame(&mut wire.as_slice(), &mut buf).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }
}
