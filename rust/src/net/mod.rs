//! Multi-process shard transport for the distributed backend.
//!
//! Pure-`std` TCP plumbing under `runtime::dist_backend`
//! (DESIGN.md §Distribution): [`frame`] puts length-prefixed,
//! FNV-1a-checksummed frames on a stream, [`protocol`] encodes the
//! request/response payloads with the checkpoint codec (so every `f64`
//! crosses the wire bit-exactly), and [`worker`] is the shard-serving side
//! — the accept loop behind both the `firefly worker` CLI mode and the
//! in-process `--workers K` spawner.
//!
//! Determinism contract: nothing in this module may influence *what* is
//! computed, only *where*. Shards are contiguous index ranges
//! ([`shard_ranges`]), per-datum results are scattered back into request
//! order, and gradient rows are re-folded through the canonical kernel
//! tree on the coordinator — so a chain's θ-trace, acceptances, z-flips
//! and query counters are byte-identical to the serial backend at any
//! worker count. Timeouts and retries come from `[dist]` config values,
//! never from ambient clocks read on a decision path.

pub mod frame;
pub mod protocol;
pub mod worker;

pub use frame::{read_frame, write_frame, FRAME_OVERHEAD, MAX_FRAME_LEN};
pub use protocol::{HelloAck, ModelSpec, Request};
pub use worker::{
    build_shard_model, serve, spawn_local_workers, spawn_worker, FaultPlan, ServeControl,
    WorkerHandle, WorkerState,
};

/// Balanced contiguous shard ranges: `n` rows over `k` shards, the first
/// `n % k` shards one row longer. This single function is the index-space
/// authority for the `convert shard` splitter, the in-process worker
/// spawner, and the coordinator's coverage validation — they must never
/// disagree on who owns a row.
pub fn shard_ranges(n: usize, k: usize) -> Vec<(usize, usize)> {
    assert!(k > 0, "need at least one shard");
    let base = n / k;
    let extra = n % k;
    let mut out = Vec::with_capacity(k);
    let mut at = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        out.push((at, at + len));
        at += len;
    }
    debug_assert_eq!(at, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_exactly_once() {
        for n in [0usize, 1, 2, 7, 8, 100, 101, 1000] {
            for k in [1usize, 2, 3, 4, 7, 16] {
                let r = shard_ranges(n, k);
                assert_eq!(r.len(), k);
                assert_eq!(r[0].0, 0);
                assert_eq!(r[k - 1].1, n);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "gap/overlap at {w:?}");
                }
                let max = r.iter().map(|(s, e)| e - s).max().unwrap();
                let min = r.iter().map(|(s, e)| e - s).min().unwrap();
                assert!(max - min <= 1, "unbalanced: {r:?}");
            }
        }
    }

    #[test]
    fn shard_ranges_match_front_loaded_remainder() {
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 6), (6, 8), (8, 10)]);
        assert_eq!(shard_ranges(3, 4), vec![(0, 1), (1, 2), (2, 3), (3, 3)]);
    }
}
